"""Units for the launch tooling: HLO collective parser, input specs,
analytic roofline formulas, padding, registry applicability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, SHAPES, get_arch
from repro.launch import hlo_stats
from repro.launch.input_specs import (
    decode_input_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from repro.launch.roofline import analytic_hbm_bytes, analytic_model_flops
from repro.parallel.padding import padded_dims


class TestCollectiveParser:
    def test_parses_real_hlo(self):
        # build a tiny program with a real all-reduce on 1 device? use
        # synthetic HLO lines instead — the regex contract is the unit.
        hlo = """
  %ag = bf16[4,128]{1,0} all-gather(%p0), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(%x), to_apply=%add
  %rs = f32[64]{0} reduce-scatter(%y), dimensions={0}
  %cp = u8[32]{0} collective-permute(%z), source_target_pairs={{0,1}}
  %a2a = bf16[2,2]{1,0} all-to-all(%w), dimensions={0}
  %dead = f32[9999]{0} add(%a, %b)
"""
        st = hlo_stats.collective_bytes(hlo)
        assert st.per_op_bytes["all-gather"] == 4 * 128 * 2
        assert st.per_op_bytes["all-reduce"] == 256 * 4
        assert st.per_op_bytes["reduce-scatter"] == 64 * 4
        assert st.per_op_bytes["collective-permute"] == 32
        assert st.per_op_bytes["all-to-all"] == 8
        assert st.count["all-reduce"] == 1

    def test_async_pairs_counted_once(self):
        hlo = """
  %s = bf16[128]{0} all-gather-start(%p0)
  %d = bf16[128]{0} all-gather-done(%s)
"""
        st = hlo_stats.collective_bytes(hlo)
        assert st.count["all-gather"] == 1
        assert st.per_op_bytes["all-gather"] == 256

    def test_tuple_result(self):
        hlo = "  %t = (bf16[64]{0}, f32[32]{0}) all-reduce(%a, %b), to_apply=%add\n"
        st = hlo_stats.collective_bytes(hlo)
        assert st.per_op_bytes["all-reduce"] == 64 * 2 + 32 * 4

    def test_roofline_terms(self):
        t = hlo_stats.roofline_terms(197e12, 819e9, 50e9, 256)
        assert abs(t["t_compute"] - 1.0) < 1e-9
        assert abs(t["t_memory"] - 1.0) < 1e-9
        assert abs(t["t_collective"] - 1.0) < 1e-9


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ARCHS)
    def test_train_specs_cover_inputs(self, arch):
        cfg = get_arch(arch)
        b = train_batch_specs(cfg, SHAPES["train_4k"])
        assert "labels" in b
        key = "tokens" if cfg.input_mode == "tokens" else "embeds"
        assert key in b
        assert b[key].shape[0] == 256
        # no allocation happened
        assert all(isinstance(v, jax.ShapeDtypeStruct) for v in b.values())

    @pytest.mark.parametrize("arch", ["yi-34b", "mamba2-780m", "deepseek-v3-671b"])
    def test_decode_specs_shapes(self, arch):
        cfg = get_arch(arch)
        cache, bt, pos = decode_input_specs(cfg, SHAPES["decode_32k"], tp=16)
        leaves = jax.tree.leaves(cache)
        assert leaves, "cache must be non-empty"
        assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
        assert pos.shape == ()

    def test_prefill_has_no_labels(self):
        b = prefill_batch_specs(get_arch("yi-34b"), SHAPES["prefill_32k"])
        assert "labels" not in b


class TestAnalyticFormulas:
    def test_train_flops_scale_with_tokens(self):
        cfg = get_arch("starcoder2-3b")
        f1 = analytic_model_flops(cfg, SHAPES["train_4k"])
        # 6·N·D lower bound (attention adds on top)
        assert f1 >= 6 * cfg.param_count() * 256 * 4096 * 0.8
        assert f1 <= 6 * cfg.param_count() * 256 * 4096 * 3

    def test_moe_active_params_lt_total(self):
        cfg = get_arch("deepseek-v3-671b")
        from repro.launch.roofline import _active_params

        a = _active_params(cfg)
        assert a < 0.1 * cfg.param_count()  # 37B active of 671B
        assert a > 0.03 * cfg.param_count()

    def test_decode_hbm_floor_has_cache(self):
        cfg = get_arch("yi-34b")
        b = analytic_hbm_bytes(cfg, SHAPES["decode_32k"], 16, 256)
        # 1 TB cache over 256 chips ≈ 4 GB dominates
        assert b > 3e9

    @pytest.mark.parametrize("arch", ARCHS)
    def test_padding_dims_divisible(self, arch):
        cfg = get_arch(arch)
        pd = padded_dims(cfg, 16)
        if cfg.uses_attention:
            assert pd.n_heads % pd.n_kv_heads == 0
        assert pd.vocab_size % 16 == 0
        if cfg.is_moe:
            assert pd.n_experts % 16 == 0
