"""Hypothesis property tests for the core + kernels.

Collected into one module behind ``pytest.importorskip`` so the suite
collects (and the unit tests in the sibling modules run) even when
hypothesis is not installed — the seed image ships without it.
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import CostModel, Eq, Query, Range, SortedTable
from repro.core.ecdf import TableStats
from repro.core.tpch import generate_simulation
from repro.kernels import (
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_ref,
    table_execute_device_many,
    table_slab_locate_many,
)

from conftest import brute_force


@settings(max_examples=30, deadline=None)
@given(
    data=st.data(),
    n=st.integers(10, 300),
    dom=st.integers(2, 20),
)
def test_property_scan_count_matches_bruteforce(data, n, dom):
    """Property: for any dataset/layout/query, slab-scan == brute force."""
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    cols = ("x", "y")
    kc = {c: rng.integers(0, dom, n).astype(np.int64) for c in cols}
    vc = {"m": rng.uniform(0, 1, n)}
    layout = data.draw(st.permutations(cols))
    t = SortedTable.from_columns(kc, vc, tuple(layout))
    f = {}
    for c in cols:
        kind = data.draw(st.sampled_from(["eq", "range", "none"]))
        if kind == "eq":
            f[c] = Eq(data.draw(st.integers(0, dom - 1)))
        elif kind == "range":
            lo = data.draw(st.integers(0, dom - 1))
            hi = data.draw(st.integers(lo + 1, dom))
            f[c] = Range(lo, hi)
    q = Query(filters=f, agg="count")
    res = t.execute(q)
    assert res.value == brute_force(t, q).sum()
    assert res.rows_scanned >= res.rows_matched


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_min_cost_leq_every_replica(seed):
    """Eq (3): Cost_min(q) ≤ Cost(r, q) for every replica r."""
    rng = np.random.default_rng(seed)
    kc, vc, schema = generate_simulation(3000, 3, seed=seed % 17)
    stats = TableStats.from_columns(kc, schema)
    model = CostModel(stats=stats)
    layouts = [("k0", "k1", "k2"), ("k2", "k1", "k0")]
    q = Query(filters={"k0": Eq(int(rng.integers(0, 8))), "k2": Range(0, 5)})
    mc, _ = model.min_cost(layouts, q)
    assert all(mc <= model.query_cost(a, q) + 1e-12 for a in layouts)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 6),
    n=st.integers(1, 700),
)
def test_property_scan_agg_matches_ref(seed, k, n):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 20, (k, n)).astype(np.int32)
    vals = rng.uniform(-1, 1, n).astype(np.float32)
    lo = rng.integers(0, 10, k).astype(np.int32)
    hi = (lo + rng.integers(0, 12, k)).astype(np.int32)
    slab = np.sort(rng.integers(0, n + 1, 2)).astype(np.int32)
    got = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=128))
    want = np.asarray(
        scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                     jnp.asarray(hi), jnp.asarray(slab))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.kernel
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 5),
    q=st.integers(1, 9),
    n=st.integers(1, 600),
)
def test_property_scan_agg_batched_matches_ref(seed, k, q, n):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 20, (k, n)).astype(np.int32)
    vals = rng.uniform(-1, 1, n).astype(np.float32)
    lo = rng.integers(0, 10, (q, k)).astype(np.int32)
    hi = (lo + rng.integers(0, 12, (q, k))).astype(np.int32)
    slabs = np.sort(rng.integers(0, n + 1, (q, 2)), axis=1).astype(np.int32)
    got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=128))
    want = np.asarray(
        scan_agg_batched_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                             jnp.asarray(hi), jnp.asarray(slabs))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.kernel
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    q=st.integers(1, 24),
    n=st.integers(0, 500),
    n_vals=st.integers(1, 4),
    data=st.data(),
)
def test_property_rowstream_kernel_matches_ref(seed, q, n, n_vals, data):
    """Revisited-accumulator (row-streaming) kernel vs the jnp oracle:
    random schemas (narrow and two-lane wide columns), batch sizes,
    empty ranges/slabs, and mixed value-row selectors (mixed agg kinds),
    elementwise."""
    from repro.kernels.scan_agg import WIDE_LANE_BITS, scan_agg_batched_pallas

    rng = np.random.default_rng(seed)
    col_parts = tuple(data.draw(st.lists(st.sampled_from([1, 2]), min_size=1, max_size=4)))
    k_ex = sum(col_parts)
    keys_rows, lo_rows, hi_rows = [], [], []
    for parts in col_parts:
        bits = 8 if parts == 1 else WIDE_LANE_BITS + 8
        dom = 1 << bits
        col = rng.integers(0, dom, n).astype(np.int64)
        # bound draws include empty ranges (hi <= lo) and the full domain
        b_lo = rng.integers(0, dom, q)
        b_hi = np.where(rng.random(q) < 0.25, b_lo, rng.integers(0, dom + 1, q))
        if parts == 1:
            keys_rows.append(col.astype(np.int32))
            lo_rows.append(b_lo.astype(np.int32))
            hi_rows.append(b_hi.astype(np.int32))
        else:
            mask = (1 << WIDE_LANE_BITS) - 1
            keys_rows += [(col >> WIDE_LANE_BITS).astype(np.int32),
                          (col & mask).astype(np.int32)]
            lo_rows += [(b_lo >> WIDE_LANE_BITS).astype(np.int32),
                        (b_lo & mask).astype(np.int32)]
            hi_rows += [(b_hi >> WIDE_LANE_BITS).astype(np.int32),
                        (b_hi & mask).astype(np.int32)]
    keys = np.stack(keys_rows).reshape(k_ex, n)
    lo = np.stack(lo_rows, axis=1)
    hi = np.stack(hi_rows, axis=1)
    vals = rng.uniform(-1, 1, (n_vals, n)).astype(np.float32)
    sel = rng.integers(0, n_vals, q).astype(np.int32)
    slabs = np.sort(rng.integers(0, n + 1, (q, 2)), axis=1).astype(np.int32)
    slabs[rng.random(q) < 0.2, 1] = 0  # force some empty slabs

    got = np.asarray(
        scan_agg_batched_pallas(keys, vals, lo, hi, slabs, sel,
                                col_parts=col_parts, block_n=128)
    )
    want = np.asarray(
        scan_agg_batched_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                             jnp.asarray(hi), jnp.asarray(slabs), jnp.asarray(sel),
                             col_parts=col_parts)
    )
    assert got.shape == (q, 2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)


@pytest.mark.kernel
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 400))
def test_property_device_table_matches_numpy_engine(seed, n):
    """End-to-end property: a device-resident table answers mixed
    sum/count batches (including empty ranges) identically to the numpy
    engine — counts exact, sums to float32 tolerance."""
    rng = np.random.default_rng(seed)
    kc = {"x": rng.integers(0, 12, n), "y": rng.integers(0, 12, n)}
    vc = {"m": rng.uniform(0, 1, n), "w": rng.uniform(-3, 3, n)}
    dev = SortedTable.from_columns(kc, vc, ("x", "y")).place_on_device()
    host = SortedTable.from_columns(kc, vc, ("x", "y"))
    qs = []
    for _ in range(8):
        f = {}
        if rng.random() < 0.8:
            f["x"] = Eq(int(rng.integers(0, 12)))
        if rng.random() < 0.8:
            lo = int(rng.integers(0, 12))
            f["y"] = Range(lo, lo + int(rng.integers(0, 4)))  # may be empty
        agg = "count" if rng.random() < 0.5 else "sum"
        qs.append(Query(filters=f, agg=agg,
                        value_col=("m" if rng.random() < 0.5 else "w") if agg == "sum" else None))
    for q, rd in zip(qs, dev.execute_many(qs)):
        rh = host.execute(q)
        assert rd.rows_scanned == rh.rows_scanned
        assert rd.rows_matched == rh.rows_matched
        np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5, atol=1e-5)


def _random_queries(rng, schema, cols, k, *, aggs=("count",), value_col=None):
    qs = []
    for _ in range(k):
        f = {}
        for c in cols:
            u = rng.random()
            dom = schema.max_value(c) + 1
            if u < 0.3:
                continue
            if u < 0.6:
                f[c] = Eq(int(rng.integers(0, dom)))
            else:
                lo = int(rng.integers(0, dom))
                f[c] = Range(lo, min(dom, lo + int(rng.integers(0, dom // 2 + 2))))
        agg = aggs[int(rng.integers(0, len(aggs)))]
        qs.append(Query(filters=f, agg=agg,
                        value_col=value_col if agg == "sum" else None))
    return qs


@pytest.mark.kernel
@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 600),
    bits_a=st.sampled_from([3, 8, 31, 40, 60]),
    bits_b=st.integers(1, 3),
)
def test_property_slab_locate_matches_searchsorted(seed, n, bits_a, bits_b):
    """Property: the device binary-search kernel == the numpy
    searchsorted oracle, over random schemas (narrow and two-lane wide
    columns), empty ranges, and bounds at the table edges."""
    from repro.core import KeySchema
    from repro.core.table import slab_bounds_many

    rng = np.random.default_rng(seed)
    schema = KeySchema({"a": bits_a, "b": bits_b})
    kc = {
        c: rng.integers(0, schema.max_value(c) + 1, n).astype(np.int64)
        for c in ("a", "b")
    }
    vc = {"m": rng.uniform(0, 1, n)}
    t = SortedTable.from_columns(kc, vc, ("a", "b"), schema)
    qs = _random_queries(rng, schema, ("a", "b"), 10)
    # force edge-of-table and degenerate bounds into every run
    qs += [
        Query(filters={"a": Eq(0)}),
        Query(filters={"a": Eq(schema.max_value("a"))}),
        Query(filters={"b": Range(1, 1)}),
        Query(filters={}),
    ]
    bounds = slab_bounds_many(qs, t.layout, t.schema)
    lo = np.searchsorted(t.packed, bounds[:, 0], side="left")
    hi = np.searchsorted(t.packed, bounds[:, 1], side="right")
    want = np.stack([lo, hi], axis=1).astype(np.int64)
    got = table_slab_locate_many(t.place_on_device(), qs)
    np.testing.assert_array_equal(got, want)


@pytest.mark.kernel
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 500))
def test_property_select_compaction_matches_numpy_indices(seed, n):
    """Property: device "select" emits exactly the numpy engine's row
    indices (same values, same ascending order), mixed into sum/count
    batches, including after incremental device appends."""
    from repro.core import KeySchema

    rng = np.random.default_rng(seed)
    # explicit schema: the appended run may exceed the seed data's max
    schema = KeySchema({"x": 4, "y": 4})
    kc = {"x": rng.integers(0, 10, n), "y": rng.integers(0, 10, n)}
    vc = {"m": rng.uniform(0, 1, n)}
    dev = SortedTable.from_columns(kc, vc, ("x", "y"), schema).place_on_device()
    host = SortedTable.from_columns(kc, vc, ("x", "y"), schema)
    if rng.random() < 0.5:  # half the runs read after an appended write
        m = int(rng.integers(1, 50))
        kc2 = {"x": rng.integers(0, 10, m), "y": rng.integers(0, 10, m)}
        vc2 = {"m": rng.uniform(0, 1, m)}
        dev = dev.merge_insert(kc2, vc2)
        host = host.merge_insert(kc2, vc2)
        assert dev._device["n_runs"] == 2
    qs = _random_queries(
        rng, dev.schema, ("x", "y"), 8, aggs=("select", "sum", "count"),
        value_col="m",
    )
    for q, rd in zip(qs, table_execute_device_many(dev, qs)):
        rh = host.execute(q)
        assert rd.rows_scanned == rh.rows_scanned
        assert rd.rows_matched == rh.rows_matched
        np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5, atol=1e-5)
        if q.agg == "select":
            np.testing.assert_array_equal(rd.selected, rh.selected)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n_writes=st.integers(0, 6),
    byte_level=st.booleans(),
    data=st.data(),
)
def test_property_truncated_commitlog_replays_consistent_prefix(
    seed, n_writes, byte_level, data
):
    """Crash-recovery property: truncating the commit log at an
    arbitrary record (or an arbitrary BYTE of its serialized form) and
    replaying yields a prefix-consistent table — exactly the table built
    from the surviving whole records, identical across all heterogeneous
    layouts of the column family."""
    from repro.core import CommitLog, KeySchema

    rng = np.random.default_rng(seed)
    schema = KeySchema({"x": 5, "y": 5})
    layouts = (("x", "y"), ("y", "x"))
    log = CommitLog(key_names=("x", "y"), value_names=("m",))
    batches = []
    for _ in range(1 + n_writes):  # record 0 plays the CREATE-time base
        m = int(rng.integers(1, 60))
        kc = {"x": rng.integers(0, 32, m), "y": rng.integers(0, 32, m)}
        vc = {"m": rng.uniform(0, 1, m)}
        log.append(kc, vc)
        batches.append((kc, vc))

    if byte_level:
        blob = log.to_bytes()
        cut = data.draw(st.integers(0, len(blob)))
        survived = CommitLog.from_bytes(blob[:cut])
        # torn-tail framing: what survives is some whole-record prefix
        assert 0 <= len(survived) <= len(log)
    else:
        keep = data.draw(st.integers(0, len(log)))
        survived = CommitLog.from_bytes(log.to_bytes())
        survived.truncate(keep)
        assert len(survived) == keep

    kcr, vcr = survived.replay_columns()
    k = len(survived)
    if k == 0:
        # a fully-torn log knows no columns; nothing to rebuild
        assert survived.n_rows == 0
        assert all(v.size == 0 for v in kcr.values())
        return
    prefix_k = {c: np.concatenate([b[0][c] for b in batches[:k]]) for c in ("x", "y")}
    prefix_v = {"m": np.concatenate([b[1]["m"] for b in batches[:k]])}
    fps = set()
    for layout in layouts:
        replayed = SortedTable.from_columns(kcr, vcr, layout, schema)
        expected = SortedTable.from_columns(prefix_k, prefix_v, layout, schema)
        np.testing.assert_array_equal(replayed.packed, expected.packed)
        for c in ("x", "y"):
            np.testing.assert_array_equal(replayed.key_cols[c], expected.key_cols[c])
        np.testing.assert_array_equal(
            np.asarray(replayed.value_cols["m"]), np.asarray(expected.value_cols["m"])
        )
        fps.add(replayed.dataset_fingerprint())
    assert len(fps) == 1  # every heterogeneous layout holds the same prefix


@pytest.mark.kernel
@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 400),
    n_runs=st.integers(1, 4),
)
def test_property_merge_kernel_matches_lexsort_oracle(seed, n, n_runs):
    """Property: the k-way merge-path kernel's permutation equals the
    lexsort oracle AND the incrementally maintained row_map for any run
    stack, and compaction preserves every query result."""
    from repro.kernels import merge_run_positions, merge_run_positions_ref

    rng = np.random.default_rng(seed)
    kc = {"x": rng.integers(0, 6, n), "y": rng.integers(0, 6, n)}
    vc = {"m": rng.uniform(0, 1, n)}
    t = SortedTable.from_columns(kc, vc, ("x", "y")).place_on_device()
    for _ in range(n_runs - 1):
        m = int(rng.integers(1, 80))
        t = t.merge_insert(
            {"x": rng.integers(0, 6, m), "y": rng.integers(0, 6, m)},
            {"m": rng.uniform(0, 1, m)},
        )
    st_dev = t._device
    n_lanes = sum(st_dev["col_parts"])
    got = merge_run_positions(
        st_dev["keys"], st_dev["run_starts"], st_dev["n_rows"],
        n_lanes=n_lanes, block_n=256,
    )
    want = merge_run_positions_ref(
        st_dev["keys"], st_dev["run_starts"], st_dev["n_rows"], n_lanes=n_lanes
    )
    np.testing.assert_array_equal(got, want)
    if st_dev["row_map"] is not None:
        np.testing.assert_array_equal(got, st_dev["row_map"])
    q = Query(filters={"x": Eq(int(rng.integers(0, 6)))}, agg="select")
    before = t.execute(q)
    t.compact_runs()
    assert t._device["n_runs"] == 1
    after = t.execute(q)
    assert after.rows_matched == before.rows_matched
    np.testing.assert_array_equal(after.selected, before.selected)


@settings(max_examples=25, deadline=None)
@given(data=st.data())
def test_property_result_cache_byte_accounting(data):
    """Property (PR 5 satellite): over ANY sequence of cache stores —
    including overwrites of live keys and stores that trigger FIFO or
    byte-budget evictions — interleaved with invalidations, the
    per-replica ``_cache_sel_bytes`` counter equals the true sum of
    retained selected-array bytes: it never drifts negative and never
    leaks an entry once ``_invalidate_result_cache`` drops its map."""
    from repro.core import HREngine
    from repro.core.table import ScanResult

    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    kc, vc, schema = generate_simulation(300, 3, seed=1)
    eng = HREngine(n_nodes=2, result_cache_max_entries=data.draw(st.integers(1, 4)))
    eng.create_column_family(
        "cf", kc, vc, replication_factor=2,
        layouts=[("k0", "k1", "k2"), ("k1", "k2", "k0")], schema=schema,
    )
    # tiny instance-level budgets so every eviction path is reachable
    eng._CACHE_MAX_SELECT_BYTES = data.draw(st.sampled_from([64, 256]))
    eng._CACHE_MAX_MAP_BYTES = data.draw(st.sampled_from([128, 512]))
    map_keys = [("cf", 0), ("cf", 1)]
    for _ in range(data.draw(st.integers(10, 60))):
        mk = map_keys[int(rng.integers(0, 2))]
        if rng.random() < 0.85:
            key = ("select", None, (("k0", int(rng.integers(0, 4))),))
            n_sel = int(rng.integers(0, 48))
            sel = np.arange(n_sel, dtype=np.int64) if rng.random() < 0.8 else None
            eng._cache_store(
                mk,
                eng._result_cache.setdefault(mk, {}),
                key,
                ScanResult(float(n_sel), n_sel, n_sel, selected=sel),
            )
        else:
            eng._invalidate_result_cache("cf", replica_id=mk[1])
        for check_mk in map_keys:
            cache = eng._result_cache.get(check_mk, {})
            actual = sum(
                r.selected.nbytes for r in cache.values() if r.selected is not None
            )
            recorded = eng._cache_sel_bytes.get(check_mk, 0)
            assert recorded == actual
            assert recorded >= 0
            assert len(cache) <= eng._cache_max
            assert actual <= eng._CACHE_MAX_MAP_BYTES
        assert set(eng._cache_sel_bytes) <= set(eng._result_cache)
    eng._invalidate_result_cache("cf")
    assert eng._result_cache == {} and eng._cache_sel_bytes == {}


@settings(max_examples=15, deadline=None)
@given(
    data=st.data(),
    n=st.integers(50, 500),
    n_partitions=st.integers(2, 5),
)
def test_property_partitioned_read_matches_p1_oracle(data, n, n_partitions):
    """Property (PR 5 tentpole): for any dataset and query mix,
    ``read_many`` on a P-partition column family returns the same
    aggregates, matched counts and selected *rows* as the P = 1 oracle
    — queries spanning several partitions and queries pinned to one."""
    from repro.core import HREngine, KeySchema

    rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
    dom = data.draw(st.integers(4, 16))
    cols = ("x", "y")
    kc = {c: rng.integers(0, dom, n).astype(np.int64) for c in cols}
    vc = {"m": rng.uniform(0, 1, n)}
    schema = KeySchema({c: max(1, int(dom - 1).bit_length()) for c in cols})
    layouts = [("x", "y"), ("y", "x")]
    engines = []
    for partitions in (1, n_partitions):
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=layouts[:1], schema=schema,
            partitions=partitions,
        )
        engines.append(eng)
    e1, ep = engines
    qs = []
    for _ in range(8):
        f = {}
        for c in cols:
            kind = data.draw(st.sampled_from(["eq", "range", "none"]))
            if kind == "eq":
                f[c] = Eq(data.draw(st.integers(0, dom - 1)))
            elif kind == "range":
                lo = data.draw(st.integers(0, dom - 1))
                f[c] = Range(lo, data.draw(st.integers(lo, dom)))
        agg = data.draw(st.sampled_from(["count", "sum", "select"]))
        qs.append(Query(filters=f, agg=agg, value_col="m" if agg == "sum" else None))

    def rows_of(eng, selected):
        cf = eng.column_families["cf"]
        offsets = eng._partition_row_offsets(cf)
        pids = np.searchsorted(offsets, selected, side="right") - 1
        out = []
        for pid, g in zip(pids, selected):
            t = eng._table(cf, cf.partitions[int(pid)].replicas[0])
            li = int(g - offsets[int(pid)])
            out.append(
                tuple(int(t.key_cols[c][li]) for c in cols)
                + (float(np.asarray(t.value_cols["m"])[li]),)
            )
        return sorted(out)

    for q, (a, _), (b, _) in zip(qs, e1.read_many("cf", qs), ep.read_many("cf", qs)):
        assert b.rows_matched == a.rows_matched
        if q.agg == "sum":
            np.testing.assert_allclose(b.value, a.value, rtol=1e-9)
        else:
            assert b.value == a.value
        if q.agg == "select":
            assert rows_of(ep, b.selected) == rows_of(e1, a.selected)
