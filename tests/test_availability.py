"""Availability layer: hinted handoff, tunable consistency with digest
reads + read repair, accrual failure detection, scrub, failover retry.

The acceptance bar: (1) a transient outage heals by replaying only the
hinted log tail — and a zero-write outage costs nothing; (2) QUORUM /
ALL digest reads detect every injected corruption that reaches the
consulted replica set, repair it from the log, and still return the
fault-free answer; (3) a suspected straggler is routed around and an
injected transient read fault fails over without surfacing; (4) scrub
finds and heals silent bit flips checksums witness.
"""

import numpy as np
import pytest

from repro.core import (
    ALL,
    DeadlineExceeded,
    Eq,
    HREngine,
    ONE,
    QUORUM,
    Query,
    TransientFault,
)
from repro.core.tpch import generate_simulation
from repro.ft.detector import FailureDetector
from repro.ft.failures import FailureInjector, FailurePlan

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _engine(kc, vc, schema, *, partitions=1, rf=3, n_nodes=6, **kw):
    eng = HREngine(n_nodes=n_nodes, **kw)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=rf, layouts=LAYOUTS[:rf],
        schema=schema, partitions=partitions,
    )
    return eng


def _write_batches(rng, schema, eng, n_batches, rows=200):
    for _ in range(n_batches):
        kc = {
            c: rng.integers(0, schema.max_value(c) + 1, rows).astype(np.int64)
            for c in ("k0", "k1", "k2")
        }
        eng.write("cf", kc, {"metric": rng.uniform(0, 1, rows)})


def _fingerprints(eng, cf_name="cf"):
    cf = eng.column_families[cf_name]
    return [
        {eng._table(cf, r).dataset_fingerprint() for r in part.replicas}
        for part in cf.partitions
    ]


def _corrupt(eng, cf_name="cf", replica=0, elem=0):
    """Flip an exponent bit of one stored float — silent corruption."""
    cf = eng.column_families[cf_name]
    r = cf.replicas[replica]
    arr = eng._table(cf, r).value_cols["metric"]
    arr.view(np.int64)[elem % arr.size] ^= np.int64(1) << np.int64(62)
    return r


class TestHintedHandoff:
    def test_transient_outage_heals_by_tail_replay(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=0)
        rng = np.random.default_rng(1)
        eng = _engine(kc, vc, schema, partitions=2)
        victim = eng.column_families["cf"].partitions[0].replicas[0].node_id
        eng.fail_node(victim, transient=True)
        assert eng.stats["hints_open"] > 0
        _write_batches(rng, schema, eng, 3)
        assert eng.stats["hints_queued"] > 0
        eng.node_up(victim)
        st = eng.stats
        assert st["hint_replays"] >= 1
        assert st["hint_fallbacks"] == 0
        assert st["hints_open"] == 0
        assert all(len(fps) == 1 for fps in _fingerprints(eng))

    def test_zero_missed_writes_costs_nothing(self):
        kc, vc, schema = generate_simulation(3_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        cf = eng.column_families["cf"]
        victim = cf.replicas[0].node_id
        before = {
            r.replica_id: eng._table(cf, r)
            for r in cf.replicas
            if r.node_id == victim
        }
        eng.fail_node(victim, transient=True)
        eng.node_up(victim)
        st = eng.stats
        assert st["hint_replays"] == 0 and st["hint_fallbacks"] == 0
        for rid, table in before.items():
            r = next(x for x in cf.replicas if x.replica_id == rid)
            assert eng._table(cf, r) is table  # untouched, not rebuilt

    def test_checkpoint_collapse_forces_full_rebuild(self):
        kc, vc, schema = generate_simulation(3_000, 3, seed=0)
        rng = np.random.default_rng(2)
        eng = _engine(kc, vc, schema)
        victim = eng.column_families["cf"].replicas[0].node_id
        eng.fail_node(victim, transient=True)
        _write_batches(rng, schema, eng, 2)
        # collapsing the log invalidates the hint watermark: the tail
        # below the snapshot is no longer separable
        eng.checkpoint_commitlog("cf")
        eng.node_up(victim)
        st = eng.stats
        assert st["hint_fallbacks"] >= 1
        assert all(len(fps) == 1 for fps in _fingerprints(eng))

    def test_auto_checkpoint_deferred_while_hint_open(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=0)
        rng = np.random.default_rng(3)
        eng = _engine(kc, vc, schema, commitlog_checkpoint_records=3)
        victim = eng.column_families["cf"].replicas[0].node_id
        eng.fail_node(victim, transient=True)
        _write_batches(rng, schema, eng, 6, rows=50)
        assert eng.stats["commitlog_auto_checkpoints"] == 0  # deferred
        eng.node_up(victim)
        assert eng.stats["hint_replays"] == 1
        _write_batches(rng, schema, eng, 1, rows=50)
        assert eng.stats["commitlog_auto_checkpoints"] >= 1  # resumes

    def test_hint_replay_matches_full_rebuild(self):
        kc, vc, schema = generate_simulation(3_000, 3, seed=0)
        rng = np.random.default_rng(4)
        hinted = _engine(kc, vc, schema, partitions=2)
        full = _engine(kc, vc, schema, partitions=2)
        victim = hinted.column_families["cf"].partitions[0].replicas[0].node_id
        hinted.fail_node(victim, transient=True)
        full.fail_node(victim)  # durable loss
        for eng in (hinted, full):
            _write_batches(np.random.default_rng(5), schema, eng, 2)
        hinted.node_up(victim)
        full.recover_node(victim)
        assert _fingerprints(hinted) == _fingerprints(full)
        assert hinted.stats["hint_replays"] >= 1


class TestFailRecoverEdges:
    def test_unknown_node_raises(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema, n_nodes=3)
        with pytest.raises(ValueError):
            eng.fail_node(17)
        with pytest.raises(ValueError):
            eng.node_up(-1)
        with pytest.raises(ValueError):
            eng.recover_node(17)

    def test_fail_dead_node_is_noop(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        cf = eng.column_families["cf"]
        victim = cf.replicas[0].node_id
        eng.fail_node(victim, transient=True)
        hints = dict(cf.partitions[0].hints)
        _write_batches(np.random.default_rng(1), schema, eng, 1)
        # a second failure of the same node must not clobber the first
        # outage's (older, still correct) watermarks
        eng.fail_node(victim, transient=True)
        assert dict(cf.partitions[0].hints) == hints
        eng.fail_node(victim)  # durable re-fail of a dead node: no-op too
        assert dict(cf.partitions[0].hints) == hints
        eng.node_up(victim)
        assert all(len(fps) == 1 for fps in _fingerprints(eng))

    def test_recover_live_node_is_noop(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        cf = eng.column_families["cf"]
        node = cf.replicas[0].node_id
        table = eng._table(cf, cf.replicas[0])
        assert eng.recover_node(node) == 0.0
        assert eng.node_up(node) == 0.0
        assert eng._table(cf, cf.replicas[0]) is table


class TestConsistency:
    def test_quorum_equals_one_when_clean(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=1)
        rng = np.random.default_rng(1)
        eng = _engine(kc, vc, schema)
        qs = [
            Query(filters={"k0": Eq(int(rng.integers(0, 8)))}, agg="sum",
                  value_col="metric")
            for _ in range(6)
        ] + [Query(filters={}, agg="count")]
        one = eng.read_many("cf", qs, consistency=ONE)
        quorum = eng.read_many("cf", qs, consistency=QUORUM)
        al = eng.read_many("cf", qs, consistency=ALL)
        for (r1, _), (rq, _), (ra, _) in zip(one, quorum, al):
            assert r1.value == rq.value == ra.value
            assert r1.rows_matched == rq.rows_matched == ra.rows_matched
        assert eng.stats["digest_mismatches"] == 0
        assert eng.stats["read_repairs"] == 0

    def test_all_detects_every_injected_corruption(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=1)
        eng = _engine(kc, vc, schema, n_nodes=3, result_cache=False)
        oracle = _engine(kc, vc, schema, n_nodes=3)
        probe = Query(filters={}, agg="sum", value_col="metric")
        want = oracle.read("cf", probe)[0].value
        rng = np.random.default_rng(9)
        trials = 12
        for t in range(trials):
            r = _corrupt(eng, replica=t % 3, elem=int(rng.integers(0, 2_000)))
            assert eng.stats["digest_mismatches"] == t
            got, _ = eng.read("cf", probe, consistency=ALL)
            # detection is guaranteed: ALL consults every replica, and
            # the full-scan sum digests the corrupted element
            assert eng.stats["digest_mismatches"] == t + 1
            assert got.value == want  # repaired answer is the truth
            table = eng._table(eng.column_families["cf"], r)
            assert table.verify_checksum()  # minority replica healed
        assert eng.stats["read_repairs"] >= trials

    def test_rf2_split_repairs_from_log(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=1)
        eng = _engine(kc, vc, schema, rf=2, n_nodes=2, result_cache=False)
        oracle = _engine(kc, vc, schema, rf=2, n_nodes=2)
        probe = Query(filters={}, agg="sum", value_col="metric")
        want = oracle.read("cf", probe)[0].value
        _corrupt(eng, replica=0)
        # k = 2 of 2: a 1-1 digest split has no majority — both replicas
        # rebuild from the log and the re-executed answer is correct
        got, _ = eng.read("cf", probe, consistency=QUORUM)
        assert eng.stats["digest_mismatches"] == 1
        assert got.value == want
        assert all(len(fps) == 1 for fps in _fingerprints(eng))

    def test_partitioned_all_consistency(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=2)
        eng = _engine(kc, vc, schema, partitions=4, result_cache=False)
        oracle = _engine(kc, vc, schema, partitions=4)
        probe = Query(filters={}, agg="sum", value_col="metric")
        want = oracle.read("cf", probe)[0].value
        cf = eng.column_families["cf"]
        _corrupt(eng, replica=0)  # partition 0, slot 0
        got, _ = eng.read("cf", probe, consistency=ALL)
        assert eng.stats["digest_mismatches"] >= 1
        assert got.value == want
        assert all(len(fps) == 1 for fps in _fingerprints(eng))
        assert eng._table(cf, cf.replicas[0]).verify_checksum()

    def test_invalid_level_and_insufficient_quorum(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema, n_nodes=3)
        with pytest.raises(ValueError):
            eng.read("cf", Query(filters={}), consistency="TWO")
        eng.fail_node(0)
        eng.fail_node(1)
        with pytest.raises(RuntimeError):
            eng.read("cf", Query(filters={}), consistency=QUORUM)
        eng.recover_node(0)
        with pytest.raises(RuntimeError):  # ALL needs every replica live
            eng.read("cf", Query(filters={}), consistency=ALL)


class TestScrub:
    def test_scrub_finds_and_heals_bit_flips(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=3)
        eng = _engine(kc, vc, schema, partitions=2)
        r = _corrupt(eng, replica=1, elem=37)
        report = eng.scrub_column_family("cf")
        assert report["corrupt"] == [r.replica_id]
        assert report["repaired"] == 1
        assert all(len(fps) == 1 for fps in _fingerprints(eng))
        clean = eng.scrub_column_family("cf")
        assert clean["corrupt"] == [] and clean["repaired"] == 0

    def test_scrub_report_only(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=3)
        eng = _engine(kc, vc, schema)
        r = _corrupt(eng, replica=2)
        report = eng.scrub_column_family("cf", repair=False)
        assert report["corrupt"] == [r.replica_id]
        assert report["repaired"] == 0
        cf = eng.column_families["cf"]
        assert not eng._table(cf, r).verify_checksum()  # still corrupt

    def test_flush_does_not_launder_corruption(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=3)
        eng = _engine(kc, vc, schema)
        oracle = _engine(kc, vc, schema)
        _corrupt(eng, replica=0, elem=11)
        # flushes merge ON TOP of the corrupt base, but the sealed
        # digest extends the durable history (CREATE seal + run
        # digests), never the in-memory arrays — so the corruption
        # stays detectable however many flushes land on it
        for e in (eng, oracle):
            _write_batches(np.random.default_rng(6), schema, e, 2, rows=100)
        report = eng.scrub_column_family("cf")
        assert report["repaired"] == 1
        assert _fingerprints(eng) == _fingerprints(oracle)


class TestFailureDetector:
    def test_latency_outlier_becomes_suspected(self):
        det = FailureDetector(window=16, phi_suspect=4.0)
        for _ in range(16):
            for nid in (1, 2, 3):
                det.record(nid, 1e-4)
            det.record(0, 5e-3)  # 50x its peers
        assert det.phi(0) >= det.phi_suspect
        assert det.state(0) == "suspected" or det.state(0) == "dead"
        assert det.cost_factor(0) > 1.0
        assert det.cost_factor(1) == 1.0
        assert det.suspected_nodes() == [0]

    def test_failure_streak_accrues_and_clears(self):
        det = FailureDetector(failure_phi=4.0, phi_dead=12.0)
        for _ in range(3):
            det.record_failure(5)
        assert det.state(5) == "dead"
        det.record(5, 1e-4)  # one answer clears the streak
        assert det.phi(5) < det.phi_suspect

    def test_validation(self):
        with pytest.raises(ValueError):
            FailureDetector(window=1)
        with pytest.raises(ValueError):
            FailureDetector(phi_suspect=8.0, phi_dead=4.0)
        with pytest.raises(ValueError):
            FailureDetector(suspect_penalty=0.5)

    def test_suspected_node_routed_around(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=4)
        det = FailureDetector(min_samples=2, window=8)
        eng = HREngine(n_nodes=3, failure_detector=det, result_cache=False)
        # identical layouts: every replica ties on estimated cost, so
        # routing spreads RR — until suspicion breaks the tie
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3,
            layouts=[LAYOUTS[0]] * 3, schema=schema,
        )
        probe = Query(filters={}, agg="count")
        picked = {eng.read("cf", probe)[1].node_id for _ in range(6)}
        assert len(picked) == 3  # healthy cluster: ties rotate
        for _ in range(8):
            det.record(0, 5e-2)
            det.record(1, 1e-4)
            det.record(2, 1e-4)
        assert det.state(0) != "alive"
        picked = {eng.read("cf", probe)[1].node_id for _ in range(6)}
        assert 0 not in picked  # soft-avoided, not excluded
        assert picked == {1, 2}

    def test_transient_read_fault_fails_over(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=4)
        eng = _engine(kc, vc, schema, n_nodes=3, result_cache=False)
        oracle = _engine(kc, vc, schema, n_nodes=3)
        probe = Query(filters={}, agg="sum", value_col="metric")
        want, wrep = oracle.read("cf", probe)
        faulted = wrep.node_id  # eng's first read routes identically
        eng.nodes[faulted].read_fault_budget = 1
        got, rep = eng.read("cf", probe)
        assert got.value == want.value
        assert rep.node_id != faulted
        assert eng.stats["read_retries"] == 1

    def test_retry_exhaustion_raises(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=4)
        eng = _engine(kc, vc, schema, n_nodes=3, result_cache=False)
        for n in eng.nodes:
            n.read_fault_budget = 5
        with pytest.raises(RuntimeError):
            eng.read("cf", Query(filters={}, agg="count"))


class TestFailureInjector:
    def test_duplicate_step_entries_both_fire(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=5)
        eng = _engine(kc, vc, schema, n_nodes=6)
        plan = FailurePlan(fail_at_steps=(5, 5), nodes=(0, 1))
        inj = FailureInjector(plan, eng)
        assert inj.maybe_fail(5)
        assert {e["node"] for e in inj.log} == {0, 1}  # not node 0 twice
        assert all(n.alive for n in eng.nodes)  # instant fail+recover
        assert not inj.maybe_fail(5)  # fired entries never re-fire

    def test_open_outage_heals_at_duration(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=5)
        eng = _engine(kc, vc, schema, n_nodes=6)
        plan = FailurePlan(
            fail_at_steps=(3,), nodes=(0,), durations=(2,), transient=True
        )
        inj = FailureInjector(plan, eng)
        inj.tick(3)
        assert not eng.nodes[0].alive
        assert inj.open_outages == [{"node": 0, "recover_step": 5}]
        inj.tick(4)
        assert not eng.nodes[0].alive  # not due yet
        inj.tick(5)
        assert eng.nodes[0].alive
        assert inj.open_outages == []
        assert any(e.get("recovered") for e in inj.log)

    def test_legacy_plan_shape_unchanged(self):
        plan = FailurePlan(fail_at_steps=(12,), nodes=(0,))
        inj = FailureInjector(plan, None)
        assert inj.maybe_fail(12)
        assert inj.log[0]["step"] == 12 and inj.log[0]["node"] == 0
        assert not inj.maybe_recover(13)  # nothing left open


class TestHedgeConsistency:
    """Pin the hedge × consistency contract: the hedge duplicates ONLY
    the primary read (the hedge pass runs before the digest pass, and
    digest reads are never hedged), and a losing hedge leaves the
    primary's report — node, wall — untouched."""

    @staticmethod
    def _slow_all_but(eng, node_id, factor=1e6):
        for n in eng.nodes:
            n.slowdown = 1.0 if n.node_id == node_id else factor

    def test_hedge_duplicates_primary_only_at_quorum(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=6)
        eng = _engine(kc, vc, schema, result_cache=False)
        q = Query({"k0": Eq(int(kc["k0"][0]))})
        _, rep0 = eng.read("cf", q)
        # make the scheduler's pick a straggler so the hedge fires
        eng.nodes[rep0.node_id].slowdown = 3.0
        calls = []
        orig = eng._scan_with_cache

        def spy(cf, r, group, trace=None):
            calls.append((r.replica_id, len(group)))
            return orig(cf, r, group, trace=trace)

        eng._scan_with_cache = spy
        plain, _ = eng.read("cf", q)
        hedged, rep = eng.read(
            "cf", q, hedge=True, consistency=QUORUM
        )
        assert hedged.value == plain.value
        # the plain read is 1 scan. QUORUM at rf=3 needs k=2 distinct
        # replicas, so without hedging the second read would add 2
        # scans (primary + 1 digest); the hedge adds exactly ONE more
        # (the duplicated primary) for 3 — a count of 5 would mean the
        # whole quorum was duplicated, which is NOT the contract
        assert len(calls) == 1 + 3
        assert all(n == 1 for _rid, n in calls)

    def test_hedge_at_all_reads_every_replica_once_plus_one(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=6)
        eng = _engine(kc, vc, schema, result_cache=False)
        q = Query({"k0": Eq(int(kc["k0"][1]))})
        _, rep0 = eng.read("cf", q)
        eng.nodes[rep0.node_id].slowdown = 3.0
        calls = []
        orig = eng._scan_with_cache

        def spy(cf, r, group, trace=None):
            calls.append(r.replica_id)
            return orig(cf, r, group, trace=trace)

        eng._scan_with_cache = spy
        _res, _rep = eng.read("cf", q, hedge=True, consistency=ALL)
        # ALL = every replica answers (3 scans) + one hedge duplicate
        assert len(calls) == 4
        assert set(calls) == {r.replica_id for r in eng.column_families["cf"].replicas}

    def test_losing_hedge_keeps_primary_report(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=6)
        eng = _engine(kc, vc, schema, result_cache=False)
        q = Query({"k0": Eq(int(kc["k0"][2]))})
        _, rep0 = eng.read("cf", q)
        # primary just past the hedge threshold; every alternate is
        # catastrophically slow, so the duplicate always loses
        self._slow_all_but(eng, rep0.node_id, factor=1e6)
        eng.nodes[rep0.node_id].slowdown = 3.0
        res, rep = eng.read("cf", q, hedge=True)
        oracle, _ = eng.read("cf", q)
        assert res.value == oracle.value
        assert rep.node_id == rep0.node_id  # the primary's answer stands
        assert rep.hedged is False  # the losing hedge is not reported
        assert rep.wall_seconds < 1e3  # not the 1e6-scaled hedge wall

    def test_winning_hedge_reports_alternate(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=6)
        eng = _engine(kc, vc, schema, result_cache=False)
        q = Query({"k0": Eq(int(kc["k0"][3]))})
        _, rep0 = eng.read("cf", q)
        # the pick is hopeless, the alternates are healthy: the
        # duplicate must win and the report must say so
        eng.nodes[rep0.node_id].slowdown = 1e6
        res, rep = eng.read("cf", q, hedge=True)
        oracle, _ = eng.read("cf", q)
        assert res.value == oracle.value
        assert rep.hedged is True
        assert rep.node_id != rep0.node_id


class TestReadRetryLimitValidation:
    def test_zero_and_negative_rejected_at_construction(self):
        # regression: 0 used to slip through both retry loops as "zero
        # attempts allowed", turning the first transient fault into an
        # immediate unanswerable-query RuntimeError
        for bad in (0, -1, -7):
            with pytest.raises(ValueError, match="read_retry_limit"):
                HREngine(n_nodes=3, read_retry_limit=bad)

    def test_one_and_none_still_accepted(self):
        assert HREngine(n_nodes=3, read_retry_limit=1).read_retry_limit == 1
        assert HREngine(n_nodes=3, read_retry_limit=None).read_retry_limit is None


class TestDeadlineBudgets:
    def test_spent_budget_raises_typed_error(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=7)
        eng = _engine(kc, vc, schema)
        q = Query({"k0": Eq(int(kc["k0"][0]))})
        for call in (
            lambda: eng.read("cf", q, deadline_s=0.0),
            lambda: eng.read_many("cf", [q], deadline_s=0.0),
            lambda: eng.read("cf", q, consistency=QUORUM, deadline_s=0.0),
            lambda: eng.read_many("cf", [q], consistency=ALL, deadline_s=-1.0),
        ):
            with pytest.raises(DeadlineExceeded):
                call()

    def test_deadline_is_not_a_transient_fault(self):
        # failover must not swallow a deadline: a budget refusal is a
        # terminal answer-shape, not a retryable replica fault
        assert not issubclass(DeadlineExceeded, TransientFault)

    def test_generous_budget_answers_normally(self):
        kc, vc, schema = generate_simulation(1_000, 3, seed=7)
        eng = _engine(kc, vc, schema, partitions=2)
        q = Query({"k0": Eq(int(kc["k0"][0]))})
        plain, _ = eng.read("cf", q)
        res, _ = eng.read("cf", q, deadline_s=60.0)
        assert res.value == plain.value
        many = eng.read_many("cf", [q] * 3, consistency=QUORUM, deadline_s=60.0)
        assert [r.value for r, _ in many] == [plain.value] * 3
