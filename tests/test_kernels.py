"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Eq, Query, Range, SortedTable
from repro.kernels import (
    ecdf_hist,
    ecdf_hist_ref,
    scan_agg,
    scan_agg_ref,
    table_scan_device,
)


class TestScanAgg:
    @pytest.mark.parametrize("K", [1, 2, 3, 5, 8, 11])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep(self, rng, K, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, K).astype(np.int32)
        hi = (lo + rng.integers(1, 32, K)).astype(np.int32)
        slab = np.sort(rng.integers(0, N + 1, 2)).astype(np.int32)
        got = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=512))
        want = np.asarray(
            scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                         jnp.asarray(hi), jnp.asarray(slab))
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = np.zeros(3, np.int32)
        hi = np.full(3, 8, np.int32)
        slab = np.array([100, 2900], np.int32)
        a = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=block_n))
        b = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_value_dtypes(self, rng):
        keys = rng.integers(0, 8, (2, 1000)).astype(np.int32)
        lo = np.zeros(2, np.int32); hi = np.full(2, 4, np.int32)
        slab = np.array([0, 1000], np.int32)
        for dt in (np.float32, np.float64, np.int32):
            vals = rng.integers(0, 5, 1000).astype(dt)
            got = np.asarray(scan_agg(keys, vals, lo, hi, slab))
            want = np.asarray(
                scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals, dtype=jnp.float32),
                             jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(slab))
            )
            np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_empty_slab(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        got = np.asarray(scan_agg(keys, vals, np.zeros(2, np.int32),
                                  np.full(2, 8, np.int32), np.array([7, 7], np.int32)))
        assert got[0] == 0 and got[1] == 0

    def test_matches_table_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        for _ in range(5):
            q = Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            dev_val, dev_cnt = table_scan_device(t, q)
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)


class TestEcdfHist:
    @pytest.mark.parametrize("N,B,W", [(100, 8, 1), (4096, 64, 3), (10_000, 512, 2),
                                       (3000, 1024, 7), (555, 16, 16)])
    def test_shape_sweep(self, rng, N, B, W):
        col = rng.integers(0, B * W, N).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=B, bin_width=W, block_n=256))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=B, bin_width=W))
        np.testing.assert_allclose(got, want)

    def test_total_mass(self, rng):
        col = rng.integers(0, 100, 5000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=100, bin_width=1))
        assert got.sum() == 5000

    def test_large_bins_fallback_to_ref(self, rng):
        col = rng.integers(0, 10_000, 2000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=5000, bin_width=2))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=5000, bin_width=2))
        np.testing.assert_allclose(got, want)


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    k=st.integers(1, 6),
    n=st.integers(1, 700),
)
def test_property_scan_agg_matches_ref(seed, k, n):
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, 20, (k, n)).astype(np.int32)
    vals = rng.uniform(-1, 1, n).astype(np.float32)
    lo = rng.integers(0, 10, k).astype(np.int32)
    hi = (lo + rng.integers(0, 12, k)).astype(np.int32)
    slab = np.sort(rng.integers(0, n + 1, 2)).astype(np.int32)
    got = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=128))
    want = np.asarray(
        scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                     jnp.asarray(hi), jnp.asarray(slab))
    )
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)
