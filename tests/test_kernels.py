"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Property tests live in test_properties.py (they need hypothesis and
skip cleanly when it is absent).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Eq, Query, Range, SortedTable
from repro.kernels import (
    ecdf_hist,
    ecdf_hist_ref,
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_ref,
    table_scan_device,
    table_scan_device_many,
)


class TestScanAgg:
    @pytest.mark.parametrize("K", [1, 2, 3, 5, 8, 11])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep(self, rng, K, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, K).astype(np.int32)
        hi = (lo + rng.integers(1, 32, K)).astype(np.int32)
        slab = np.sort(rng.integers(0, N + 1, 2)).astype(np.int32)
        got = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=512))
        want = np.asarray(
            scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                         jnp.asarray(hi), jnp.asarray(slab))
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = np.zeros(3, np.int32)
        hi = np.full(3, 8, np.int32)
        slab = np.array([100, 2900], np.int32)
        a = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=block_n))
        b = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_value_dtypes(self, rng):
        keys = rng.integers(0, 8, (2, 1000)).astype(np.int32)
        lo = np.zeros(2, np.int32); hi = np.full(2, 4, np.int32)
        slab = np.array([0, 1000], np.int32)
        for dt in (np.float32, np.float64, np.int32):
            vals = rng.integers(0, 5, 1000).astype(dt)
            got = np.asarray(scan_agg(keys, vals, lo, hi, slab))
            want = np.asarray(
                scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals, dtype=jnp.float32),
                             jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(slab))
            )
            np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_empty_slab(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        got = np.asarray(scan_agg(keys, vals, np.zeros(2, np.int32),
                                  np.full(2, 8, np.int32), np.array([7, 7], np.int32)))
        assert got[0] == 0 and got[1] == 0

    def test_matches_table_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        for _ in range(5):
            q = Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            dev_val, dev_cnt = table_scan_device(t, q)
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)


class TestScanAggBatched:
    @pytest.mark.parametrize("K", [1, 3, 8])
    @pytest.mark.parametrize("Q", [1, 5, 17])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep_vs_ref(self, rng, K, Q, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, (Q, K)).astype(np.int32)
        hi = (lo + rng.integers(1, 32, (Q, K))).astype(np.int32)
        slabs = np.sort(rng.integers(0, N + 1, (Q, 2)), axis=1).astype(np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        want = np.asarray(
            scan_agg_batched_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                                 jnp.asarray(hi), jnp.asarray(slabs))
        )
        assert got.shape == (Q, 2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = rng.integers(0, 8, (9, 3)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (9, 3))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 3001, (9, 2)), axis=1).astype(np.int32)
        a = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=block_n))
        b = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_matches_unbatched_kernel_per_query(self, rng):
        keys = rng.integers(0, 32, (4, 2500)).astype(np.int32)
        vals = rng.uniform(-1, 1, 2500).astype(np.float32)
        lo = rng.integers(0, 16, (6, 4)).astype(np.int32)
        hi = (lo + rng.integers(1, 16, (6, 4))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 2501, (6, 2)), axis=1).astype(np.int32)
        batched = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        for q in range(6):
            single = np.asarray(
                scan_agg(keys, vals, lo[q], hi[q], slabs[q], block_n=512)
            )
            np.testing.assert_allclose(batched[q], single, rtol=1e-5, atol=1e-3)

    def test_empty_slabs(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        lo = np.zeros((3, 2), np.int32)
        hi = np.full((3, 2), 8, np.int32)
        slabs = np.array([[7, 7], [0, 0], [512, 512]], np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs))
        np.testing.assert_array_equal(got, 0.0)

    def test_table_scan_device_many_matches_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        queries = [
            Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            for _ in range(8)
        ]
        dev = table_scan_device_many(t, queries)
        for q, (dev_val, dev_cnt) in zip(queries, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_mixed_agg_batch_rejected(self, rng):
        kc = {"a": rng.integers(0, 8, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        qs = [Query(filters={"a": Eq(1)}, agg="count"),
              Query(filters={"a": Eq(2)}, agg="sum", value_col="m")]
        with pytest.raises(ValueError):
            table_scan_device_many(t, qs)

    @pytest.mark.parametrize("bits", [31, 32])
    def test_wide_schema_rejected_clearly(self, rng, bits):
        """Keys/bounds live in int32 on device: a column whose exclusive
        global bound 2**bits exceeds int32 (bits > 30) must raise a
        clear error, not wrap or overflow — 31 bits is the off-by-one
        case (keys fit int32 but the unfiltered bound does not)."""
        from repro.core import KeySchema

        schema = KeySchema({"a": bits})
        top = 2**bits
        kc = {"a": rng.integers(top - 8, top, 100).astype(np.int64)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",), schema)
        q = Query(filters={}, agg="count")
        with pytest.raises(ValueError, match="30-bit"):
            table_scan_device(t, q)
        with pytest.raises(ValueError, match="30-bit"):
            table_scan_device_many(t, [q])
        # the numpy engine still serves the wide schema
        assert t.execute_many([q])[0].rows_scanned == 100


class TestEcdfHist:
    @pytest.mark.parametrize("N,B,W", [(100, 8, 1), (4096, 64, 3), (10_000, 512, 2),
                                       (3000, 1024, 7), (555, 16, 16)])
    def test_shape_sweep(self, rng, N, B, W):
        col = rng.integers(0, B * W, N).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=B, bin_width=W, block_n=256))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=B, bin_width=W))
        np.testing.assert_allclose(got, want)

    def test_total_mass(self, rng):
        col = rng.integers(0, 100, 5000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=100, bin_width=1))
        assert got.sum() == 5000

    def test_large_bins_fallback_to_ref(self, rng):
        col = rng.integers(0, 10_000, 2000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=5000, bin_width=2))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=5000, bin_width=2))
        np.testing.assert_allclose(got, want)
