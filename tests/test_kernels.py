"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Property tests live in test_properties.py (they need hypothesis and
skip cleanly when it is absent).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Eq, Query, Range, SortedTable
from repro.kernels import (
    device_key_plan,
    ecdf_hist,
    ecdf_hist_ref,
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_ref,
    table_scan_device,
    table_scan_device_many,
)

pytestmark = pytest.mark.kernel


class TestScanAgg:
    @pytest.mark.parametrize("K", [1, 2, 3, 5, 8, 11])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep(self, rng, K, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, K).astype(np.int32)
        hi = (lo + rng.integers(1, 32, K)).astype(np.int32)
        slab = np.sort(rng.integers(0, N + 1, 2)).astype(np.int32)
        got = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=512))
        want = np.asarray(
            scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                         jnp.asarray(hi), jnp.asarray(slab))
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = np.zeros(3, np.int32)
        hi = np.full(3, 8, np.int32)
        slab = np.array([100, 2900], np.int32)
        a = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=block_n))
        b = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_value_dtypes(self, rng):
        keys = rng.integers(0, 8, (2, 1000)).astype(np.int32)
        lo = np.zeros(2, np.int32); hi = np.full(2, 4, np.int32)
        slab = np.array([0, 1000], np.int32)
        for dt in (np.float32, np.float64, np.int32):
            vals = rng.integers(0, 5, 1000).astype(dt)
            got = np.asarray(scan_agg(keys, vals, lo, hi, slab))
            want = np.asarray(
                scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals, dtype=jnp.float32),
                             jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(slab))
            )
            np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_empty_slab(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        got = np.asarray(scan_agg(keys, vals, np.zeros(2, np.int32),
                                  np.full(2, 8, np.int32), np.array([7, 7], np.int32)))
        assert got[0] == 0 and got[1] == 0

    def test_matches_table_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        for _ in range(5):
            q = Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            dev_val, dev_cnt = table_scan_device(t, q)
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)


class TestScanAggBatched:
    @pytest.mark.parametrize("K", [1, 3, 8])
    @pytest.mark.parametrize("Q", [1, 5, 17])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep_vs_ref(self, rng, K, Q, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, (Q, K)).astype(np.int32)
        hi = (lo + rng.integers(1, 32, (Q, K))).astype(np.int32)
        slabs = np.sort(rng.integers(0, N + 1, (Q, 2)), axis=1).astype(np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        want = np.asarray(
            scan_agg_batched_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                                 jnp.asarray(hi), jnp.asarray(slabs))
        )
        assert got.shape == (Q, 2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = rng.integers(0, 8, (9, 3)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (9, 3))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 3001, (9, 2)), axis=1).astype(np.int32)
        a = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=block_n))
        b = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_matches_unbatched_kernel_per_query(self, rng):
        keys = rng.integers(0, 32, (4, 2500)).astype(np.int32)
        vals = rng.uniform(-1, 1, 2500).astype(np.float32)
        lo = rng.integers(0, 16, (6, 4)).astype(np.int32)
        hi = (lo + rng.integers(1, 16, (6, 4))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 2501, (6, 2)), axis=1).astype(np.int32)
        batched = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        for q in range(6):
            single = np.asarray(
                scan_agg(keys, vals, lo[q], hi[q], slabs[q], block_n=512)
            )
            np.testing.assert_allclose(batched[q], single, rtol=1e-5, atol=1e-3)

    def test_empty_slabs(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        lo = np.zeros((3, 2), np.int32)
        hi = np.full((3, 2), 8, np.int32)
        slabs = np.array([[7, 7], [0, 0], [512, 512]], np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs))
        np.testing.assert_array_equal(got, 0.0)

    def test_table_scan_device_many_matches_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        queries = [
            Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            for _ in range(8)
        ]
        dev = table_scan_device_many(t, queries)
        for q, (dev_val, dev_cnt) in zip(queries, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_mixed_agg_batch_one_launch(self, rng):
        """Sum queries over different value columns and count queries
        ride one launch (multi-row value tile + per-query selector)."""
        kc = {"a": rng.integers(0, 8, 3000), "b": rng.integers(0, 8, 3000)}
        vc = {"m": rng.uniform(0, 1, 3000), "w": rng.uniform(-2, 2, 3000)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"))
        qs = [Query(filters={"a": Eq(1)}, agg="count"),
              Query(filters={"a": Eq(2)}, agg="sum", value_col="m"),
              Query(filters={"b": Range(1, 6)}, agg="sum", value_col="w"),
              Query(filters={"b": Eq(3)}, agg="sum", value_col="m"),
              Query(filters={}, agg="count")]
        dev = table_scan_device_many(t, qs)
        for q, (dev_val, dev_cnt) in zip(qs, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_select_agg_rejected(self, rng):
        kc = {"a": rng.integers(0, 8, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        with pytest.raises(ValueError, match="sum/count"):
            table_scan_device_many(t, [Query(filters={"a": Eq(1)}, agg="select")])
        with pytest.raises(ValueError, match="value_col"):
            table_scan_device_many(t, [Query(filters={"a": Eq(1)}, agg="sum")])

    @pytest.mark.parametrize("bits", [31, 32, 45, 60])
    def test_wide_schema_two_lane_packing(self, rng, bits):
        """Columns wider than one int32 lane (> 30 bits) are split into
        (hi, lo) lane pairs and served on device; 31 bits is the old
        off-by-one rejection case (keys fit int32, the unfiltered
        exclusive bound 2**31 does not)."""
        from repro.core import KeySchema

        schema = KeySchema({"a": bits})
        top = 2**bits
        kc = {"a": rng.integers(top - 8, top, 100).astype(np.int64)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",), schema)
        assert device_key_plan(t) == (2,)
        qs = [Query(filters={}, agg="count"),
              Query(filters={"a": Eq(int(kc["a"][0]))}, agg="sum", value_col="m"),
              Query(filters={"a": Range(top - 6, top - 2)}, agg="count")]
        dev = table_scan_device_many(t, qs)
        for q, (dev_val, dev_cnt) in zip(qs, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_too_wide_column_rejected_by_name(self, rng):
        """> 60 bits exceeds the two-lane budget: the error names the
        offending column so schema owners know what to shrink."""
        from repro.core import KeySchema

        schema = KeySchema({"ok": 2, "huge": 61})  # 63 bits total
        kc = {"ok": rng.integers(0, 4, 50).astype(np.int64),
              "huge": rng.integers(0, 2**61, 50).astype(np.int64)}
        vc = {"m": rng.uniform(0, 1, 50)}
        t = SortedTable.from_columns(kc, vc, ("ok", "huge"), schema)
        q = Query(filters={}, agg="count")
        with pytest.raises(ValueError, match="'huge'"):
            table_scan_device(t, q)
        with pytest.raises(ValueError, match="60-bit"):
            table_scan_device_many(t, [q])
        with pytest.raises(ValueError, match="'huge'"):
            t.place_on_device()
        # the numpy engine still serves the wide schema
        assert t.execute_many([q])[0].rows_scanned == 50

    @pytest.mark.parametrize("grid", ["rows_outer", "queries_outer"])
    def test_table_scan_ref_fallback_both_grids(self, rng, grid):
        """use_pallas=False must serve either grid via the shared oracle
        (the queries_outer fallback used to crash on the resident keys'
        padded sublanes)."""
        kc = {"a": rng.integers(0, 16, 500)}
        vc = {"m": rng.uniform(0, 1, 500)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        qs = [Query(filters={"a": Eq(int(rng.integers(0, 16)))},
                    agg="sum", value_col="m") for _ in range(4)]
        got = table_scan_device_many(t, qs, use_pallas=False, grid=grid)
        for q, (val, cnt) in zip(qs, got):
            res = t.execute(q)
            assert cnt == res.rows_matched
            np.testing.assert_allclose(val, res.value, rtol=1e-5)

    def test_row_count_cap_lifted_to_int32(self, rng, monkeypatch):
        """Counts now accumulate in int32 lanes: the cap sits at the
        int32 row-index budget (≫ the old float32 2**24), and beyond it
        tables still refuse device placement with a precise error."""
        from repro.kernels import ops

        assert ops.MAX_DEVICE_ROWS > (1 << 24)  # the old cap is lifted
        kc = {"a": rng.integers(0, 16, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        monkeypatch.setattr(ops, "MAX_DEVICE_ROWS", 64)
        with pytest.raises(ValueError, match="int32 row"):
            t.place_on_device()
        with pytest.raises(ValueError, match="numpy engine"):
            table_scan_device_many(t, [Query(filters={}, agg="count")])
        # appends respect the cap too
        monkeypatch.setattr(ops, "MAX_DEVICE_ROWS", 128)
        t2 = t.place_on_device()
        with pytest.raises(ValueError, match="int32 row"):
            t2.merge_insert({"a": rng.integers(0, 16, 50)}, {"m": np.zeros(50)})
        # the numpy engine still serves it
        assert t.execute_many([Query(filters={}, agg="count")])[0].value == 100.0

    def test_rowstream_matches_qgrid(self, rng):
        """The row-streaming grid and the legacy queries-outer grid are
        the same computation with different HBM traffic."""
        keys = rng.integers(0, 32, (4, 3000)).astype(np.int32)
        vals = rng.uniform(-1, 1, 3000).astype(np.float32)
        lo = rng.integers(0, 16, (9, 4)).astype(np.int32)
        hi = (lo + rng.integers(1, 16, (9, 4))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 3001, (9, 2)), axis=1).astype(np.int32)
        new = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        old = np.asarray(
            scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512, grid="queries_outer")
        )
        np.testing.assert_allclose(new, old, rtol=1e-5, atol=1e-3)

    def test_value_selector_vs_ref(self, rng):
        """(V, N) value tiles with a per-query row selector."""
        keys = rng.integers(0, 16, (2, 2000)).astype(np.int32)
        vals = rng.uniform(-1, 1, (3, 2000)).astype(np.float32)
        lo = rng.integers(0, 8, (7, 2)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (7, 2))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 2001, (7, 2)), axis=1).astype(np.int32)
        sel = rng.integers(0, 3, 7).astype(np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, sel, block_n=256))
        want = np.asarray(
            scan_agg_batched_ref(
                jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                jnp.asarray(hi), jnp.asarray(slabs), jnp.asarray(sel),
            )
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    def test_batch_chunking_matches_single_launch(self, rng):
        """Batches beyond max_q are chunked; results are unchanged."""
        from repro.kernels.scan_agg import scan_agg_batched_pallas

        keys = rng.integers(0, 16, (2, 1000)).astype(np.int32)
        vals = rng.uniform(0, 1, 1000).astype(np.float32)
        lo = rng.integers(0, 8, (21, 2)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (21, 2))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 1001, (21, 2)), axis=1).astype(np.int32)
        whole = np.asarray(scan_agg_batched_pallas(keys, vals, lo, hi, slabs, block_n=256))
        chunked = np.asarray(
            scan_agg_batched_pallas(keys, vals, lo, hi, slabs, block_n=256, max_q=8)
        )
        np.testing.assert_allclose(whole, chunked, rtol=1e-6)


def _lane_split(v, parts):
    """Split int64 column values into 1 or 2 int32 lanes (test helper
    mirroring the device layout)."""
    from repro.kernels.scan_agg import WIDE_LANE_BITS

    v = np.asarray(v, np.int64)
    if parts == 1:
        return [v.astype(np.int32)]
    mask = (1 << WIDE_LANE_BITS) - 1
    return [(v >> WIDE_LANE_BITS).astype(np.int32), (v & mask).astype(np.int32)]


class TestSlabLocate:
    """slab_locate_batched vs the numpy searchsorted oracle."""

    def _oracle(self, table, queries):
        from repro.core.table import slab_bounds_many

        bounds = slab_bounds_many(queries, table.layout, table.schema)
        lo = np.searchsorted(table.packed, bounds[:, 0], side="left")
        hi = np.searchsorted(table.packed, bounds[:, 1], side="right")
        return np.stack([lo, hi], axis=1).astype(np.int64)

    @pytest.mark.parametrize("bits", [(4, 4), (31, 8), (43, 20), (60, 3)])
    def test_matches_searchsorted_random_schemas(self, rng, bits):
        from repro.core import KeySchema
        from repro.kernels import table_slab_locate_many

        schema = KeySchema({"a": bits[0], "b": bits[1]})
        n = 3000
        kc = {c: rng.integers(0, min(schema.max_value(c) + 1, 2**20), n).astype(np.int64)
              for c in ("a", "b")}
        vc = {"m": rng.uniform(0, 1, n)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"), schema)
        qs = []
        for _ in range(12):
            f = {}
            if rng.random() < 0.7:
                v = int(kc["a"][rng.integers(0, n)])
                f["a"] = Eq(v) if rng.random() < 0.5 else Range(
                    max(0, v - 5), min(schema.max_value("a") + 1, v + 5))
            if rng.random() < 0.5:
                lo = int(rng.integers(0, schema.max_value("b")))
                f["b"] = Range(lo, lo + int(rng.integers(0, 4)))  # may be empty
            qs.append(Query(filters=f))
        dev = t.place_on_device()
        np.testing.assert_array_equal(table_slab_locate_many(dev, qs), self._oracle(t, qs))
        # ref oracle path agrees too
        np.testing.assert_array_equal(
            table_slab_locate_many(dev, qs, use_pallas=False), self._oracle(t, qs)
        )

    def test_bounds_at_table_edges(self, rng):
        """Slabs clamped at row 0 / row N: bounds entirely below the
        smallest key, above the largest, exact first/last key, full
        table, and empty filter ranges."""
        from repro.core import KeySchema
        from repro.kernels import table_slab_locate_many

        schema = KeySchema({"a": 10})
        vals = np.sort(rng.integers(100, 900, 500)).astype(np.int64)
        t = SortedTable.from_columns(
            {"a": vals}, {"m": np.ones(500)}, ("a",), schema
        ).place_on_device()
        qs = [
            Query(filters={"a": Range(0, 50)}),          # fully below
            Query(filters={"a": Range(950, 1024)}),       # fully above
            Query(filters={"a": Eq(int(vals[0]))}),       # first key
            Query(filters={"a": Eq(int(vals[-1]))}),      # last key
            Query(filters={}),                            # full table
            Query(filters={"a": Range(7, 7)}),            # empty range
        ]
        got = table_slab_locate_many(t, qs)
        np.testing.assert_array_equal(got, self._oracle(t, qs))
        assert tuple(got[0]) == (0, 0)
        assert tuple(got[4]) == (0, 500)
        assert tuple(got[5]) == (0, 0)

    def test_kernel_matches_ref_on_raw_lanes(self, rng):
        """Kernel vs jnp oracle on synthetic sorted lane arrays (wide
        two-lane column + narrow column), multiple row blocks."""
        from repro.kernels import slab_locate_batched, slab_locate_batched_ref

        n, q = 5000, 9
        packed = np.sort(rng.integers(0, 2**40, n)).astype(np.int64)
        narrow = rng.integers(0, 50, n).astype(np.int64)  # not part of order
        keys = np.stack(_lane_split(packed, 2) + _lane_split(narrow, 1))
        b_lo = rng.integers(0, 2**40, (q,)).astype(np.int64)
        b_hi = b_lo + rng.integers(0, 2**39, (q,))
        slab_lo = np.stack(_lane_split(b_lo, 2) + [np.zeros(q, np.int32)], axis=1)
        slab_hi = np.stack(_lane_split(b_hi, 2) + [np.full(q, 49, np.int32)], axis=1)
        limits = np.tile(np.array([[0, n]], np.int64), (q, 1))
        got = np.asarray(
            slab_locate_batched(keys, slab_lo, slab_hi, limits, block_n=512)
        )
        want = np.asarray(
            slab_locate_batched_ref(
                jnp.asarray(keys), jnp.asarray(slab_lo), jnp.asarray(slab_hi),
                jnp.asarray(limits, jnp.int32),
            )
        )
        np.testing.assert_array_equal(got, want)

    def test_requires_single_sorted_run(self, rng):
        from repro.kernels import table_slab_locate_many

        kc = {"a": rng.integers(0, 16, 300)}
        t = SortedTable.from_columns(kc, {"m": np.ones(300)}, ("a",)).place_on_device()
        merged = t.merge_insert({"a": np.array([3])}, {"m": np.array([1.0])})
        with pytest.raises(ValueError, match="single sorted run"):
            table_slab_locate_many(merged, [Query(filters={})])
        host = SortedTable.from_columns(kc, {"m": np.ones(300)}, ("a",))
        with pytest.raises(ValueError, match="device-resident"):
            table_slab_locate_many(host, [Query(filters={})])


class TestFusedLocateScan:
    """scan_agg_locate_batched (fused kernel) vs oracles and the engine."""

    def test_kernel_matches_ref(self, rng):
        from repro.kernels import scan_agg_locate_batched, scan_agg_locate_batched_ref

        n, q, k = 4000, 11, 3
        keys = np.sort(rng.integers(0, 64, (k, n)), axis=1).astype(np.int32)
        vals = rng.uniform(-2, 2, (3, n)).astype(np.float32)
        res_lo = rng.integers(0, 32, (q, k)).astype(np.int32)
        res_hi = (res_lo + rng.integers(0, 32, (q, k))).astype(np.int32)
        slab_lo = rng.integers(0, 32, (q, k)).astype(np.int32)
        slab_hi = (slab_lo + rng.integers(0, 32, (q, k))).astype(np.int32)
        limits = np.tile(np.array([[0, n]], np.int32), (q, 1))
        limits[2] = (0, 0)  # one dead query
        sel = rng.integers(0, 3, q).astype(np.int32)
        got = scan_agg_locate_batched(
            keys, vals, res_lo, res_hi, slab_lo, slab_hi, limits, sel, block_n=512
        )
        want = scan_agg_locate_batched_ref(
            jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(res_lo),
            jnp.asarray(res_hi), jnp.asarray(slab_lo), jnp.asarray(slab_hi),
            jnp.asarray(limits), jnp.asarray(sel),
        )
        assert np.asarray(got[1]).dtype == np.int32  # exact int counts
        assert np.asarray(got[2]).dtype == np.int32
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want[0]), rtol=1e-5, atol=1e-3)
        np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(got[2]), np.asarray(want[2]))

    def test_block_size_and_chunking_invariance(self, rng):
        from repro.kernels import scan_agg_locate_batched
        from repro.kernels.slab_locate import scan_agg_locate_batched as raw

        n, q = 3000, 21
        keys = np.sort(rng.integers(0, 16, (2, n)), axis=1).astype(np.int32)
        vals = rng.uniform(0, 1, n).astype(np.float32)
        res_lo = rng.integers(0, 8, (q, 2)).astype(np.int32)
        res_hi = (res_lo + rng.integers(1, 8, (q, 2))).astype(np.int32)
        limits = np.tile(np.array([[0, n]], np.int32), (q, 1))
        a = raw(keys, vals, res_lo, res_hi, res_lo, res_hi, limits, block_n=128)
        b = raw(keys, vals, res_lo, res_hi, res_lo, res_hi, limits, block_n=1024)
        c = raw(keys, vals, res_lo, res_hi, res_lo, res_hi, limits, block_n=128, max_q=8)
        for x, y in ((a, b), (a, c)):
            np.testing.assert_allclose(np.asarray(x[0]), np.asarray(y[0]), rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(x[1]), np.asarray(y[1]))
            np.testing.assert_array_equal(np.asarray(x[2]), np.asarray(y[2]))

    @pytest.mark.parametrize("use_pallas", [True, False])
    def test_table_execute_matches_numpy_engine(self, rng, use_pallas):
        from repro.kernels import table_execute_device_many

        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000), "w": rng.uniform(-2, 2, 4000)}
        dev = SortedTable.from_columns(kc, vc, ("b", "a")).place_on_device()
        host = SortedTable.from_columns(kc, vc, ("b", "a"))
        qs = [
            Query(filters={"a": Range(3, 20), "b": Eq(7)}, agg="sum", value_col="m"),
            Query(filters={"b": Range(2, 9)}, agg="count"),
            Query(filters={"a": Eq(5)}, agg="select"),
            Query(filters={"a": Range(4, 4)}, agg="count"),   # empty range
            Query(filters={"b": Range(4, 4)}, agg="select"),  # empty select
            Query(filters={}, agg="sum", value_col="w"),
        ]
        out = table_execute_device_many(dev, qs, use_pallas=use_pallas)
        for q, rd in zip(qs, out):
            rh = host.execute(q)
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)
            if q.agg == "select":
                np.testing.assert_array_equal(rd.selected, rh.selected)

    def test_agg_validation(self, rng):
        from repro.kernels import table_execute_device_many

        kc = {"a": rng.integers(0, 8, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",)).place_on_device()
        with pytest.raises(ValueError, match="sum/count/select"):
            table_execute_device_many(t, [Query(filters={}, agg="median")])
        with pytest.raises(ValueError, match="value_col"):
            table_execute_device_many(t, [Query(filters={}, agg="sum")])
        with pytest.raises(KeyError):
            table_execute_device_many(
                t, [Query(filters={}, agg="sum", value_col="nope")]
            )


class TestSelectCompact:
    def test_kernel_matches_ref_and_nonzero(self, rng):
        from repro.kernels import select_compact_batched, select_compact_batched_ref

        n, q = 7000, 6  # several 2048-row blocks exercise the carry
        keys = rng.integers(0, 10, (2, n)).astype(np.int32)
        res_lo = rng.integers(0, 5, (q, 2)).astype(np.int32)
        res_hi = (res_lo + rng.integers(1, 6, (q, 2))).astype(np.int32)
        limits = np.tile(np.array([[0, n]], np.int32), (q, 1))
        limits[3] = (100, 900)  # a restricted window
        mask = np.ones((q, n), bool)
        ridx = np.arange(n)
        for j in range(q):
            m = (ridx >= limits[j, 0]) & (ridx < limits[j, 1])
            for lane in range(2):
                m &= (keys[lane] >= res_lo[j, lane]) & (keys[lane] < res_hi[j, lane])
            mask[j] = m
        counts = mask.sum(axis=1)
        width = 128
        while width < counts.max():
            width *= 2
        got = np.asarray(
            select_compact_batched(
                keys, res_lo, res_hi, limits, out_width=width, block_n=512
            )
        )
        want = np.asarray(
            select_compact_batched_ref(
                jnp.asarray(keys), jnp.asarray(res_lo), jnp.asarray(res_hi),
                jnp.asarray(limits), out_width=width,
            )
        )
        np.testing.assert_array_equal(got, want)
        for j in range(q):
            np.testing.assert_array_equal(
                got[j, : counts[j]], np.nonzero(mask[j])[0]
            )

    def test_width_exactly_count(self, rng):
        """out_width == max matched count: the clamp path must not
        corrupt the last slot."""
        from repro.kernels import select_compact_batched

        n = 600
        keys = np.zeros((1, n), np.int32)
        keys[0, 5:133] = 1  # exactly 128 matches
        res_lo = np.array([[1]], np.int32)
        res_hi = np.array([[2]], np.int32)
        limits = np.array([[0, n]], np.int32)
        got = np.asarray(
            select_compact_batched(keys, res_lo, res_hi, limits, out_width=128, block_n=256)
        )
        np.testing.assert_array_equal(got[0], np.arange(5, 133))


class TestEcdfHist:
    @pytest.mark.parametrize("N,B,W", [(100, 8, 1), (4096, 64, 3), (10_000, 512, 2),
                                       (3000, 1024, 7), (555, 16, 16)])
    def test_shape_sweep(self, rng, N, B, W):
        col = rng.integers(0, B * W, N).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=B, bin_width=W, block_n=256))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=B, bin_width=W))
        np.testing.assert_allclose(got, want)

    def test_total_mass(self, rng):
        col = rng.integers(0, 100, 5000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=100, bin_width=1))
        assert got.sum() == 5000

    def test_large_bins_fallback_to_ref(self, rng):
        col = rng.integers(0, 10_000, 2000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=5000, bin_width=2))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=5000, bin_width=2))
        np.testing.assert_allclose(got, want)


class TestMergeRuns:
    """K-way merge-path kernel vs the lexsort oracle, the incremental
    row_map, and the rebuild escape hatch."""

    def _stacked(self, rng, n_base, runs, dom=16, layout=("a", "b")):
        kc = {"a": rng.integers(0, dom, n_base), "b": rng.integers(0, dom, n_base)}
        vc = {"m": rng.uniform(0, 1, n_base)}
        t = SortedTable.from_columns(kc, vc, layout).place_on_device()
        for m in runs:
            t = t.merge_insert(
                {"a": rng.integers(0, dom, m), "b": rng.integers(0, dom, m)},
                {"m": rng.uniform(0, 1, m)},
            )
        return t

    @pytest.mark.parametrize("runs", [(1,), (100,), (37, 208, 5), (64, 64, 64, 64)])
    def test_positions_match_oracle_and_row_map(self, rng, runs):
        from repro.kernels import merge_run_positions, merge_run_positions_ref

        t = self._stacked(rng, 900, runs, dom=8)  # small domain: many ties
        st = t._device
        n_lanes = sum(st["col_parts"])
        got = merge_run_positions(
            st["keys"], st["run_starts"], st["n_rows"], n_lanes=n_lanes, block_n=256
        )
        want = merge_run_positions_ref(
            st["keys"], st["run_starts"], st["n_rows"], n_lanes=n_lanes
        )
        np.testing.assert_array_equal(got, want)
        # the merge tie rule IS the host merge order, so the kernel's
        # permutation equals the incrementally maintained row_map
        np.testing.assert_array_equal(got, st["row_map"])

    def test_block_size_invariance(self, rng):
        from repro.kernels import merge_run_positions

        t = self._stacked(rng, 700, (90, 33))
        st = t._device
        n_lanes = sum(st["col_parts"])
        outs = [
            merge_run_positions(
                st["keys"], st["run_starts"], st["n_rows"], n_lanes=n_lanes,
                block_n=bn,
            )
            for bn in (128, 512, 4096)
        ]
        for o in outs[1:]:
            np.testing.assert_array_equal(outs[0], o)

    def test_merge_device_runs_equals_rebuild(self, rng):
        """Compacted state == place_on_device(rebuild=True) state, array
        for array — device order becomes host order with no re-upload."""
        import copy

        from repro.kernels import merge_device_runs

        t = self._stacked(rng, 1500, (200, 80, 41))
        compacted = merge_device_runs(t._device)
        rebuilt = copy.deepcopy(t).place_on_device(rebuild=True)._device
        assert compacted["n_runs"] == 1 and compacted["row_map"] is None
        assert compacted["run_starts"] == (0,)
        np.testing.assert_array_equal(
            np.asarray(compacted["keys"]), np.asarray(rebuilt["keys"])
        )
        np.testing.assert_array_equal(
            np.asarray(compacted["values_tile"]), np.asarray(rebuilt["values_tile"])
        )

    def test_wide_two_lane_columns(self, rng):
        """A 40-bit key column (two int32 lanes, lexicographic) merges
        correctly through the kernel."""
        from repro.core import KeySchema
        from repro.kernels import merge_device_runs

        schema = KeySchema({"a": 40, "b": 6})
        kc = {"a": rng.integers(0, 2**40, 1200).astype(np.int64),
              "b": rng.integers(0, 64, 1200).astype(np.int64)}
        vc = {"m": rng.uniform(0, 1, 1200)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"), schema).place_on_device()
        t = t.merge_insert(
            {"a": rng.integers(0, 2**40, 150).astype(np.int64),
             "b": rng.integers(0, 64, 150).astype(np.int64)},
            {"m": rng.uniform(0, 1, 150)},
        )
        st = merge_device_runs(t._device)
        import copy

        rebuilt = copy.deepcopy(t).place_on_device(rebuild=True)._device
        np.testing.assert_array_equal(
            np.asarray(st["keys"]), np.asarray(rebuilt["keys"])
        )

    def test_compact_runs_preserves_results(self, rng):
        t = self._stacked(rng, 1000, (120, 60))
        qs = [Query(filters={"a": Eq(3)}, agg="count"),
              Query(filters={"b": Range(2, 9)}, agg="sum", value_col="m"),
              Query(filters={"a": Eq(5)}, agg="select")]
        before = [t.execute(q) for q in qs]
        t.compact_runs()
        assert t._device["n_runs"] == 1
        after = [t.execute(q) for q in qs]
        for b, a in zip(before, after):
            assert b.rows_matched == a.rows_matched
            assert b.rows_scanned == a.rows_scanned
            np.testing.assert_allclose(a.value, b.value, rtol=1e-5)
            if b.selected is not None:
                np.testing.assert_array_equal(a.selected, b.selected)

    def test_single_run_noop(self, rng):
        from repro.kernels import merge_device_runs, merge_run_positions

        t = self._stacked(rng, 500, ())
        st = t._device
        assert merge_device_runs(st)["n_runs"] == 1
        np.testing.assert_array_equal(
            merge_run_positions(st["keys"], st["run_starts"], 500, n_lanes=2),
            np.arange(500),
        )


class TestEcdfDeviceStats:
    """Satellite: ecdf_hist wired into TableStats.merge_rows — the
    device refresh must equal the host bincount path exactly."""

    def test_merge_rows_device_equals_host(self, rng):
        import copy

        from repro.core import KeySchema
        from repro.core.ecdf import TableStats

        schema = KeySchema({"a": 6, "b": 14})
        kc = {"a": rng.integers(0, 64, 4000), "b": rng.integers(0, 1 << 14, 4000)}
        host_stats = TableStats.from_columns(kc, schema)
        dev_stats = copy.deepcopy(host_stats)
        batch = {"a": rng.integers(0, 64, 900), "b": rng.integers(0, 1 << 14, 900)}
        host_stats.merge_rows(batch, device=False)
        dev_stats.merge_rows(batch, device=True)
        assert dev_stats.n_rows == host_stats.n_rows
        for c in ("a", "b"):
            np.testing.assert_array_equal(
                dev_stats.columns[c].counts, host_stats.columns[c].counts
            )
            assert dev_stats.columns[c].total == host_stats.columns[c].total

    def test_wide_domain_falls_back_to_host(self, rng):
        import copy

        from repro.core import KeySchema
        from repro.core.ecdf import TableStats

        schema = KeySchema({"w": 40})  # domain exceeds the int32 lanes
        kc = {"w": rng.integers(0, 2**40, 2000).astype(np.int64)}
        a = TableStats.from_columns(kc, schema)
        b = copy.deepcopy(a)
        batch = {"w": rng.integers(0, 2**40, 500).astype(np.int64)}
        a.merge_rows(batch, device=False)
        b.merge_rows(batch, device=True)  # silently host-path
        np.testing.assert_array_equal(a.columns["w"].counts, b.columns["w"].counts)

    def test_selectivities_identical_after_device_refresh(self, rng):
        import copy

        from repro.core import KeySchema
        from repro.core.ecdf import TableStats

        schema = KeySchema({"a": 10})
        kc = {"a": rng.integers(0, 1024, 3000)}
        a = TableStats.from_columns(kc, schema)
        b = copy.deepcopy(a)
        batch = {"a": rng.integers(0, 1024, 700)}
        a.merge_rows(batch, device=False)
        b.merge_rows(batch, device=True)
        xs = rng.uniform(0, 1024, 50)
        np.testing.assert_array_equal(
            a.columns["a"].cdf_many(xs), b.columns["a"].cdf_many(xs)
        )
