"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode).

Property tests live in test_properties.py (they need hypothesis and
skip cleanly when it is absent).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Eq, Query, Range, SortedTable
from repro.kernels import (
    device_key_plan,
    ecdf_hist,
    ecdf_hist_ref,
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_ref,
    table_scan_device,
    table_scan_device_many,
)

pytestmark = pytest.mark.kernel


class TestScanAgg:
    @pytest.mark.parametrize("K", [1, 2, 3, 5, 8, 11])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep(self, rng, K, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, K).astype(np.int32)
        hi = (lo + rng.integers(1, 32, K)).astype(np.int32)
        slab = np.sort(rng.integers(0, N + 1, 2)).astype(np.int32)
        got = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=512))
        want = np.asarray(
            scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                         jnp.asarray(hi), jnp.asarray(slab))
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = np.zeros(3, np.int32)
        hi = np.full(3, 8, np.int32)
        slab = np.array([100, 2900], np.int32)
        a = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=block_n))
        b = np.asarray(scan_agg(keys, vals, lo, hi, slab, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_value_dtypes(self, rng):
        keys = rng.integers(0, 8, (2, 1000)).astype(np.int32)
        lo = np.zeros(2, np.int32); hi = np.full(2, 4, np.int32)
        slab = np.array([0, 1000], np.int32)
        for dt in (np.float32, np.float64, np.int32):
            vals = rng.integers(0, 5, 1000).astype(dt)
            got = np.asarray(scan_agg(keys, vals, lo, hi, slab))
            want = np.asarray(
                scan_agg_ref(jnp.asarray(keys), jnp.asarray(vals, dtype=jnp.float32),
                             jnp.asarray(lo), jnp.asarray(hi), jnp.asarray(slab))
            )
            np.testing.assert_allclose(got, want, rtol=1e-5)

    def test_empty_slab(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        got = np.asarray(scan_agg(keys, vals, np.zeros(2, np.int32),
                                  np.full(2, 8, np.int32), np.array([7, 7], np.int32)))
        assert got[0] == 0 and got[1] == 0

    def test_matches_table_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        for _ in range(5):
            q = Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            dev_val, dev_cnt = table_scan_device(t, q)
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)


class TestScanAggBatched:
    @pytest.mark.parametrize("K", [1, 3, 8])
    @pytest.mark.parametrize("Q", [1, 5, 17])
    @pytest.mark.parametrize("N", [1, 100, 2048, 5000])
    def test_shape_sweep_vs_ref(self, rng, K, Q, N):
        keys = rng.integers(0, 64, (K, N)).astype(np.int32)
        vals = rng.uniform(-2, 2, N).astype(np.float32)
        lo = rng.integers(0, 32, (Q, K)).astype(np.int32)
        hi = (lo + rng.integers(1, 32, (Q, K))).astype(np.int32)
        slabs = np.sort(rng.integers(0, N + 1, (Q, 2)), axis=1).astype(np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        want = np.asarray(
            scan_agg_batched_ref(jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                                 jnp.asarray(hi), jnp.asarray(slabs))
        )
        assert got.shape == (Q, 2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    @pytest.mark.parametrize("block_n", [128, 256, 2048])
    def test_block_size_invariance(self, rng, block_n):
        keys = rng.integers(0, 16, (3, 3000)).astype(np.int32)
        vals = rng.uniform(0, 1, 3000).astype(np.float32)
        lo = rng.integers(0, 8, (9, 3)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (9, 3))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 3001, (9, 2)), axis=1).astype(np.int32)
        a = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=block_n))
        b = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=1024))
        np.testing.assert_allclose(a, b, rtol=1e-6)

    def test_matches_unbatched_kernel_per_query(self, rng):
        keys = rng.integers(0, 32, (4, 2500)).astype(np.int32)
        vals = rng.uniform(-1, 1, 2500).astype(np.float32)
        lo = rng.integers(0, 16, (6, 4)).astype(np.int32)
        hi = (lo + rng.integers(1, 16, (6, 4))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 2501, (6, 2)), axis=1).astype(np.int32)
        batched = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        for q in range(6):
            single = np.asarray(
                scan_agg(keys, vals, lo[q], hi[q], slabs[q], block_n=512)
            )
            np.testing.assert_allclose(batched[q], single, rtol=1e-5, atol=1e-3)

    def test_empty_slabs(self, rng):
        keys = rng.integers(0, 8, (2, 512)).astype(np.int32)
        vals = rng.uniform(0, 1, 512).astype(np.float32)
        lo = np.zeros((3, 2), np.int32)
        hi = np.full((3, 2), 8, np.int32)
        slabs = np.array([[7, 7], [0, 0], [512, 512]], np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs))
        np.testing.assert_array_equal(got, 0.0)

    def test_table_scan_device_many_matches_engine(self, rng):
        kc = {"a": rng.integers(0, 30, 4000), "b": rng.integers(0, 30, 4000)}
        vc = {"m": rng.uniform(0, 5, 4000)}
        t = SortedTable.from_columns(kc, vc, ("b", "a"))
        queries = [
            Query(
                filters={"a": Range(int(rng.integers(0, 15)), int(rng.integers(15, 30))),
                         "b": Eq(int(rng.integers(0, 30)))},
                agg="sum", value_col="m",
            )
            for _ in range(8)
        ]
        dev = table_scan_device_many(t, queries)
        for q, (dev_val, dev_cnt) in zip(queries, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_mixed_agg_batch_one_launch(self, rng):
        """Sum queries over different value columns and count queries
        ride one launch (multi-row value tile + per-query selector)."""
        kc = {"a": rng.integers(0, 8, 3000), "b": rng.integers(0, 8, 3000)}
        vc = {"m": rng.uniform(0, 1, 3000), "w": rng.uniform(-2, 2, 3000)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"))
        qs = [Query(filters={"a": Eq(1)}, agg="count"),
              Query(filters={"a": Eq(2)}, agg="sum", value_col="m"),
              Query(filters={"b": Range(1, 6)}, agg="sum", value_col="w"),
              Query(filters={"b": Eq(3)}, agg="sum", value_col="m"),
              Query(filters={}, agg="count")]
        dev = table_scan_device_many(t, qs)
        for q, (dev_val, dev_cnt) in zip(qs, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_select_agg_rejected(self, rng):
        kc = {"a": rng.integers(0, 8, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        with pytest.raises(ValueError, match="sum/count"):
            table_scan_device_many(t, [Query(filters={"a": Eq(1)}, agg="select")])
        with pytest.raises(ValueError, match="value_col"):
            table_scan_device_many(t, [Query(filters={"a": Eq(1)}, agg="sum")])

    @pytest.mark.parametrize("bits", [31, 32, 45, 60])
    def test_wide_schema_two_lane_packing(self, rng, bits):
        """Columns wider than one int32 lane (> 30 bits) are split into
        (hi, lo) lane pairs and served on device; 31 bits is the old
        off-by-one rejection case (keys fit int32, the unfiltered
        exclusive bound 2**31 does not)."""
        from repro.core import KeySchema

        schema = KeySchema({"a": bits})
        top = 2**bits
        kc = {"a": rng.integers(top - 8, top, 100).astype(np.int64)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",), schema)
        assert device_key_plan(t) == (2,)
        qs = [Query(filters={}, agg="count"),
              Query(filters={"a": Eq(int(kc["a"][0]))}, agg="sum", value_col="m"),
              Query(filters={"a": Range(top - 6, top - 2)}, agg="count")]
        dev = table_scan_device_many(t, qs)
        for q, (dev_val, dev_cnt) in zip(qs, dev):
            res = t.execute(q)
            assert dev_cnt == res.rows_matched
            np.testing.assert_allclose(dev_val, res.value, rtol=1e-4, atol=1e-3)

    def test_too_wide_column_rejected_by_name(self, rng):
        """> 60 bits exceeds the two-lane budget: the error names the
        offending column so schema owners know what to shrink."""
        from repro.core import KeySchema

        schema = KeySchema({"ok": 2, "huge": 61})  # 63 bits total
        kc = {"ok": rng.integers(0, 4, 50).astype(np.int64),
              "huge": rng.integers(0, 2**61, 50).astype(np.int64)}
        vc = {"m": rng.uniform(0, 1, 50)}
        t = SortedTable.from_columns(kc, vc, ("ok", "huge"), schema)
        q = Query(filters={}, agg="count")
        with pytest.raises(ValueError, match="'huge'"):
            table_scan_device(t, q)
        with pytest.raises(ValueError, match="60-bit"):
            table_scan_device_many(t, [q])
        with pytest.raises(ValueError, match="'huge'"):
            t.place_on_device()
        # the numpy engine still serves the wide schema
        assert t.execute_many([q])[0].rows_scanned == 50

    @pytest.mark.parametrize("grid", ["rows_outer", "queries_outer"])
    def test_table_scan_ref_fallback_both_grids(self, rng, grid):
        """use_pallas=False must serve either grid via the shared oracle
        (the queries_outer fallback used to crash on the resident keys'
        padded sublanes)."""
        kc = {"a": rng.integers(0, 16, 500)}
        vc = {"m": rng.uniform(0, 1, 500)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        qs = [Query(filters={"a": Eq(int(rng.integers(0, 16)))},
                    agg="sum", value_col="m") for _ in range(4)]
        got = table_scan_device_many(t, qs, use_pallas=False, grid=grid)
        for q, (val, cnt) in zip(qs, got):
            res = t.execute(q)
            assert cnt == res.rows_matched
            np.testing.assert_allclose(val, res.value, rtol=1e-5)

    def test_row_count_cap_guards_float32_counts(self, rng, monkeypatch):
        """Counts accumulate in a float32 lane (exact to 2**24): larger
        tables must refuse device placement instead of silently rounding."""
        from repro.kernels import ops

        kc = {"a": rng.integers(0, 16, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        t = SortedTable.from_columns(kc, vc, ("a",))
        monkeypatch.setattr(ops, "MAX_DEVICE_ROWS", 64)
        with pytest.raises(ValueError, match="float32 count"):
            t.place_on_device()
        with pytest.raises(ValueError, match="numpy engine"):
            table_scan_device_many(t, [Query(filters={}, agg="count")])
        # the numpy engine still serves it
        assert t.execute(Query(filters={}, agg="count")).value == 100.0

    def test_rowstream_matches_qgrid(self, rng):
        """The row-streaming grid and the legacy queries-outer grid are
        the same computation with different HBM traffic."""
        keys = rng.integers(0, 32, (4, 3000)).astype(np.int32)
        vals = rng.uniform(-1, 1, 3000).astype(np.float32)
        lo = rng.integers(0, 16, (9, 4)).astype(np.int32)
        hi = (lo + rng.integers(1, 16, (9, 4))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 3001, (9, 2)), axis=1).astype(np.int32)
        new = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512))
        old = np.asarray(
            scan_agg_batched(keys, vals, lo, hi, slabs, block_n=512, grid="queries_outer")
        )
        np.testing.assert_allclose(new, old, rtol=1e-5, atol=1e-3)

    def test_value_selector_vs_ref(self, rng):
        """(V, N) value tiles with a per-query row selector."""
        keys = rng.integers(0, 16, (2, 2000)).astype(np.int32)
        vals = rng.uniform(-1, 1, (3, 2000)).astype(np.float32)
        lo = rng.integers(0, 8, (7, 2)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (7, 2))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 2001, (7, 2)), axis=1).astype(np.int32)
        sel = rng.integers(0, 3, 7).astype(np.int32)
        got = np.asarray(scan_agg_batched(keys, vals, lo, hi, slabs, sel, block_n=256))
        want = np.asarray(
            scan_agg_batched_ref(
                jnp.asarray(keys), jnp.asarray(vals), jnp.asarray(lo),
                jnp.asarray(hi), jnp.asarray(slabs), jnp.asarray(sel),
            )
        )
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    def test_batch_chunking_matches_single_launch(self, rng):
        """Batches beyond max_q are chunked; results are unchanged."""
        from repro.kernels.scan_agg import scan_agg_batched_pallas

        keys = rng.integers(0, 16, (2, 1000)).astype(np.int32)
        vals = rng.uniform(0, 1, 1000).astype(np.float32)
        lo = rng.integers(0, 8, (21, 2)).astype(np.int32)
        hi = (lo + rng.integers(1, 8, (21, 2))).astype(np.int32)
        slabs = np.sort(rng.integers(0, 1001, (21, 2)), axis=1).astype(np.int32)
        whole = np.asarray(scan_agg_batched_pallas(keys, vals, lo, hi, slabs, block_n=256))
        chunked = np.asarray(
            scan_agg_batched_pallas(keys, vals, lo, hi, slabs, block_n=256, max_q=8)
        )
        np.testing.assert_allclose(whole, chunked, rtol=1e-6)


class TestEcdfHist:
    @pytest.mark.parametrize("N,B,W", [(100, 8, 1), (4096, 64, 3), (10_000, 512, 2),
                                       (3000, 1024, 7), (555, 16, 16)])
    def test_shape_sweep(self, rng, N, B, W):
        col = rng.integers(0, B * W, N).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=B, bin_width=W, block_n=256))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=B, bin_width=W))
        np.testing.assert_allclose(got, want)

    def test_total_mass(self, rng):
        col = rng.integers(0, 100, 5000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=100, bin_width=1))
        assert got.sum() == 5000

    def test_large_bins_fallback_to_ref(self, rng):
        col = rng.integers(0, 10_000, 2000).astype(np.int32)
        got = np.asarray(ecdf_hist(col, n_bins=5000, bin_width=2))
        want = np.asarray(ecdf_hist_ref(jnp.asarray(col), n_bins=5000, bin_width=2))
        np.testing.assert_allclose(got, want)
