"""Deterministic chaos harness: seeded fault schedules vs a no-fault
oracle (``repro.ft.chaos``).

The acceptance property — for any seeded schedule of crashes, torn log
tails, run corruptions, slow nodes and flush aborts, the victim cluster
after detector-driven repair answers every probe identically to an
engine that saw the same writes and no faults, and every replica of
every partition converges to the same row set.
"""

import pytest

from repro.ft.chaos import (
    KINDS,
    ChaosHarness,
    ChaosSchedule,
    OverloadHarness,
)

pytestmark = pytest.mark.chaos


class TestSchedule:
    def test_same_seed_same_schedule(self):
        a = ChaosSchedule.generate(7, n_steps=40)
        b = ChaosSchedule.generate(7, n_steps=40)
        assert a == b
        assert ChaosSchedule.generate(8, n_steps=40) != a

    def test_events_well_formed(self):
        sched = ChaosSchedule.generate(3, n_steps=60, n_nodes=6,
                                       n_partitions=4, rate=0.6)
        assert sched.events, "a 60-step schedule at rate 0.6 must inject"
        for ev in sched.events:
            assert ev.kind in KINDS
            assert 0 <= ev.step < 60
            if ev.kind in ("crash", "slow_node"):
                assert 0 <= ev.node_id < 6
                assert ev.duration > 0

    def test_at_most_one_node_down(self):
        # overlap avoidance: two crash windows never intersect, so the
        # RF=3 cluster always holds a write quorum
        for seed in range(10):
            sched = ChaosSchedule.generate(seed, n_steps=50, rate=0.8)
            spans = [
                (ev.step, ev.step + ev.duration)
                for ev in sched.events
                if ev.kind == "crash"
            ]
            for i, (s0, e0) in enumerate(spans):
                for s1, e1 in spans[i + 1:]:
                    assert e0 < s1 or e1 < s0


class TestOracleProperty:
    @pytest.mark.parametrize("seed", range(4))
    def test_seeded_schedule_converges_to_oracle(self, seed):
        report = ChaosHarness(seed, n_steps=20, n_rows=2_000).run()
        assert report.ok, report.failures

    def test_faults_actually_exercised(self):
        # the harness must not pass vacuously: across a few seeds the
        # availability machinery has to have fired
        totals: dict[str, int] = {}
        for seed in range(3):
            report = ChaosHarness(seed, n_steps=20, n_rows=2_000).run()
            assert report.ok, report.failures
            for k, v in report.stats.items():
                if isinstance(v, int):
                    totals[k] = totals.get(k, 0) + v
        assert totals["hints_queued"] > 0
        assert totals["hint_replays"] > 0
        assert totals["scrub_checks"] > 0

    def test_report_is_reproducible(self):
        r1 = ChaosHarness(11, n_steps=15, n_rows=1_500).run()
        r2 = ChaosHarness(11, n_steps=15, n_rows=1_500).run()
        assert r1.ok and r2.ok
        assert r1.n_events == r2.n_events
        ints = lambda s: {k: v for k, v in s.items() if isinstance(v, int)}
        assert ints(r1.stats) == ints(r2.stats)  # wall timings excluded


class TestOverload:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_shed_or_exact_under_overload(self, seed):
        report = OverloadHarness(seed).run()
        assert report.ok, report.failures
        # non-vacuous by construction (the property asserts it too):
        # the burst + slow-drain actually forced explicit refusals
        s = report.stats
        refusals = (
            s["rejected_queue_full"] + s["rejected_throttle"]
            + s["rejected_bulkhead"] + s["shed_overload"] + s["shed_deadline"]
        )
        assert refusals > 0
        assert s["served_ok"] > 0  # ...but the door stayed open

    def test_overload_exercises_the_ladder(self):
        # one run must climb past rung 1: hedges and degradations both
        # fire under the slow-drain window, and recovery follows
        report = OverloadHarness(0).run()
        assert report.ok, report.failures
        assert report.stats["hedged_batches"] > 0
        assert report.stats["consistency_degraded"] > 0

    def test_arrival_stream_is_seed_deterministic(self):
        a = OverloadHarness(5)
        b = OverloadHarness(5)
        assert [r.arrival_s for r in a.requests] == [
            r.arrival_s for r in b.requests
        ]
        assert [r.priority for r in a.requests] == [
            r.priority for r in b.requests
        ]
