"""Token-ring partitioning: scatter-gather reads over partitioned
heterogeneous replicas.

The acceptance bar: (1) a ``partitions=1`` column family is
bit-identical to the unpartitioned engine — same tables, same commit
log, same read results and select indices; (2) for P ∈ {2, 4} every
``read_many`` answer (aggregate value, matched count, and the actual
selected *rows*) equals the P = 1 oracle over the same dataset and
queries, including queries whose slab spans several partitions and
queries pinned to one; (3) ``fail_node``/``recover_node(source="log")``
rebuild only the failed node's partition replicas, bit-identically,
from each partition's own log.
"""

import copy
import itertools

import numpy as np
import pytest

from repro.core import (
    Eq,
    HREngine,
    KeySchema,
    Query,
    Range,
    SortedTable,
    TokenRing,
    merge_partial_scans,
    place_replica,
    slab_bounds_many,
)
from repro.core.table import ScanResult
from repro.core.tpch import generate_simulation

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _mixed_queries(rng, schema, n=24, value_col="metric"):
    """Mixed workload: partition-key equalities (single-partition),
    leading-key ranges (multi-partition spans), residual-only filters
    (full fan-out), across all three aggregations."""
    qs = []
    doms = {c: schema.max_value(c) + 1 for c in ("k0", "k1", "k2")}
    aggs = itertools.cycle(["count", "sum", "select"])
    for _ in range(n):
        agg = next(aggs)
        u = rng.random()
        if u < 0.35:  # pinned to one partition (leading-key equality)
            f = {"k0": Eq(int(rng.integers(0, doms["k0"])))}
        elif u < 0.65:  # contiguous span of partitions
            lo = int(rng.integers(0, doms["k0"] - 1))
            width = int(rng.integers(1, max(2, doms["k0"] // 3)))
            f = {"k0": Range(lo, min(lo + width, doms["k0"]))}
            if rng.random() < 0.5:
                f["k2"] = Eq(int(rng.integers(0, doms["k2"])))
        else:  # residual filter only: fans out to every partition
            lo = int(rng.integers(0, doms["k1"] - 1))
            f = {"k1": Range(lo, min(lo + 2, doms["k1"]))}
        qs.append(
            Query(filters=f, agg=agg, value_col=value_col if agg == "sum" else None)
        )
    return qs


def _engine(kc, vc, schema, *, partitions, rf=3, n_nodes=6, **kw):
    eng = HREngine(n_nodes=n_nodes, **kw)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=rf, layouts=LAYOUTS[:rf], schema=schema,
        partitions=partitions,
    )
    return eng


def _selected_rows(eng, cf_name, selected):
    """Materialize a partitioned (RF = 1) engine's global select indices
    into actual (keys..., value) rows — the representation-independent
    form the P = 1 oracle comparison uses."""
    cf = eng.column_families[cf_name]
    offsets = eng._partition_row_offsets(cf)
    pids = np.searchsorted(offsets, selected, side="right") - 1
    rows = []
    for pid, g in zip(pids, selected):
        t = eng._table(cf, cf.partitions[int(pid)].replicas[0])
        li = int(g - offsets[int(pid)])
        rows.append(
            tuple(int(t.key_cols[c][li]) for c in cf.key_names)
            + (float(np.asarray(t.value_cols["metric"])[li]),)
        )
    return sorted(rows)


class TestTokenRing:
    def test_ranges_partition_the_space(self):
        schema = KeySchema({"a": 5, "b": 3})
        ring = TokenRing.build(schema, ("a", "b"), 5)
        assert ring.n_partitions == 5 and ring.starts[0] == 0
        space = 1 << ring.total_bits
        # contiguous, disjoint, covering
        prev_hi = -1
        for p in range(5):
            lo, hi = ring.token_range(p)
            assert lo == prev_hi + 1 and hi >= lo
            prev_hi = hi
        assert prev_hi == space - 1

    def test_partition_of_tokens_matches_ranges(self):
        schema = KeySchema({"a": 6, "b": 4})
        ring = TokenRing.build(schema, ("a", "b"), 7)
        rng = np.random.default_rng(0)
        tokens = rng.integers(0, 1 << ring.total_bits, 500)
        pids = ring.partition_of_tokens(tokens)
        for t, p in zip(tokens, pids):
            lo, hi = ring.token_range(int(p))
            assert lo <= int(t) <= hi

    def test_rows_route_by_canonical_packing(self):
        """A row's partition is a pure function of its composite key in
        canonical column order — never of any replica layout."""
        schema = KeySchema({"a": 4, "b": 4})
        ring = TokenRing.build(schema, ("a", "b"), 4)
        kc = {"a": np.array([0, 5, 10, 15]), "b": np.array([0, 0, 0, 0])}
        pids = ring.partition_of_tokens(ring.tokens(kc, schema))
        # 8-bit space split in 4: a-value quartiles (b is the low byte)
        np.testing.assert_array_equal(pids, [0, 1, 2, 3])

    def test_span_partitions_pins_and_fans(self):
        schema = KeySchema({"a": 4, "b": 4})
        ring = TokenRing.build(schema, ("a", "b"), 4)
        qs = [
            Query(filters={"a": Eq(5)}),          # one partition
            Query(filters={"a": Range(3, 13)}),   # a span
            Query(filters={"b": Eq(2)}),          # residual: all partitions
            Query(filters={"a": Range(5, 5)}),    # empty slab: clamped home
        ]
        bounds = slab_bounds_many(qs, ("a", "b"), schema)
        p_lo, p_hi = ring.span_partitions(bounds)
        assert p_lo[0] == p_hi[0] == 1  # a=5 → second quartile
        assert (p_lo[1], p_hi[1]) == (0, 3)
        assert (p_lo[2], p_hi[2]) == (0, 3)
        assert p_lo[3] == p_hi[3]  # executes (empty) on one partition

    def test_build_validation(self):
        schema = KeySchema({"a": 2})
        with pytest.raises(ValueError, match="partitions"):
            TokenRing.build(schema, ("a",), 0)
        with pytest.raises(ValueError, match="partitions"):
            TokenRing.build(schema, ("a",), 5)  # 4-token space

    def test_placement_consistent_with_engine(self):
        eng = HREngine(n_nodes=7)
        for rid in range(12):
            assert eng._place(rid, "orders") == place_replica("orders", rid, 7)


class TestMergePartialScans:
    def test_aggregates_add_and_selects_offset(self):
        a = ScanResult(3.0, 10, 3, selected=np.array([0, 2, 5]))
        b = ScanResult(2.0, 4, 2, selected=np.array([1, 3]))
        m = merge_partial_scans([(a, 0), (b, 100)], "select")
        assert m.value == 5.0 and m.rows_scanned == 14 and m.rows_matched == 5
        np.testing.assert_array_equal(m.selected, [0, 2, 5, 101, 103])
        s = merge_partial_scans([(ScanResult(1.5, 7, 4), 0), (ScanResult(2.5, 3, 1), 7)], "sum")
        assert s.value == 4.0 and s.rows_scanned == 10 and s.rows_matched == 5

    def test_does_not_mutate_cached_partials(self):
        sel = np.array([4, 5])
        sel.setflags(write=False)  # as the result cache freezes it
        a = ScanResult(2.0, 2, 2, selected=sel)
        m = merge_partial_scans([(a, 10)], "select")
        np.testing.assert_array_equal(a.selected, [4, 5])
        np.testing.assert_array_equal(m.selected, [14, 15])


class TestP1BitIdentity:
    """partitions=1 must BE the unpartitioned engine: identical replica
    tables, identical commit log content, identical read results and
    select indices, identical placement."""

    def test_tables_and_log_match_direct_construction(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=3)
        eng = _engine(kc, vc, schema, partitions=1)
        cf = eng.column_families["cf"]
        assert cf.ring.n_partitions == 1 and len(cf.partitions) == 1
        assert cf.partitions[0].token_lo == 0
        for slot, r in enumerate(cf.replicas):
            assert r.replica_id == slot and r.partition_id == 0
            direct = SortedTable.from_columns(kc, vc, LAYOUTS[slot], schema)
            t = eng._table(cf, r)
            np.testing.assert_array_equal(t.packed, direct.packed)
            for c in kc:
                np.testing.assert_array_equal(t.key_cols[c], direct.key_cols[c])
            np.testing.assert_array_equal(
                np.asarray(t.value_cols["metric"]),
                np.asarray(direct.value_cols["metric"]),
            )
        (rec,) = cf.commitlog.replay()
        for c in kc:
            np.testing.assert_array_equal(rec.key_cols[c], np.asarray(kc[c]))

    def test_reads_and_selects_match_table_oracle(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=3)
        rng = np.random.default_rng(7)
        eng = _engine(kc, vc, schema, partitions=1)
        cf = eng.column_families["cf"]
        for q in _mixed_queries(rng, schema, n=12):
            res, rep = eng.read("cf", q)
            oracle = eng._table(cf, cf.replicas[rep.replica_id]).execute(q)
            assert res.value == oracle.value
            assert res.rows_scanned == oracle.rows_scanned
            if q.agg == "select":
                np.testing.assert_array_equal(res.selected, oracle.selected)


class TestPartitionedReadEquivalence:
    """THE partitioning acceptance criterion: P ∈ {2, 4} ``read_many``
    equals the P = 1 oracle for sum/count/select on the same dataset."""

    @pytest.mark.parametrize("partitions", [2, 4])
    @pytest.mark.parametrize("seed", [0, 1])
    def test_aggregates_match_p1_oracle(self, partitions, seed):
        kc, vc, schema = generate_simulation(8_000, 3, seed=seed)
        rng = np.random.default_rng(100 + seed)
        qs = _mixed_queries(rng, schema, n=30)
        e1 = _engine(kc, vc, schema, partitions=1)
        ep = _engine(kc, vc, schema, partitions=partitions)
        assert ep.stats["partitions"] == partitions
        for q, (a, _), (b, _) in zip(qs, e1.read_many("cf", qs), ep.read_many("cf", qs)):
            assert b.rows_matched == a.rows_matched, q
            if q.agg == "sum":
                np.testing.assert_allclose(b.value, a.value, rtol=1e-9)
            else:
                assert b.value == a.value
            if q.agg == "select":
                assert b.selected is not None
                assert len(b.selected) == b.rows_matched

    @pytest.mark.parametrize("partitions", [2, 4])
    def test_selected_rows_match_p1_oracle(self, partitions):
        """Select equality at row granularity: the global indices of a
        P-partition select materialize to exactly the rows the P = 1
        oracle selects (RF = 1 pins the serving layout on both sides)."""
        kc, vc, schema = generate_simulation(5_000, 3, seed=5)
        rng = np.random.default_rng(11)
        e1 = _engine(kc, vc, schema, partitions=1, rf=1)
        ep = _engine(kc, vc, schema, partitions=partitions, rf=1)
        qs = [q for q in _mixed_queries(rng, schema, n=30) if q.agg == "select"]
        for q, (a, _), (b, _) in zip(qs, e1.read_many("cf", qs), ep.read_many("cf", qs)):
            assert _selected_rows(ep, "cf", b.selected) == _selected_rows(
                e1, "cf", a.selected
            ), q

    def test_equivalence_survives_writes(self):
        """Routed writes keep the P-partition family equal to the P = 1
        oracle — including rows staged under a group-commit threshold
        (the per-partition flush-on-read barrier)."""
        kc, vc, schema = generate_simulation(6_000, 3, seed=9)
        rng = np.random.default_rng(13)
        e1 = _engine(kc, vc, schema, partitions=1, memtable_rows=1 << 30)
        ep = _engine(kc, vc, schema, partitions=3, memtable_rows=1 << 30)
        qs = _mixed_queries(rng, schema, n=18)
        for _ in range(3):
            bk = {
                c: rng.integers(0, schema.max_value(c) + 1, 200).astype(np.int64)
                for c in ("k0", "k1", "k2")
            }
            bv = {"metric": rng.uniform(0, 1, 200)}
            e1.write("cf", bk, bv)
            ep.write("cf", bk, bv)
        assert ep.stats["staged_rows"] > 0  # really exercising the barrier
        for q, (a, _), (b, _) in zip(qs, e1.read_many("cf", qs), ep.read_many("cf", qs)):
            assert b.rows_matched == a.rows_matched, q
            np.testing.assert_allclose(b.value, a.value, rtol=1e-9)

    def test_single_partition_queries_touch_one_partition(self):
        """A leading-key equality consumes exactly one partition's RR
        draw — the scatter plan really prunes to one replica set."""
        kc, vc, schema = generate_simulation(3_000, 3, seed=1)
        ep = _engine(kc, vc, schema, partitions=4)
        cf = ep.column_families["cf"]
        q = Query(filters={"k0": Eq(1)}, agg="count")
        bounds = slab_bounds_many([q], cf.key_names, cf.schema)
        p_lo, p_hi = cf.ring.span_partitions(bounds)
        assert p_lo[0] == p_hi[0]
        before = [copy.deepcopy(p.rr_counter) for p in cf.partitions]
        ep.read_many("cf", [q])
        after_draws = [
            next(p.rr_counter) - next(b)
            for p, b in zip(cf.partitions, before)
        ]
        assert after_draws[int(p_lo[0])] == 1
        assert all(d == 0 for i, d in enumerate(after_draws) if i != int(p_lo[0]))

    def test_scalar_read_equals_batched(self):
        kc, vc, schema = generate_simulation(3_000, 3, seed=2)
        rng = np.random.default_rng(4)
        qs = _mixed_queries(rng, schema, n=10)
        e_a = _engine(kc, vc, schema, partitions=4)
        e_b = _engine(kc, vc, schema, partitions=4)
        seq = [e_a.read("cf", q) for q in qs]
        bat = e_b.read_many("cf", qs)
        for (rs, rep_s), (rb, rep_b) in zip(seq, bat):
            assert rb.value == rs.value
            assert rb.rows_matched == rs.rows_matched
            assert rep_b.replica_id == rep_s.replica_id

    def test_hedged_partitioned_batch(self):
        from repro.ft.straggler import clear_slowdowns, inject_slowdown

        kc, vc, schema = generate_simulation(3_000, 3, seed=2)
        rng = np.random.default_rng(4)
        qs = _mixed_queries(rng, schema, n=10)
        eng = _engine(kc, vc, schema, partitions=2)
        oracle = _engine(kc, vc, schema, partitions=2)
        victim = eng.column_families["cf"].partitions[0].replicas[0].node_id
        inject_slowdown(eng, victim, 1e4)
        try:
            out = eng.read_many("cf", qs, hedge=True)
            ref = oracle.read_many("cf", qs)
            for (rb, _), (rs, _) in zip(out, ref):
                assert rb.value == rs.value
        finally:
            clear_slowdowns(eng)

    def test_hedge_fires_and_lands_off_straggler(self):
        from repro.ft.straggler import clear_slowdowns, inject_slowdown

        kc, vc, schema = generate_simulation(3_000, 3, seed=2)
        rng = np.random.default_rng(4)
        qs = _mixed_queries(rng, schema, n=24)
        eng = _engine(kc, vc, schema, partitions=2, result_cache=False)
        victim = eng.column_families["cf"].partitions[0].replicas[0].node_id
        inject_slowdown(eng, victim, 1e4)
        try:
            out = eng.read_many("cf", qs, hedge=True, hedge_ratio=1.5)
        finally:
            clear_slowdowns(eng)
        hedged = [rep for _, rep in out if rep.hedged]
        # RR routing must send some of 24 mixed queries to the victim's
        # rows, and a 1e4x straggler always trips a 1.5x hedge ratio
        assert hedged, "no hedge fired against a 1e4x straggler"
        # hedges only fire against the slowed node, the victim hosts
        # exactly one replica (6 nodes, 2x3 replicas), and a cold-cache
        # hedge always beats a 1e4x wall — so every answer, hedged or
        # not, must be served off-victim
        assert all(rep.node_id != victim for _, rep in out)


class TestPartitionedWriteRouting:
    def test_rows_land_in_owning_partition_logs(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=6)
        rng = np.random.default_rng(8)
        eng = _engine(kc, vc, schema, partitions=4)
        cf = eng.column_families["cf"]
        for _ in range(3):
            bk = {
                c: rng.integers(0, schema.max_value(c) + 1, 150).astype(np.int64)
                for c in ("k0", "k1", "k2")
            }
            eng.write("cf", bk, {"metric": rng.uniform(0, 1, 150)})
        total = 0
        for part in cf.partitions:
            kc_p, _ = part.commitlog.replay_columns()
            tokens = cf.ring.tokens(kc_p, cf.schema)
            assert ((tokens >= part.token_lo) & (tokens <= part.token_hi)).all()
            total += part.commitlog.n_rows
        assert total == 4_000 + 3 * 150
        # within a partition every replica holds the same row slice;
        # across partitions the slices are disjoint
        fps = [
            {eng._table(cf, r).dataset_fingerprint() for r in part.replicas}
            for part in cf.partitions
        ]
        assert all(len(s) == 1 for s in fps)
        assert len({next(iter(s)) for s in fps}) == len(cf.partitions)

    def test_threshold_flush_covers_untouched_partitions(self):
        """Group-commit regression: rows deferred in one partition must
        flush once over the staging threshold, even when every later
        write routes to *other* partitions — the threshold check spans
        all live replicas, not just the current write's routed ones."""
        schema = KeySchema({"k0": 4, "k1": 4})
        rng = np.random.default_rng(5)
        kc = {c: rng.integers(0, 16, 600).astype(np.int64) for c in ("k0", "k1")}
        vc = {"metric": rng.uniform(0, 1, 600)}
        eng = HREngine(n_nodes=4, memtable_rows=100)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=[("k0", "k1")],
            schema=schema, partitions=2,
        )
        # 150 rows into partition 0 (k0 < 8), deferred past the threshold
        eng.write(
            "cf",
            {"k0": np.full(150, 2, np.int64), "k1": np.zeros(150, np.int64)},
            {"metric": np.zeros(150)},
            flush=False,
        )
        assert eng.stats["staged_rows"] == 150
        # a later write routed ONLY to partition 1 must still trip the
        # CF-wide threshold and drain partition 0's backlog
        eng.write(
            "cf",
            {"k0": np.full(5, 12, np.int64), "k1": np.zeros(5, np.int64)},
            {"metric": np.zeros(5)},
        )
        assert eng.stats["staged_rows"] == 0

    def test_empty_partition_stays_consistent(self):
        """A partition owning no rows (skewed dataset) serves reads and
        absorbs its first routed write."""
        schema = KeySchema({"k0": 4, "k1": 4})
        n = 800
        rng = np.random.default_rng(3)
        kc = {
            "k0": rng.integers(8, 16, n).astype(np.int64),  # upper half only
            "k1": rng.integers(0, 16, n).astype(np.int64),
        }
        vc = {"metric": rng.uniform(0, 1, n)}
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2,
            layouts=[("k0", "k1"), ("k1", "k0")], schema=schema, partitions=2,
        )
        cf = eng.column_families["cf"]
        # base record exists but carries zero rows
        assert len(cf.partitions[0].commitlog) == 1
        assert cf.partitions[0].n_rows_committed == 0
        assert len(eng._table(cf, cf.partitions[0].replicas[0])) == 0
        q = Query(filters={"k0": Range(0, 16)}, agg="count")
        (res, _), = eng.read_many("cf", [q])
        assert res.value == n
        # first write into the empty partition
        eng.write(
            "cf",
            {"k0": np.array([2, 3]), "k1": np.array([1, 1])},
            {"metric": np.array([0.5, 0.5])},
        )
        (res, _), = eng.read_many("cf", [q])
        assert res.value == n + 2
        (res, _), = eng.read_many(
            "cf", [Query(filters={"k0": Range(0, 8)}, agg="select")]
        )
        assert res.rows_matched == 2 and len(res.selected) == 2


class TestPartitionedFailRecover:
    def _engine_with_writes(self, partitions=4, rf=2, seed=4):
        kc, vc, schema = generate_simulation(5_000, 3, seed=seed)
        rng = np.random.default_rng(seed)
        eng = _engine(kc, vc, schema, partitions=partitions, rf=rf, n_nodes=5)
        for _ in range(3):
            bk = {
                c: rng.integers(0, schema.max_value(c) + 1, 100).astype(np.int64)
                for c in ("k0", "k1", "k2")
            }
            eng.write("cf", bk, {"metric": rng.uniform(0, 1, 100)})
        return eng

    def test_node_loses_only_its_partition_replicas(self):
        eng = self._engine_with_writes()
        cf = eng.column_families["cf"]
        victim = cf.partitions[1].replicas[0].node_id
        hosted = {r.replica_id for r in cf.replicas if r.node_id == victim}
        surviving = {
            r.replica_id: eng._table(cf, r)
            for r in cf.replicas
            if r.node_id != victim
        }
        eng.fail_node(victim)
        assert eng.nodes[victim].tables == {}
        # replicas on other nodes are untouched (same table objects)
        for r in cf.replicas:
            if r.node_id != victim:
                assert eng._table(cf, r) is surviving[r.replica_id]
        # every partition the victim hosted still has a live peer (RF=2)
        for part in cf.partitions:
            lost = [r for r in part.replicas if r.replica_id in hosted]
            live = [r for r in part.replicas if eng.nodes[r.node_id].alive]
            assert len(lost) <= 1 and live

    def test_log_recovery_bit_identical_per_partition(self):
        """THE partition-recovery criterion: log replay rebuilds exactly
        the failed node's partition replicas, each bit-identical to the
        survivor re-sort of its own partition, and touches nothing
        else."""
        eng = self._engine_with_writes()
        cf = eng.column_families["cf"]
        victim = cf.partitions[2].replicas[1].node_id
        e_log, e_sur = copy.deepcopy(eng), copy.deepcopy(eng)
        e_log.fail_node(victim)
        e_log.recover_node(victim, source="log")
        e_sur.fail_node(victim)
        e_sur.recover_node(victim, source="survivor")
        checked = 0
        for part in cf.partitions:
            for r in part.replicas:
                if r.node_id != victim:
                    continue
                t_log = e_log._table(e_log.column_families["cf"], r)
                t_sur = e_sur._table(e_sur.column_families["cf"], r)
                np.testing.assert_array_equal(t_log.packed, t_sur.packed)
                for c in t_log.key_cols:
                    np.testing.assert_array_equal(
                        t_log.key_cols[c], t_sur.key_cols[c]
                    )
                assert t_log.dataset_fingerprint() == t_sur.dataset_fingerprint()
                checked += 1
        assert checked > 0
        # untouched nodes keep their exact table objects through recovery
        cf_log = e_log.column_families["cf"]
        for r in cf_log.replicas:
            if r.node_id != victim:
                assert (
                    e_log._table(cf_log, r)
                    is e_log.nodes[r.node_id].tables[("cf", r.replica_id)]
                )

    def test_recovery_repairs_missed_partition_writes(self):
        """Writes committed while a node is down reach only the live
        partitions' replicas; log recovery repairs the dead node's
        partition slices including those rows."""
        eng = self._engine_with_writes(partitions=4, rf=2)
        cf = eng.column_families["cf"]
        victim = cf.partitions[0].replicas[0].node_id
        eng.fail_node(victim)
        rng = np.random.default_rng(99)
        bk = {
            c: rng.integers(0, schema_max + 1, 120).astype(np.int64)
            for c, schema_max in (
                (c, cf.schema.max_value(c)) for c in ("k0", "k1", "k2")
            )
        }
        eng.write("cf", bk, {"metric": rng.uniform(0, 1, 120)})
        eng.recover_node(victim, source="log")
        for part in cf.partitions:
            fps = {eng._table(cf, r).dataset_fingerprint() for r in part.replicas}
            assert len(fps) == 1

    def test_full_scan_correct_through_fail_recover(self):
        eng = self._engine_with_writes(partitions=4, rf=2)
        cf = eng.column_families["cf"]
        q = Query(filters={}, agg="count")
        (before, _), = eng.read_many("cf", [q])
        victim = cf.partitions[1].replicas[0].node_id
        eng.fail_node(victim)
        (during, _), = eng.read_many("cf", [q])  # routed around per partition
        assert during.value == before.value
        eng.recover_node(victim, source="log")
        (after, _), = eng.read_many("cf", [q])
        assert after.value == before.value


class TestPartitionedDevicePath:
    def test_device_partitioned_matches_host(self):
        kc, vc, schema = generate_simulation(4_000, 3, seed=12)
        rng = np.random.default_rng(21)
        qs = _mixed_queries(rng, schema, n=18)
        host = _engine(kc, vc, schema, partitions=2, rf=2)
        dev = HREngine(n_nodes=6)
        dev.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
            partitions=2, device_resident=True,
        )
        for q, (a, _), (b, _) in zip(qs, host.read_many("cf", qs), dev.read_many("cf", qs)):
            assert b.rows_matched == a.rows_matched, q
            np.testing.assert_allclose(b.value, a.value, rtol=1e-5)
            if q.agg == "select":
                np.testing.assert_array_equal(b.selected, a.selected)

    def test_device_partitioned_write_and_compact(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=12)
        rng = np.random.default_rng(22)
        from repro.core import CompactionPolicy

        dev = HREngine(n_nodes=4, compaction=CompactionPolicy(appended_frac=0.1))
        dev.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
            partitions=2, device_resident=True,
        )
        host = _engine(kc, vc, schema, partitions=2, rf=1, n_nodes=4)
        for _ in range(3):
            bk = {
                c: rng.integers(0, schema.max_value(c) + 1, 300).astype(np.int64)
                for c in ("k0", "k1", "k2")
            }
            bv = {"metric": rng.uniform(0, 1, 300)}
            dev.write("cf", bk, bv)
            host.write("cf", bk, bv)
        assert dev.stats["compactions"] >= 1
        qs = _mixed_queries(rng, schema, n=12)
        for q, (a, _), (b, _) in zip(qs, host.read_many("cf", qs), dev.read_many("cf", qs)):
            assert b.rows_matched == a.rows_matched, q
            np.testing.assert_allclose(b.value, a.value, rtol=1e-5)
            if q.agg == "select":
                np.testing.assert_array_equal(b.selected, a.selected)
