"""Serving front door: continuous batching, admission control,
deadlines, and the degradation ladder.

The acceptance bar: (1) every request ends in exactly one explicit
terminal state — ok / rejected / shed / deadline — and every ok answer
equals the direct-engine oracle; (2) batches form by the continuous-
batching rule (launch at ``max_batch`` or ``max_wait``, in-flight
arrivals join the next batch); (3) each admission guard (queue bound,
token bucket, bulkhead) rejects with RetryAfter instead of queuing
without bound, and each ladder rung (hedge, degrade, shed, deadline)
fires at its threshold and is counted in ``frontdoor.stats``.
"""

import numpy as np
import pytest

from repro.core import ALL, Eq, HREngine, ONE, QUORUM, Query
from repro.core.tpch import generate_simulation
from repro.ft.detector import LatencyEWMA
from repro.serving.admission import Bulkhead, RetryAfter, TokenBucket
from repro.serving.frontdoor import FrontDoor, Request

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _engine(kc, vc, schema, *, partitions=1, rf=3, n_nodes=6, **kw):
    kw.setdefault("result_cache", False)
    eng = HREngine(n_nodes=n_nodes, **kw)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=rf, layouts=LAYOUTS[:rf],
        schema=schema, partitions=partitions,
    )
    return eng


def _requests(rng, schema, n, *, spacing=0.0, **kw):
    hi = schema.max_value("k0") + 1
    return [
        Request(
            "cf",
            Query({"k0": Eq(int(rng.integers(0, hi)))}),
            arrival_s=i * spacing,
            **kw,
        )
        for i in range(n)
    ]


def _accounted(fd, resps):
    """Every submitted request reached exactly one terminal state."""
    by = {s: sum(1 for r in resps if r.status == s) for s in
          ("ok", "rejected", "shed", "deadline")}
    s = fd.stats
    assert by["ok"] == s["served_ok"]
    assert by["rejected"] == (
        s["rejected_throttle"] + s["rejected_bulkhead"] + s["rejected_queue_full"]
    )
    assert by["shed"] == s["shed_overload"]
    assert by["deadline"] == s["shed_deadline"]
    assert sum(by.values()) == len(resps) == s["submitted"]


class TestAdmissionPrimitives:
    def test_token_bucket_burst_then_rate(self):
        tb = TokenBucket(rate=10.0, burst=3.0)
        for _ in range(3):
            tb.admit(0.0)
        with pytest.raises(RetryAfter) as e:
            tb.admit(0.0)
        assert e.value.retry_after_s == pytest.approx(0.1)
        tb.admit(0.1)  # one token refilled
        with pytest.raises(RetryAfter):
            tb.admit(0.1)

    def test_token_bucket_clock_never_runs_backwards(self):
        tb = TokenBucket(rate=1.0, burst=1.0)
        tb.admit(10.0)
        with pytest.raises(RetryAfter):
            tb.admit(0.0)  # earlier time must not mint tokens

    def test_bulkhead_isolates_compartments(self):
        bh = Bulkhead(2, retry_after_s=0.5)
        bh.acquire("hot")
        bh.acquire("hot")
        with pytest.raises(RetryAfter):
            bh.acquire("hot")
        bh.acquire("cold")  # other compartment unaffected
        bh.release("hot")
        bh.acquire("hot")
        with pytest.raises(RuntimeError):
            bh.release("absent")

    def test_latency_ewma_tracks_mean_and_spread(self):
        ew = LatencyEWMA(alpha=0.5)
        assert ew.mean() == 0.0 and ew.count == 0
        for x in (1.0, 1.0, 1.0):
            ew.record(x)
        assert ew.mean() == pytest.approx(1.0)
        assert ew.deviation() == pytest.approx(0.0, abs=1e-12)
        ew.record(3.0)
        assert 1.0 < ew.mean() < 3.0
        assert ew.deviation() > 0.0

    def test_constructor_validation(self):
        with pytest.raises(ValueError):
            TokenBucket(rate=0.0, burst=1.0)
        with pytest.raises(ValueError):
            Bulkhead(0, retry_after_s=1.0)
        with pytest.raises(ValueError):
            LatencyEWMA(alpha=0.0)
        with pytest.raises(ValueError):
            Request("cf", Query({}), consistency="MOST")
        eng = object.__new__(HREngine)  # no engine needed to validate knobs
        with pytest.raises(ValueError):
            FrontDoor(eng, max_batch=0)
        with pytest.raises(ValueError):
            FrontDoor(eng, max_batch=8, max_queue=4)
        with pytest.raises(ValueError):
            FrontDoor(eng, shed_fill=0.0)


class TestContinuousBatching:
    def test_ok_answers_match_direct_engine(self, rng):
        kc, vc, schema = generate_simulation(3_000, 3, seed=0)
        eng = _engine(kc, vc, schema, partitions=4)
        fd = FrontDoor(eng, max_batch=8, max_wait=1e-3, max_queue=64)
        reqs = _requests(rng, schema, 30, spacing=2e-4)
        resps = fd.serve(reqs)
        assert all(r.ok for r in resps)
        for req, r in zip(reqs, resps):
            oracle, _ = eng.read("cf", req.query)
            assert r.result.value == oracle.value
        _accounted(fd, resps)

    def test_batch_launches_when_full(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(eng, max_batch=4, max_wait=10.0, max_queue=64)
        # all at t=0 with a huge max_wait: only the size trigger can
        # launch, so 12 requests must form exactly 3 full batches
        resps = fd.serve(_requests(rng, schema, 12))
        assert all(r.ok for r in resps)
        assert fd.stats["batches"] == 3
        assert all(r.queue_wait_s < 10.0 for r in resps)

    def test_batch_launches_on_max_wait_timer(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(eng, max_batch=64, max_wait=5e-3, max_queue=64)
        # far fewer than max_batch: only the timer can launch
        resps = fd.serve(_requests(rng, schema, 3))
        assert all(r.ok for r in resps)
        assert fd.stats["batches"] == 1
        assert all(r.queue_wait_s == pytest.approx(5e-3) for r in resps)

    def test_inflight_arrivals_join_next_batch(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(eng, max_batch=4, max_wait=1e-4, max_queue=64)
        # burst fills batch 1 at t=0; the rest arrive while it is in
        # flight (real scan walls >> 1us spacing) and must coalesce
        # into later batches, never expand the in-flight one
        reqs = _requests(rng, schema, 4) + _requests(rng, schema, 4, spacing=1e-6)
        resps = fd.serve(reqs)
        assert all(r.ok for r in resps)
        assert 2 <= fd.stats["batches"] <= 3
        _accounted(fd, resps)

    def test_empty_input(self, rng):
        kc, vc, schema = generate_simulation(500, 3, seed=0)
        eng = _engine(kc, vc, schema)
        assert FrontDoor(eng).serve([]) == []


class TestAdmissionGuards:
    def test_queue_bound_rejects_with_backpressure(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(eng, max_batch=4, max_wait=10.0, max_queue=8)
        resps = fd.serve(_requests(rng, schema, 20))
        s = fd.stats
        assert s["rejected_queue_full"] > 0
        assert s["max_queue_depth"] <= 8  # the bound really bounds
        rejected = [r for r in resps if r.status == "rejected"]
        assert all(r.retry_after_s > 0.0 for r in rejected)
        _accounted(fd, resps)

    def test_token_bucket_throttles_offered_rate(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(
            eng, max_batch=4, max_wait=1e-3, max_queue=64,
            rate=100.0, burst=2.0,
        )
        # 10 arrivals in 1ms >> 100/s: burst admits 2, the rest throttle
        resps = fd.serve(_requests(rng, schema, 10, spacing=1e-4))
        assert fd.stats["rejected_throttle"] == 10 - fd.stats["admitted"]
        assert fd.stats["rejected_throttle"] >= 6
        _accounted(fd, resps)

    def test_bulkhead_keeps_hot_cf_from_starving_cold(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        kc2, vc2, schema2 = generate_simulation(1_000, 3, seed=1)
        eng.create_column_family(
            "cold", kc2, vc2, replication_factor=3, layouts=LAYOUTS,
            schema=schema2, partitions=1,
        )
        fd = FrontDoor(
            eng, max_batch=32, max_wait=10.0, max_queue=64,
            bulkhead_inflight=3,
        )
        hot = _requests(rng, schema, 10)
        cold = [
            Request("cold", Query({"k0": Eq(int(rng.integers(0, 4)))}))
            for _ in range(3)
        ]
        resps = fd.serve(hot + cold)
        # the hot CF fills its own compartment and overflows...
        assert fd.stats["rejected_bulkhead"] == 10 - 3
        # ...while every cold-CF request keeps its slot
        assert all(r.ok for r in resps[10:])
        _accounted(fd, resps)


class TestDegradationLadder:
    def test_priority_shed_drops_lowest_first(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(
            eng, max_batch=4, max_wait=10.0, max_queue=16, shed_fill=0.5,
        )
        # 0..15 all queued at t=0 (fill 16 > trigger 8): the shed rung
        # must sacrifice exactly the low-priority tail, never the VIPs
        reqs = _requests(rng, schema, 8, priority=1) + _requests(
            rng, schema, 8, priority=0
        )
        resps = fd.serve(reqs)
        assert fd.stats["shed_overload"] > 0
        assert all(r.ok for r in resps[:8])  # every VIP answered
        assert all(r.status == "shed" for r in resps if not r.ok)
        _accounted(fd, resps)

    def test_degrades_quorum_to_one_under_pressure_and_recovers(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(
            eng, max_batch=4, max_wait=1e-6, max_queue=256,
            degrade_wait_factor=1.0,
        )
        # a t=0 burst makes every post-first batch's oldest wait exceed
        # degrade_after (scan walls >> 1us), then a lone late straggler
        # arrives against an empty queue and must be served undegraded
        burst = _requests(rng, schema, 24, consistency=QUORUM)
        late = _requests(rng, schema, 1, consistency=QUORUM)[0]
        late = Request(
            late.cf_name, late.query, arrival_s=1e9, consistency=QUORUM
        )
        resps = fd.serve(burst + [late])
        s = fd.stats
        assert s["consistency_degraded"] > 0
        assert s["degraded_batches"] > 0
        assert s["degrade_recoveries"] >= 1  # the ladder stepped back down
        degraded = [r for r in resps[:-1] if r.degraded]
        assert degraded and all(
            r.consistency_used == ONE and r.ok for r in degraded
        )
        assert resps[-1].ok and not resps[-1].degraded
        assert resps[-1].consistency_used == QUORUM
        _accounted(fd, resps)

    def test_hedges_fire_from_queue_wait_ewma(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(
            eng, max_batch=4, max_wait=1e-6, max_queue=256,
            hedge_wait_factor=1.0, ewma_warmup=4,
        )
        resps = fd.serve(_requests(rng, schema, 32))
        # sustained queue wait >> max_wait: once the EWMA warms up the
        # hedge rung must engage (batches 1..warmup can't hedge yet)
        assert fd.stats["hedged_batches"] > 0
        assert all(r.ok for r in resps)
        _accounted(fd, resps)

    def test_spent_deadline_sheds_explicitly(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(eng, max_batch=8, max_wait=1e-3, max_queue=64)
        reqs = _requests(rng, schema, 4, deadline_s=1e-12) + _requests(
            rng, schema, 4, deadline_s=1e3
        )
        resps = fd.serve(reqs)
        # an un-meetable budget is a typed refusal, not a slow answer
        assert all(r.status == "deadline" for r in resps[:4])
        assert all("deadline" in r.error for r in resps[:4])
        assert all(r.ok for r in resps[4:])
        assert fd.stats["shed_deadline"] == 4
        _accounted(fd, resps)

    def test_timeline_callbacks_fire_in_virtual_time(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=0)
        eng = _engine(kc, vc, schema)
        fd = FrontDoor(eng, max_batch=2, max_wait=1e-3, max_queue=64)
        fired: list[float] = []
        reqs = _requests(rng, schema, 4, spacing=1.0)  # t = 0, 1, 2, 3
        resps = fd.serve(
            reqs, timeline=[(2.5, lambda: fired.append(2.5)),
                            (0.5, lambda: fired.append(0.5))],
        )
        assert fired == [0.5, 2.5]  # sorted, each exactly once
        assert all(r.ok for r in resps)
