"""Observability layer: metrics registry, span trees, exports, and the
coverage/determinism contracts.

Four properties anchor this file:

1. **Counter coverage** — every typed refusal (``RetryAfter`` kinds,
   ``DeadlineExceeded``), engine fault, degradation rung, and repair
   path named in the audit inventories (``ENGINE_COUNTERS`` /
   ``FAULT_COUNTERS`` / ``REPAIR_COUNTERS`` on the engine,
   ``FRONTDOOR_COUNTERS`` / ``REFUSAL_COUNTERS`` / ``RUNG_COUNTERS``
   on the front door) resolves to a live registry metric, and the
   provokable ones actually increment.
2. **Span-tree integrity** — parent/child links are consistent, no
   span is orphaned from its tracer's roots, timestamps are monotone
   under a ``TickClock``, and success paths close every span.
3. **Acceptance** — a traced QUORUM request through the front door
   over a device-resident column family yields ONE tree from
   ``frontdoor.request`` down to ``kernel.scan_launch``, whose
   frontdoor stage walls sum to the client-observed latency.
4. **Determinism** — two runs of the same seeded chaos schedule with
   ``TickClock`` tracers export byte-identical JSON-lines dumps.
"""

import io

import numpy as np
import pytest

from repro.core import (
    DeadlineExceeded,
    Eq,
    HREngine,
    QUORUM,
    Query,
    TransientFault,
)
from repro.core.engine import (
    ENGINE_COUNTERS,
    FAULT_COUNTERS,
    REPAIR_COUNTERS,
    VIEW_COUNTERS,
)
from repro.core.tpch import generate_simulation
from repro.ft.chaos import ChaosHarness
from repro.obs import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    SlowQueryLog,
    TickClock,
    Tracer,
    dump_jsonl,
    format_tree,
    load_jsonl,
    span_to_line,
    stage_totals,
    walk,
)
from repro.obs.__main__ import main as obs_main
from repro.serving.frontdoor import (
    FRONTDOOR_COUNTERS,
    FrontDoor,
    REFUSAL_COUNTERS,
    Request,
    RUNG_COUNTERS,
)

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]
_CF = "cf"


def _engine(n_rows=512, *, device_resident=False, partitions=1, views=False, **kw):
    kc, vc, schema = generate_simulation(n_rows, 3, seed=0)
    kw.setdefault("result_cache", False)
    eng = HREngine(n_nodes=6, **kw)
    eng.create_column_family(
        _CF, kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        partitions=partitions, device_resident=device_resident, views=views,
    )
    return eng, schema


# -- metrics primitives ------------------------------------------------------


class TestMetrics:
    def test_counter_inc_reset(self):
        c = Counter("c")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        c.reset()
        assert c.value == 0.0

    def test_gauge_set_and_high_water(self):
        g = Gauge("g")
        g.set(4.0)
        g.max(2.0)
        assert g.value == 4.0
        g.max(9.0)
        assert g.value == 9.0

    def test_histogram_quantiles_bracket_the_data(self):
        h = Histogram("h")
        data = np.random.default_rng(0).uniform(1e-4, 1e-1, 2000)
        for v in data:
            h.observe(float(v))
        snap = h.snapshot()
        assert snap["count"] == 2000
        assert snap["sum"] == pytest.approx(float(data.sum()), rel=1e-9)
        assert snap["max"] == pytest.approx(float(data.max()))
        # log-bucketed quantiles are bucket upper bounds: conservative
        # (>= the true quantile) but within one bucket (~9%) of it
        for q, name in ((0.5, "p50"), (0.95, "p95"), (0.99, "p99")):
            true = float(np.quantile(data, q))
            assert true <= snap[name] <= min(true * 1.15, snap["max"])

    def test_histogram_nonpositive_goes_to_zero_bucket(self):
        h = Histogram("h")
        h.observe(0.0)
        h.observe(-1.0)
        assert h.count == 2
        assert h.quantile(0.5) == 0.0

    def test_registry_get_or_create_and_kind_conflict(self):
        reg = MetricsRegistry()
        c = reg.counter("x")
        assert reg.counter("x") is c
        with pytest.raises(TypeError):
            reg.gauge("x")
        assert "x" in reg
        assert reg.catalog() == ("x",)

    def test_registry_as_dict_explodes_histograms(self):
        reg = MetricsRegistry()
        reg.counter("a").inc(2)
        reg.histogram("lat").observe(0.5)
        d = reg.as_dict()
        assert d["a"] == 2.0
        assert {"lat.count", "lat.p50", "lat.p95", "lat.p99"} <= set(d)

    def test_registry_reset_keeps_handles_live(self):
        reg = MetricsRegistry()
        c = reg.counter("a")
        c.inc(5)
        reg.reset()
        assert c.value == 0.0
        c.inc()
        assert reg.value("a") == 1.0


# -- counter coverage audits -------------------------------------------------


class TestCounterCoverage:
    def test_engine_inventories_resolve_in_registry(self):
        eng, _ = _engine(64)
        cat = set(eng.metrics.catalog())
        for name in ENGINE_COUNTERS:
            assert name in cat, f"ENGINE_COUNTERS[{name!r}] not registered"
        for exc_name, counter in FAULT_COUNTERS.items():
            assert counter in cat, f"{exc_name} has no registry counter"
            assert counter in ENGINE_COUNTERS
        for counter in REPAIR_COUNTERS:
            assert counter in cat
            assert counter in ENGINE_COUNTERS
        for counter in VIEW_COUNTERS:
            assert counter in cat, f"VIEW_COUNTERS[{counter!r}] not registered"
            assert counter in ENGINE_COUNTERS
        # the stats view exposes every engine counter
        stats = eng.stats
        for name in ENGINE_COUNTERS:
            assert name in stats

    def test_frontdoor_inventories_resolve_in_registry(self):
        eng, _ = _engine(64)
        fd = FrontDoor(eng)
        cat = set(fd.metrics.catalog())
        for name in FRONTDOOR_COUNTERS:
            assert name in cat
        for counter in (*REFUSAL_COUNTERS.values(), *RUNG_COUNTERS.values()):
            assert counter in cat
            assert counter in FRONTDOOR_COUNTERS
        # exact public stats key set: the pre-registry dict, unchanged
        assert set(fd.stats) == set(FRONTDOOR_COUNTERS) | {"max_queue_depth"}

    def test_deadline_exceeded_increments_counter(self):
        eng, schema = _engine(256)
        q = Query({"k0": Eq(1)})
        before = eng.stats["deadline_exceeded"]
        with pytest.raises(DeadlineExceeded):
            eng.read_many(_CF, [q], deadline_s=0.0)
        assert eng.stats["deadline_exceeded"] == before + 1

    def test_read_fault_increments_counter(self):
        eng, _ = _engine(256)
        # every node faults its first scan: all three replicas raise
        # TransientReadError, each incrementing the counter before the
        # failover gives up
        for node in eng.nodes:
            node.read_fault_budget = 1
        with pytest.raises(RuntimeError, match="no live replica answered"):
            eng.read(_CF, Query({"k0": Eq(1)}))
        assert eng.stats["read_faults"] == 3
        assert eng.stats["read_retries"] == 3

    def test_flush_fault_increments_counter(self):
        eng, _ = _engine(256)
        for node in eng.nodes:
            node.flush_fault_budget = 99
        kc = {c: np.array([1], np.int64) for c in ("k0", "k1", "k2")}
        vc = {"metric": np.array([0.5])}
        with pytest.raises(TransientFault):
            eng.write(_CF, kc, vc)
        assert eng.stats["flush_faults"] >= 1

    def test_frontdoor_refusals_increment_their_counters(self):
        eng, schema = _engine(256)
        # queue bound: max_queue arrivals at t=0 fill it, the rest refuse
        fd = FrontDoor(eng, max_batch=4, max_queue=4, shed_fill=1.0)
        reqs = [Request(_CF, Query({"k0": Eq(i % 4)})) for i in range(7)]
        fd.serve(reqs)
        assert fd.stats["rejected_queue_full"] == 3
        # token bucket: burst of 2 at one instant, third arrival refused
        fd = FrontDoor(eng, rate=10.0, burst=2.0)
        fd.serve([Request(_CF, Query({"k0": Eq(0)})) for _ in range(3)])
        assert fd.stats["rejected_throttle"] == 1
        # deadline rung: a budget of zero is spent on arrival
        fd = FrontDoor(eng, max_wait=1e-3)
        resps = fd.serve([Request(_CF, Query({"k0": Eq(0)}), deadline_s=0.0)])
        assert resps[0].status == "deadline"
        assert fd.stats["shed_deadline"] == 1

    def test_reset_stats_on_engine_and_frontdoor(self):
        eng, _ = _engine(256, result_cache=True)
        eng.read(_CF, Query({"k0": Eq(1)}))
        assert eng.stats["result_cache_misses"] > 0
        eng.reset_stats()
        assert eng.stats["result_cache_misses"] == 0
        assert eng.stats["result_cache_hits"] == 0

        fd = FrontDoor(eng)
        fd.serve([Request(_CF, Query({"k0": Eq(1)}))])
        assert fd.stats["submitted"] == 1
        assert fd.stats["max_queue_depth"] == 1
        fd.reset_stats()
        assert fd.stats["submitted"] == 0
        assert fd.stats["max_queue_depth"] == 0


# -- span trees --------------------------------------------------------------


def _assert_tree_integrity(tracer):
    """Parent links, unique ids, no orphans, closed spans."""
    seen = []
    for root in tracer.roots:
        assert root.parent_id is None
        for s in walk(root):
            seen.append(s.span_id)
            for c in s.children:
                assert c.parent_id == s.span_id
                assert c.t_start >= s.t_start
    assert len(seen) == len(set(seen)), "span ids must be unique"
    assert len(seen) == tracer.spans_started, "orphaned spans exist"


class TestSpanTrees:
    def test_traced_read_many_integrity_and_monotone_ticks(self):
        eng, _ = _engine(512, partitions=4)
        tracer = Tracer(clock=TickClock())
        root = tracer.root("test.root")
        qs = [Query({"k0": Eq(i)}) for i in range(6)]
        eng.read_many(_CF, qs, consistency=QUORUM, trace=root)
        root.end()
        _assert_tree_integrity(tracer)
        for s in walk(root):
            assert s.t_end is not None, f"{s.name} left open on success path"
            assert s.t_end >= s.t_start
        names = {s.name for s in walk(root)}
        # the partitioned path ranks replicas per partition, so no
        # top-level engine.plan appears (the acceptance test covers it)
        assert {"engine.read_many", "engine.scatter", "engine.partition",
                "engine.group_scan", "engine.gather"} <= names

    def test_traced_write_path_reaches_flush(self):
        eng, _ = _engine(256)
        tracer = Tracer(clock=TickClock())
        root = tracer.root("test.root")
        kc = {c: np.arange(4, dtype=np.int64) for c in ("k0", "k1", "k2")}
        vc = {"metric": np.ones(4)}
        eng.write(_CF, kc, vc, trace=root)
        root.end()
        _assert_tree_integrity(tracer)
        names = {s.name for s in walk(root)}
        assert {"engine.write", "engine.log_append", "engine.memtable_stage",
                "engine.flush", "engine.flush_merge"} <= names

    def test_error_spans_carry_error_attr(self):
        eng, _ = _engine(256)
        for node in eng.nodes:
            node.read_fault_budget = 1
        tracer = Tracer(clock=TickClock())
        root = tracer.root("test.root")
        with pytest.raises(RuntimeError):
            eng.read_many(_CF, [Query({"k0": Eq(1)})], trace=root)
        root.end()
        # the faulting group scans record which exception killed them,
        # and the finally still closes the read_many span
        rm = root.find("engine.read_many")
        assert rm is not None and rm.t_end is not None
        errs = [s.attrs.get("error") for s in root.find_all("engine.group_scan")]
        assert "TransientReadError" in errs


# -- acceptance: one tree, kernel depth, walls sum to latency ----------------


class TestFrontDoorAcceptance:
    def test_single_quorum_request_one_tree_to_kernel_depth(self):
        eng, _ = _engine(256, device_resident=True)
        fd = FrontDoor(eng, max_batch=4, max_wait=1e-4, tracer=Tracer())
        resps = fd.serve(
            [Request(_CF, Query({"k0": Eq(3)}), consistency=QUORUM)]
        )
        assert resps[0].ok
        roots = fd.tracer.roots
        # ONE tree: the sole group member parents the engine subtree
        # under its own service span, no frontdoor.batch root appears
        assert [r.name for r in roots] == ["frontdoor.request"]
        root = roots[0]
        names = [s.name for s in walk(root)]
        assert "kernel.scan_launch" in names
        assert "engine.read_many" in names
        assert "engine.digest" in names
        # frontdoor stage walls (virtual clock) sum to the latency the
        # client observed, exactly the decomposition the tree promises
        q = root.find("frontdoor.queue")
        s = root.find("frontdoor.service")
        total = q.wall + s.wall
        assert total == pytest.approx(resps[0].latency_s, rel=1e-9)
        assert root.wall == pytest.approx(resps[0].latency_s, rel=1e-9)
        assert root.attrs["status"] == "ok"
        # the completed tree landed in the slow-query log
        entries = fd.slow_log.entries()
        assert len(entries) == 1
        assert entries[0][1] is root

    def test_multi_request_batch_links_members_to_batch_root(self):
        eng, _ = _engine(512)
        fd = FrontDoor(eng, max_batch=4, max_wait=1e-3, tracer=Tracer())
        reqs = [
            Request(_CF, Query({"k0": Eq(i)}), arrival_s=i * 1e-5)
            for i in range(3)
        ]
        resps = fd.serve(reqs)
        assert all(r.ok for r in resps)
        roots = fd.tracer.roots
        batch_roots = [r for r in roots if r.name == "frontdoor.batch"]
        req_roots = [r for r in roots if r.name == "frontdoor.request"]
        assert len(batch_roots) == 1 and len(req_roots) == 3
        bid = batch_roots[0].span_id
        for r in req_roots:
            svc = r.find("frontdoor.service")
            assert svc is not None and svc.attrs["batch"] == bid
        assert batch_roots[0].find("engine.read_many") is not None
        _assert_tree_integrity(fd.tracer)


# -- exports -----------------------------------------------------------------


class TestExport:
    def _tree(self):
        tr = Tracer(clock=TickClock())
        root = tr.root("a")
        root.child("b").end()
        root.end()
        return root

    def test_slow_query_log_keeps_k_slowest(self):
        log = SlowQueryLog(2)
        spans = []
        for i, lat in enumerate((0.3, 0.1, 0.5, 0.2)):
            s = self._tree()
            spans.append(s)
            log.offer(s, latency=lat)
        got = log.entries()
        assert [lat for lat, _ in got] == [0.5, 0.3]

    def test_jsonl_round_trip_and_determinism(self):
        root = self._tree()
        line = span_to_line(root, latency=1.5)
        assert line == span_to_line(root, latency=1.5)
        buf = io.StringIO()
        n = dump_jsonl([(1.5, root), root], buf)
        assert n == 2
        docs = load_jsonl(io.StringIO(buf.getvalue()))
        assert docs[0]["latency"] == 1.5
        assert docs[0]["tree"]["name"] == "a"
        assert docs[1]["name"] == "a"

    def test_load_jsonl_rejects_malformed(self):
        with pytest.raises(ValueError, match="line 1"):
            load_jsonl(io.StringIO("{not json\n"))
        with pytest.raises(ValueError):
            load_jsonl(io.StringIO('{"no_name": 1}\n'))

    def test_stage_totals_and_format_tree(self):
        root = self._tree()
        totals = stage_totals([root])
        assert totals["a"]["count"] == 1
        assert "a" in format_tree(root, unit="ticks")

    def test_report_cli(self, tmp_path):
        out = tmp_path / "t.jsonl"
        dump_jsonl([self._tree()], str(out))
        assert obs_main([str(out), "--unit", "ticks"]) == 0
        empty = tmp_path / "empty.jsonl"
        empty.write_text("")
        assert obs_main([str(empty)]) == 1
        bad = tmp_path / "bad.jsonl"
        bad.write_text("{nope\n")
        assert obs_main([str(bad)]) == 1


# -- determinism: byte-identical chaos traces --------------------------------


class TestChaosTraceDeterminism:
    def _run(self):
        tracer = Tracer(clock=TickClock())
        harness = ChaosHarness(
            seed=3, n_steps=8, n_rows=400, write_rows=40, n_probes=3,
            probe_every=3, memtable_rows=120, tracer=tracer,
        )
        report = harness.run()
        assert report.ok, report.failures
        buf = io.StringIO()
        dump_jsonl(tracer.roots, buf)
        return buf.getvalue()

    def test_same_seed_same_bytes(self):
        a = self._run()
        b = self._run()
        assert a, "traced chaos run exported no span trees"
        assert a == b, "same seeded schedule must export identical traces"


# -- materialized views: span stages + counters ------------------------------


class TestViewObservability:
    def test_view_serve_span_and_counters(self):
        eng, _ = _engine(512, device_resident=True, views=True)
        tracer = Tracer(clock=TickClock())
        root = tracer.root("test.root")
        # no filters → view-eligible on every layout
        q = Query({}, agg="sum", value_col="metric")
        eng.read_many(_CF, [q], trace=root)
        root.end()
        _assert_tree_integrity(tracer)
        sv = root.find("view.serve")
        assert sv is not None and sv.t_end is not None
        assert sv.attrs["queries"] == 1
        assert "boundary_rows" in sv.attrs
        assert eng.stats["view_hits"] == 1
        assert eng.stats["view_boundary_rows"] == sv.attrs["boundary_rows"]

    def test_view_build_span_on_flush(self):
        eng, _ = _engine(512, device_resident=True, views=True)
        tracer = Tracer(clock=TickClock())
        root = tracer.root("test.root")
        kc = {c: np.arange(6, dtype=np.int64) for c in ("k0", "k1", "k2")}
        vc = {"metric": np.ones(6)}
        eng.write(_CF, kc, vc, trace=root)
        root.end()
        _assert_tree_integrity(tracer)
        builds = root.find_all("view.build")
        assert builds, "write-through flush must record view.build spans"
        for s in builds:
            assert s.attrs.get("incremental") is True
            assert s.attrs["rows"] == 6
        # incremental extensions are NOT rebuilds
        assert eng.stats["view_rebuilds"] == 0

    def test_non_view_engine_emits_no_view_stages(self):
        eng, _ = _engine(512, device_resident=True)
        tracer = Tracer(clock=TickClock())
        root = tracer.root("test.root")
        eng.read_many(_CF, [Query({}, agg="sum", value_col="metric")],
                      trace=root)
        root.end()
        assert root.find("view.serve") is None
        assert eng.stats["view_hits"] == 0
