"""Unit tests for model components: flash attention vs naive, RoPE, SSD."""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, flash_attention, rmsnorm


def naive_attention(q, k, v, causal=True, window=0, scale=None):
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    sc = scale or 1.0 / math.sqrt(D)
    qr = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32), k.astype(jnp.float32)) * sc
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p, v.astype(jnp.float32))
    return jnp.transpose(o, (0, 3, 1, 2, 4)).reshape(B, S, H, v.shape[-1])


class TestFlashAttention:
    @pytest.mark.parametrize("S,qc,kc", [(16, 4, 4), (33, 8, 16), (64, 64, 64), (17, 5, 3)])
    @pytest.mark.parametrize("G", [1, 4])
    def test_matches_naive_causal(self, rng, S, qc, kc, G):
        B, Hkv, D = 2, 2, 8
        q = jnp.asarray(rng.normal(0, 1, (B, S, Hkv * G, D)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, S, Hkv, D)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        got = flash_attention(q, k, v, pos, pos, causal=True, q_chunk=qc, kv_chunk=kc)
        want = naive_attention(q, k, v)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("window", [1, 4, 9])
    def test_sliding_window(self, rng, window):
        B, S, H, D = 1, 24, 2, 8
        q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        got = flash_attention(q, k, v, pos, pos, causal=True, window=window,
                              q_chunk=8, kv_chunk=8)
        want = naive_attention(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)

    def test_traced_window_equals_static(self, rng):
        B, S, H, D = 1, 16, 2, 4
        q = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        kv = jnp.asarray(rng.normal(0, 1, (B, S, H, D)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        a = flash_attention(q, kv, kv, pos, pos, window=5, q_chunk=4, kv_chunk=4)
        b = flash_attention(q, kv, kv, pos, pos, window=jnp.int32(5), q_chunk=4, kv_chunk=4)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


class TestRoPE:
    def test_preserves_norm(self, rng):
        x = jnp.asarray(rng.normal(0, 1, (2, 8, 4, 16)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(8, dtype=jnp.int32), (2, 8))
        y = apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )

    def test_relative_property(self, rng):
        """<rope(q,m), rope(k,n)> depends only on m−n."""
        q = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 8)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (1, 1, 1, 8)), jnp.float32)

        def dot(m, n):
            qm = apply_rope(q, jnp.full((1, 1), m, jnp.int32), 100.0)
            kn = apply_rope(k, jnp.full((1, 1), n, jnp.int32), 100.0)
            return float(jnp.sum(qm * kn))

        assert abs(dot(3, 1) - dot(7, 5)) < 1e-4
        assert abs(dot(10, 10) - dot(0, 0)) < 1e-4

    def test_partial_rotary_untouched_dims(self, rng):
        x = jnp.asarray(rng.normal(0, 1, (1, 4, 2, 16)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(4, dtype=jnp.int32), (1, 4))
        y = apply_rope(x, pos, 10_000.0, rotary_pct=0.5)
        np.testing.assert_array_equal(np.asarray(x[..., 8:]), np.asarray(y[..., 8:]))
        assert not np.allclose(np.asarray(x[..., :8]), np.asarray(y[..., :8]))


class TestSSD:
    def _cfg(self):
        return ArchConfig(
            name="t", family="ssm", n_layers=1, d_model=32, n_heads=0, n_kv_heads=0,
            d_ff=0, vocab_size=16, attention="none", ssm_state=8, ssm_head_dim=8,
            ssm_expand=2, ssm_conv=4, ssm_chunk=4, dtype="float32",
        )

    def test_chunk_size_invariance(self, rng):
        cfg = self._cfg()
        p = ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (2, 16, 32)), jnp.float32)
        y1 = ssm_mod.ssm_forward(p, x, cfg, 1, chunk=1)
        y4 = ssm_mod.ssm_forward(p, x, cfg, 1, chunk=4)
        y16 = ssm_mod.ssm_forward(p, x, cfg, 1, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y4), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y16), rtol=1e-4, atol=1e-5)

    def test_decode_matches_forward(self, rng):
        cfg = self._cfg()
        p = ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
        B, T = 2, 12
        x = jnp.asarray(rng.normal(0, 1, (B, T, 32)), jnp.float32)
        y_full = ssm_mod.ssm_forward(p, x, cfg, 1, chunk=4)
        st = ssm_mod.init_ssm_state(cfg, 1, B, jnp.float32)
        ys = []
        for t in range(T):
            y, st = ssm_mod.ssm_decode(p, x[:, t : t + 1], st, cfg, 1)
            ys.append(y)
        y_dec = jnp.concatenate(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_dec), rtol=1e-4, atol=1e-5)

    def test_causality(self, rng):
        """Future tokens cannot change past outputs."""
        cfg = self._cfg()
        p = ssm_mod.init_ssm(jax.random.PRNGKey(0), cfg, 1, jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (1, 8, 32)), jnp.float32)
        y1 = ssm_mod.ssm_forward(p, x, cfg, 1, chunk=4)
        x2 = x.at[0, 6].set(99.0)
        y2 = ssm_mod.ssm_forward(p, x2, cfg, 1, chunk=4)
        np.testing.assert_allclose(
            np.asarray(y1[:, :6]), np.asarray(y2[:, :6]), rtol=1e-5, atol=1e-6
        )


def test_rmsnorm_scale_and_dtype(rng):
    x = jnp.asarray(rng.normal(0, 3, (4, 16)), jnp.bfloat16)
    y = rmsnorm(x, jnp.ones(16, jnp.float32))
    assert y.dtype == jnp.bfloat16
    norm = np.linalg.norm(np.asarray(y, np.float32), axis=-1) / np.sqrt(16)
    np.testing.assert_allclose(norm, 1.0, rtol=0.05)
