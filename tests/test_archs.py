"""Per-architecture smoke tests (reduced configs, CPU, 1 device):
forward/train step shape + finiteness, prefill→decode consistency.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch, get_smoke
from repro.models import lm
from repro.parallel.padding import padded_dims, padding_report
from repro.training.optimizer import OptConfig, init_opt, opt_update
from repro.training.steps import TrainSettings, make_train_step


def _batch(cfg, rng, B=2, S=32):
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    else:
        batch["embeds"] = jnp.asarray(rng.normal(0, 1, (B, S, cfg.d_model)), jnp.float32)
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, S)
    batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_loss_finite(self, arch, rng):
        cfg = get_smoke(arch)
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        batch = _batch(cfg, rng)
        loss, metrics = lm.forward_train(params, batch, cfg, None, remat="none",
                                         q_chunk=16, kv_chunk=16)
        assert jnp.isfinite(loss)
        # random-init loss ≈ ln(vocab)
        assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 1.5

    def test_train_step_updates_params(self, arch, rng):
        cfg = get_smoke(arch)
        settings = TrainSettings(remat="none", q_chunk=16, kv_chunk=16,
                                 opt=OptConfig(lr=1e-2, warmup_steps=1))
        step, _, _ = make_train_step(cfg, None, settings)
        step = jax.jit(step)
        params = lm.init_lm(jax.random.PRNGKey(0), cfg)
        opt_state = init_opt(params, settings.opt)
        batch = _batch(cfg, rng)
        p2, o2, metrics = step(params, opt_state, batch)
        assert jnp.isfinite(metrics["loss"])
        # at least one leaf moved
        moved = any(
            float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))) > 0
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
        )
        assert moved

    def test_decode_matches_forward(self, arch, rng):
        """Teacher-forced forward == prefill + decode_step (fp32, dropless)."""
        cfg = dataclasses.replace(
            get_smoke(arch), dtype="float32", capacity_factor=64.0
        )
        params = lm.init_lm(jax.random.PRNGKey(1), cfg)
        B, S = 2, 17
        batch = _batch(cfg, rng, B, S)
        pre = {k: v[:, : S - 1] for k, v in batch.items()}
        last = {k: v[:, S - 1 :] for k, v in batch.items() if k != "labels"}
        ref, _ = lm.prefill(params, batch, cfg, None, q_chunk=4, kv_chunk=4)
        _, cache = lm.prefill(params, pre, cfg, None, s_alloc=S + 3, q_chunk=4, kv_chunk=4)
        dec, _ = lm.decode_step(params, cache, last, jnp.int32(S - 1), cfg, None)
        r = np.asarray(ref, np.float32)[..., : cfg.vocab_size]
        d = np.asarray(dec, np.float32)[..., : cfg.vocab_size]
        err = np.max(np.abs(r - d) / (np.abs(r) + 1e-2))
        assert err < 5e-3, f"{arch}: decode diverges from forward ({err})"

    def test_full_config_exact_dims(self, arch):
        """The registry carries the exact published dims."""
        cfg = get_arch(arch)
        assert cfg.param_count() > 0
        pd = padded_dims(cfg, 16)
        if cfg.uses_attention:
            assert pd.n_heads % 16 == 0 or pd.n_kv_heads < 16
        rep = padding_report(cfg, 16)
        # padding only ever grows dims
        for k, (a, b) in rep.items():
            assert b > a


def test_param_counts_match_published():
    expected = {
        "starcoder2-3b": (3.0e9, 0.05),
        "yi-34b": (34.4e9, 0.02),
        "chatglm3-6b": (6.2e9, 0.05),
        "minitron-8b": (7.7e9, 0.06),
        "mamba2-780m": (0.78e9, 0.05),
        "deepseek-v3-671b": (671e9, 0.005),
        "hymba-1.5b": (1.6e9, 0.1),
        "paligemma-3b": (3.0e9, 0.05),
    }
    for arch, (n, tol) in expected.items():
        got = get_arch(arch).param_count()
        assert abs(got - n) / n < tol, f"{arch}: {got/1e9:.2f}B vs {n/1e9:.2f}B"


def test_long_context_applicability():
    from repro.configs.registry import shape_applicable

    assert shape_applicable(get_arch("mamba2-780m"), "long_500k")[0]
    assert shape_applicable(get_arch("hymba-1.5b"), "long_500k")[0]
    for a in ("yi-34b", "deepseek-v3-671b", "musicgen-medium"):
        ok, reason = shape_applicable(get_arch(a), "long_500k")
        assert not ok and reason
