"""SortedTable + composite keys: unit tests.

Property tests live in test_properties.py (they need hypothesis and
skip cleanly when it is absent).
"""

import numpy as np
import pytest

from repro.core import (
    Eq,
    KeySchema,
    Query,
    Range,
    SortedTable,
    pack_columns,
    pack_tuple,
    unpack_key,
)


from conftest import brute_force


def _table(rng, n=2000, dom=32, layout=("a", "b", "c")):
    kc = {c: rng.integers(0, dom, n).astype(np.int64) for c in ("a", "b", "c")}
    vc = {"m": rng.uniform(0, 10, n)}
    return SortedTable.from_columns(kc, vc, layout)


class TestPackedKeys:
    def test_pack_roundtrip(self, rng):
        schema = KeySchema({"a": 7, "b": 5, "c": 9})
        layout = ("c", "a", "b")
        vals = (311, 100, 17)
        packed = pack_tuple(vals, layout, schema)
        assert unpack_key(packed, layout, schema) == vals

    def test_pack_order_is_lexicographic(self, rng):
        schema = KeySchema({"a": 8, "b": 8})
        tuples = [tuple(rng.integers(0, 256, 2)) for _ in range(500)]
        packed = [pack_tuple(t, ("a", "b"), schema) for t in tuples]
        assert sorted(range(500), key=lambda i: packed[i]) == sorted(
            range(500), key=lambda i: tuples[i]
        )

    def test_overflow_rejected(self):
        schema = KeySchema({"a": 4})
        with pytest.raises(ValueError):
            pack_tuple((16,), ("a",), schema)
        with pytest.raises(ValueError):
            KeySchema({"a": 40, "b": 30}).check_layout(("a", "b"))


class TestScan:
    def test_execute_matches_bruteforce(self, rng):
        t = _table(rng)
        for _ in range(30):
            f = {}
            if rng.random() < 0.7:
                f["a"] = Eq(int(rng.integers(0, 32)))
            if rng.random() < 0.7:
                lo = int(rng.integers(0, 28))
                f["b"] = Range(lo, lo + int(rng.integers(1, 5)))
            if not f:
                f["c"] = Eq(int(rng.integers(0, 32)))
            q = Query(filters=f, agg="count")
            res = t.execute(q)
            assert res.value == brute_force(t, q).sum()

    def test_sum_aggregation(self, rng):
        t = _table(rng)
        q = Query(filters={"a": Eq(3)}, agg="sum", value_col="m")
        res = t.execute(q)
        expect = t.value_cols["m"][brute_force(t, q)].sum()
        np.testing.assert_allclose(res.value, expect, rtol=1e-12)

    def test_slab_contains_all_matches(self, rng):
        """The located slab is a superset of matching rows (Fig 2)."""
        t = _table(rng)
        q = Query(filters={"a": Eq(5), "b": Range(3, 9)})
        lo, hi = t.slab(q)
        mask = brute_force(t, q)
        idx = np.nonzero(mask)[0]
        if len(idx):
            assert idx.min() >= lo and idx.max() < hi

    def test_prefix_slab_is_tight_for_leading_equality(self, rng):
        """With an equality on the FIRST layout key, no row outside the
        slab has that key value."""
        t = _table(rng, layout=("a", "b", "c"))
        q = Query(filters={"a": Eq(7)})
        lo, hi = t.slab(q)
        assert (t.key_cols["a"][lo:hi] == 7).all()
        assert hi - lo == (t.key_cols["a"] == 7).sum()


class TestReplicaEquivalence:
    def test_layouts_return_same_results(self, rng):
        """HR invariant: every serialization answers every query equally."""
        kc = {c: rng.integers(0, 16, 1500).astype(np.int64) for c in ("a", "b", "c")}
        vc = {"m": rng.uniform(0, 1, 1500)}
        import itertools

        tables = [
            SortedTable.from_columns(kc, vc, lay)
            for lay in itertools.permutations(("a", "b", "c"))
        ]
        fps = {t.dataset_fingerprint() for t in tables}
        assert len(fps) == 1
        for _ in range(10):
            q = Query(
                filters={"a": Eq(int(rng.integers(0, 16))), "b": Range(2, 9)},
                agg="sum",
                value_col="m",
            )
            vals = [t.execute(q).value for t in tables]
            np.testing.assert_allclose(vals, vals[0], rtol=1e-9)

    def test_resorted_preserves_dataset(self, rng):
        t = _table(rng, layout=("a", "b", "c"))
        t2 = t.resorted(("c", "b", "a"))
        assert t.dataset_fingerprint() == t2.dataset_fingerprint()
        assert t2.layout == ("c", "b", "a")

    def test_merge_insert_keeps_sorted_and_dataset(self, rng):
        t = _table(rng, n=500)
        kc2 = {c: rng.integers(0, 32, 100).astype(np.int64) for c in ("a", "b", "c")}
        vc2 = {"m": rng.uniform(0, 10, 100)}
        t2 = t.merge_insert(kc2, vc2)
        assert len(t2) == 600
        assert (np.diff(t2.packed) >= 0).all()
