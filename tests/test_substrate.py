"""Substrate integration: checkpoints (atomic/HR/elastic), data pipeline,
optimizer variants, training loop with failure injection.
"""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.layouts import CheckpointRouter
from repro.checkpoint.manager import (
    CheckpointManager,
    latest_step,
    rebuild_tree,
    restore_checkpoint,
    save_checkpoint,
)
from repro.configs import get_smoke
from repro.core import Eq, Query, Range
from repro.data.corpus import CorpusSpec, SyntheticCorpus
from repro.data.pipeline import HRDataPipeline, curriculum_workload
from repro.ft.failures import FailurePlan
from repro.launch.train import TrainLoopConfig, run_training
from repro.models import lm
from repro.training.optimizer import OptConfig, init_opt, opt_update


class TestCheckpoint:
    def _tree(self, rng):
        return {
            "params": {
                "stack_main": {"w": rng.normal(0, 1, (8, 16, 4)).astype(np.float32)},
                "embed": rng.normal(0, 1, (32, 4)).astype(np.float32),
            },
            "opt": {"m": rng.normal(0, 1, (8, 16, 4)).astype(np.float32)},
        }

    def test_roundtrip(self, rng, tmp_path):
        tree = self._tree(rng)
        save_checkpoint(str(tmp_path), 7, tree, n_chunks=3, replicas=3)
        step, flat = restore_checkpoint(str(tmp_path))
        assert step == 7
        out = rebuild_tree(tree, flat)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
            np.testing.assert_array_equal(a, b)

    def test_bf16_roundtrip(self, rng, tmp_path):
        tree = {"w": jnp.asarray(rng.normal(0, 1, (8, 4)), jnp.bfloat16)}
        save_checkpoint(str(tmp_path), 1, tree)
        _, flat = restore_checkpoint(str(tmp_path))
        assert str(flat["w"].dtype) == "bfloat16"
        np.testing.assert_array_equal(np.asarray(tree["w"]), flat["w"])

    def test_atomicity_no_tmp_left(self, rng, tmp_path):
        save_checkpoint(str(tmp_path), 3, self._tree(rng))
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))
        assert latest_step(str(tmp_path)) == 3

    def test_replica_manifests_same_dataset_different_order(self, rng, tmp_path):
        save_checkpoint(str(tmp_path), 5, self._tree(rng), n_chunks=4, replicas=3)
        import json

        d = tmp_path / "step_00000005"
        orders = []
        for r in range(3):
            with open(d / f"manifest_r{r}.json") as f:
                m = json.load(f)
            orders.append([e["path"] for e in m["leaves"]])
        assert sorted(orders[0]) == sorted(orders[1]) == sorted(orders[2])
        assert any(orders[0] != o for o in orders[1:])
        # every replica restores identically
        _, flat0 = restore_checkpoint(str(tmp_path), replica=0)
        _, flat1 = restore_checkpoint(str(tmp_path), replica=1)
        for k in flat0:
            np.testing.assert_array_equal(flat0[k], flat1[k])

    def test_router_picks_cheaper_replica(self, rng, tmp_path):
        save_checkpoint(str(tmp_path), 2, self._tree(rng), n_chunks=8, replicas=3)
        router = CheckpointRouter(str(tmp_path), 2)
        # layer-range restore (chunk range): the layer-major replica wins
        q = Query(filters={"layer": Range(0, 2)})
        plan = router.plan(q)
        worst = router.worst_plan(q)
        assert plan.files_needed == worst.files_needed
        assert plan.files_span <= worst.files_span
        assert plan.files_needed <= plan.files_span

    def test_manager_resume(self, rng, tmp_path):
        tree = self._tree(rng)
        mgr = CheckpointManager(str(tmp_path), every=2, async_save=False, replicas=2)
        assert mgr.maybe_save(2, tree)
        assert not mgr.maybe_save(3, tree)
        restored = mgr.restore_latest(tree)
        assert restored is not None and restored[0] == 2


class TestElastic:
    def test_restore_to_different_tp(self, rng, tmp_path):
        cfg = get_smoke("yi-34b")
        p1 = lm.init_lm(jax.random.PRNGKey(0), cfg, tp=1)
        save_checkpoint(str(tmp_path), 1, {"params": p1})
        _, flat = restore_checkpoint(str(tmp_path))
        tree = rebuild_tree({"params": p1}, flat)
        # same logical axes apply at any tp: simply re-materialize
        p2 = jax.tree.map(jnp.asarray, tree["params"])
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDataPipeline:
    def test_hr_beats_tr_rows_scanned(self):
        corpus = SyntheticCorpus(CorpusSpec(n_docs=30_000, vocab_size=1000))
        wl = curriculum_workload(np.random.default_rng(5), 30)
        hr = HRDataPipeline(corpus, mechanism="HR", workload=wl, seed=1)
        tr = HRDataPipeline(corpus, mechanism="TR", workload=wl, seed=1)
        for q in wl.queries:
            hr.sample_batch(4, 16, query=q)
            tr.sample_batch(4, 16, query=q)
        assert hr.total_rows_scanned < tr.total_rows_scanned

    def test_batch_shapes_and_determinism(self):
        corpus = SyntheticCorpus(CorpusSpec(n_docs=5000, vocab_size=128))
        pipe = HRDataPipeline(corpus, seed=3, hrca_kwargs={"k_max": 300, "seed": 0})
        batch, rep = pipe.sample_batch(4, 32)
        assert batch["tokens"].shape == (4, 32)
        assert batch["labels"].shape == (4, 32)
        assert (batch["tokens"] >= 0).all() and (batch["tokens"] < 128).all()
        toks = corpus.tokens(np.array([7]), 8)
        np.testing.assert_array_equal(toks, corpus.tokens(np.array([7]), 8))


class TestOptimizer:
    @pytest.mark.parametrize("kind", ["adamw", "adamw_bf16", "adafactor"])
    def test_descends_quadratic(self, kind, rng):
        w = jnp.asarray(rng.normal(0, 1, (16, 16)), jnp.float32)
        target = jnp.zeros_like(w)
        cfg = OptConfig(kind=kind, lr=0.1, warmup_steps=1, weight_decay=0.0,
                        total_steps=100)
        params = {"w": w}
        state = init_opt(params, cfg)
        loss0 = float(jnp.mean((params["w"] - target) ** 2))
        for _ in range(30):
            g = {"w": 2 * (params["w"] - target) / w.size}
            params, state, _ = opt_update(g, state, params, cfg)
        loss1 = float(jnp.mean((params["w"] - target) ** 2))
        assert loss1 < loss0 * 0.5

    def test_adafactor_state_is_factored(self, rng):
        params = {"w": jnp.zeros((32, 64), jnp.float32)}
        st = init_opt(params, OptConfig(kind="adafactor"))
        assert st["state"]["w"]["vr"].shape == (32,)
        assert st["state"]["w"]["vc"].shape == (64,)

    def test_grad_clip_caps_update(self, rng):
        cfg = OptConfig(lr=1.0, grad_clip=1e-3, warmup_steps=1, weight_decay=0.0)
        params = {"w": jnp.ones((4, 4))}
        st = init_opt(params, cfg)
        g = {"w": jnp.full((4, 4), 1e6)}
        p2, _, stats = opt_update(g, st, params, cfg)
        assert float(stats["grad_norm"]) > 1e3
        assert float(jnp.max(jnp.abs(p2["w"] - params["w"]))) < 10.0


class TestTrainingLoopFT:
    def test_failure_recovery_resumes(self, tmp_path):
        cfg = dataclasses.replace(get_smoke("starcoder2-3b"), n_layers=1, d_model=32,
                                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64)
        loop = TrainLoopConfig(
            steps=14, batch_size=2, seq_len=16, ckpt_dir=str(tmp_path),
            ckpt_every=5, log_every=100,
            failure_plan=FailurePlan(fail_at_steps=(12,), nodes=(0,)),
        )
        out = run_training(cfg, loop)
        assert out["steps_run"] == 14
        assert len(out["recoveries"]) == 1
        assert np.isfinite(out["final_loss"])

    def test_resume_from_checkpoint(self, tmp_path):
        cfg = dataclasses.replace(get_smoke("starcoder2-3b"), n_layers=1, d_model=32,
                                  n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64)
        loop = TrainLoopConfig(steps=6, batch_size=2, seq_len=16,
                               ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100)
        run_training(cfg, loop)
        loop2 = dataclasses.replace(loop, steps=9)
        out = run_training(cfg, loop2, resume=True)
        assert out["steps_run"] == 9
        assert latest_step(str(tmp_path)) == 9
