"""HR engine integration: routing, writes, recovery, hedging, TR-vs-HR."""

import numpy as np
import pytest

from repro.core import Eq, HREngine, Query, Range, random_workload
from repro.core.tpch import generate_simulation
from repro.ft.straggler import clear_slowdowns, inject_slowdown, measure_tail


@pytest.fixture(scope="module")
def setup():
    kc, vc, schema = generate_simulation(60_000, 3, seed=0)
    rng = np.random.default_rng(1)
    wl = random_workload(rng, schema, list(kc), 30, value_col="metric")
    eng = HREngine(n_nodes=5)
    eng.create_column_family(
        "hr", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
        schema=schema, hrca_kwargs={"k_max": 1500, "seed": 0},
    )
    eng.create_column_family(
        "tr", kc, vc, replication_factor=3, mechanism="TR", workload=wl, schema=schema,
    )
    return eng, wl, schema


class TestRouting:
    def test_results_identical_across_mechanisms(self, setup):
        eng, wl, _ = setup
        for q in wl.queries[:10]:
            r1, _ = eng.read("hr", q)
            r2, _ = eng.read("tr", q)
            assert abs(r1.value - r2.value) <= 1e-6 * max(1.0, abs(r1.value))

    def test_scheduler_picks_cheapest_estimate(self, setup):
        eng, wl, _ = setup
        cf = eng.column_families["hr"]
        q = wl.queries[0]
        ranked = eng._ranked_replicas(cf, q)
        _, rep = eng.read("hr", q)
        assert rep.estimated_cost <= ranked[-1][0] + 1e-9

    def test_hr_scans_fewer_rows_than_tr(self, setup):
        eng, wl, _ = setup
        hr = sum(eng.read("hr", q)[1].rows_scanned for q in wl.queries)
        tr = sum(eng.read("tr", q)[1].rows_scanned for q in wl.queries)
        assert hr < tr  # the paper's central effect

    def test_tie_breaking_round_robin_spreads_load(self, setup):
        eng, _, schema = setup
        # unfiltered query: all replicas equal cost → RR over replicas
        q = Query(filters={})
        seen = {eng.read("hr", q)[1].replica_id for _ in range(6)}
        assert len(seen) > 1


class TestWrites:
    def test_write_fans_out_and_keeps_consistency(self, setup):
        eng, wl, schema = setup
        rng = np.random.default_rng(7)
        dom = 2 ** schema.bits["k0"]
        kc2 = {c: rng.integers(0, dom, 500).astype(np.int64) for c in ("k0", "k1", "k2")}
        vc2 = {"metric": rng.uniform(0, 1, 500)}
        n_before = eng.column_families["hr"].stats.n_rows
        eng.write("hr", kc2, vc2)
        cf = eng.column_families["hr"]
        assert cf.stats.n_rows == n_before + 500
        fps = {
            eng._table(cf, r).dataset_fingerprint()
            for r in cf.replicas
        }
        assert len(fps) == 1


class TestRecovery:
    def test_node_failure_and_rebuild(self, setup):
        eng, wl, _ = setup
        cf = eng.column_families["hr"]
        fp = eng._table(cf, cf.replicas[0]).dataset_fingerprint()
        victim = cf.replicas[0].node_id
        eng.fail_node(victim)
        # reads keep working on survivors
        r, rep = eng.read("hr", wl.queries[0])
        assert rep.node_id != victim
        eng.recover_node(victim)
        assert eng._table(cf, cf.replicas[0]).dataset_fingerprint() == fp

    def test_recovery_preserves_layout(self, setup):
        eng, _, _ = setup
        cf = eng.column_families["hr"]
        lay = cf.replicas[1].layout
        victim = cf.replicas[1].node_id
        eng.fail_node(victim)
        eng.recover_node(victim)
        assert eng._table(cf, cf.replicas[1]).layout == lay


class TestStragglerHedging:
    def test_hedging_beats_straggler(self, setup):
        eng, wl, _ = setup
        cf = eng.column_families["hr"]
        # slow down the node hosting replica 0 — hard enough that the
        # slowdown dominates wall-clock jitter on a loaded CI machine
        victim = cf.replicas[0].node_id
        inject_slowdown(eng, victim, 1e4)
        try:
            unhedged = measure_tail(eng, "hr", wl, hedge=False, repeats=3)
            hedged = measure_tail(eng, "hr", wl, hedge=True, repeats=3)
            assert hedged.hedged_fraction > 0
            # hedged reads duplicate onto a non-straggler, so the tail
            # must drop by far more than scheduler noise
            assert hedged.p99 <= unhedged.p99 * 1.05
        finally:
            clear_slowdowns(eng)

    def test_hedged_read_lands_off_straggler(self, setup):
        eng, wl, _ = setup
        cf = eng.column_families["hr"]
        victim = cf.replicas[0].node_id
        inject_slowdown(eng, victim, 1e4)
        try:
            for q in wl.queries[:10]:
                _, rep = eng.read("hr", q, hedge=True)
                if rep.hedged:
                    assert rep.node_id != victim
        finally:
            clear_slowdowns(eng)
