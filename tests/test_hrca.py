"""HRCA (Algorithm 1): optimality on small instances + behaviour."""

import numpy as np
import pytest

from repro.core import (
    CostModel,
    Eq,
    Query,
    Range,
    Workload,
    exhaustive_search,
    hrca,
    initial_state,
)
from repro.core.ecdf import TableStats
from repro.core.tpch import generate_simulation


def _setup(n_keys=3, n_rows=20_000, seed=0, n_q=20):
    kc, vc, schema = generate_simulation(n_rows, n_keys, seed=seed)
    stats = TableStats.from_columns(kc, schema)
    model = CostModel(stats=stats)
    rng = np.random.default_rng(seed + 1)
    from repro.core import random_workload

    wl = random_workload(rng, schema, list(kc), n_q)
    return model, wl, tuple(kc)


class TestHRCA:
    def test_matches_exhaustive_on_small_instance(self):
        model, wl, keys = _setup(n_keys=3, n_q=15)
        _, best_cost = exhaustive_search(model, wl, keys, 2)
        res = hrca(model, wl, initial_state(keys, 2), k_max=3000, seed=0,
                   restarts=2, greedy_descent=True)
        assert res.cost <= best_cost * 1.001 + 1e-9

    def test_never_worse_than_initial(self):
        model, wl, keys = _setup(n_keys=4, n_q=25, seed=3)
        res = hrca(model, wl, initial_state(keys, 3), k_max=1500, seed=1)
        assert res.cost <= res.initial_cost + 1e-12

    def test_rf1_equals_single_layout_search(self):
        """With RF=1 heterogeneity cannot help (paper Fig 5b: HR == TR at
        replication factor 1)."""
        model, wl, keys = _setup(n_keys=3, n_q=15, seed=5)
        _, best1 = exhaustive_search(model, wl, keys, 1)
        res = hrca(model, wl, initial_state(keys, 1), k_max=3000, seed=0,
                   greedy_descent=True)
        assert res.cost <= best1 * 1.001 + 1e-9
        assert res.cost >= best1 * 0.999 - 1e-9

    def test_more_replicas_never_hurt(self):
        model, wl, keys = _setup(n_keys=3, n_q=20, seed=7)
        costs = []
        for rf in (1, 2, 3):
            res = hrca(model, wl, initial_state(keys, rf), k_max=2500, seed=0,
                       greedy_descent=True)
            costs.append(res.cost)
        assert costs[1] <= costs[0] * 1.001
        assert costs[2] <= costs[1] * 1.001

    def test_trace_monotone_best(self):
        model, wl, keys = _setup(seed=9)
        res = hrca(model, wl, initial_state(keys, 2), k_max=800, seed=2)
        assert res.n_steps == 800
        assert min(res.trace) <= res.trace[0]

    def test_converges_fast_wallclock(self):
        """Paper §3.2: 'generally converges in ten seconds' — our memoized
        implementation is far under that at paper-scale instances."""
        model, wl, keys = _setup(n_keys=5, n_q=50, seed=11)
        res = hrca(model, wl, initial_state(keys, 3), k_max=4000, seed=0)
        assert res.wall_seconds < 10.0
