"""Distributed-numerics tests on 8 forced host devices.

Each test runs in a subprocess (XLA device count is locked at first jax
init, so the main pytest process must keep seeing 1 device). The
subprocess asserts internally and exits non-zero on failure.
"""

import os
import subprocess
import sys
import textwrap

import jax
import pytest

# These tests exercise newer-jax auto-sharding; both jax.set_mesh and
# jax.sharding.AxisType are required (set_mesh became public API in jax
# 0.6.2, AxisType landed with the 0.6.x explicit-sharding work) and
# BOTH are absent from the baked-in jax 0.4.37 — skip with a reason
# naming the minimum version instead of failing on an AttributeError in
# the subprocess.
pytestmark = pytest.mark.skipif(
    not (hasattr(jax, "set_mesh") and hasattr(jax.sharding, "AxisType")),
    reason=(
        "needs jax.set_mesh and jax.sharding.AxisType (jax>=0.6.2; "
        f"installed jax {jax.__version__})"
    ),
)

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, timeout: int = 600) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    prelude = textwrap.dedent(
        """
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_smoke
        from repro.parallel.sharding import make_ctx
        from repro.models import lm
        mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices(),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        ctx = make_ctx(mesh)
        rng = np.random.default_rng(0)
        """
    )
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(body)],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
        cwd=_ROOT,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr[-3000:]}"


@pytest.mark.slow
def test_sharded_decode_matches_single_device():
    _run(
        """
        for arch in ["starcoder2-3b", "deepseek-v3-671b", "hymba-1.5b"]:
            cfg = dataclasses.replace(get_smoke(arch), dtype="float32", capacity_factor=64.0)
            params = lm.init_lm(jax.random.PRNGKey(0), cfg, tp=ctx.tp_size)
            B, S = 4, 17
            toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
            batch = {"tokens": toks}
            pre = {k: v[:, : S - 1] for k, v in batch.items()}
            last = {k: v[:, S - 1 :] for k, v in batch.items()}
            _, c0 = lm.prefill(params, pre, cfg, None, s_alloc=20, q_chunk=4, kv_chunk=4)
            ref, _ = lm.decode_step(params, c0, last, jnp.int32(S - 1), cfg, None)
            with jax.set_mesh(mesh):
                _, c1 = lm.prefill(params, pre, cfg, ctx, s_alloc=20, q_chunk=4, kv_chunk=4)
                dist, _ = lm.decode_step(params, c1, last, jnp.int32(S - 1), cfg, ctx)
            r, d = np.asarray(ref, np.float32), np.asarray(dist, np.float32)
            err = np.max(np.abs(r - d) / (np.abs(r) + 1e-2))
            assert err < 1e-3, (arch, err)
        print("ok")
        """
    )


@pytest.mark.slow
def test_serve_ep_matches_fsdp_placement():
    """Global-EP MoE serving path computes the same logits as baseline."""
    _run(
        """
        from repro.serving.steps import make_decode_step
        from repro.models import lm as lm_mod
        cfg = dataclasses.replace(get_smoke("qwen2-moe-a2.7b"), dtype="float32",
                                  capacity_factor=64.0)
        tp = ctx.tp_size
        params = lm_mod.init_lm(jax.random.PRNGKey(0), cfg, tp=tp)
        B, S_alloc = 4, 8
        cache = lm_mod.init_cache(cfg, B, S_alloc, tp)
        tok = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)}
        # pass uncommitted host trees so each jit is free to place them
        params_h = jax.tree.map(np.asarray, params)
        cache_h = jax.tree.map(np.asarray, cache)
        outs = {}
        with jax.set_mesh(mesh):
            for mode in ("fsdp", "tp"):
                step = make_decode_step(cfg, ctx, serve_sharding=mode)
                logits, _ = step(jax.tree.map(np.copy, params_h),
                                 jax.tree.map(np.copy, cache_h), tok, jnp.int32(0))
                outs[mode] = np.asarray(logits, np.float32)
        err = np.max(np.abs(outs["fsdp"] - outs["tp"]) / (np.abs(outs["fsdp"]) + 1e-2))
        assert err < 1e-3, err
        print("ok")
        """
    )


@pytest.mark.slow
def test_fsdp_all_train_step_matches_fsdp():
    """param_mode=fsdp_all computes the same loss/update as ZeRO-3+TP."""
    _run(
        """
        from repro.training.steps import TrainSettings, make_train_step
        from repro.training.optimizer import OptConfig, init_opt
        cfg = dataclasses.replace(get_smoke("yi-34b"), dtype="float32",
                                  d_model=64, n_heads=8, n_kv_heads=2)
        B, S = 8, 16
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        losses = {}
        params0 = lm.init_lm(jax.random.PRNGKey(0), cfg, tp=ctx.tp_size)
        opt0 = init_opt(params0, OptConfig(lr=1e-3, warmup_steps=1))
        params_h = jax.tree.map(np.asarray, params0)
        opt_h = jax.tree.map(np.asarray, opt0)
        with jax.set_mesh(mesh):
            for mode in ("fsdp", "fsdp_all"):
                settings = TrainSettings(remat="none", q_chunk=8, kv_chunk=8,
                                         param_mode=mode,
                                         opt=OptConfig(lr=1e-3, warmup_steps=1))
                step, _, _ = make_train_step(cfg, ctx, settings)
                _, _, metrics = step(jax.tree.map(np.copy, params_h),
                                     jax.tree.map(np.copy, opt_h), batch)
                losses[mode] = float(metrics["loss"])
        assert abs(losses["fsdp"] - losses["fsdp_all"]) < 1e-4, losses
        print("ok")
        """
    )


@pytest.mark.slow
def test_pipeline_over_pod_matches_baseline():
    """GPipe over the pod axis: identical loss through fwd+bwd+optimizer."""
    _run(
        """
        from repro.training.steps import TrainSettings, make_train_step
        from repro.training.optimizer import OptConfig, init_opt
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                              devices=jax.devices(),
                              axis_types=(jax.sharding.AxisType.Auto,) * 3)
        ctx3 = make_ctx(mesh3)
        cfg = dataclasses.replace(get_smoke("yi-34b"), dtype="float32")
        B, S = 8, 16
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32)
        batch = {"tokens": np.asarray(toks[:, :-1]), "labels": np.asarray(toks[:, 1:])}
        params0 = lm.init_lm(jax.random.PRNGKey(0), cfg, tp=ctx3.tp_size)
        opt0 = init_opt(params0, OptConfig(lr=1e-3, warmup_steps=1))
        params_h = jax.tree.map(np.asarray, params0)
        opt_h = jax.tree.map(np.asarray, opt0)
        losses = {}
        with jax.set_mesh(mesh3):
            for pp in (0, 4):
                settings = TrainSettings(remat="none", q_chunk=8, kv_chunk=8,
                                         pipeline_micro=pp,
                                         opt=OptConfig(lr=1e-3, warmup_steps=1))
                step, _, _ = make_train_step(cfg, ctx3, settings)
                _, _, m = step(jax.tree.map(np.copy, params_h),
                               jax.tree.map(np.copy, opt_h), dict(batch))
                losses[pp] = float(m["loss"])
        assert abs(losses[0] - losses[4]) < 1e-4, losses
        print("ok")
        """
    )
