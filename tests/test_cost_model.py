"""Cost model (Eq 1–4): estimates vs ground-truth slab sizes.

Property tests live in test_properties.py (they need hypothesis and
skip cleanly when it is absent).
"""

import numpy as np
import pytest

from repro.core import (
    CostModel,
    Eq,
    KeySchema,
    LinearCostFunction,
    Query,
    Range,
    SortedTable,
    Workload,
    estimate_rows,
    estimate_rows_many,
)
from repro.core.ecdf import ColumnStats, TableStats
from repro.core.tpch import generate_simulation


class TestColumnStats:
    def test_exact_counts(self, rng):
        vals = rng.integers(0, 50, 5000)
        cs = ColumnStats.from_values(vals, 50)
        assert cs.bin_width == 1
        for v in (0, 7, 49):
            assert cs.pmf(v) == (vals == v).sum() / 5000
        np.testing.assert_allclose(cs.cdf(50), 1.0)
        np.testing.assert_allclose(cs.cdf(0), 0.0)
        np.testing.assert_allclose(
            cs.range_selectivity(10, 20), ((vals >= 10) & (vals < 20)).sum() / 5000
        )

    def test_binned_large_domain(self, rng):
        vals = rng.integers(0, 1_000_000, 20_000)
        cs = ColumnStats.from_values(vals, 1_000_000, max_bins=1024)
        assert cs.bin_width > 1
        sel = cs.range_selectivity(100_000, 500_000)
        truth = ((vals >= 100_000) & (vals < 500_000)).sum() / 20_000
        assert abs(sel - truth) < 0.02

    def test_merge_values_streaming(self, rng):
        a = rng.integers(0, 32, 1000)
        b = rng.integers(0, 32, 500)
        cs = ColumnStats.from_values(a, 32)
        cs.merge_values(b)
        both = np.concatenate([a, b])
        ref = ColumnStats.from_values(both, 32)
        np.testing.assert_allclose(cs.counts, ref.counts)


class TestEq1:
    """Row() estimates track the true slab size (the paper notes a small
    over-estimate δ vs Fig 2 — we assert within 2× + absolute slack)."""

    @pytest.mark.parametrize("layout", [("k0", "k1", "k2"), ("k2", "k0", "k1")])
    def test_estimate_vs_true_slab(self, rng, layout):
        kc, vc, schema = generate_simulation(30_000, 3, seed=1)
        t = SortedTable.from_columns(kc, vc, layout, schema)
        stats = TableStats.from_columns(kc, schema)
        for _ in range(25):
            f = {
                "k0": Eq(int(rng.integers(0, 16))),
                "k1": Range(int(rng.integers(0, 8)), int(rng.integers(8, 32))),
            }
            q = Query(filters=f)
            est = estimate_rows(stats, layout, q)
            true = t.slab(q)[1] - t.slab(q)[0]
            assert est <= 2.5 * max(true, 5) + 50
            assert true <= 2.5 * max(est, 5) + 50

    def test_equality_prefix_cuts_selectivity(self):
        kc, vc, schema = generate_simulation(10_000, 3, seed=2)
        stats = TableStats.from_columns(kc, schema)
        q = Query(filters={"k0": Eq(3), "k1": Eq(5)})
        # layout with both eq keys leading → much smaller than reversed
        est_good = estimate_rows(stats, ("k0", "k1", "k2"), q)
        est_bad = estimate_rows(stats, ("k2", "k0", "k1"), q)
        assert est_good < est_bad

    def test_range_stops_prefix(self):
        """Keys after the first range filter do not shrink Row() (Eq 1)."""
        kc, vc, schema = generate_simulation(10_000, 3, seed=3)
        stats = TableStats.from_columns(kc, schema)
        q1 = Query(filters={"k0": Range(0, 4), "k1": Eq(2)})
        q2 = Query(filters={"k0": Range(0, 4)})
        a = estimate_rows(stats, ("k0", "k1", "k2"), q1)
        b = estimate_rows(stats, ("k0", "k1", "k2"), q2)
        assert a == b  # k1's equality is residual — scanned, not sliced


class TestCostFunction:
    def test_linear_fit_recovers_slope(self, rng):
        rows = rng.uniform(100, 100_000, 50)
        times = 3.5e-6 * rows + 0.42 + rng.normal(0, 1e-3, 50)
        f = LinearCostFunction.fit(rows, times)
        assert abs(f.slope - 3.5e-6) / 3.5e-6 < 0.05
        assert f.r2(rows, times) > 0.99

    def test_min_cost_and_workload_cost(self, rng):
        kc, vc, schema = generate_simulation(20_000, 3, seed=4)
        stats = TableStats.from_columns(kc, schema)
        model = CostModel(stats=stats)
        layouts = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]
        q = Query(filters={"k1": Eq(2), "k2": Range(0, 8)})
        costs = [model.query_cost(a, q) for a in layouts]
        mc, j = model.min_cost(layouts, q)
        assert mc == min(costs) and costs[j] == mc
        wl = Workload([q, Query(filters={"k0": Eq(1)})])
        wc = model.workload_cost(layouts, wl)
        assert wc <= max(costs)

    def test_cost_many_matches_scalar_exactly(self, rng):
        """The vectorized Eq (1)-(2) path is bit-identical to the scalar
        one — batched routing must agree with sequential routing."""
        kc, vc, schema = generate_simulation(20_000, 3, seed=5)
        stats = TableStats.from_columns(kc, schema)
        model = CostModel(
            stats=stats, cost_fns={3: LinearCostFunction(3.5e-6, 0.42)}
        )
        queries = []
        for _ in range(40):
            f = {}
            if rng.random() < 0.6:
                f["k0"] = Eq(int(rng.integers(0, 16)))
            if rng.random() < 0.6:
                f["k1"] = Range(int(rng.integers(0, 8)), int(rng.integers(8, 32)))
            if rng.random() < 0.3 or not f:
                f["k2"] = Eq(int(rng.integers(0, 16)))
            queries.append(Query(filters=f))
        for layout in [("k0", "k1", "k2"), ("k2", "k0", "k1"), ("k1", "k2", "k0")]:
            many_rows = estimate_rows_many(stats, layout, queries)
            many_costs = model.cost_many(layout, queries)
            for i, q in enumerate(queries):
                assert many_rows[i] == estimate_rows(stats, layout, q)
                assert many_costs[i] == model.query_cost(layout, q)
