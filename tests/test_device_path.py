"""Device-resident batched read path: engine/table routing through the
fused locate+scan Pallas kernel.

The acceptance bar for the device path is *identity* with the sequential
scalar path: ``read_many`` on a device-resident column family must return
per-query results equal to a loop of ``read`` (both route through the
same kernel — the scalar path is the Q = 1 launch), and equal to the
numpy engine up to float32 accumulation for sums (exactly, for counts,
rows_scanned and select indices) — while performing ZERO host
searchsorted calls and ZERO numpy residual scans (asserted by
monkeypatching the host paths away).
"""

import copy

import numpy as np
import pytest

from repro.core import Eq, HREngine, KeySchema, Query, Range, SortedTable, random_workload
from repro.core.table import SortedTable as _SortedTable
from repro.core.tpch import generate_simulation

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


@pytest.fixture(scope="module")
def setup():
    kc, vc, schema = generate_simulation(30_000, 3, seed=0)
    rng = np.random.default_rng(1)
    wl = random_workload(rng, schema, list(kc), 30, agg="sum", value_col="metric")
    # mixed agg kinds in one batch: sums + counts
    queries = list(wl.queries[:20]) + [
        Query(filters=q.filters, agg="count") for q in wl.queries[20:]
    ]
    dev = HREngine(n_nodes=5)
    dev.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        device_resident=True,
    )
    host = HREngine(n_nodes=5)
    host.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
    )
    return dev, host, queries, schema, kc


class TestDeviceReadMany:
    def test_tables_resident(self, setup):
        dev, _, _, _, _ = setup
        tables = [t for n in dev.nodes for t in n.tables.values()]
        assert tables and all(t.device_resident for t in tables)

    def test_read_many_identical_to_sequential_read(self, setup):
        """The acceptance criterion: per-query results (values included)
        identical between read_many and a sequential read loop."""
        dev, _, queries, _, _ = setup
        eng_a, eng_b = copy.deepcopy(dev), copy.deepcopy(dev)
        seq = [eng_a.read("cf", q) for q in queries]
        bat = eng_b.read_many("cf", queries)
        for (rs, rep_s), (rb, rep_b) in zip(seq, bat):
            assert rb.value == rs.value
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.rows_matched == rs.rows_matched
            assert rep_b.replica_id == rep_s.replica_id
            assert rep_b.node_id == rep_s.node_id

    def test_matches_numpy_engine(self, setup):
        """Counts and rows_scanned exact vs the numpy reference engine;
        sums within float32 accumulation tolerance."""
        dev, host, queries, _, _ = setup
        bat = copy.deepcopy(dev).read_many("cf", queries)
        ref = copy.deepcopy(host).read_many("cf", queries)
        for (rd, _), (rh, _) in zip(bat, ref):
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)

    def test_select_agg_served_on_device(self, setup, monkeypatch):
        """A "select" query rides the device too (prefix-sum index
        compaction): indices equal the numpy engine's even with the
        numpy residual scan monkeypatched away."""
        dev, host, queries, _, _ = setup
        qsel = Query(filters={"k0": Eq(1)}, agg="select")
        batch = [queries[0], qsel, queries[1]]
        ref = copy.deepcopy(host).read_many("cf", batch)
        monkeypatch.setattr(
            _SortedTable,
            "_scan_slab",
            lambda *a, **k: pytest.fail("numpy fallback used on device table"),
        )
        out = copy.deepcopy(dev).read_many("cf", batch)
        assert out[1][0].selected is not None
        np.testing.assert_array_equal(out[1][0].selected, ref[1][0].selected)
        for (rd, _), (rh, _) in zip(out, ref):
            assert rd.rows_matched == rh.rows_matched

    def test_zero_host_searchsorted_zero_numpy_fallback(self, setup, monkeypatch):
        """THE acceptance criterion: a batched read on a device-resident
        column family runs no host slab location (``slab``/``slab_many``,
        the only searchsorted sites on the read path) and no numpy
        residual scan (``_scan_slab``) for any sum/count/select mix —
        including empty ranges — and still returns the reference
        results."""
        dev, host, queries, _, _ = setup
        batch = list(queries[:6]) + [
            Query(filters={"k0": Eq(2)}, agg="select"),
            Query(filters={"k1": Range(3, 3)}, agg="count"),  # empty range
        ]
        ref = copy.deepcopy(host).read_many("cf", batch)

        def _forbidden(name):
            def fail(*a, **k):
                pytest.fail(f"host path {name} used on device-resident table")

            return fail

        monkeypatch.setattr(_SortedTable, "slab", _forbidden("slab"))
        monkeypatch.setattr(_SortedTable, "slab_many", _forbidden("slab_many"))
        monkeypatch.setattr(_SortedTable, "_scan_slab", _forbidden("_scan_slab"))
        eng = copy.deepcopy(dev)
        for (rd, _), (rh, _) in zip(eng.read_many("cf", batch), ref):
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)
            if rh.selected is not None:
                np.testing.assert_array_equal(rd.selected, rh.selected)
        # the scalar path obeys the same contract (Q = 1 fused launch)
        for q in batch[:3]:
            res, _ = eng.read("cf", q)
            assert res is not None

    def test_empty_range_on_device(self, setup):
        dev, _, _, _, _ = setup
        q = Query(filters={"k1": Range(2, 2)}, agg="count")
        ((res, rep),) = copy.deepcopy(dev).read_many("cf", [q])
        assert res.value == 0.0 and res.rows_scanned == 0 and res.rows_matched == 0

    def test_write_then_read_stays_on_device_and_correct(self, setup):
        dev, host, queries, schema, kc = setup
        dev2, host2 = copy.deepcopy(dev), copy.deepcopy(host)
        rng = np.random.default_rng(7)
        kc2 = {c: rng.integers(0, schema.max_value(c) + 1, 400) for c in kc}
        vc2 = {"metric": rng.uniform(0, 1, 400)}
        dev2.write("cf", kc2, vc2)
        host2.write("cf", kc2, vc2)
        assert all(
            t.device_resident for n in dev2.nodes for t in n.tables.values()
        )
        bat = dev2.read_many("cf", queries[:8])
        ref = host2.read_many("cf", queries[:8])
        for (rd, _), (rh, _) in zip(bat, ref):
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)

    def test_recovery_replaces_on_device(self, setup):
        dev, _, queries, _, _ = setup
        dev2 = copy.deepcopy(dev)
        victim = dev2.column_families["cf"].replicas[0].node_id
        dev2.fail_node(victim)
        dev2.recover_node(victim)
        assert dev2.nodes[victim].tables
        assert all(t.device_resident for t in dev2.nodes[victim].tables.values())
        out = dev2.read_many("cf", queries[:5])
        assert all(r is not None for r, _ in out)


class TestTableResidency:
    def _table(self, rng, n=2000):
        kc = {"a": rng.integers(0, 16, n), "b": rng.integers(0, 16, n)}
        vc = {"m": rng.uniform(0, 1, n)}
        return SortedTable.from_columns(kc, vc, ("a", "b"))

    def test_place_and_evict(self, rng):
        t = self._table(rng)
        assert not t.device_resident
        assert t.place_on_device() is t and t.device_resident
        q = Query(filters={"a": Eq(3)}, agg="count")
        on_dev = t.execute(q)
        t.evict_from_device()
        assert not t.device_resident
        off_dev = t.execute(q)
        assert on_dev.value == off_dev.value
        assert on_dev.rows_scanned == off_dev.rows_scanned

    def test_scalar_equals_batched_on_device(self, rng):
        """execute (Q = 1 launch) and execute_many (grouped launch)
        agree exactly — both sides of the engine's identity contract."""
        t = self._table(rng).place_on_device()
        qs = [
            Query(filters={"a": Eq(int(rng.integers(0, 16)))}, agg="sum", value_col="m")
            for _ in range(9)
        ] + [Query(filters={"b": Range(2, 9)}, agg="count")]
        many = t.execute_many(qs)
        for q, rb in zip(qs, many):
            rs = t.execute(q)
            assert rb.value == rs.value
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.rows_matched == rs.rows_matched

    def test_wide_schema_resident(self, rng):
        """A 40-bit key column rides two int32 lanes on device."""
        schema = KeySchema({"a": 40, "b": 8})
        kc = {"a": rng.integers(0, 2**40, 1500).astype(np.int64),
              "b": rng.integers(0, 256, 1500).astype(np.int64)}
        vc = {"m": rng.uniform(0, 5, 1500)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"), schema).place_on_device()
        host = SortedTable.from_columns(kc, vc, ("a", "b"), schema)
        lo = int(rng.integers(0, 2**39))
        qs = [Query(filters={"a": Range(lo, lo + 2**36)}, agg="sum", value_col="m"),
              Query(filters={"b": Eq(7)}, agg="count"),
              Query(filters={}, agg="count")]
        for q, rd in zip(qs, t.execute_many(qs)):
            rh = host.execute(q)
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)

    def test_merge_insert_appends_to_device_cache(self, rng):
        """merge_insert on a resident table keeps it resident by
        APPENDING the merged run to the device arrays (incremental
        placement) — results stay correct, and the pre-merge table's
        own cache is untouched."""
        t = self._table(rng).place_on_device()
        assert t._device["n_runs"] == 1 and t._device["row_map"] is None
        merged = t.merge_insert(
            {"a": np.array([1, 2]), "b": np.array([3, 4])},
            {"m": np.array([0.5, 0.25])},
        )
        assert merged.device_resident
        assert merged._device["n_runs"] == 2
        assert merged._device["n_rows"] == len(t) + 2
        assert t._device["n_runs"] == 1 and t._device["n_rows"] == len(t)
        host = SortedTable(
            merged.layout, merged.schema, merged.key_cols, merged.value_cols,
            merged.packed,
        )
        for q in (Query(filters={"a": Eq(1)}, agg="count"),
                  Query(filters={"b": Range(2, 9)}, agg="sum", value_col="m"),
                  Query(filters={"a": Eq(2)}, agg="select")):
            rd, rh = merged.execute(q), host.execute(q)
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)
            if q.agg == "select":
                np.testing.assert_array_equal(rd.selected, rh.selected)

    def test_merge_insert_never_rebuilds_device_state(self, rng, monkeypatch):
        """The incremental path must not re-upload: after placement,
        build_device_state is forbidden and merges + reads still work
        (including capacity growth past the padded block)."""
        import repro.kernels as kernels

        t = self._table(rng, n=2000).place_on_device()
        monkeypatch.setattr(
            kernels, "build_device_state",
            lambda *a, **k: pytest.fail("device state rebuilt on write"),
        )
        merged = t
        cap = t._device["keys"].shape[1]
        # size the runs so the third append crosses the padded capacity,
        # whatever DEVICE_BLOCK_N is — the jnp.pad growth branch of
        # device_state_append must be exercised, not just in-place writes
        run_n = (cap - 2000) // 3 + 256
        for i in range(3):
            kc = {"a": np.full(run_n, i % 16), "b": np.arange(run_n) % 16}
            vc = {"m": np.linspace(0, 1, run_n)}
            merged = merged.merge_insert(kc, vc)
        assert merged.device_resident and merged._device["n_runs"] == 4
        assert merged._device["keys"].shape[1] > cap  # capacity grew
        host = SortedTable(
            merged.layout, merged.schema, merged.key_cols, merged.value_cols,
            merged.packed,
        )
        for q in (Query(filters={"a": Eq(1)}, agg="count"),
                  Query(filters={"b": Eq(3)}, agg="select")):
            rd, rh = merged.execute(q), host.execute(q)
            assert rd.rows_matched == rh.rows_matched
            assert rd.rows_scanned == rh.rows_scanned
            if q.agg == "select":
                np.testing.assert_array_equal(rd.selected, rh.selected)

    def test_legacy_scan_guards_float32_count_rows(self, rng, monkeypatch):
        """table_scan_device_many still counts in a float32 lane (exact
        to 2**24): tables past that must be rejected at ITS entry point
        even though placement (int32 fused path) now allows them."""
        from repro.kernels import ops, table_execute_device_many

        t = self._table(rng).place_on_device()
        q = Query(filters={"a": Eq(3)}, agg="count")
        monkeypatch.setattr(ops, "FLOAT32_EXACT_ROWS", t.n_rows - 1)
        with pytest.raises(ValueError, match="float32 count lane"):
            ops.table_scan_device_many(t, [q])
        # the fused int32 path is unaffected
        (res,) = table_execute_device_many(t, [q])
        assert res.rows_matched == t.execute(q).rows_matched

    def test_empty_merge_run_costs_no_run(self, rng):
        """An empty write run must leave the device state untouched:
        n_runs stays 1 and row_map stays None, so the single-run fast
        paths (device slab_many, no-gather select) survive."""
        t = self._table(rng).place_on_device()
        merged = t.merge_insert(
            {"a": np.empty(0, np.int64), "b": np.empty(0, np.int64)},
            {"m": np.empty(0, np.float64)},
        )
        assert merged.device_resident
        assert merged._device["n_runs"] == 1
        assert merged._device["row_map"] is None
        for agg in ("count", "select"):
            q = Query(filters={"a": Eq(3)}, agg=agg)
            got, ref = merged.execute(q), t.execute(q)
            assert got.rows_matched == ref.rows_matched
            if agg == "select":
                np.testing.assert_array_equal(got.selected, ref.selected)

    def test_wide_select_falls_back_to_mask_compaction(self, rng, monkeypatch):
        """Selects matching more rows than SELECT_COMPACT_MAX_WIDTH skip
        the compaction kernel (its (Q_pad, width) output block must stay
        VMEM-sized) and compact a device membership mask on host instead
        — same indices, still zero numpy residual scans, and a narrow
        select sharing the batch still takes the kernel."""
        from repro.kernels import ops

        t = self._table(rng).place_on_device()
        host = SortedTable(t.layout, t.schema, t.key_cols, t.value_cols, t.packed)
        # appended runs: the mask fallback must translate device row
        # order back to host order through row_map too
        merged = t.merge_insert(
            {"a": np.full(50, 3), "b": np.arange(50) % 16},
            {"m": np.linspace(0, 1, 50)},
        )
        hmerged = SortedTable(
            merged.layout, merged.schema, merged.key_cols, merged.value_cols,
            merged.packed,
        )
        wide_q = Query(filters={"a": Eq(3)}, agg="select")  # ~2000/16 rows
        narrow_q = Query(filters={"a": Eq(3), "b": Eq(5)}, agg="select")
        ref_wide, ref_narrow = host.execute(wide_q), host.execute(narrow_q)
        ref_merged = hmerged.execute(wide_q)
        assert ref_wide.rows_matched > 64  # the lowered cap splits the batch

        monkeypatch.setattr(ops, "SELECT_COMPACT_MAX_WIDTH", 64)
        monkeypatch.setattr(
            _SortedTable, "_scan_slab",
            lambda *a, **k: pytest.fail("numpy residual scan on device path"),
        )
        got_wide, got_narrow = t.execute_many([wide_q, narrow_q])
        assert got_wide.rows_matched == ref_wide.rows_matched
        np.testing.assert_array_equal(got_wide.selected, ref_wide.selected)
        np.testing.assert_array_equal(got_narrow.selected, ref_narrow.selected)

        got = merged.execute(wide_q)
        assert got.rows_matched == ref_merged.rows_matched
        np.testing.assert_array_equal(got.selected, ref_merged.selected)

    def test_place_on_device_rebuild_escape_hatch(self, rng):
        """place_on_device() on a resident table is a no-op;
        rebuild=True collapses appended runs into one sorted upload
        (identity row order) with identical results."""
        t = self._table(rng).place_on_device()
        state = t._device
        assert t.place_on_device()._device is state  # no-op
        merged = t.merge_insert(
            {"a": np.array([3]), "b": np.array([3])}, {"m": np.array([0.5])}
        )
        assert merged._device["n_runs"] == 2
        q = Query(filters={"a": Eq(3)}, agg="select")
        before = merged.execute(q)
        merged.place_on_device(rebuild=True)
        assert merged._device["n_runs"] == 1 and merged._device["row_map"] is None
        after = merged.execute(q)
        assert before.rows_matched == after.rows_matched
        np.testing.assert_array_equal(before.selected, after.selected)


class TestDeviceSlabLocation:
    def test_slab_many_uses_locate_kernel(self, rng, monkeypatch):
        """On a single-run resident table, slab_many routes through the
        device binary-search kernel and agrees with the numpy oracle."""
        import repro.kernels as kernels

        kc = {"a": rng.integers(0, 32, 4000), "b": rng.integers(0, 32, 4000)}
        vc = {"m": rng.uniform(0, 1, 4000)}
        dev = SortedTable.from_columns(kc, vc, ("a", "b")).place_on_device()
        host = SortedTable.from_columns(kc, vc, ("a", "b"))
        qs = [Query(filters={"a": Eq(int(rng.integers(0, 32)))}) for _ in range(6)]
        qs += [Query(filters={"b": Range(4, 4)}), Query(filters={})]
        np.testing.assert_array_equal(dev.slab_many(qs), host.slab_many(qs))
        calls = {"n": 0}
        real = kernels.table_slab_locate_many

        def counting(*a, **k):
            calls["n"] += 1
            return real(*a, **k)

        monkeypatch.setattr(kernels, "table_slab_locate_many", counting)
        dev.slab_many(qs)
        assert calls["n"] == 1
        host.slab_many(qs)
        assert calls["n"] == 1  # host tables keep the numpy path

    def test_slab_many_falls_back_after_append(self, rng):
        """Appended runs break sorted device order: slab_many must
        return host-order slabs via the numpy path, still correct."""
        kc = {"a": rng.integers(0, 16, 1000), "b": rng.integers(0, 16, 1000)}
        vc = {"m": rng.uniform(0, 1, 1000)}
        dev = SortedTable.from_columns(kc, vc, ("a", "b")).place_on_device()
        merged = dev.merge_insert(
            {"a": np.array([5, 6]), "b": np.array([1, 2])},
            {"m": np.array([0.1, 0.2])},
        )
        host = SortedTable(
            merged.layout, merged.schema, merged.key_cols, merged.value_cols,
            merged.packed,
        )
        qs = [Query(filters={"a": Eq(5)}), Query(filters={})]
        np.testing.assert_array_equal(merged.slab_many(qs), host.slab_many(qs))


class TestResultCache:
    def _engine(self, rng, **kw):
        kc, vc, schema = generate_simulation(8_000, 3, seed=3)
        eng = HREngine(n_nodes=4, **kw)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        return eng, schema

    def test_hit_miss_counters_and_identity(self, rng):
        eng, _ = self._engine(rng)
        q = Query(filters={"k0": Eq(3)}, agg="count")
        r1, rep1 = eng.read("cf", q)
        assert eng.stats["result_cache_misses"] == 1
        assert eng.stats["result_cache_hits"] == 0
        # same replica serves the repeat (single query, rr over ties of
        # the same cost set) — force it by reading until a hit lands
        hits_before = eng.stats["result_cache_hits"]
        vals = {eng.read("cf", q)[0].value for _ in range(4)}
        assert vals == {r1.value}
        assert eng.stats["result_cache_hits"] > hits_before
        assert eng.stats["result_cache_entries"] >= 1

    def test_read_many_uses_cache(self, rng):
        eng, _ = self._engine(rng)
        qs = [Query(filters={"k1": Eq(i)}, agg="count") for i in range(5)]
        first = eng.read_many("cf", qs)
        misses = eng.stats["result_cache_misses"]
        second = eng.read_many("cf", qs)
        assert eng.stats["result_cache_misses"] == misses  # all hits
        assert eng.stats["result_cache_hits"] >= len(qs)
        for (ra, _), (rb, _) in zip(first, second):
            assert ra.value == rb.value and ra.rows_scanned == rb.rows_scanned

    def test_same_slab_different_residual_not_conflated(self, rng):
        """Two queries can share packed slab bounds while differing in
        residual filters — the filter signature keeps them apart. One
        replica with layout (k0, k1, k2): a leading k0 range opens the
        prefix, so a residual k1 filter changes the result but not the
        slab."""
        kc, vc, schema = generate_simulation(8_000, 3, seed=3)
        eng = HREngine(n_nodes=2)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
        )
        qa = Query(filters={"k0": Range(0, 4)}, agg="count")
        qb = Query(filters={"k0": Range(0, 4), "k1": Eq(2)}, agg="count")
        ra, _ = eng.read("cf", qa)
        rb, _ = eng.read("cf", qb)
        assert ra.rows_scanned == rb.rows_scanned  # same slab
        assert ra.value > rb.value  # different residual result

    def test_write_invalidates(self, rng):
        eng, schema = self._engine(rng)
        q = Query(filters={"k0": Eq(1)}, agg="count")
        before, _ = eng.read("cf", q)
        eng.read("cf", q)
        kc2 = {c: np.full(50, 1 if c == "k0" else 0) for c in ("k0", "k1", "k2")}
        eng.write("cf", kc2, {"metric": np.zeros(50)})
        assert eng.stats["result_cache_entries"] == 0
        after, _ = eng.read("cf", q)
        assert after.value == before.value + 50  # fresh, not cached

    def test_recover_invalidates_and_disable_switch(self, rng):
        eng, _ = self._engine(rng)
        q = Query(filters={"k2": Eq(2)}, agg="count")
        eng.read("cf", q)
        victim = eng.column_families["cf"].replicas[0].node_id
        eng.fail_node(victim)
        eng.recover_node(victim)
        assert all(
            key[1] != eng.column_families["cf"].replicas[0].replica_id
            for key in eng._result_cache
        )
        off, _ = self._engine(rng, result_cache=False)
        off.read("cf", q)
        off.read("cf", q)
        assert off.stats["result_cache_hits"] == 0
        assert off.stats["result_cache_misses"] == 0

    def test_cached_select_identical(self, rng):
        eng, _ = self._engine(rng)
        q = Query(filters={"k0": Eq(4)}, agg="select")
        first = [eng.read("cf", q)[0] for _ in range(3)]
        base = first[0].selected
        assert base is not None
        for r in first[1:]:
            np.testing.assert_array_equal(r.selected, base)
        # hits share one array object, so it is frozen on the way into
        # the cache — caller-side mutation must not corrupt later hits
        with pytest.raises(ValueError):
            base[...] = -1

    def test_cache_bounded_fifo(self, rng):
        """Per-replica maps evict FIFO at result_cache_max_entries, so
        all-distinct-query workloads cannot grow memory without bound."""
        kc, vc, schema = generate_simulation(8_000, 3, seed=3)
        eng = HREngine(n_nodes=4, result_cache_max_entries=8)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
        )
        for v in range(12):
            eng.read("cf", Query(filters={"k0": Eq(v)}, agg="count"))
        assert eng.stats["result_cache_entries"] <= 8
        eng.read("cf", Query(filters={"k0": Eq(11)}, agg="count"))  # resident
        assert eng.stats["result_cache_hits"] == 1
        eng.read("cf", Query(filters={"k0": Eq(0)}, agg="count"))  # evicted
        assert eng.stats["result_cache_misses"] == 13

    def test_read_many_hit_survives_eviction_by_miss_store(self, rng):
        """Storing a group's misses can FIFO-evict a key that was a hit
        when the group was classified — the hit's value must have been
        read out already, not looked up afterwards."""
        kc, vc, schema = generate_simulation(8_000, 3, seed=3)
        eng = HREngine(n_nodes=4, result_cache_max_entries=1)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
        )
        qa = Query(filters={"k0": Eq(1)}, agg="count")
        qb = Query(filters={"k0": Eq(2)}, agg="count")
        (ra, _), = [eng.read("cf", qa)]
        res = eng.read_many("cf", [qa, qb])  # qa hits, qb's store evicts it
        assert res[0][0].value == ra.value
        assert eng.stats["result_cache_hits"] == 1

    def test_zero_max_entries_rejected(self, rng):
        with pytest.raises(ValueError, match="result_cache=False"):
            HREngine(n_nodes=2, result_cache_max_entries=0)

    def test_cache_select_byte_budget(self, rng, monkeypatch):
        """Retained select-index bytes per replica map are budgeted:
        oversized entries are never cached, and stores evict FIFO until
        the map fits the byte budget — entry count alone must not let
        select arrays grow memory without bound."""
        kc, vc, schema = generate_simulation(8_000, 3, seed=3)
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
        )
        monkeypatch.setattr(HREngine, "_CACHE_MAX_SELECT_BYTES", 1 << 30)
        monkeypatch.setattr(HREngine, "_CACHE_MAX_MAP_BYTES", 1 << 14)
        for v in range(6):
            eng.read("cf", Query(filters={"k0": Eq(v)}, agg="select"))
        assert 0 < eng.stats["result_cache_select_bytes"] <= (1 << 14)
        # an entry bigger than the per-entry cap is served, not cached
        monkeypatch.setattr(HREngine, "_CACHE_MAX_SELECT_BYTES", 8)
        entries = eng.stats["result_cache_entries"]
        r, _ = eng.read("cf", Query(filters={}, agg="select"))
        assert r.rows_matched == 8_000
        assert eng.stats["result_cache_entries"] == entries

    @staticmethod
    def _check_byte_accounting(eng, map_keys):
        """The audited invariant: each map's recorded select bytes equal
        the true retained sum (so the counter can neither drift negative
        nor leak), entry counts respect the FIFO bound, and no byte
        entry outlives its map."""
        for mk in map_keys:
            cache = eng._result_cache.get(mk, {})
            actual = sum(
                r.selected.nbytes for r in cache.values() if r.selected is not None
            )
            recorded = eng._cache_sel_bytes.get(mk, 0)
            assert recorded == actual
            assert recorded >= 0
            assert len(cache) <= eng._cache_max
            assert actual <= eng._CACHE_MAX_MAP_BYTES
        assert set(eng._cache_sel_bytes) <= set(eng._result_cache)

    def test_select_byte_accounting_never_drifts(self, rng, monkeypatch):
        """Satellite audit (deterministic twin of the hypothesis
        property): ``_cache_sel_bytes`` equals the true retained
        selected-array bytes after ANY sequence of store / overwrite /
        evict / invalidate — in particular the overwrite-then-evict
        interleaving, where an overwritten key's bytes are subtracted
        before the eviction loop recomputes the running total."""
        from repro.core.table import ScanResult

        kc, vc, schema = generate_simulation(2_000, 3, seed=3)
        eng = HREngine(n_nodes=4, result_cache_max_entries=3)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        # tiny budgets so overwrite, FIFO and byte evictions all fire
        monkeypatch.setattr(HREngine, "_CACHE_MAX_SELECT_BYTES", 256)
        monkeypatch.setattr(HREngine, "_CACHE_MAX_MAP_BYTES", 512)
        map_keys = [("cf", 0), ("cf", 1)]
        r = np.random.default_rng(7)
        stored = 0
        for _ in range(400):
            mk = map_keys[int(r.integers(0, 2))]
            if r.random() < 0.85:
                # small key space → frequent overwrites of live entries
                key = ("select", None, (("k0", int(r.integers(0, 5))),))
                n_sel = int(r.integers(0, 40))  # some exceed the entry cap
                sel = (
                    np.arange(n_sel, dtype=np.int64)
                    if r.random() < 0.8
                    else None  # count/sum entries carry no select bytes
                )
                res = ScanResult(float(n_sel), n_sel, n_sel, selected=sel)
                cache = eng._result_cache.setdefault(mk, {})
                eng._cache_store(mk, cache, key, res)
                stored += 1
            else:
                eng._invalidate_result_cache("cf", replica_id=mk[1])
            self._check_byte_accounting(eng, map_keys)
        assert stored > 300  # the sequence actually exercised stores
        eng._invalidate_result_cache("cf")
        assert eng._result_cache == {} and eng._cache_sel_bytes == {}

    def test_byte_accounting_through_real_reads(self, rng):
        """End-to-end twin: after reads, writes (invalidation) and more
        reads through the public API, the recorded select bytes equal a
        recount over the live maps."""
        kc, vc, schema = generate_simulation(4_000, 3, seed=3)
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        map_keys = [("cf", 0), ("cf", 1)]
        for v in range(6):
            eng.read("cf", Query(filters={"k0": Eq(v)}, agg="select"))
        self._check_byte_accounting(eng, map_keys)
        total = sum(
            r.selected.nbytes
            for c in eng._result_cache.values()
            for r in c.values()
            if r.selected is not None
        )
        assert eng.stats["result_cache_select_bytes"] == total > 0
        kw = {c: np.full(10, 2 if c == "k0" else 0) for c in ("k0", "k1", "k2")}
        eng.write("cf", kw, {"metric": np.zeros(10)})  # invalidates all
        self._check_byte_accounting(eng, map_keys)
        assert eng.stats["result_cache_select_bytes"] == 0
        eng.read_many(
            "cf", [Query(filters={"k1": Eq(i)}, agg="select") for i in range(4)]
        )
        self._check_byte_accounting(eng, map_keys)
