"""Device-resident batched read path: engine/table routing through the
row-streaming Pallas kernel.

The acceptance bar for the device path is *identity* with the sequential
scalar path: ``read_many`` on a device-resident column family must return
per-query results equal to a loop of ``read`` (both route through the
same kernel — the scalar path is the Q = 1 launch), and equal to the
numpy engine up to float32 accumulation for sums (exactly, for counts
and rows_scanned).
"""

import copy

import numpy as np
import pytest

from repro.core import Eq, HREngine, KeySchema, Query, Range, SortedTable, random_workload
from repro.core.tpch import generate_simulation

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


@pytest.fixture(scope="module")
def setup():
    kc, vc, schema = generate_simulation(30_000, 3, seed=0)
    rng = np.random.default_rng(1)
    wl = random_workload(rng, schema, list(kc), 30, agg="sum", value_col="metric")
    # mixed agg kinds in one batch: sums + counts
    queries = list(wl.queries[:20]) + [
        Query(filters=q.filters, agg="count") for q in wl.queries[20:]
    ]
    dev = HREngine(n_nodes=5)
    dev.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        device_resident=True,
    )
    host = HREngine(n_nodes=5)
    host.create_column_family(
        "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
    )
    return dev, host, queries, schema, kc


class TestDeviceReadMany:
    def test_tables_resident(self, setup):
        dev, _, _, _, _ = setup
        tables = [t for n in dev.nodes for t in n.tables.values()]
        assert tables and all(t.device_resident for t in tables)

    def test_read_many_identical_to_sequential_read(self, setup):
        """The acceptance criterion: per-query results (values included)
        identical between read_many and a sequential read loop."""
        dev, _, queries, _, _ = setup
        eng_a, eng_b = copy.deepcopy(dev), copy.deepcopy(dev)
        seq = [eng_a.read("cf", q) for q in queries]
        bat = eng_b.read_many("cf", queries)
        for (rs, rep_s), (rb, rep_b) in zip(seq, bat):
            assert rb.value == rs.value
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.rows_matched == rs.rows_matched
            assert rep_b.replica_id == rep_s.replica_id
            assert rep_b.node_id == rep_s.node_id

    def test_matches_numpy_engine(self, setup):
        """Counts and rows_scanned exact vs the numpy reference engine;
        sums within float32 accumulation tolerance."""
        dev, host, queries, _, _ = setup
        bat = copy.deepcopy(dev).read_many("cf", queries)
        ref = copy.deepcopy(host).read_many("cf", queries)
        for (rd, _), (rh, _) in zip(bat, ref):
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)

    def test_select_agg_falls_back_in_mixed_batch(self, setup):
        """A "select" query needs row indices the kernel does not emit:
        it takes the numpy path while the rest of the batch stays on
        device, and the partition is invisible in the results."""
        dev, host, queries, _, _ = setup
        qsel = Query(filters={"k0": Eq(1)}, agg="select")
        batch = [queries[0], qsel, queries[1]]
        out = copy.deepcopy(dev).read_many("cf", batch)
        ref = copy.deepcopy(host).read_many("cf", batch)
        assert out[1][0].selected is not None
        np.testing.assert_array_equal(out[1][0].selected, ref[1][0].selected)
        for (rd, _), (rh, _) in zip(out, ref):
            assert rd.rows_matched == rh.rows_matched

    def test_empty_range_on_device(self, setup):
        dev, _, _, _, _ = setup
        q = Query(filters={"k1": Range(2, 2)}, agg="count")
        ((res, rep),) = copy.deepcopy(dev).read_many("cf", [q])
        assert res.value == 0.0 and res.rows_scanned == 0 and res.rows_matched == 0

    def test_write_then_read_stays_on_device_and_correct(self, setup):
        dev, host, queries, schema, kc = setup
        dev2, host2 = copy.deepcopy(dev), copy.deepcopy(host)
        rng = np.random.default_rng(7)
        kc2 = {c: rng.integers(0, schema.max_value(c) + 1, 400) for c in kc}
        vc2 = {"metric": rng.uniform(0, 1, 400)}
        dev2.write("cf", kc2, vc2)
        host2.write("cf", kc2, vc2)
        assert all(
            t.device_resident for n in dev2.nodes for t in n.tables.values()
        )
        bat = dev2.read_many("cf", queries[:8])
        ref = host2.read_many("cf", queries[:8])
        for (rd, _), (rh, _) in zip(bat, ref):
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)

    def test_recovery_replaces_on_device(self, setup):
        dev, _, queries, _, _ = setup
        dev2 = copy.deepcopy(dev)
        victim = dev2.column_families["cf"].replicas[0].node_id
        dev2.fail_node(victim)
        dev2.recover_node(victim)
        assert dev2.nodes[victim].tables
        assert all(t.device_resident for t in dev2.nodes[victim].tables.values())
        out = dev2.read_many("cf", queries[:5])
        assert all(r is not None for r, _ in out)


class TestTableResidency:
    def _table(self, rng, n=2000):
        kc = {"a": rng.integers(0, 16, n), "b": rng.integers(0, 16, n)}
        vc = {"m": rng.uniform(0, 1, n)}
        return SortedTable.from_columns(kc, vc, ("a", "b"))

    def test_place_and_evict(self, rng):
        t = self._table(rng)
        assert not t.device_resident
        assert t.place_on_device() is t and t.device_resident
        q = Query(filters={"a": Eq(3)}, agg="count")
        on_dev = t.execute(q)
        t.evict_from_device()
        assert not t.device_resident
        off_dev = t.execute(q)
        assert on_dev.value == off_dev.value
        assert on_dev.rows_scanned == off_dev.rows_scanned

    def test_scalar_equals_batched_on_device(self, rng):
        """execute (Q = 1 launch) and execute_many (grouped launch)
        agree exactly — both sides of the engine's identity contract."""
        t = self._table(rng).place_on_device()
        qs = [
            Query(filters={"a": Eq(int(rng.integers(0, 16)))}, agg="sum", value_col="m")
            for _ in range(9)
        ] + [Query(filters={"b": Range(2, 9)}, agg="count")]
        many = t.execute_many(qs)
        for q, rb in zip(qs, many):
            rs = t.execute(q)
            assert rb.value == rs.value
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.rows_matched == rs.rows_matched

    def test_wide_schema_resident(self, rng):
        """A 40-bit key column rides two int32 lanes on device."""
        schema = KeySchema({"a": 40, "b": 8})
        kc = {"a": rng.integers(0, 2**40, 1500).astype(np.int64),
              "b": rng.integers(0, 256, 1500).astype(np.int64)}
        vc = {"m": rng.uniform(0, 5, 1500)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"), schema).place_on_device()
        host = SortedTable.from_columns(kc, vc, ("a", "b"), schema)
        lo = int(rng.integers(0, 2**39))
        qs = [Query(filters={"a": Range(lo, lo + 2**36)}, agg="sum", value_col="m"),
              Query(filters={"b": Eq(7)}, agg="count"),
              Query(filters={}, agg="count")]
        for q, rd in zip(qs, t.execute_many(qs)):
            rh = host.execute(q)
            assert rd.rows_scanned == rh.rows_scanned
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)

    def test_merge_insert_drops_stale_cache(self, rng):
        """merge_insert returns a fresh table without the old device
        cache — stale device columns must never serve reads."""
        t = self._table(rng).place_on_device()
        merged = t.merge_insert(
            {"a": np.array([1, 2]), "b": np.array([3, 4])},
            {"m": np.array([0.5, 0.25])},
        )
        assert not merged.device_resident
        q = Query(filters={"a": Eq(1)}, agg="count")
        assert merged.execute(q).value == merged.place_on_device().execute(q).value
