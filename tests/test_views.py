"""Materialized per-slab aggregate views (``repro.core.storage.views``).

The acceptance bar is *bit-identity*: a view-routed sum/count must
return the exact float the fused full-scan launch returns — same
float32 partials, same sequential block-order fold — across fresh
builds, incremental flush extensions, compactions, migrations and
scrub heals. Everything else (eligibility walk, cost capping, counters,
the all-select fast path) hangs off that invariant.
"""

import copy

import numpy as np
import pytest

from repro.core import Eq, HREngine, KeySchema, Query, Range, SortedTable
from repro.core.storage.memtable import sort_run
from repro.core.storage.views import (
    VIEW_ROWS_CAP,
    build_views_state,
    query_view_eligible,
    serve_view_many,
    verify_views,
    view_eligible_matrix,
)
from repro.core.tpch import generate_simulation
from repro.core.workload import Workload
from repro.kernels import (
    DEVICE_BLOCK_N,
    block_sums,
    block_sums_ref,
    boundary_block_sums,
)

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def random_queries(rng, n, *, domains, aggs=("sum", "count"), value_col="metric"):
    qs = []
    cols = list(domains)
    for _ in range(n):
        f = {}
        for c in cols:
            d = domains[c]
            r = rng.random()
            if r < 0.35:
                f[c] = Eq(int(rng.integers(0, d)))
            elif r < 0.65:
                lo = int(rng.integers(0, d - 1))
                f[c] = Range(lo, int(rng.integers(lo + 1, d + 1)))
        qs.append(
            Query(
                agg=str(rng.choice(list(aggs))), filters=f, value_col=value_col
            )
        )
    return qs


# -- kernel vs oracle -----------------------------------------------------


@pytest.mark.kernel
class TestBlockSumsKernel:
    @pytest.mark.parametrize("shape", [(1, 100), (3, 8192), (4, 40_000)])
    def test_matches_ref(self, rng, shape):
        vals = rng.standard_normal(shape).astype(np.float32)
        got = np.asarray(block_sums(vals, block_n=DEVICE_BLOCK_N))
        want = np.asarray(block_sums_ref(vals, block_n=DEVICE_BLOCK_N))
        assert got.shape == want.shape
        np.testing.assert_array_equal(got, want)

    def test_boundary_matches_interior_on_full_window(self, rng):
        # a boundary rescan whose window covers the whole block must
        # reproduce the stored partial bit-for-bit — the property the
        # serve fold's interior/boundary split relies on
        n = 3 * DEVICE_BLOCK_N
        vals = rng.standard_normal((2, n)).astype(np.float32)
        full = np.asarray(block_sums_ref(vals, block_n=DEVICE_BLOCK_N))
        got = np.asarray(
            boundary_block_sums(
                vals,
                [1, 0, 1],
                [0, 1, 2],
                np.array([[0, n]] * 3, np.int64)[:, :1],
                np.array([[n]] * 3, np.int64)[:, :1] * 0 + n,
                block_n=DEVICE_BLOCK_N,
            )
        )
        want = np.array([full[1, 0], full[0, 1], full[1, 2]], np.float32)
        np.testing.assert_array_equal(got, want)


# -- eligibility walk ------------------------------------------------------


class TestEligibility:
    LAYOUT = ("a", "b", "c")

    def cases(self):
        return [
            (Query(agg="sum", filters={"a": Eq(1)}, value_col="v"), True),
            (Query(agg="count", filters={}), True),
            (Query(agg="sum", filters={"a": Eq(1), "b": Range(0, 5)},
                   value_col="v"), True),
            (Query(agg="sum", filters={"a": Range(0, 5)}, value_col="v"),
             True),
            # filter after the prefix opens → residual scan required
            (Query(agg="sum", filters={"b": Eq(1)}, value_col="v"), False),
            (Query(agg="sum", filters={"a": Range(0, 5), "b": Eq(1)},
                   value_col="v"), False),
            (Query(agg="sum", filters={"a": Eq(1), "c": Eq(2)},
                   value_col="v"), False),
            # selects never route through views
            (Query(agg="select", filters={"a": Eq(1)}), False),
        ]

    def test_walk(self):
        for q, want in self.cases():
            assert query_view_eligible(q, self.LAYOUT) is want, q

    def test_matrix_matches_scalar(self):
        qs = [q for q, _ in self.cases()]
        layouts = [self.LAYOUT, ("c", "b", "a")]
        m = view_eligible_matrix(layouts, qs)
        for k, lay in enumerate(layouts):
            for j, q in enumerate(qs):
                assert m[k, j] == query_view_eligible(q, lay)


# -- table-level bit-identity ---------------------------------------------


@pytest.fixture(scope="module")
def table_pair():
    """(views table, fused twin) over the same 40k-row dataset."""
    kc, vc, schema = generate_simulation(40_000, 3, seed=2)
    tv = SortedTable.from_columns(kc, vc, LAYOUTS[0], schema)
    tv.place_on_device()
    tv.build_views()
    tf = SortedTable.from_columns(kc, vc, LAYOUTS[0], schema)
    tf.place_on_device()
    return tv, tf, schema


class TestTableBitIdentity:
    def queries(self, seed=5, n=40):
        rng = np.random.default_rng(seed)
        return random_queries(rng, n, domains={"k0": 64, "k1": 64, "k2": 64})

    def test_fresh_build(self, table_pair):
        tv, tf, _ = table_pair
        qs = self.queries()
        rv = tv.execute_many(qs)
        rf = tf.execute_many(qs)
        for q, a, b in zip(qs, rv, rf):
            assert a.value == b.value, q
            assert a.rows_matched == b.rows_matched
            assert a.rows_scanned == b.rows_scanned

    def test_view_actually_serves(self, table_pair):
        tv, _, _ = table_pair
        elig = [
            q for q in self.queries() if query_view_eligible(q, tv.layout)
        ]
        assert elig, "query generator must produce eligible queries"
        stats = {}
        tv.execute_many(elig, view_stats=stats)
        assert stats["hits"] == len(elig)

    def test_drip_and_compaction_stay_identical(self, table_pair):
        tv, tf, schema = table_pair
        tv, tf = copy.deepcopy(tv), copy.deepcopy(tf)
        rng = np.random.default_rng(9)
        qs = self.queries(seed=11)
        for step in range(3):
            m = int(rng.integers(500, 4000))
            kw = {c: rng.integers(0, 64, m).astype(np.int64)
                  for c in ("k0", "k1", "k2")}
            vw = {"metric": rng.standard_normal(m)}
            run = sort_run(kw, vw, tv.layout, schema)
            tv = tv.merge_run(run)
            tf = tf.merge_run(run)
            assert verify_views(tv), f"step {step}: stale view after merge"
            for a, b in zip(tv.execute_many(qs), tf.execute_many(qs)):
                assert a.value == b.value and a.rows_matched == b.rows_matched
        tv = tv.compact_runs()
        tf = tf.compact_runs()
        assert verify_views(tv), "stale view after compaction"
        for a, b in zip(tv.execute_many(qs), tf.execute_many(qs)):
            assert a.value == b.value and a.rows_matched == b.rows_matched

    def test_serve_matches_execute_per_query(self, table_pair):
        tv, _, _ = table_pair
        elig = [
            q for q in self.queries(seed=13)
            if query_view_eligible(q, tv.layout)
        ]
        batch = serve_view_many(tv, elig)
        for q, r in zip(elig, batch):
            s = tv.execute(q)
            assert r.value == s.value and r.rows_matched == s.rows_matched

    def test_verify_detects_corruption(self, table_pair):
        tv, _, _ = table_pair
        tv = copy.deepcopy(tv)
        assert verify_views(tv)
        tv._device["views"]["block_sums"][0, 0] += 1.0
        assert not verify_views(tv)
        tv.build_views()
        assert verify_views(tv)

    def test_build_views_requires_device(self):
        kc, vc, schema = generate_simulation(1000, 2, seed=0)
        t = SortedTable.from_columns(kc, vc, ("k0", "k1"), schema)
        with pytest.raises(ValueError):
            t.build_views()


# -- engine parity: views on vs off ---------------------------------------


@pytest.fixture(scope="module")
def engine_pair():
    kc, vc, schema = generate_simulation(50_000, 3, seed=4)

    def build(views):
        e = HREngine(n_nodes=5, result_cache=False)
        e.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS,
            schema=schema, device_resident=True, views=views,
            memtable_rows=0,
        )
        return e

    return build(True), build(False)


def assert_parity(ev, ef, queries, *, tag=""):
    rv = ev.read_many("cf", queries)
    rf = ef.read_many("cf", queries)
    for q, (a, _), (b, _) in zip(queries, rv, rf):
        assert a.value == b.value, f"{tag}: {q}"
        assert a.rows_matched == b.rows_matched, f"{tag}: {q}"
        if a.selected is not None or b.selected is not None:
            np.testing.assert_array_equal(a.selected, b.selected)


class TestEngineParity:
    def queries(self, seed=21, n=40, aggs=("sum", "count", "select")):
        rng = np.random.default_rng(seed)
        return random_queries(
            rng, n, domains={"k0": 64, "k1": 64, "k2": 64}, aggs=aggs
        )

    def test_read_many_parity_and_hits(self, engine_pair):
        ev, ef = engine_pair
        ev.reset_stats()
        assert_parity(ev, ef, self.queries(), tag="fresh")
        assert ev.stats["view_hits"] > 0
        assert ef.stats["view_hits"] == 0

    def test_scalar_read_parity(self, engine_pair):
        ev, ef = engine_pair
        for q in self.queries(seed=23, n=12):
            a, _ = ev.read("cf", q)
            b, _ = ef.read("cf", q)
            assert a.value == b.value and a.rows_matched == b.rows_matched

    def test_view_routing_caps_estimated_cost(self, engine_pair):
        ev, ef = engine_pair
        # an unfiltered sum is view-eligible on every layout: the view
        # engine's planner must see the capped (cheap) estimate
        q = Query(agg="sum", filters={}, value_col="metric")
        _, rep_v = ev.read("cf", q)
        _, rep_f = ef.read("cf", q)
        assert rep_v.estimated_cost < rep_f.estimated_cost
        fn = ev.column_families["cf"].cost_model.cost_fn(3)
        assert rep_v.estimated_cost == fn(
            min(rep_v.estimated_rows, float(VIEW_ROWS_CAP))
        )

    def test_write_flush_compaction_parity(self, engine_pair):
        ev, ef = copy.deepcopy(engine_pair[0]), copy.deepcopy(engine_pair[1])
        rng = np.random.default_rng(31)
        qs = self.queries(seed=33)
        for _ in range(2):
            m = int(rng.integers(2000, 6000))
            kw = {c: rng.integers(0, 64, m).astype(np.int64)
                  for c in ("k0", "k1", "k2")}
            vw = {"metric": rng.standard_normal(m)}
            ev.write("cf", kw, vw)
            ef.write("cf", kw, vw)
            assert_parity(ev, ef, qs, tag="post-write")
        for node in ev.nodes:
            for t in node.tables.values():
                assert t.has_views and verify_views(t)

    def test_stats_expose_view_counters(self, engine_pair):
        ev, _ = engine_pair
        for key in ("view_hits", "view_boundary_rows", "view_rebuilds"):
            assert key in ev.stats
            assert key in ev.metrics.catalog()

    def test_views_require_device_resident(self):
        kc, vc, schema = generate_simulation(1000, 2, seed=0)
        e = HREngine(n_nodes=2)
        with pytest.raises(ValueError, match="device_resident"):
            e.create_column_family(
                "cf", kc, vc, replication_factor=1,
                layouts=[("k0", "k1")], schema=schema, views=True,
            )


class TestSelectOnlyFastPath:
    def test_all_select_batch_skips_eligibility_arrays(self, engine_pair,
                                                       monkeypatch):
        """Regression: a batch of pure selects used to walk the
        aggregate planning arrays; now it must never touch them."""
        ev, _ = engine_pair
        import repro.core.engine as engine_mod

        def boom(*a, **k):  # pragma: no cover - failure path
            raise AssertionError(
                "select-only batch walked the view planning arrays"
            )

        monkeypatch.setattr(engine_mod, "view_eligible_matrix", boom)
        qs = [
            Query(agg="select", filters={"k0": Eq(i % 16)})
            for i in range(8)
        ]
        res = ev.read_many("cf", qs)
        assert len(res) == 8
        for (r, _), q in zip(res, qs):
            assert r.selected is not None

    def test_mixed_batch_still_routes_views(self, engine_pair):
        ev, ef = engine_pair
        ev.reset_stats()
        qs = [
            Query(agg="select", filters={"k0": Eq(3)}),
            Query(agg="sum", filters={"k0": Range(0, 32)},
                  value_col="metric"),
        ]
        assert_parity(ev, ef, qs, tag="mixed")
        assert ev.stats["view_hits"] >= 1


# -- scrub heals derived view state (satellite 2) --------------------------


class TestScrubHealsViews:
    def test_corrupted_partial_detected_and_rebuilt(self, engine_pair):
        ev = copy.deepcopy(engine_pair[0])
        ev.reset_stats()
        cf = ev.column_families["cf"]
        r0 = cf.replicas[0]
        t0 = ev.nodes[r0.node_id].tables[("cf", r0.replica_id)]
        t0._device["views"]["block_sums"][0, 0] += 0.5
        assert not verify_views(t0)
        res = ev.scrub_column_family("cf")
        assert res["repaired"] == 1
        assert res["corrupt"] == [r0.replica_id]
        assert verify_views(t0)
        assert ev.stats["scrub_repairs"] == 1
        assert ev.stats["view_rebuilds"] == 1

    def test_missing_views_also_healed(self, engine_pair):
        ev = copy.deepcopy(engine_pair[0])
        ev.reset_stats()
        cf = ev.column_families["cf"]
        r0 = cf.replicas[0]
        t0 = ev.nodes[r0.node_id].tables[("cf", r0.replica_id)]
        del t0._device["views"]
        res = ev.scrub_column_family("cf")
        assert res["repaired"] == 1
        assert t0.has_views and verify_views(t0)

    def test_report_only_mode_leaves_corruption(self, engine_pair):
        ev = copy.deepcopy(engine_pair[0])
        cf = ev.column_families["cf"]
        r0 = cf.replicas[0]
        t0 = ev.nodes[r0.node_id].tables[("cf", r0.replica_id)]
        t0._device["views"]["block_sums"][0, 0] += 0.5
        res = ev.scrub_column_family("cf", repair=False)
        assert res["corrupt"] == [r0.replica_id] and res["repaired"] == 0
        assert not verify_views(t0)


# -- migration keeps views consistent --------------------------------------


class TestMigrationViews:
    def build(self, views, partitions=4):
        kc, vc, schema = generate_simulation(40_000, 3, seed=6)
        e = HREngine(n_nodes=5, result_cache=False)
        e.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS,
            schema=schema, device_resident=True, views=views,
            memtable_rows=0, partitions=partitions,
        )
        return e

    def queries(self):
        rng = np.random.default_rng(41)
        return random_queries(
            rng, 30, domains={"k0": 64, "k1": 64, "k2": 64},
            aggs=("sum", "count", "select"),
        )

    def all_views_valid(self, e):
        for part in e.column_families["cf"].partitions:
            for r in part.replicas:
                t = e.nodes[r.node_id].tables.get(("cf", r.replica_id))
                if t is not None:
                    assert t.has_views and verify_views(t)

    def test_split_merge_rebalance_parity(self):
        ev, ef = self.build(True), self.build(False)
        qs = self.queries()
        assert_parity(ev, ef, qs, tag="P=4")
        for e in (ev, ef):
            e.split_partition("cf", 0)
        assert_parity(ev, ef, qs, tag="post-split")
        for e in (ev, ef):
            e.merge_partitions("cf", 1)
        assert_parity(ev, ef, qs, tag="post-merge")
        rng = np.random.default_rng(43)
        m = 4000
        kw = {c: rng.integers(0, 64, m).astype(np.int64)
              for c in ("k0", "k1", "k2")}
        vw = {"metric": rng.standard_normal(m)}
        ev.write("cf", kw, vw)
        ef.write("cf", kw, vw)
        for e in (ev, ef):
            e.rebalance("cf")
        assert_parity(ev, ef, qs, tag="post-rebalance")
        self.all_views_valid(ev)
        assert ev.stats["view_rebuilds"] > 0

    def test_untouched_vnodes_keep_their_views(self):
        ev = self.build(True)
        cf = ev.column_families["cf"]
        # snapshot the partials of a partition the split won't touch
        keep = cf.partitions[-1]
        before = {
            r.replica_id: ev.nodes[r.node_id]
            .tables[("cf", r.replica_id)]
            ._device["views"]["block_sums"]
            .copy()
            for r in keep.replicas
        }
        ev.split_partition("cf", 0)
        keep2 = ev.column_families["cf"].partitions[-1]
        assert keep2.vnode_id == keep.vnode_id
        for r in keep2.replicas:
            t = ev.nodes[r.node_id].tables[("cf", r.replica_id)]
            np.testing.assert_array_equal(
                t._device["views"]["block_sums"], before[r.replica_id]
            )


# -- recovery / node_up ----------------------------------------------------


class TestRecoveryViews:
    def test_recovered_replicas_regain_views(self, engine_pair):
        ev = copy.deepcopy(engine_pair[0])
        ev.reset_stats()
        victim_node = ev.column_families["cf"].replicas[0].node_id
        ev.fail_node(victim_node, transient=False)
        ev.recover_node(victim_node)
        for r in ev.column_families["cf"].replicas:
            t = ev.nodes[r.node_id].tables[("cf", r.replica_id)]
            assert t.has_views and verify_views(t)
        assert ev.stats["view_rebuilds"] > 0

    def test_hinted_node_up_extends_views(self, engine_pair):
        ev = copy.deepcopy(engine_pair[0])
        rng = np.random.default_rng(51)
        victim_node = ev.column_families["cf"].replicas[0].node_id
        ev.fail_node(victim_node, transient=True)
        m = 1500
        kw = {c: rng.integers(0, 64, m).astype(np.int64)
              for c in ("k0", "k1", "k2")}
        vw = {"metric": rng.standard_normal(m)}
        ev.write("cf", kw, vw)
        ev.node_up(victim_node)
        for r in ev.column_families["cf"].replicas:
            t = ev.nodes[r.node_id].tables[("cf", r.replica_id)]
            assert t.has_views and verify_views(t)


# -- interleaving property: P=1 oracle stays bit-identical -----------------


def _interleaving_state(seed, ops):
    """Apply an op sequence to a (views engine, fused engine) pair and
    assert bit-identical eligible reads after every step."""
    kc, vc, schema = generate_simulation(20_000, 3, seed=seed)

    def build(views):
        e = HREngine(n_nodes=5, result_cache=False)
        e.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS,
            schema=schema, device_resident=True, views=views,
            memtable_rows=400, partitions=2,
        )
        return e

    ev, ef = build(True), build(False)
    rng = np.random.default_rng(seed + 17)
    dom = {c: schema.max_value(c) + 1 for c in ("k0", "k1", "k2")}
    qs = random_queries(rng, 12, domains=dom, aggs=("sum", "count"))
    for step, op in enumerate(ops):
        if op == "write":
            m = int(rng.integers(100, 900))
            kw = {c: rng.integers(0, dom[c], m).astype(np.int64)
                  for c in ("k0", "k1", "k2")}
            vw = {"metric": rng.standard_normal(m)}
            ev.write("cf", kw, vw)
            ef.write("cf", kw, vw)
        elif op == "flush":
            ev.flush_memtables("cf")
            ef.flush_memtables("cf")
        elif op == "split":
            pid = int(rng.integers(ev.column_families["cf"].ring.n_partitions))
            ev.split_partition("cf", pid)
            ef.split_partition("cf", pid)
        elif op == "merge":
            n_p = ev.column_families["cf"].ring.n_partitions
            if n_p > 1:
                pid = int(rng.integers(n_p - 1))
                ev.merge_partitions("cf", pid)
                ef.merge_partitions("cf", pid)
        elif op == "rebalance":
            ev.rebalance("cf")
            ef.rebalance("cf")
        assert_parity(ev, ef, qs, tag=f"step {step} ({op})")
    for part in ev.column_families["cf"].partitions:
        for r in part.replicas:
            t = ev.nodes[r.node_id].tables.get(("cf", r.replica_id))
            if t is not None and t.has_views:
                assert verify_views(t), "derived state diverged"


OPS = ("write", "flush", "split", "merge", "rebalance", "read")


class TestInterleavingDeterministic:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_random_interleavings(self, seed):
        rng = np.random.default_rng(100 + seed)
        ops = [str(rng.choice(OPS)) for _ in range(8)]
        _interleaving_state(seed, ops)

    def test_adversarial_sequence(self):
        _interleaving_state(
            7,
            ["write", "write", "flush", "split", "write", "rebalance",
             "merge", "flush"],
        )


class TestInterleavingHypothesis:
    """The same property, search-driven (skipped when hypothesis is not
    installed — the deterministic twin above runs everywhere)."""

    def test_any_interleaving_matches_oracle(self):
        hyp = pytest.importorskip("hypothesis")
        st = pytest.importorskip("hypothesis.strategies")

        @hyp.settings(max_examples=10, deadline=None)
        @hyp.given(
            seed=st.integers(min_value=0, max_value=3),
            ops=st.lists(st.sampled_from(OPS), min_size=1, max_size=6),
        )
        def prop(seed, ops):
            _interleaving_state(seed, ops)

        prop()


# -- chaos schedules with views on -----------------------------------------


@pytest.mark.chaos
class TestViewsChaos:
    @pytest.mark.parametrize("seed", [0, 3])
    def test_chaos_converges_with_views(self, seed):
        from repro.ft.chaos import ChaosHarness

        report = ChaosHarness(seed, n_steps=14, n_rows=2_000,
                              views=True).run()
        assert report.ok, report.failures
        assert report.stats["view_hits"] > 0
