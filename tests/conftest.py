import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def brute_force(table, query):
    """Filter-evaluation oracle shared by the unit and property tests."""
    mask = np.ones(len(table), bool)
    for col, f in query.filters.items():
        lo, hi = f.bounds(table.schema, col)
        v = table.key_cols[col]
        mask &= (v >= lo) & (v < hi)
    return mask
