"""Batched read path: read_many/execute_many/slab_many equivalence with
the sequential path, plus regressions for the empty-range crash and the
nondeterministic replica placement."""

import copy

import numpy as np
import pytest

from repro.core import (
    Eq,
    HREngine,
    Query,
    Range,
    SortedTable,
    random_workload,
    slab_bounds_for,
)
from repro.core.tpch import generate_simulation
from repro.ft.straggler import clear_slowdowns, inject_slowdown


@pytest.fixture(scope="module")
def setup():
    kc, vc, schema = generate_simulation(50_000, 3, seed=0)
    rng = np.random.default_rng(1)
    wl = random_workload(rng, schema, list(kc), 40, value_col="metric")
    eng = HREngine(n_nodes=5)
    eng.create_column_family(
        "hr", kc, vc, replication_factor=3, mechanism="HR", workload=wl,
        schema=schema, hrca_kwargs={"k_max": 1200, "seed": 0},
    )
    eng.create_column_family(
        "tr", kc, vc, replication_factor=3, mechanism="TR", workload=wl, schema=schema,
    )
    return eng, wl, schema


def _sequential(eng, cf_name, queries, **kw):
    return [eng.read(cf_name, q, **kw) for q in queries]


class TestReadManyEquivalence:
    @pytest.mark.parametrize("cf_name", ["hr", "tr"])
    def test_matches_sequential_loop(self, setup, cf_name):
        """Results, rows_scanned and routing match a loop of read().

        Both paths consume the column family's round-robin counter, so
        the comparison runs on two engines deep-copied from the same
        state — each starts from the identical counter position.
        """
        eng, wl, _ = setup
        eng_a, eng_b = copy.deepcopy(eng), copy.deepcopy(eng)
        seq = _sequential(eng_a, cf_name, wl.queries)
        bat = eng_b.read_many(cf_name, wl.queries)
        assert len(bat) == len(wl.queries)
        for (rs, rep_s), (rb, rep_b) in zip(seq, bat):
            assert rb.value == rs.value
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.rows_matched == rs.rows_matched
            assert rep_b.replica_id == rep_s.replica_id
            assert rep_b.node_id == rep_s.node_id
            assert rep_b.estimated_rows == rep_s.estimated_rows
            assert rep_b.estimated_cost == rep_s.estimated_cost

    def test_random_workloads_equivalence(self, setup):
        eng, _, schema = setup
        for seed in range(3):
            rng = np.random.default_rng(100 + seed)
            wl = random_workload(rng, schema, ["k0", "k1", "k2"], 25,
                                 agg="sum", value_col="metric")
            eng_a, eng_b = copy.deepcopy(eng), copy.deepcopy(eng)
            seq = _sequential(eng_a, "hr", wl.queries)
            bat = eng_b.read_many("hr", wl.queries)
            for (rs, _), (rb, _) in zip(seq, bat):
                assert rb.value == rs.value
                assert rb.rows_scanned == rs.rows_scanned

    def test_round_robin_continues_across_batches(self, setup):
        """read_many draws the same rr counter as read: an unfiltered
        query batch spreads across replicas."""
        eng, _, _ = setup
        qs = [Query(filters={}) for _ in range(6)]
        out = eng.read_many("hr", qs)
        assert len({rep.replica_id for _, rep in out}) > 1

    def test_empty_batch(self, setup):
        eng, _, _ = setup
        assert eng.read_many("hr", []) == []

    def test_dead_node_routed_around(self, setup):
        eng, wl, _ = setup
        eng2 = copy.deepcopy(eng)
        victim = eng2.column_families["hr"].replicas[0].node_id
        eng2.fail_node(victim)
        out = eng2.read_many("hr", wl.queries[:10])
        assert all(rep.node_id != victim for _, rep in out)

    def test_hedged_batch_lands_off_straggler(self, setup):
        eng, wl, _ = setup
        eng2 = copy.deepcopy(eng)
        cf = eng2.column_families["hr"]
        victim = cf.replicas[0].node_id
        inject_slowdown(eng2, victim, 1e4)
        try:
            out = eng2.read_many("hr", wl.queries[:15], hedge=True)
            hedged = [rep for _, rep in out if rep.hedged]
            assert all(rep.node_id != victim for rep in hedged)
            # hedged results still answer the query correctly
            eng3 = copy.deepcopy(eng)
            seq = _sequential(eng3, "hr", wl.queries[:15])
            for (rs, _), (rb, _) in zip(seq, out):
                assert rb.value == rs.value
        finally:
            clear_slowdowns(eng2)


class TestSlabExecuteMany:
    def _table(self, rng, n=3000, dom=32, layout=("a", "b", "c")):
        kc = {c: rng.integers(0, dom, n).astype(np.int64) for c in ("a", "b", "c")}
        vc = {"m": rng.uniform(0, 10, n)}
        return SortedTable.from_columns(kc, vc, layout)

    def _queries(self, rng, n=30, dom=32):
        qs = []
        for _ in range(n):
            f = {}
            if rng.random() < 0.7:
                f["a"] = Eq(int(rng.integers(0, dom)))
            if rng.random() < 0.7:
                lo = int(rng.integers(0, dom - 4))
                f["b"] = Range(lo, lo + int(rng.integers(0, 5)))  # may be empty
            if not f:
                f["c"] = Eq(int(rng.integers(0, dom)))
            qs.append(Query(filters=f, agg="count"))
        return qs

    def test_slab_many_matches_slab_loop(self, rng):
        t = self._table(rng)
        qs = self._queries(rng)
        slabs = t.slab_many(qs)
        for i, q in enumerate(qs):
            assert tuple(slabs[i]) == t.slab(q)

    def test_execute_many_matches_execute_loop(self, rng):
        t = self._table(rng)
        qs = self._queries(rng)
        batched = t.execute_many(qs)
        for q, rb in zip(qs, batched):
            rs = t.execute(q)
            assert rb.value == rs.value
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.rows_matched == rs.rows_matched

    def test_execute_many_select_agg(self, rng):
        t = self._table(rng)
        qs = [Query(filters={"a": Eq(int(rng.integers(0, 32)))}, agg="select")
              for _ in range(5)]
        for q, rb in zip(qs, t.execute_many(qs)):
            rs = t.execute(q)
            np.testing.assert_array_equal(rb.selected, rs.selected)


class TestEmptyRangeRegression:
    """slab_bounds_for used to raise ValueError from pack_tuple when a
    filter range was empty (lo == hi); it must yield zero rows instead."""

    def test_empty_range_returns_zero_rows(self, rng):
        kc = {"a": rng.integers(0, 16, 1000), "b": rng.integers(0, 16, 1000)}
        vc = {"m": rng.uniform(0, 1, 1000)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"))
        q = Query(filters={"a": Range(5, 5)}, agg="count")
        lo, hi = slab_bounds_for(q, t.layout, t.schema)
        assert hi <= lo
        res = t.execute(q)
        assert res.value == 0.0 and res.rows_scanned == 0 and res.rows_matched == 0

    def test_empty_range_on_residual_key(self, rng):
        kc = {"a": rng.integers(0, 16, 1000), "b": rng.integers(0, 16, 1000)}
        vc = {"m": rng.uniform(0, 1, 1000)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"))
        # empty range on the *second* layout key, behind a leading range:
        # the slab is nonempty but no row matches
        q = Query(filters={"a": Range(0, 16), "b": Range(7, 7)}, agg="count")
        res = t.execute(q)
        assert res.value == 0.0 and res.rows_matched == 0

    def test_empty_range_through_engine(self, setup):
        eng, _, _ = setup
        q = Query(filters={"k0": Range(3, 3)}, agg="count")
        res, rep = eng.read("hr", q)
        assert res.value == 0.0 and rep.rows_scanned == 0
        (res_b, _), = eng.read_many("hr", [q])
        assert res_b.value == 0.0

    def test_empty_range_then_out_of_domain_filter(self, rng):
        """Once a query is empty, its remaining filters must not be
        evaluated in the batched walk — the scalar path returns before
        reaching them, so e.g. an out-of-domain Eq after an empty Range
        must not raise (it used to poison the whole batch)."""
        kc = {"a": rng.integers(0, 16, 500), "b": rng.integers(0, 16, 500)}
        vc = {"m": rng.uniform(0, 1, 500)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"))
        q_bad = Query(filters={"a": Range(5, 5), "b": Eq(99)}, agg="count")
        q_ok = Query(filters={"a": Eq(3)}, agg="count")
        assert t.execute(q_bad).rows_scanned == 0
        batched = t.execute_many([q_bad, q_ok])
        assert batched[0].rows_scanned == 0 and batched[0].value == 0.0
        assert batched[1].value == t.execute(q_ok).value

    def test_out_of_domain_before_empty_range(self, rng):
        """Layout order ('a','b') with Eq out-of-domain on 'a' and an
        empty range on 'b': the scalar walk returns empty before any
        validation, so the batched walk must not raise either."""
        kc = {"a": rng.integers(0, 16, 500), "b": rng.integers(0, 16, 500)}
        vc = {"m": rng.uniform(0, 1, 500)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"))
        q = Query(filters={"a": Eq(99), "b": Range(5, 5)}, agg="count")
        assert t.execute(q).rows_scanned == 0
        (res,) = t.execute_many([q])
        assert res.rows_scanned == 0 and res.value == 0.0
        # without the empty range the out-of-domain Eq raises on BOTH paths
        q_bad = Query(filters={"a": Eq(99)}, agg="count")
        with pytest.raises(ValueError):
            t.execute(q_bad)
        with pytest.raises(ValueError):
            t.execute_many([q_bad])

    def test_63_bit_schema_no_overflow(self, rng):
        """total_bits == 63 packs the max key to 2**63 − 1; the batched
        path's exclusive upper bound used to wrap int64 and silently
        return empty slabs where execute() returned rows."""
        from repro.core import KeySchema

        schema = KeySchema({"a": 31, "b": 32})
        kc = {
            "a": rng.integers(2**31 - 4, 2**31, 50).astype(np.int64),
            "b": rng.integers(2**32 - 4, 2**32, 50).astype(np.int64),
        }
        vc = {"m": rng.uniform(0, 1, 50)}
        t = SortedTable.from_columns(kc, vc, ("a", "b"), schema)
        qs = [Query(filters={}), Query(filters={"a": Eq(int(kc["a"][0]))})]
        batched = t.execute_many(qs)
        for q, rb in zip(qs, batched):
            rs = t.execute(q)
            assert rb.rows_scanned == rs.rows_scanned
            assert rb.value == rs.value

    def test_empty_range_in_batch_mixed(self, setup):
        eng, wl, _ = setup
        eng_a, eng_b = copy.deepcopy(eng), copy.deepcopy(eng)
        qs = [wl.queries[0], Query(filters={"k1": Range(2, 2)}), wl.queries[1]]
        seq = _sequential(eng_a, "hr", qs)
        bat = eng_b.read_many("hr", qs)
        for (rs, _), (rb, _) in zip(seq, bat):
            assert rb.value == rs.value and rb.rows_scanned == rs.rows_scanned


class TestNegativeCostTies:
    def test_negative_costs_still_route(self, rng):
        """A fitted cost function with a negative intercept can make
        every replica's cost negative; the tie threshold must still
        include the cheapest replica (it used to exclude everything:
        read raised ZeroDivisionError, read_many silently mod-by-zeroed)."""
        import warnings

        from repro.core import LinearCostFunction

        kc, vc, schema = generate_simulation(5_000, 3, seed=0)
        eng = HREngine(n_nodes=3)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3,
            layouts=[("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")],
            schema=schema,
            cost_fns={3: LinearCostFunction(slope=1.0, intercept=-5.0)},
        )
        # zero-selectivity equality on every key: whatever leads a
        # layout, the rows estimate is 0 → cost = intercept < 0
        dom = schema.max_value("k0") + 1
        q = Query(filters={c: Eq(dom - 1) for c in ("k0", "k1", "k2")})
        with warnings.catch_warnings():
            warnings.simplefilter("error")  # numpy mod-by-zero would raise
            picks = {eng.read("cf", q)[1].replica_id for _ in range(3)}
            out = eng.read_many("cf", [q] * 3)
        assert picks == {0, 1, 2}  # all tied at the intercept → RR spreads
        assert {rep.replica_id for _, rep in out} == {0, 1, 2}


class TestPlacementDeterminism:
    """_place used the salted builtin hash(); placement must be a pure
    function of (cf name, replica id, cluster size)."""

    def test_placement_is_stable_function(self):
        import zlib

        eng = HREngine(n_nodes=7)
        for name in ("orders", "hr", "tr", "??"):
            h = zlib.crc32(name.encode("utf-8")) % 7
            for rid in range(3):
                assert eng._place(rid, name) == (h + rid) % 7

    def test_successive_replicas_distinct_nodes(self):
        eng = HREngine(n_nodes=5)
        nodes = {eng._place(rid, "cf") for rid in range(3)}
        assert len(nodes) == 3
