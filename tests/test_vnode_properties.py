"""Hypothesis property tests for vnode-ring migration (PR 6).

Collected into its own module behind ``pytest.importorskip`` (same
arrangement as ``test_properties.py``) so the deterministic vnode
tests in ``test_vnode_ring.py`` run even when hypothesis is not
installed — the seed image ships without it.

The properties: (1) ANY sequence of online ``split_partition`` /
``merge_partitions`` / ``rebalance`` calls leaves every read answer —
sums, counts, and the actual selected row sets — equal to the P = 1
oracle; (2) after any such program, ``recover_node(source="log")``
rebuilds the failed node's replicas bit-identically to a survivor
re-sort, i.e. commit-log lineage survives migration.
"""

import copy

import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.tpch import generate_simulation

from test_vnode_ring import (
    _assert_oracle_equal,
    _engine,
    _mixed_queries,
    apply_migration_ops,
)


@st.composite
def op_sequences(draw):
    """Random split/merge/rebalance programs; indices are drawn wide
    and reduced modulo the live partition count at apply time."""
    n_ops = draw(st.integers(min_value=1, max_value=5))
    return [
        (
            draw(st.sampled_from(["split", "merge", "rebalance"])),
            draw(st.integers(min_value=0, max_value=63)),
        )
        for _ in range(n_ops)
    ]


class TestMigrationProperties:
    @settings(max_examples=12, deadline=None)
    @given(ops=op_sequences(), seed=st.integers(min_value=0, max_value=3))
    def test_any_split_merge_sequence_equals_p1_oracle(self, ops, seed):
        kc, vc, schema = generate_simulation(800, 3, seed=seed)
        rng = np.random.default_rng(seed)
        eng = _engine(kc, vc, schema, partitions=2, rf=1, n_nodes=4)
        oracle = _engine(kc, vc, schema, partitions=1, rf=1, n_nodes=4)
        apply_migration_ops(eng, ops)
        cf = eng.column_families["cf"]
        assert sum(p.n_rows_committed for p in cf.partitions) == 800
        _assert_oracle_equal(
            eng, oracle, _mixed_queries(rng, schema, n=12), rows=True
        )

    @settings(max_examples=8, deadline=None)
    @given(ops=op_sequences(), seed=st.integers(min_value=0, max_value=2))
    def test_log_recovery_bit_identical_after_any_sequence(self, ops, seed):
        kc, vc, schema = generate_simulation(600, 3, seed=seed)
        eng = _engine(kc, vc, schema, partitions=2, rf=2, n_nodes=4)
        apply_migration_ops(eng, ops)
        cf = eng.column_families["cf"]
        victim = cf.partitions[0].replicas[0].node_id
        e_log, e_sur = copy.deepcopy(eng), copy.deepcopy(eng)
        e_log.fail_node(victim)
        e_log.recover_node(victim, source="log")
        e_sur.fail_node(victim)
        e_sur.recover_node(victim, source="survivor")
        for part in cf.partitions:
            for r in part.replicas:
                if r.node_id != victim:
                    continue
                t_log = e_log._table(e_log.column_families["cf"], r)
                t_sur = e_sur._table(e_sur.column_families["cf"], r)
                np.testing.assert_array_equal(t_log.packed, t_sur.packed)
                assert t_log.dataset_fingerprint() == t_sur.dataset_fingerprint()
