"""Durable write path: commit log, memtable, run merge, compaction,
flush-on-read consistency and log-replay recovery.

The acceptance bar: (1) replaying the commit log rebuilds every
heterogeneous replica bit-identical to the surviving-peer recovery
path; (2) automatic compaction keeps the resident run count bounded
under a sustained write workload with no manual
``place_on_device(rebuild=True)``; (3) staged-but-unflushed writes can
never serve stale aggregates — the per-replica result cache is
invalidated by memtable flush and automatic compaction, not just
``write``/``fail_node``/``recover_node``.
"""

import copy

import numpy as np
import pytest

from repro.core import (
    CommitLog,
    CompactionPolicy,
    Eq,
    HREngine,
    KeySchema,
    Query,
    Range,
    SortedTable,
)
from repro.core.storage.memtable import Memtable, sort_run
from repro.core.tpch import generate_simulation

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _batch(rng, schema, n, cols=("k0", "k1", "k2")):
    kc = {
        c: rng.integers(0, schema.max_value(c) + 1, n).astype(np.int64) for c in cols
    }
    vc = {"metric": rng.uniform(0, 1, n)}
    return kc, vc


class TestCommitLog:
    def _log(self, rng, n_records=4, rows=50):
        log = CommitLog(key_names=("a", "b"), value_names=("m",))
        for _ in range(n_records):
            log.append(
                {"a": rng.integers(0, 8, rows), "b": rng.integers(0, 8, rows)},
                {"m": rng.uniform(0, 1, rows)},
            )
        return log

    def test_lsns_monotonic_and_replay_order(self, rng):
        log = self._log(rng)
        assert [r.lsn for r in log.replay()] == [0, 1, 2, 3]
        assert [r.lsn for r in log.replay(start_lsn=2)] == [2, 3]
        assert len(log) == 4 and log.n_rows == 200

    def test_records_immune_to_caller_mutation(self, rng):
        log = CommitLog()
        a = np.array([1, 2, 3], dtype=np.int64)
        log.append({"a": a}, {"m": np.zeros(3)})
        a[:] = 99
        (rec,) = log.replay()
        np.testing.assert_array_equal(rec.key_cols["a"], [1, 2, 3])

    def test_replay_columns_concatenates_in_commit_order(self, rng):
        log = CommitLog(key_names=("a",), value_names=("m",))
        log.append({"a": np.array([3, 1])}, {"m": np.array([0.3, 0.1])})
        log.append({"a": np.array([2])}, {"m": np.array([0.2])})
        kc, vc = log.replay_columns()
        np.testing.assert_array_equal(kc["a"], [3, 1, 2])
        np.testing.assert_array_equal(vc["m"], [0.3, 0.1, 0.2])
        kc1, _ = log.replay_columns(end_lsn=1)
        np.testing.assert_array_equal(kc1["a"], [3, 1])

    def test_bytes_round_trip(self, rng):
        log = self._log(rng)
        back = CommitLog.from_bytes(log.to_bytes())
        assert len(back) == len(log)
        for a, b in zip(log.replay(), back.replay()):
            assert a.lsn == b.lsn
            for c in a.key_cols:
                np.testing.assert_array_equal(a.key_cols[c], b.key_cols[c])
            for c in a.value_cols:
                np.testing.assert_array_equal(a.value_cols[c], b.value_cols[c])

    def test_torn_tail_drops_only_the_tail(self, rng):
        """Crash mid-append: truncating the byte stream at ANY offset
        replays a clean prefix of whole records."""
        log = self._log(rng, n_records=3, rows=20)
        data = log.to_bytes()
        frame_ends = []
        back_full = CommitLog.from_bytes(data)
        assert len(back_full) == 3
        for cut in [len(data) - 1, len(data) // 2, 17, 3, 0]:
            back = CommitLog.from_bytes(data[:cut])
            assert len(back) < 3 or cut == len(data)
            # every replayed record is a verbatim prefix record
            for a, b in zip(log.replay(), back.replay()):
                assert a.lsn == b.lsn
                for c in a.key_cols:
                    np.testing.assert_array_equal(a.key_cols[c], b.key_cols[c])

    def test_corrupt_crc_stops_replay(self, rng):
        log = self._log(rng, n_records=2, rows=10)
        data = bytearray(log.to_bytes())
        data[-1] ^= 0xFF  # flip a payload byte of the last record
        back = CommitLog.from_bytes(bytes(data))
        assert len(back) == 1

    def test_truncate_records(self, rng):
        log = self._log(rng)
        log.truncate(2)
        assert [r.lsn for r in log.replay()] == [0, 1]
        lsn = log.append({"a": np.array([1]), "b": np.array([1])}, {"m": np.array([0.5])})
        assert lsn == 2  # sequence resumes after the truncation point

    def test_ragged_batch_rejected(self):
        log = CommitLog()
        with pytest.raises(ValueError, match="ragged"):
            log.append({"a": np.array([1, 2])}, {"m": np.array([0.5])})

    def test_missing_column_rejected(self):
        log = CommitLog(key_names=("a", "b"), value_names=("m",))
        with pytest.raises(KeyError):
            log.append({"a": np.array([1])}, {"m": np.array([0.5])})


class TestMemtable:
    def test_stage_counts_and_rejects_missing_columns(self, rng):
        schema = KeySchema({"a": 4, "b": 4})
        mt = Memtable(("b", "a"), schema, ("a", "b"), ("m",))
        assert mt.n_staged == 0 and mt.flush() is None
        mt.stage({"a": np.array([3, 1]), "b": np.array([0, 2])}, {"m": np.array([0.3, 0.1])})
        with pytest.raises(KeyError):  # incomplete batch never stages
            mt.stage({"a": np.array([2])}, {"m": np.array([0.2])})
        assert mt.n_staged == 2

    def test_flush_equals_sort_run_of_concatenation(self, rng):
        schema = KeySchema({"a": 5, "b": 5})
        mt = Memtable(("b", "a"), schema, ("a", "b"), ("m",))
        batches = []
        for _ in range(3):
            kc = {"a": rng.integers(0, 32, 40), "b": rng.integers(0, 32, 40)}
            vc = {"m": rng.uniform(0, 1, 40)}
            mt.stage(kc, vc)
            batches.append((kc, vc))
        assert mt.n_staged == 120
        run = mt.flush()
        assert mt.n_staged == 0 and mt.flush() is None
        kc = {c: np.concatenate([b[0][c] for b in batches]) for c in ("a", "b")}
        vc = {"m": np.concatenate([b[1]["m"] for b in batches])}
        ref = sort_run(kc, vc, ("b", "a"), schema)
        np.testing.assert_array_equal(run.packed, ref.packed)
        for c in ("a", "b"):
            np.testing.assert_array_equal(run.key_cols[c], ref.key_cols[c])
        np.testing.assert_array_equal(run.value_cols["m"], ref.value_cols["m"])
        assert np.all(np.diff(run.packed) >= 0)

    def test_clear_drops_staged_rows(self, rng):
        schema = KeySchema({"a": 4})
        mt = Memtable(("a",), schema, ("a",), ("m",))
        mt.stage({"a": np.array([1, 2])}, {"m": np.array([0.1, 0.2])})
        mt.clear()
        assert mt.n_staged == 0 and mt.flush() is None


class TestMergeRun:
    def _table(self, rng, n=2000, dom=16):
        kc = {"a": rng.integers(0, dom, n), "b": rng.integers(0, dom, n)}
        vc = {"m": rng.uniform(0, 1, n)}
        return SortedTable.from_columns(kc, vc, ("a", "b"))

    def test_merge_run_matches_insert_reference(self, rng):
        """The GIL-friendly scatter path (np.sort on a concatenated
        buffer + destination scatters) must reproduce np.insert's merge
        bit-for-bit — including the new-rows-first tie order."""
        t = self._table(rng, dom=4)  # small domain: many key ties
        kc = {"a": rng.integers(0, 4, 300), "b": rng.integers(0, 4, 300)}
        vc = {"m": rng.uniform(0, 1, 300)}
        run = sort_run(kc, vc, t.layout, t.schema)
        merged = t.merge_run(run)
        pos = np.searchsorted(t.packed, run.packed, side="left")
        np.testing.assert_array_equal(
            merged.packed, np.insert(t.packed, pos, run.packed)
        )
        for c in ("a", "b"):
            np.testing.assert_array_equal(
                merged.key_cols[c], np.insert(t.key_cols[c], pos, run.key_cols[c])
            )
        np.testing.assert_array_equal(
            merged.value_cols["m"],
            np.insert(t.value_cols["m"], pos, run.value_cols["m"]),
        )

    def test_merge_insert_is_sort_then_merge_run(self, rng):
        t = self._table(rng)
        kc = {"a": rng.integers(0, 16, 100), "b": rng.integers(0, 16, 100)}
        vc = {"m": rng.uniform(0, 1, 100)}
        a = t.merge_insert(kc, vc)
        b = t.merge_run(sort_run(kc, vc, t.layout, t.schema))
        np.testing.assert_array_equal(a.packed, b.packed)
        np.testing.assert_array_equal(a.value_cols["m"], b.value_cols["m"])

    def test_empty_run_returns_copy(self, rng):
        t = self._table(rng)
        merged = t.merge_run(
            sort_run(
                {"a": np.empty(0, np.int64), "b": np.empty(0, np.int64)},
                {"m": np.empty(0)},
                t.layout,
                t.schema,
            )
        )
        np.testing.assert_array_equal(merged.packed, t.packed)
        assert merged.key_cols["a"] is not t.key_cols["a"]


class TestWritePathStaging:
    def _engine(self, rng, **kw):
        kc, vc, schema = generate_simulation(6_000, 3, seed=5)
        eng = HREngine(n_nodes=4, **kw)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        return eng, schema

    def test_write_through_default_flushes_every_write(self, rng):
        eng, schema = self._engine(rng)
        kc, vc = _batch(rng, schema, 100)
        eng.write("cf", kc, vc)
        assert eng.stats["staged_rows"] == 0
        assert eng.stats["memtable_flushes"] == 3  # one per live replica
        assert eng.stats["commitlog_records"] == 2  # base + the write

    def test_group_commit_defers_until_threshold(self, rng):
        eng, schema = self._engine(rng, memtable_rows=250)
        for _ in range(2):
            eng.write("cf", *_batch(rng, schema, 100))
        assert eng.stats["memtable_flushes"] == 0
        assert eng.stats["staged_rows"] == 600  # 200 rows × 3 replicas
        eng.write("cf", *_batch(rng, schema, 100))  # crosses 250
        assert eng.stats["memtable_flushes"] == 3
        assert eng.stats["staged_rows"] == 0

    def test_explicit_flush_override(self, rng):
        eng, schema = self._engine(rng, memtable_rows=10_000)
        eng.write("cf", *_batch(rng, schema, 100), flush=True)
        assert eng.stats["staged_rows"] == 0
        eng.write("cf", *_batch(rng, schema, 100), flush=False)
        assert eng.stats["staged_rows"] == 300
        eng.flush_memtables("cf")
        assert eng.stats["staged_rows"] == 0

    def test_reads_see_staged_writes(self, rng):
        """Flush-on-read: rows staged but not yet flushed are visible
        to every read path (scalar + batched)."""
        eng, schema = self._engine(rng, memtable_rows=1 << 30)
        q = Query(filters={"k0": Eq(3)}, agg="count")
        before, _ = eng.read("cf", q)
        kc = {c: np.full(70, 3 if c == "k0" else 1) for c in ("k0", "k1", "k2")}
        eng.write("cf", kc, {"metric": np.zeros(70)})
        after, _ = eng.read("cf", q)
        assert after.value == before.value + 70
        (after_many,), = [eng.read_many("cf", [q])]
        assert after_many[0].value == before.value + 70

    def test_write_consistency_across_replicas_after_drain(self, rng):
        eng, schema = self._engine(rng, memtable_rows=500)
        for _ in range(5):
            eng.write("cf", *_batch(rng, schema, 120))
        eng.flush_memtables("cf")
        cf = eng.column_families["cf"]
        fps = {eng._table(cf, r).dataset_fingerprint() for r in cf.replicas}
        assert len(fps) == 1
        assert eng.stats["commitlog_rows"] == 6_000 + 5 * 120


class TestCacheInvalidation:
    """Satellite: the result cache is invalidated by memtable flush and
    automatic compaction — not just write/fail_node/recover_node — so
    staged-but-unflushed writes can never serve stale aggregates."""

    def test_flush_invalidates_stale_entries(self, rng):
        kc, vc, schema = generate_simulation(6_000, 3, seed=5)
        eng = HREngine(n_nodes=4, memtable_rows=1 << 30)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
        )
        q = Query(filters={"k1": Eq(2)}, agg="count")
        before, _ = eng.read("cf", q)
        eng.read("cf", q)
        assert eng.stats["result_cache_hits"] == 1
        assert eng.stats["result_cache_entries"] == 1
        kw = {c: np.full(40, 2 if c == "k1" else 0) for c in ("k0", "k1", "k2")}
        eng.write("cf", kw, {"metric": np.zeros(40)})  # staged only
        assert eng.stats["memtable_flushes"] == 0
        after, _ = eng.read("cf", q)  # read barrier flushes + invalidates
        assert eng.stats["memtable_flushes"] == 1
        assert after.value == before.value + 40  # never the stale cached 'before'
        misses = eng.stats["result_cache_misses"]
        again, _ = eng.read("cf", q)
        assert again.value == after.value
        assert eng.stats["result_cache_misses"] == misses  # cached again now

    def test_compaction_invalidates(self, rng):
        kc, vc, schema = generate_simulation(4_000, 3, seed=5)
        eng = HREngine(
            n_nodes=2, compaction=CompactionPolicy(appended_frac=0.05, max_runs=2)
        )
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
            device_resident=True,
        )
        q = Query(filters={"k2": Eq(1)}, agg="select")
        eng.read("cf", q)
        assert eng.stats["result_cache_entries"] == 1
        eng.write("cf", *_batch(rng, schema, 500))  # flush → compact
        assert eng.stats["compactions"] >= 1
        assert eng.stats["result_cache_entries"] == 0
        cf = eng.column_families["cf"]
        table = eng._table(cf, cf.replicas[0])
        assert table._device["n_runs"] == 1 and table._device["row_map"] is None


class TestLogReplayRecovery:
    def _engines(self, rng, n_rows=5_000, writes=3, unique=False):
        if unique:
            # distinct composite keys: value columns then compare
            # bit-identical too (tie order is the only freedom)
            total = n_rows + writes * 100
            perm = rng.permutation(1 << 13)[:total].astype(np.int64)
            schema = KeySchema({"k0": 5, "k1": 4, "k2": 4})
            all_kc = {
                "k0": (perm >> 8) & 0x1F, "k1": (perm >> 4) & 0xF, "k2": perm & 0xF,
            }
            all_vc = {"metric": rng.uniform(0, 1, total)}
            kc = {c: v[:n_rows] for c, v in all_kc.items()}
            vc = {c: v[:n_rows] for c, v in all_vc.items()}
            batches = [
                (
                    {c: v[n_rows + i * 100 : n_rows + (i + 1) * 100] for c, v in all_kc.items()},
                    {c: v[n_rows + i * 100 : n_rows + (i + 1) * 100] for c, v in all_vc.items()},
                )
                for i in range(writes)
            ]
        else:
            kc, vc, schema = generate_simulation(n_rows, 3, seed=7)
            batches = [_batch(rng, schema, 100) for _ in range(writes)]
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        for bk, bv in batches:
            eng.write("cf", bk, bv)
        return eng

    @pytest.mark.parametrize("unique", [False, True])
    def test_replay_bit_identical_to_survivor_path(self, rng, unique):
        """THE recovery acceptance criterion: rebuilding a lost replica
        by replaying the shared commit log equals rebuilding it from a
        surviving peer — identical packed keys and key columns always
        (the packed composite key determines every key column), and
        identical value columns whenever composite keys are unique."""
        eng = self._engines(rng, unique=unique)
        cf = eng.column_families["cf"]
        for victim_replica in range(3):
            victim = cf.replicas[victim_replica].node_id
            e_log, e_sur = copy.deepcopy(eng), copy.deepcopy(eng)
            e_log.fail_node(victim)
            e_log.recover_node(victim, source="log")
            e_sur.fail_node(victim)
            e_sur.recover_node(victim, source="survivor")
            for r in cf.replicas:
                if r.node_id != victim:
                    continue
                t_log = e_log._table(e_log.column_families["cf"], r)
                t_sur = e_sur._table(e_sur.column_families["cf"], r)
                assert t_log.layout == t_sur.layout == r.layout
                np.testing.assert_array_equal(t_log.packed, t_sur.packed)
                for c in t_log.key_cols:
                    np.testing.assert_array_equal(t_log.key_cols[c], t_sur.key_cols[c])
                assert t_log.dataset_fingerprint() == t_sur.dataset_fingerprint()
                if unique:
                    for c in t_log.value_cols:
                        np.testing.assert_array_equal(
                            np.asarray(t_log.value_cols[c]),
                            np.asarray(t_sur.value_cols[c]),
                        )

    def test_replay_repairs_missed_writes(self, rng):
        eng = self._engines(rng)
        cf = eng.column_families["cf"]
        victim = cf.replicas[0].node_id
        eng.fail_node(victim)
        missed_k = {c: np.full(30, 5) for c in ("k0", "k1", "k2")}
        eng.write("cf", missed_k, {"metric": np.ones(30)})  # victim is down
        eng.recover_node(victim, source="log")
        fps = {eng._table(cf, r).dataset_fingerprint() for r in cf.replicas}
        assert len(fps) == 1  # the recovered replica has the missed write

    def test_replay_includes_rows_staged_at_failure(self, rng):
        """Rows staged in a dead node's memtable are lost with the node
        but survive in the log: recovery replays them."""
        kc, vc, schema = generate_simulation(3_000, 3, seed=7)
        eng = HREngine(n_nodes=4, memtable_rows=1 << 30)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        eng.write("cf", *_batch(rng, schema, 80))  # staged everywhere
        cf = eng.column_families["cf"]
        victim = cf.replicas[0].node_id
        eng.fail_node(victim)
        eng.recover_node(victim, source="log")
        t = eng._table(cf, cf.replicas[0])
        assert len(t) == 3_000 + 80
        q = Query(filters={}, agg="count")
        res, _ = eng.read_many("cf", [q])[0]
        assert res.value == 3_000 + 80

    def test_unknown_source_rejected(self, rng):
        eng = self._engines(rng, n_rows=1_000, writes=0)
        with pytest.raises(ValueError, match="recovery source"):
            eng.recover_node(0, source="tape")

    def test_truncated_log_replays_prefix_consistently(self, rng):
        """Crash-recovery invariant (deterministic twin of the
        hypothesis property): truncating the log after any record and
        replaying yields exactly the table built from that prefix of
        writes, identical across every heterogeneous layout."""
        kc, vc, schema = generate_simulation(2_000, 3, seed=7)
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        batches = [_batch(rng, schema, 60) for _ in range(4)]
        for bk, bv in batches:
            eng.write("cf", bk, bv)
        log = eng.column_families["cf"].commitlog
        for keep in range(1, 6):  # 1 = base only … 5 = everything
            trunc = CommitLog.from_bytes(log.to_bytes())
            trunc.truncate(keep)
            kcr, vcr = trunc.replay_columns()
            prefix_k = {
                c: np.concatenate([kc[c]] + [b[0][c] for b in batches[: keep - 1]])
                for c in kc
            }
            prefix_v = {
                "metric": np.concatenate(
                    [vc["metric"]] + [b[1]["metric"] for b in batches[: keep - 1]]
                )
            }
            fps = set()
            for layout in LAYOUTS:
                replayed = SortedTable.from_columns(kcr, vcr, layout, schema)
                expected = SortedTable.from_columns(prefix_k, prefix_v, layout, schema)
                np.testing.assert_array_equal(replayed.packed, expected.packed)
                np.testing.assert_array_equal(
                    np.asarray(replayed.value_cols["metric"]),
                    np.asarray(expected.value_cols["metric"]),
                )
                fps.add(replayed.dataset_fingerprint())
            assert len(fps) == 1  # all layouts hold the same prefix dataset


class TestAutoCompaction:
    def test_run_count_bounded_under_sustained_writes(self, rng, monkeypatch):
        """THE compaction acceptance criterion: a 10k-row write workload
        on a device-resident column family keeps every replica's
        resident run count bounded by the policy — with
        place_on_device(rebuild=True) forbidden (no re-upload) — and
        reads stay correct throughout."""
        import repro.kernels as kernels

        kc, vc, schema = generate_simulation(1_500, 3, seed=9)
        policy = CompactionPolicy(appended_frac=0.5, max_runs=6)
        eng = HREngine(n_nodes=4, compaction=policy)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
            device_resident=True,
        )
        host = HREngine(n_nodes=4)
        host.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        # any rebuild would re-upload — the compaction path must not
        monkeypatch.setattr(
            kernels, "build_device_state",
            lambda *a, **k: pytest.fail("device state rebuilt during compaction"),
        )
        cf = eng.column_families["cf"]
        max_runs_seen = 0
        for i in range(20):  # 20 × 500 = 10k rows written
            bk, bv = _batch(rng, schema, 500)
            eng.write("cf", bk, bv)
            host.write("cf", bk, bv)
            runs = [eng._table(cf, r)._device["n_runs"] for r in cf.replicas]
            max_runs_seen = max(max_runs_seen, max(runs))
        assert eng.stats["compactions"] >= 1
        assert max_runs_seen <= policy.max_runs + 1  # bounded throughout
        qs = [
            Query(filters={"k0": Eq(int(rng.integers(0, 8)))}, agg="count")
            for _ in range(4)
        ] + [Query(filters={"k1": Range(0, 3)}, agg="select")]
        got = eng.read_many("cf", qs)
        ref = host.read_many("cf", qs)
        for (rd, _), (rh, _) in zip(got, ref):
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)
            if rh.selected is not None:
                np.testing.assert_array_equal(rd.selected, rh.selected)

    def test_compaction_restores_single_run_fast_paths(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=9)
        eng = HREngine(n_nodes=2, compaction=CompactionPolicy(appended_frac=0.1))
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
            device_resident=True,
        )
        eng.write("cf", *_batch(rng, schema, 400))
        assert eng.stats["compactions"] == 1
        cf = eng.column_families["cf"]
        t = eng._table(cf, cf.replicas[0])
        st = t._device
        assert st["n_runs"] == 1 and st["row_map"] is None
        assert st["run_starts"] == (0,) and st["n_rows"] == 2_400
        # device order == host order after on-device compaction
        host = SortedTable(t.layout, t.schema, t.key_cols, t.value_cols, t.packed)
        q = Query(filters={"k0": Eq(2)}, agg="select")
        np.testing.assert_array_equal(t.execute(q).selected, host.execute(q).selected)
        np.testing.assert_array_equal(
            t.slab_many([q]), host.slab_many([q])
        )

    def test_multi_cycle_accounting_no_thrash_no_starve(self, rng):
        """Satellite regression: repeated append→compact cycles under a
        steady drip of small writes. Each compaction folds the appended
        rows into the base run, silently raising the ``appended_frac``
        threshold for the next cycle — by design (geometric full-merge
        cadence) — while ``max_runs`` keeps the cadence bounded. The
        accounting must never drift: ``run_starts`` stays consistent
        with ``n_rows`` at every cycle, runs stay bounded (no
        starvation), compaction does not fire on every flush (no
        thrash), and reads stay correct throughout."""
        kc, vc, schema = generate_simulation(1_000, 3, seed=9)
        policy = CompactionPolicy(appended_frac=0.5, max_runs=4)
        eng = HREngine(n_nodes=2, compaction=policy)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
            device_resident=True,
        )
        host = HREngine(n_nodes=2)
        host.create_column_family(
            "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1], schema=schema,
        )
        cf = eng.column_families["cf"]
        compaction_writes = []
        for i in range(40):  # 40 drip writes → many full cycles
            bk, bv = _batch(rng, schema, 100)
            eng.write("cf", bk, bv)
            host.write("cf", bk, bv)
            st = eng._table(cf, cf.replicas[0])._device
            rs = st["run_starts"]
            # accounting invariants, every cycle
            assert st["n_runs"] == len(rs)
            assert st["n_rows"] == 1_000 + (i + 1) * 100
            base = rs[1] if len(rs) > 1 else st["n_rows"]
            appended = st["n_rows"] - base
            assert 0 <= appended <= st["n_rows"]
            assert all(a < b for a, b in zip(rs, rs[1:]))  # runs non-empty
            # bounded stack: never more than max_runs + the in-flight run
            assert st["n_runs"] <= policy.max_runs + 1
            if eng.stats["compactions"] > len(compaction_writes):
                compaction_writes.append(i)
        # repeated cycles actually happened, at a bounded cadence …
        assert len(compaction_writes) >= 5
        gaps = np.diff(compaction_writes)
        assert gaps.max() <= policy.max_runs + 1  # no starvation
        # … but the drip did not degenerate into compact-every-flush
        assert len(compaction_writes) < 40
        qs = [
            Query(filters={"k0": Eq(int(rng.integers(0, 8)))}, agg="count")
            for _ in range(3)
        ] + [Query(filters={"k1": Range(0, 4)}, agg="select")]
        for (rd, _), (rh, _) in zip(eng.read_many("cf", qs), host.read_many("cf", qs)):
            assert rd.rows_matched == rh.rows_matched
            np.testing.assert_allclose(rd.value, rh.value, rtol=1e-5)
            if rh.selected is not None:
                np.testing.assert_array_equal(rd.selected, rh.selected)

    def test_append_to_empty_base_is_single_run(self, rng):
        """A run merged into an empty resident table IS the sorted base:
        no phantom run, no row_map, fast paths keep applying."""
        schema = KeySchema({"a": 4, "b": 4})
        t = SortedTable.from_columns(
            {"a": np.empty(0, np.int64), "b": np.empty(0, np.int64)},
            {"m": np.empty(0)},
            ("a", "b"),
            schema,
        ).place_on_device()
        kc = {"a": rng.integers(0, 16, 50), "b": rng.integers(0, 16, 50)}
        merged = t.merge_insert(kc, {"m": rng.uniform(0, 1, 50)})
        st = merged._device
        assert st["n_runs"] == 1 and st["row_map"] is None
        assert st["run_starts"] == (0,) and st["n_rows"] == 50
        host = SortedTable.from_columns(kc, {"m": np.zeros(50)}, ("a", "b"), schema)
        q = Query(filters={"a": Eq(int(kc["a"][0]))}, agg="select")
        np.testing.assert_array_equal(merged.execute(q).selected, host.execute(q).selected)

    def test_policy_thresholds(self):
        p = CompactionPolicy(appended_frac=0.5, max_runs=4)
        assert not p.should_compact(base_rows=100, appended_rows=0, n_runs=1)
        assert not p.should_compact(base_rows=100, appended_rows=40, n_runs=2)
        assert p.should_compact(base_rows=100, appended_rows=60, n_runs=2)
        assert p.should_compact(base_rows=100, appended_rows=1, n_runs=5)
        with pytest.raises(ValueError):
            CompactionPolicy(max_runs=0)
        with pytest.raises(ValueError):
            CompactionPolicy(appended_frac=-0.1)


class TestCommitLogCheckpoint:
    def test_checkpoint_bounds_log_and_preserves_replay(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=11)
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        for _ in range(4):
            eng.write("cf", *_batch(rng, schema, 50))
        log = eng.column_families["cf"].commitlog
        before_k, before_v = log.replay_columns()
        assert len(log) == 5 and log.n_rows == 2_200
        lsn = eng.checkpoint_commitlog("cf")
        assert lsn == 5  # LSNs keep counting past the snapshot
        assert len(log) == 1 and log.n_rows == 2_200
        after_k, after_v = log.replay_columns()
        for c in before_k:
            np.testing.assert_array_equal(before_k[c], after_k[c])
        np.testing.assert_array_equal(before_v["metric"], after_v["metric"])
        # recovery through the snapshot is unchanged
        cf = eng.column_families["cf"]
        victim = cf.replicas[0].node_id
        fp = eng._table(cf, cf.replicas[0]).dataset_fingerprint()
        eng.fail_node(victim)
        eng.recover_node(victim, source="log")
        assert eng._table(cf, cf.replicas[0]).dataset_fingerprint() == fp

    def test_checkpoint_flushes_staged_rows_first(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=11)
        eng = HREngine(n_nodes=4, memtable_rows=1 << 30)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        eng.write("cf", *_batch(rng, schema, 60))  # staged only
        eng.checkpoint_commitlog("cf")
        assert eng.stats["staged_rows"] == 0  # flushed before collapsing
        log = eng.column_families["cf"].commitlog
        assert len(log) == 1 and log.n_rows == 1_060
        cf = eng.column_families["cf"]
        fps = {eng._table(cf, r).dataset_fingerprint() for r in cf.replicas}
        assert len(fps) == 1


class TestCommitLogAutoCheckpoint:
    """Satellite: the count-based trigger (records since last snapshot >
    k, mirroring CompactionPolicy) collapses a commit log automatically
    after a flush — replay recovery stays bit-identical across it."""

    def test_records_since_checkpoint_counter(self, rng):
        log = CommitLog(key_names=("a",), value_names=("m",))
        assert log.records_since_checkpoint == 0
        for i in range(3):
            log.append({"a": np.array([i])}, {"m": np.array([0.1])})
        assert log.records_since_checkpoint == 3
        assert log.should_checkpoint(2)
        assert not log.should_checkpoint(3)  # strict: records > k
        assert not log.should_checkpoint(0)  # 0 disables
        log.checkpoint()
        assert log.records_since_checkpoint == 0 and len(log) == 1
        # round-tripped logs approximate the counter with record count
        log.append({"a": np.array([9])}, {"m": np.array([0.9])})
        back = CommitLog.from_bytes(log.to_bytes())
        assert back.records_since_checkpoint == 2

    def test_auto_checkpoint_bounds_log_under_sustained_writes(self, rng):
        kc, vc, schema = generate_simulation(1_500, 3, seed=15)
        eng = HREngine(n_nodes=4, commitlog_checkpoint_records=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        for _ in range(14):  # write-through: every write flushes
            eng.write("cf", *_batch(rng, schema, 30))
            # bounded: at most k records accumulate before a collapse
            assert eng.stats["commitlog_records"] <= 4 + 1
        assert eng.stats["commitlog_auto_checkpoints"] >= 2
        # rows are all retained — only the framing collapsed
        assert eng.stats["commitlog_rows"] == 1_500 + 14 * 30

    def test_knob_zero_disables(self, rng):
        kc, vc, schema = generate_simulation(1_000, 3, seed=15)
        eng = HREngine(n_nodes=4, commitlog_checkpoint_records=0)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        for _ in range(10):
            eng.write("cf", *_batch(rng, schema, 20))
        assert eng.stats["commitlog_records"] == 11  # base + every write
        assert eng.stats["commitlog_auto_checkpoints"] == 0
        with pytest.raises(ValueError, match="commitlog_checkpoint_records"):
            HREngine(commitlog_checkpoint_records=-1)

    def test_replay_bit_identity_across_auto_checkpoint(self, rng):
        """THE auto-checkpoint acceptance criterion: log-replay recovery
        through an automatically collapsed log rebuilds every replica
        bit-identical to recovery from the uncollapsed twin."""
        kc, vc, schema = generate_simulation(2_000, 3, seed=15)
        engines = {
            k: HREngine(n_nodes=4, commitlog_checkpoint_records=k)
            for k in (3, 0)  # auto-checkpointing vs full history
        }
        batches = [_batch(rng, schema, 40) for _ in range(9)]
        for eng in engines.values():
            eng.create_column_family(
                "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
            )
            for bk, bv in batches:
                eng.write("cf", bk, bv)
        auto, full = engines[3], engines[0]
        assert auto.stats["commitlog_auto_checkpoints"] >= 1
        assert auto.stats["commitlog_records"] < full.stats["commitlog_records"]
        cf = full.column_families["cf"]
        victim = cf.replicas[0].node_id
        for eng in (auto, full):
            eng.fail_node(victim)
            eng.recover_node(victim, source="log")
        for r in cf.replicas:
            if r.node_id != victim:
                continue
            t_a = auto._table(auto.column_families["cf"], r)
            t_f = full._table(full.column_families["cf"], r)
            np.testing.assert_array_equal(t_a.packed, t_f.packed)
            for c in t_a.key_cols:
                np.testing.assert_array_equal(t_a.key_cols[c], t_f.key_cols[c])
            np.testing.assert_array_equal(
                np.asarray(t_a.value_cols["metric"]),
                np.asarray(t_f.value_cols["metric"]),
            )

    def test_not_fired_while_any_replica_staged(self, rng):
        """The documented checkpoint safety condition: a partition whose
        replicas still hold staged rows is never collapsed — the read
        barrier flushes only the replica it touches, so siblings keep
        the per-record history alive until a full drain."""
        kc, vc, schema = generate_simulation(1_000, 3, seed=15)
        eng = HREngine(
            n_nodes=4, memtable_rows=1 << 30, commitlog_checkpoint_records=2
        )
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        for _ in range(5):
            eng.write("cf", *_batch(rng, schema, 25))  # staged everywhere
        # the read barrier flushes exactly one replica; the other two
        # still hold staged rows, so no checkpoint may fire
        eng.read("cf", Query(filters={"k0": Eq(1)}, agg="count"))
        assert eng.stats["memtable_flushes"] == 1
        assert eng.stats["commitlog_auto_checkpoints"] == 0
        assert eng.stats["commitlog_records"] == 6
        eng.flush_memtables("cf")  # full drain → trigger fires
        assert eng.stats["commitlog_auto_checkpoints"] == 1
        assert eng.stats["commitlog_records"] == 1

    def test_partitioned_logs_checkpoint_independently(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=15)
        eng = HREngine(n_nodes=4, commitlog_checkpoint_records=3)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
            partitions=2,
        )
        cf = eng.column_families["cf"]
        # writes confined to partition 1's token range (leading key in
        # the upper half of its domain)
        dom = schema.max_value("k0") + 1
        for _ in range(6):
            bk, bv = _batch(rng, schema, 20)
            bk["k0"] = np.full(20, dom - 1, dtype=np.int64)
            eng.write("cf", bk, bv)
        assert eng.stats["commitlog_auto_checkpoints"] >= 1
        assert len(cf.partitions[0].commitlog) == 1  # untouched: base only
        assert len(cf.partitions[1].commitlog) <= 4
        total = sum(p.commitlog.n_rows for p in cf.partitions)
        assert total == 2_000 + 6 * 20


class TestFlushAtomicity:
    def test_failed_merge_loses_no_staged_rows(self, rng, monkeypatch):
        """A merge that raises mid-flush must leave the staged rows AND
        the old table intact — committed rows may be delayed, never
        lost — and a retry succeeds."""
        kc, vc, schema = generate_simulation(2_000, 3, seed=13)
        eng = HREngine(n_nodes=4, memtable_rows=1 << 30)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        eng.write("cf", *_batch(rng, schema, 90))  # staged only
        assert eng.stats["staged_rows"] == 180
        boom = RuntimeError("disk full")
        monkeypatch.setattr(
            SortedTable, "merge_run",
            lambda self, run, **kw: (_ for _ in ()).throw(boom),
        )
        with pytest.raises(RuntimeError, match="disk full"):
            eng.flush_memtables("cf")
        # nothing drained, nothing installed
        assert eng.stats["staged_rows"] == 180
        assert eng.stats["memtable_flushes"] == 0
        cf = eng.column_families["cf"]
        assert all(len(eng._table(cf, r)) == 2_000 for r in cf.replicas)
        monkeypatch.undo()
        eng.flush_memtables("cf")  # retry succeeds with the same rows
        assert eng.stats["staged_rows"] == 0
        assert all(len(eng._table(cf, r)) == 2_090 for r in cf.replicas)
        fps = {eng._table(cf, r).dataset_fingerprint() for r in cf.replicas}
        assert len(fps) == 1

    def test_parallel_flush_shares_executor_and_survives_deepcopy(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=13)
        eng = HREngine(n_nodes=4, memtable_rows=1 << 30, parallel_writes=True)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=3, layouts=LAYOUTS, schema=schema,
        )
        eng.write("cf", *_batch(rng, schema, 50))
        eng.flush_memtables("cf")  # parallel path: pool created lazily
        assert eng._pool is not None
        pool = eng._pool
        eng.write("cf", *_batch(rng, schema, 50))
        eng.flush_memtables("cf")
        assert eng._pool is pool  # reused, not rebuilt per flush
        twin = copy.deepcopy(eng)  # pools are dropped, not copied
        assert twin._pool is None
        twin.write("cf", *_batch(rng, schema, 50))
        twin.flush_memtables("cf")
        cf = twin.column_families["cf"]
        fps = {twin._table(cf, r).dataset_fingerprint() for r in cf.replicas}
        assert len(fps) == 1

    def test_flush_wall_counter_accumulates(self, rng):
        kc, vc, schema = generate_simulation(2_000, 3, seed=13)
        eng = HREngine(n_nodes=4, memtable_rows=1 << 30)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2], schema=schema,
        )
        assert eng.stats["flush_wall_seconds"] == 0.0
        eng.write("cf", *_batch(rng, schema, 200))
        eng.read("cf", Query(filters={"k0": Eq(1)}, agg="count"))  # read barrier
        assert eng.stats["flush_wall_seconds"] > 0.0
