"""Virtual-node ring: skew-aware partitioning, online split/merge,
per-partition statistics (PR 6).

The acceptance bar: (1) a Zipf-skewed keyspace created at P = 8 equal
splits drops to ≤ 1.25× max/mean row imbalance after ``rebalance()``,
and the post-rebalance ``read_many`` answers are row-identical to the
P = 1 oracle; (2) any sequence of ``split_partition`` / per-partition
``merge_partitions`` calls preserves oracle equality (sums, counts,
and the actual selected rows); (3) after a split,
``recover_node(source="log")`` rebuilds the migrated partitions'
replicas bit-identically to a survivor re-sort — the commit-log
lineage survives migration; (4) partitions owning no rows in a query's
slab range are skipped without a launch or cache probe; (5) migration
only touches the migrated partitions — untouched vnodes keep their
table objects and warm result-cache entries.
"""

import copy

import numpy as np
import pytest

from repro.core import (
    Eq,
    HREngine,
    KeySchema,
    Query,
    Range,
    TableStats,
    TokenHistogram,
    TokenRing,
)
from repro.core.keys import pack_columns
from repro.core.tpch import generate_simulation

LAYOUTS = [("k0", "k1", "k2"), ("k1", "k2", "k0"), ("k2", "k0", "k1")]


def _zipf_columns(rng, n, schema, a=1.3):
    """Zipf(a)-skewed key columns: mass piles at 0, so equal token
    splits put almost everything in the first partition."""
    out = {}
    for c in schema.bits:
        dom = schema.max_value(c) + 1
        out[c] = (np.minimum(rng.zipf(a, n), dom) - 1).astype(np.int64)
    return out


def _mixed_queries(rng, schema, n=24, value_col="metric"):
    qs = []
    cols = list(schema.bits)
    doms = {c: schema.max_value(c) + 1 for c in cols}
    aggs = ["count", "sum", "select"]
    for i in range(n):
        agg = aggs[i % 3]
        u = rng.random()
        lead, resid = cols[0], cols[-1]
        if u < 0.35:
            f = {lead: Eq(int(rng.integers(0, doms[lead])))}
        elif u < 0.65:
            lo = int(rng.integers(0, doms[lead] - 1))
            width = int(rng.integers(1, max(2, doms[lead] // 3)))
            f = {lead: Range(lo, min(lo + width, doms[lead]))}
        else:
            lo = int(rng.integers(0, doms[resid] - 1))
            f = {resid: Range(lo, min(lo + 2, doms[resid]))}
        qs.append(
            Query(filters=f, agg=agg, value_col=value_col if agg == "sum" else None)
        )
    return qs


def _engine(kc, vc, schema, *, partitions, rf=3, n_nodes=6, **kw):
    eng = HREngine(n_nodes=n_nodes, **kw)
    eng.create_column_family(
        "cf", kc, vc, replication_factor=rf, layouts=LAYOUTS[:rf],
        schema=schema, partitions=partitions,
    )
    return eng


def _selected_rows(eng, cf_name, selected, value_col="metric"):
    """Materialize global select indices into (keys..., value) rows —
    the representation-independent form oracle comparisons use (RF = 1
    pins the serving layout)."""
    cf = eng.column_families[cf_name]
    offsets = eng._partition_row_offsets(cf)
    pids = np.searchsorted(offsets, selected, side="right") - 1
    rows = []
    for pid, g in zip(pids, selected):
        t = eng._table(cf, cf.partitions[int(pid)].replicas[0])
        li = int(g - offsets[int(pid)])
        rows.append(
            tuple(int(t.key_cols[c][li]) for c in cf.key_names)
            + (float(np.asarray(t.value_cols[value_col])[li]),)
        )
    return sorted(rows)


def _assert_oracle_equal(eng, oracle, qs, *, rows=False):
    for q, (a, _), (b, _) in zip(
        qs, oracle.read_many("cf", qs), eng.read_many("cf", qs)
    ):
        assert b.rows_matched == a.rows_matched, q
        if q.agg == "sum":
            np.testing.assert_allclose(b.value, a.value, rtol=1e-9)
        else:
            assert b.value == a.value, q
        if rows and q.agg == "select":
            assert _selected_rows(eng, "cf", b.selected) == _selected_rows(
                oracle, "cf", a.selected
            ), q


class TestTokenHistogram:
    def test_masses_partition_the_total(self):
        hist = TokenHistogram.build(total_bits=16)
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 1 << 16, 5_000)
        hist.add_tokens(toks)
        assert hist.total == 5_000
        ring = TokenRing.build(KeySchema({"a": 8, "b": 8}), ("a", "b"), 4)
        masses = hist.partition_masses(ring.starts)
        assert masses.shape == (4,)
        np.testing.assert_allclose(masses.sum(), 5_000)

    def test_uniform_tokens_balanced_skewed_not(self):
        hist_u = TokenHistogram.build(16)
        hist_s = TokenHistogram.build(16)
        rng = np.random.default_rng(1)
        starts = TokenRing.build(KeySchema({"a": 8, "b": 8}), ("a", "b"), 4).starts
        hist_u.add_tokens(rng.integers(0, 1 << 16, 20_000))
        hist_s.add_tokens(rng.integers(0, 1 << 12, 20_000))  # low 1/16 only
        assert hist_u.imbalance(starts) < 1.1
        assert hist_s.imbalance(starts) > 3.0

    def test_quantile_starts_balance_the_masses(self):
        hist = TokenHistogram.build(20)
        rng = np.random.default_rng(2)
        hist.add_tokens(rng.integers(0, 1 << 14, 30_000))  # skewed low
        starts = hist.quantile_starts(8)
        assert len(starts) == 8 and starts[0] == 0
        assert hist.imbalance(starts) < 1.2

    def test_device_accumulation_matches_host(self):
        h_host = TokenHistogram.build(16)
        h_dev = TokenHistogram.build(16)
        rng = np.random.default_rng(3)
        toks = rng.integers(0, 1 << 16, 4_000)
        h_host.add_tokens(toks)
        h_dev.add_tokens(toks, device=True)
        np.testing.assert_array_equal(h_host.counts, h_dev.counts)

    def test_from_tokens_rounds_duplicate_runs(self):
        """Exact-quantile boundaries stay within half the largest
        duplicate run of the ideal cut — heavy hitters cannot push the
        realized split arbitrarily far off."""
        schema = KeySchema({"a": 6})
        toks = np.concatenate(
            [np.zeros(50, np.int64), np.arange(1, 51, dtype=np.int64)]
        )
        ring = TokenRing.from_tokens(schema, ("a",), toks, 2)
        # ideal cut = 50 rows; boundary 1 puts the 50-row zero run left
        assert ring.starts == (0, 1)


class TestSkewAwareCreate:
    def test_tokens_balance_beats_equal_splits(self):
        schema = KeySchema({"k0": 8, "k1": 8, "k2": 8})
        rng = np.random.default_rng(5)
        kc = _zipf_columns(rng, 6_000, schema)
        vc = {"metric": rng.uniform(0, 1, 6_000)}
        eq = HREngine(n_nodes=6)
        eq.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2],
            schema=schema, partitions=4,
        )
        tk = HREngine(n_nodes=6)
        tk.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2],
            schema=schema, partitions=4, partition_balance="tokens",
        )
        assert tk.partition_imbalance("cf") <= 1.25
        assert tk.partition_imbalance("cf") < eq.partition_imbalance("cf")
        qs = _mixed_queries(rng, schema, n=18)
        oracle = HREngine(n_nodes=6)
        oracle.create_column_family(
            "cf", kc, vc, replication_factor=2, layouts=LAYOUTS[:2],
            schema=schema, partitions=1,
        )
        _assert_oracle_equal(tk, oracle, qs)

    def test_invalid_balance_rejected(self):
        kc, vc, schema = generate_simulation(500, 3, seed=0)
        eng = HREngine(n_nodes=4)
        with pytest.raises(ValueError, match="partition_balance"):
            eng.create_column_family(
                "cf", kc, vc, replication_factor=1, layouts=LAYOUTS[:1],
                schema=schema, partitions=2, partition_balance="zipf",
            )

    def test_per_partition_stats_cover_exactly_own_rows(self):
        kc, vc, schema = generate_simulation(3_000, 3, seed=7)
        eng = _engine(kc, vc, schema, partitions=4)
        cf = eng.column_families["cf"]
        assert all(p.stats is not None for p in cf.partitions)
        assert (
            sum(p.stats.n_rows for p in cf.partitions) == 3_000
        )
        for p in cf.partitions:
            assert p.stats.n_rows == p.n_rows_committed
        # P = 1 keeps the CF-global model (no per-partition stats)
        e1 = _engine(kc, vc, schema, partitions=1)
        assert e1.column_families["cf"].partitions[0].stats is None

    def test_stats_track_routed_writes(self):
        kc, vc, schema = generate_simulation(2_000, 3, seed=8)
        rng = np.random.default_rng(8)
        eng = _engine(kc, vc, schema, partitions=3)
        cf = eng.column_families["cf"]
        bk = {
            c: rng.integers(0, schema.max_value(c) + 1, 300).astype(np.int64)
            for c in ("k0", "k1", "k2")
        }
        eng.write("cf", bk, {"metric": rng.uniform(0, 1, 300)})
        for p in cf.partitions:
            assert p.stats.n_rows == p.n_rows_committed
        assert sum(p.stats.n_rows for p in cf.partitions) == 2_300


class TestSplitMerge:
    def _small(self, seed=10, partitions=2, rf=2, **kw):
        kc, vc, schema = generate_simulation(3_000, 3, seed=seed)
        eng = _engine(kc, vc, schema, partitions=partitions, rf=rf, **kw)
        oracle = _engine(kc, vc, schema, partitions=1, rf=rf)
        return eng, oracle, schema

    def test_split_preserves_oracle_equality_and_counts(self):
        eng, oracle, schema = self._small()
        rng = np.random.default_rng(20)
        token = eng.split_partition("cf", 0)
        cf = eng.column_families["cf"]
        assert cf.ring.n_partitions == 3
        assert token in cf.ring.starts
        assert eng.stats["partition_splits"] == 1
        assert eng.stats["partition_merges"] == 0
        assert eng.stats["rebalance_rows_moved"] > 0
        # vnode ids: the two children are fresh, the untouched partition
        # keeps its original vnode identity
        assert sorted(p.vnode_id for p in cf.partitions) == [1, 2, 3]
        _assert_oracle_equal(eng, oracle, _mixed_queries(rng, schema, n=18))

    def test_default_split_halves_the_rows(self):
        eng, _, _ = self._small()
        cf = eng.column_families["cf"]
        before = cf.partitions[0].n_rows_committed
        eng.split_partition("cf", 0)
        a, b = cf.partitions[0], cf.partitions[1]
        assert a.n_rows_committed + b.n_rows_committed == before
        # median cut: neither child owns everything
        assert 0 < a.n_rows_committed < before

    def test_merge_restores_oracle_equality(self):
        eng, oracle, schema = self._small(partitions=4)
        rng = np.random.default_rng(21)
        eng.merge_partitions("cf", 1)
        cf = eng.column_families["cf"]
        assert cf.ring.n_partitions == 3
        assert eng.stats["partition_merges"] == 1
        _assert_oracle_equal(eng, oracle, _mixed_queries(rng, schema, n=18))

    def test_split_then_merge_round_trips(self):
        eng, oracle, schema = self._small(rf=1)
        rng = np.random.default_rng(22)
        tok = eng.split_partition("cf", 1)
        eng.merge_partitions("cf", 1)
        cf = eng.column_families["cf"]
        assert cf.ring.n_partitions == 2 and tok not in cf.ring.starts
        _assert_oracle_equal(
            eng, oracle, _mixed_queries(rng, schema, n=18), rows=True
        )

    def test_writes_route_by_new_ring_after_split(self):
        eng, oracle, schema = self._small()
        rng = np.random.default_rng(23)
        eng.split_partition("cf", 0)
        cf = eng.column_families["cf"]
        bk = {
            c: rng.integers(0, schema.max_value(c) + 1, 200).astype(np.int64)
            for c in ("k0", "k1", "k2")
        }
        bv = {"metric": rng.uniform(0, 1, 200)}
        eng.write("cf", bk, bv)
        oracle.write("cf", bk, bv)
        for part in cf.partitions:
            kc_p, _ = part.commitlog.replay_columns()
            toks = pack_columns(kc_p, cf.key_names, cf.schema)
            assert ((toks >= part.token_lo) & (toks <= part.token_hi)).all()
        _assert_oracle_equal(eng, oracle, _mixed_queries(rng, schema, n=12))

    def test_staged_rows_survive_migration(self):
        """Rows staged under the group-commit threshold are commit-log
        records, so they ride the log-slicing migration and stay
        readable — no pre-split flush required."""
        eng, oracle, schema = self._small(memtable_rows=1 << 30)
        rng = np.random.default_rng(24)
        bk = {
            c: rng.integers(0, schema.max_value(c) + 1, 150).astype(np.int64)
            for c in ("k0", "k1", "k2")
        }
        bv = {"metric": rng.uniform(0, 1, 150)}
        eng.write("cf", bk, bv, flush=False)
        oracle.write("cf", bk, bv, flush=False)
        assert eng.stats["staged_rows"] > 0
        eng.split_partition("cf", 0)
        _assert_oracle_equal(eng, oracle, _mixed_queries(rng, schema, n=12))

    def test_validation(self):
        eng, _, _ = self._small(partitions=2)
        cf = eng.column_families["cf"]
        with pytest.raises(ValueError, match="no right neighbor"):
            eng.merge_partitions("cf", 1)
        with pytest.raises(ValueError, match="outside partition"):
            eng.split_partition("cf", 0, token=cf.partitions[1].token_hi)

    def test_untouched_partitions_keep_tables_and_cache(self):
        """Migration surgically touches the split partition only: the
        other vnode keeps its table objects, log, stats, and its warm
        result-cache entries; the migrated replicas' cache entries are
        dropped."""
        eng, _, schema = self._small(partitions=2)
        cf = eng.column_families["cf"]
        keep, split = cf.partitions[1], cf.partitions[0]
        keep_tables = {
            r.replica_id: eng._table(cf, r) for r in keep.replicas
        }
        keep_ids = {r.replica_id for r in keep.replicas}
        split_ids = {r.replica_id for r in split.replicas}
        keep_log, keep_stats = keep.commitlog, keep.stats
        # warm the cache (fan-out query twice — RR may alternate the
        # serving replica, so both rounds together seed ≥1 entry per
        # partition)
        q = Query(filters={"k1": Eq(3)}, agg="count")
        eng.read_many("cf", [q])
        eng.read_many("cf", [q])
        cached_keep = {k for k in eng._result_cache if k[1] in keep_ids}
        cached_split = {k for k in eng._result_cache if k[1] in split_ids}
        assert cached_keep and cached_split

        eng.split_partition("cf", 0)
        # untouched partition: same objects, renumbered position only
        assert keep in cf.partitions
        for r in keep.replicas:
            assert eng._table(cf, r) is keep_tables[r.replica_id]
        assert cached_keep <= set(eng._result_cache)
        assert keep.commitlog is keep_log and keep.stats is keep_stats
        # migrated replicas: tables and cache entries are gone
        for rid in split_ids:
            assert ("cf", rid) not in eng._result_cache
            assert all(
                (cf.name, rid) not in n.tables for n in eng.nodes
            )

    def test_rebuilt_partition_stats_match_recompute(self):
        """Merged stats (bin-wise histogram addition) equal a from-
        scratch recompute over the merged rows."""
        eng, _, _ = self._small(partitions=4, rf=1)
        cf = eng.column_families["cf"]
        eng.merge_partitions("cf", 2)
        part = cf.partitions[2]
        kc_p, _ = part.commitlog.replay_columns()
        fresh = TableStats.from_columns(kc_p, cf.schema)
        assert part.stats.n_rows == fresh.n_rows
        for c in fresh.columns:
            np.testing.assert_allclose(
                part.stats.columns[c].counts, fresh.columns[c].counts
            )


class TestRecoveryAfterMigration:
    def test_log_replay_bit_identical_after_split(self):
        """THE migration-lineage criterion: after a split, failing a
        node and recovering from the sliced-and-concatenated logs
        rebuilds every hosted replica bit-identically to a survivor
        re-sort."""
        kc, vc, schema = generate_simulation(4_000, 3, seed=30)
        rng = np.random.default_rng(30)
        eng = _engine(kc, vc, schema, partitions=2, rf=2, n_nodes=5)
        for _ in range(2):
            bk = {
                c: rng.integers(0, schema.max_value(c) + 1, 120).astype(np.int64)
                for c in ("k0", "k1", "k2")
            }
            eng.write("cf", bk, {"metric": rng.uniform(0, 1, 120)})
        eng.split_partition("cf", 0)
        eng.merge_partitions("cf", 1)
        cf = eng.column_families["cf"]
        victim = cf.partitions[0].replicas[0].node_id
        e_log, e_sur = copy.deepcopy(eng), copy.deepcopy(eng)
        e_log.fail_node(victim)
        e_log.recover_node(victim, source="log")
        e_sur.fail_node(victim)
        e_sur.recover_node(victim, source="survivor")
        checked = 0
        for part in cf.partitions:
            for r in part.replicas:
                if r.node_id != victim:
                    continue
                t_log = e_log._table(e_log.column_families["cf"], r)
                t_sur = e_sur._table(e_sur.column_families["cf"], r)
                np.testing.assert_array_equal(t_log.packed, t_sur.packed)
                for c in t_log.key_cols:
                    np.testing.assert_array_equal(
                        t_log.key_cols[c], t_sur.key_cols[c]
                    )
                assert t_log.dataset_fingerprint() == t_sur.dataset_fingerprint()
                checked += 1
        assert checked > 0

    def test_split_with_node_down_installs_on_recovery(self):
        """A reshard while a node is dead does not install tables on it;
        ``recover_node(source="log")`` later rebuilds the new vnodes'
        replicas from the migrated logs."""
        kc, vc, schema = generate_simulation(2_500, 3, seed=31)
        eng = _engine(kc, vc, schema, partitions=2, rf=2, n_nodes=4)
        oracle = _engine(kc, vc, schema, partitions=1, rf=2, n_nodes=4)
        cf = eng.column_families["cf"]
        victim = cf.partitions[0].replicas[0].node_id
        eng.fail_node(victim)
        eng.split_partition("cf", 0)
        assert eng.nodes[victim].tables == {}
        eng.recover_node(victim, source="log")
        for part in cf.partitions:
            fps = {
                eng._table(cf, r).dataset_fingerprint() for r in part.replicas
            }
            assert len(fps) == 1
        rng = np.random.default_rng(31)
        _assert_oracle_equal(eng, oracle, _mixed_queries(rng, schema, n=12))


class TestRebalanceAcceptance:
    """ISSUE 6 acceptance: Zipf keyspace at P = 8 equal splits →
    ``rebalance()`` → imbalance ≤ 1.25×, reads row-identical to P = 1."""

    def _zipf_family(self, partitions, rf=1, n=12_000, seed=40, **kw):
        schema = KeySchema({"k0": 8, "k1": 8, "k2": 8})
        rng = np.random.default_rng(seed)
        kc = _zipf_columns(rng, n, schema)
        vc = {"metric": rng.uniform(0, 1, n)}
        eng = HREngine(n_nodes=8, **kw)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=rf, layouts=LAYOUTS[:rf],
            schema=schema, partitions=partitions,
        )
        return eng, schema, rng

    def test_zipf_p8_rebalances_under_1_25(self):
        eng, schema, rng = self._zipf_family(8)
        oracle, _, _ = self._zipf_family(1)
        before = eng.partition_imbalance("cf")
        assert before > 2.0  # the skew is real
        info = eng.rebalance("cf")
        assert info["imbalance_before"] == before
        assert info["imbalance_after"] <= 1.25
        assert eng.partition_imbalance("cf") <= 1.25
        assert info["rows_moved"] > 0
        assert eng.column_families["cf"].ring.n_partitions == 8
        _assert_oracle_equal(
            eng, oracle, _mixed_queries(rng, schema, n=24), rows=True
        )

    def test_histogram_rebalance_reduces_imbalance(self):
        eng, _, _ = self._zipf_family(8)
        before = eng.partition_imbalance("cf")
        info = eng.rebalance("cf", exact=False)
        assert info["imbalance_after"] < before

    def test_rebalance_changes_partition_count(self):
        eng, schema, rng = self._zipf_family(4, n=6_000)
        oracle, _, _ = self._zipf_family(1, n=6_000)
        info = eng.rebalance("cf", partitions=6)
        assert info["partitions"] == 6
        assert eng.column_families["cf"].ring.n_partitions == 6
        assert eng.partition_imbalance("cf") <= 1.25
        _assert_oracle_equal(eng, oracle, _mixed_queries(rng, schema, n=12))

    def test_rebalance_is_idempotent(self):
        eng, _, _ = self._zipf_family(8, n=6_000)
        eng.rebalance("cf")
        moved_once = eng.stats["rebalance_rows_moved"]
        info = eng.rebalance("cf")
        assert info["rows_moved"] == 0
        assert eng.stats["rebalance_rows_moved"] == moved_once

    def test_auto_rebalance_on_write_drift(self):
        """The ``rebalance_imbalance`` knob: uniform data stays put;
        once skewed writes push the token histogram past the threshold,
        the write path reshards by itself."""
        kc, vc, schema = generate_simulation(4_000, 3, seed=41)
        rng = np.random.default_rng(41)
        eng = _engine(
            kc, vc, schema, partitions=4, rf=1, rebalance_imbalance=2.0
        )
        assert eng.stats["rebalance_rows_moved"] == 0
        # skewed burst: all writes into one narrow key region
        for _ in range(4):
            bk = {
                c: rng.integers(0, 4, 2_000).astype(np.int64)
                for c in ("k0", "k1", "k2")
            }
            eng.write("cf", bk, {"metric": rng.uniform(0, 1, 2_000)})
        assert eng.stats["rebalance_rows_moved"] > 0
        cf = eng.column_families["cf"]
        assert cf.token_hist.imbalance(cf.ring.starts) <= 2.0


class TestEmptyRangeSkip:
    def _gapped_family(self, rf=2):
        """k0 ∈ upper half only → partition 0 of a 2-way equal split
        owns zero rows."""
        schema = KeySchema({"k0": 4, "k1": 4})
        rng = np.random.default_rng(50)
        n = 1_000
        kc = {
            "k0": rng.integers(8, 16, n).astype(np.int64),
            "k1": rng.integers(0, 16, n).astype(np.int64),
        }
        vc = {"metric": rng.uniform(0, 1, n)}
        eng = HREngine(n_nodes=4)
        eng.create_column_family(
            "cf", kc, vc, replication_factor=rf,
            layouts=[("k0", "k1"), ("k1", "k0")][:rf], schema=schema,
            partitions=2,
        )
        return eng, n

    def test_empty_partition_skipped_not_scanned(self):
        eng, n = self._gapped_family()
        # fan-out range: the empty partition is pruned by its observed
        # token extrema, not executed
        q = Query(filters={"k0": Range(0, 16)}, agg="count")
        (res, _), = eng.read_many("cf", [q])
        assert res.value == n
        assert eng.stats["empty_partition_skips"] >= 1

    def test_fully_skipped_query_yields_empty_result(self):
        eng, _ = self._gapped_family()
        skips0 = eng.stats["empty_partition_skips"]
        # pinned entirely inside the empty partition's range
        q = Query(filters={"k0": Eq(2)}, agg="select")
        (res, rep), = eng.read_many("cf", [q])
        assert res.value == 0 and res.rows_matched == 0
        assert res.selected is not None and len(res.selected) == 0
        assert rep.replica_id == -1 and rep.node_id == -1
        assert eng.stats["empty_partition_skips"] > skips0

    def test_skip_disarms_after_first_routed_write(self):
        eng, n = self._gapped_family()
        eng.write(
            "cf",
            {"k0": np.array([2, 3]), "k1": np.array([1, 1])},
            {"metric": np.array([0.5, 0.5])},
        )
        (res, _), = eng.read_many(
            "cf", [Query(filters={"k0": Range(0, 8)}, agg="count")]
        )
        assert res.value == 2
        (res, _), = eng.read_many(
            "cf", [Query(filters={"k0": Range(0, 16)}, agg="count")]
        )
        assert res.value == n + 2

    def test_skip_matches_unskipped_oracle(self):
        eng, _ = self._gapped_family(rf=1)
        cf = eng.column_families["cf"]
        rng = np.random.default_rng(51)
        qs = _mixed_queries(rng, cf.schema, n=18)
        oracle = HREngine(n_nodes=4)
        kc_o, vc_o = cf.partitions[1].commitlog.replay_columns()
        oracle.create_column_family(
            "cf", kc_o, vc_o, replication_factor=1, layouts=[("k0", "k1")],
            schema=cf.schema, partitions=1,
        )
        _assert_oracle_equal(eng, oracle, qs)


def apply_migration_ops(eng, ops):
    """Apply (op, index) migration programs, reducing indices modulo
    the live partition count; shared with the hypothesis module
    (``test_vnode_properties``)."""
    applied = []
    for op, idx in ops:
        P = eng.column_families["cf"].ring.n_partitions
        if op == "split":
            part = eng.column_families["cf"].partitions[idx % P]
            if part.token_hi > part.token_lo:  # splittable range
                eng.split_partition("cf", idx % P)
                applied.append((op, idx % P))
        elif op == "merge":
            if P > 1:
                eng.merge_partitions("cf", idx % (P - 1))
                applied.append((op, idx % (P - 1)))
        else:
            eng.rebalance("cf")
            applied.append((op, 0))
    return applied


class TestMigrationSequences:
    """Seeded random split/merge/rebalance programs — the deterministic
    slice of the property the hypothesis module explores more widely."""

    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_sequence_equals_p1_oracle(self, seed):
        kc, vc, schema = generate_simulation(800, 3, seed=seed)
        rng = np.random.default_rng(1000 + seed)
        ops = [
            (str(rng.choice(["split", "merge", "rebalance"])),
             int(rng.integers(0, 64)))
            for _ in range(int(rng.integers(2, 6)))
        ]
        eng = _engine(kc, vc, schema, partitions=2, rf=1, n_nodes=4)
        oracle = _engine(kc, vc, schema, partitions=1, rf=1, n_nodes=4)
        applied = apply_migration_ops(eng, ops)
        cf = eng.column_families["cf"]
        assert sum(p.n_rows_committed for p in cf.partitions) == 800
        _assert_oracle_equal(
            eng, oracle, _mixed_queries(rng, schema, n=12), rows=True
        ), applied

    @pytest.mark.parametrize("seed", [0, 1])
    def test_log_recovery_bit_identical_after_random_sequence(self, seed):
        kc, vc, schema = generate_simulation(600, 3, seed=seed)
        rng = np.random.default_rng(2000 + seed)
        ops = [
            (str(rng.choice(["split", "merge", "rebalance"])),
             int(rng.integers(0, 64)))
            for _ in range(int(rng.integers(2, 6)))
        ]
        eng = _engine(kc, vc, schema, partitions=2, rf=2, n_nodes=4)
        apply_migration_ops(eng, ops)
        cf = eng.column_families["cf"]
        victim = cf.partitions[0].replicas[0].node_id
        e_log, e_sur = copy.deepcopy(eng), copy.deepcopy(eng)
        e_log.fail_node(victim)
        e_log.recover_node(victim, source="log")
        e_sur.fail_node(victim)
        e_sur.recover_node(victim, source="survivor")
        for part in cf.partitions:
            for r in part.replicas:
                if r.node_id != victim:
                    continue
                t_log = e_log._table(e_log.column_families["cf"], r)
                t_sur = e_sur._table(e_sur.column_families["cf"], r)
                np.testing.assert_array_equal(t_log.packed, t_sur.packed)
                assert t_log.dataset_fingerprint() == t_sur.dataset_fingerprint()
