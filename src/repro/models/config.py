"""Architecture configuration (one instance per assigned arch).

Exact published numbers live in ``repro.configs.<arch>``; this dataclass
is the neutral schema. Padding for TP divisibility is *not* applied here
— ``parallel.padding`` derives padded sizes at sharding time and
``padding_report()`` documents the deltas.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ArchConfig"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 → d_model // n_heads

    # attention
    attention: Literal["gqa", "mla", "none"] = "gqa"
    rope_theta: float = 10_000.0
    rotary_pct: float = 1.0  # ChatGLM3 "2d" RoPE = rotary on half the dims
    sliding_window: int = 0  # 0 = global attention
    global_layers: tuple[int, ...] = ()  # layers that stay global under SWA
    qk_norm: bool = False

    # MLA (DeepSeek-V3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # FFN
    act: Literal["silu", "gelu"] = "silu"
    gated_mlp: bool = True  # SwiGLU/GeGLU when True

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    first_k_dense: int = 0  # DeepSeek: leading dense layers
    dense_d_ff: int = 0  # d_ff of those dense layers
    capacity_factor: float = 1.25
    router_score: Literal["softmax", "sigmoid"] = "softmax"  # sigmoid = DeepSeek-V3
    moe_aux_alpha: float = 0.01  # 0 → aux-loss-free (DeepSeek-V3)

    # SSM (Mamba-2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (Hymba)
    hybrid: bool = False
    meta_tokens: int = 0

    # frontend / heads
    input_mode: Literal["tokens", "embeddings"] = "tokens"
    n_codebooks: int = 1  # MusicGen: parallel output heads over the vocab
    tie_embeddings: bool = False
    mtp: bool = False  # DeepSeek multi-token prediction head
    embed_scale: bool = False  # Gemma-style sqrt(d_model) embedding scale

    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6

    def __post_init__(self) -> None:
        if self.d_head == 0 and self.attention != "none" and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived -------------------------------------------------------------

    @property
    def d_inner(self) -> int:  # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def uses_attention(self) -> bool:
        return self.attention != "none"

    @property
    def uses_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode state: SSM state and/or rolling SWA KV."""
        return self.family == "ssm" or (self.hybrid and self.sliding_window > 0)

    def param_count(self) -> int:
        """Analytic parameter count (unpadded, embeddings included)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab_size * d  # input embed
        if not self.tie_embeddings:
            total += self.vocab_size * d * self.n_codebooks
        for layer in range(L):
            total += 2 * d  # norms
            if self.uses_attention:
                if self.attention == "mla":
                    total += d * self.q_lora_rank
                    total += self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    total += self.n_heads * self.v_head_dim * d
                else:
                    total += d * self.n_heads * self.d_head
                    total += 2 * d * self.n_kv_heads * self.d_head
                    total += self.n_heads * self.d_head * d
            if self.uses_ssm:
                di, H, N = self.d_inner, self.ssm_heads, self.ssm_state
                total += d * (2 * di + 2 * N + H)  # in_proj(x,z), B,C, dt
                total += self.ssm_conv * (di + 2 * N)
                total += 2 * H  # A, D
                total += di  # ssm out norm
                total += di * d
            if self.is_moe and layer >= self.first_k_dense:
                e_ff = self.moe_d_ff
                total += d * self.n_experts  # router
                total += self.n_experts * (3 if self.gated_mlp else 2) * d * e_ff
                total += self.n_shared_experts * (3 if self.gated_mlp else 2) * d * e_ff
            else:
                ff = self.dense_d_ff if (self.is_moe and layer < self.first_k_dense) else self.d_ff
                if ff:
                    total += (3 if self.gated_mlp else 2) * d * ff
        total += d  # final norm
        return total
