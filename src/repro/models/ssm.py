"""Mamba-2 (SSD — state-space duality) block, chunked-scan formulation.

Train/prefill: `lax.scan` over sequence chunks of length Q; within a
chunk the quadratic "attention-like" form (masked decay matrix L) runs on
the MXU, across chunks the O(1) state [B, H, P, N] is carried — per-step
memory is O(B·H·Q²), independent of S (long_500k-safe).

Decode: exact O(1) recurrent step on (conv_state, ssm_state).

TP: heads are sharded over the ``model`` axis (padded to a multiple —
pad heads have zero dt/out_proj so contribute nothing); the shared B/C
projections (ngroups=1) are replicated. The fused in_proj of the
reference implementation is split into (w_xz, w_bc, w_dt) so each part
shards cleanly — mathematically identical.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import Params, dense, dense_init, rmsnorm

__all__ = ["ssm_dims", "init_ssm", "ssm_axes", "ssm_forward", "ssm_decode", "init_ssm_state"]


def ssm_dims(cfg: ArchConfig, tp: int) -> dict[str, int]:
    """Padded SSD dimensions for tensor-parallel degree ``tp``."""
    P = cfg.ssm_head_dim
    H = cfg.d_inner // P
    H_pad = -(-H // tp) * tp if tp > 1 else H
    return {
        "P": P,
        "H": H,
        "H_pad": H_pad,
        "di": H_pad * P,
        "N": cfg.ssm_state,
        "conv": cfg.ssm_conv,
    }


def init_ssm(key, cfg: ArchConfig, tp: int, dtype) -> Params:
    dims = ssm_dims(cfg, tp)
    d, di, N, H = cfg.d_model, dims["di"], dims["N"], dims["H_pad"]
    ks = jax.random.split(key, 8)
    dt = jnp.exp(
        jax.random.uniform(ks[5], (H,)) * (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001)
    )
    p = {
        "w_xz": dense_init(ks[0], d, 2 * di, dtype),
        "w_bc": dense_init(ks[1], d, 2 * N, dtype),
        "w_dt": dense_init(ks[2], d, H, dtype),
        "conv_x": (jax.random.normal(ks[3], (cfg.ssm_conv, di)) * 0.1).astype(dtype),
        "conv_bc": (jax.random.normal(ks[4], (cfg.ssm_conv, 2 * N)) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_bc_b": jnp.zeros((2 * N,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": (jnp.log(jnp.expm1(dt))).astype(jnp.float32),
        "out_norm": jnp.ones((di,), jnp.float32),
        "w_out": dense_init(ks[6], di, d, dtype),
    }
    # zero the pad heads end-to-end
    if dims["H_pad"] != dims["H"]:
        P = dims["P"]
        live = dims["H"] * P
        p["w_xz"] = p["w_xz"].at[:, live : di].set(0.0)  # x part
        p["w_xz"] = p["w_xz"].at[:, di + live :].set(0.0)  # z part
        p["w_out"] = p["w_out"].at[live:, :].set(0.0)
        p["D"] = p["D"].at[dims["H"] :].set(0.0)
    return p


def ssm_axes(cfg: ArchConfig, tp: int) -> Params:
    return {
        "w_xz": ("fsdp", "heads"),
        "w_bc": ("fsdp", None),
        "w_dt": ("fsdp", "heads"),
        "conv_x": (None, "heads"),
        "conv_bc": (None, None),
        "conv_x_b": ("heads",),
        "conv_bc_b": (None,),
        "A_log": ("heads",),
        "D": ("heads",),
        "dt_bias": ("heads",),
        "out_norm": ("heads",),
        "w_out": ("heads", "fsdp"),
    }


def _causal_depthwise_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x [B,S,C], w [K,C] → causal depthwise conv, silu activation."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssm_forward(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    tp: int,
    *,
    chunk: int | None = None,
    return_state: bool = False,
):
    """Full-sequence SSD. x [B,S,D] → y [B,S,D] (+ final recurrent state
    when ``return_state`` — used by prefill to prime the decode cache)."""
    dims = ssm_dims(cfg, tp)
    B, S, _ = x.shape
    di, N, H, P = dims["di"], dims["N"], dims["H_pad"], dims["P"]
    Q = min(chunk or cfg.ssm_chunk, S)
    while S % Q:  # largest divisor ≤ requested chunk (keeps maths exact)
        Q -= 1
    nc = S // Q

    xz = dense(x, p["w_xz"])
    xs_raw, z = xz[..., :di], xz[..., di:]
    bc_raw = dense(x, p["w_bc"])
    dt = jax.nn.softplus(
        dense(x, p["w_dt"]).astype(jnp.float32) + p["dt_bias"]
    )  # [B,S,H]
    xs = _causal_depthwise_conv(xs_raw, p["conv_x"], p["conv_x_b"])
    bc = _causal_depthwise_conv(bc_raw, p["conv_bc"], p["conv_bc_b"])
    Bm, Cm = bc[..., :N], bc[..., N:]  # [B,S,N] (ngroups=1, shared by heads)

    A = -jnp.exp(p["A_log"])  # [H], negative
    a = dt * A  # [B,S,H] log-decay per step
    xh = xs.reshape(B, S, H, P)
    dtx = xh.astype(jnp.float32) * dt[..., None]  # [B,S,H,P]

    # chunked scan
    a_c = a.reshape(B, nc, Q, H)
    dtx_c = dtx.reshape(B, nc, Q, H, P)
    B_c = Bm.reshape(B, nc, Q, N).astype(jnp.float32)
    C_c = Cm.reshape(B, nc, Q, N).astype(jnp.float32)

    def chunk_step(h_state, inp):
        a_q, dtx_q, B_q, C_q = inp  # [B,Q,H], [B,Q,H,P], [B,Q,N], [B,Q,N]
        cum = jnp.cumsum(a_q, axis=1)  # [B,Q,H] inclusive
        # within-chunk: L[b,h,q,t] = exp(cum[q]-cum[t]) for q>=t
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # [B,Q,T,H]
        qt_mask = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(qt_mask[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bqn,btn->bqt", C_q, B_q)  # shared across heads
        Y_diag = jnp.einsum("bqt,bqth,bthp->bqhp", CB, L, dtx_q)
        # off-chunk: contribution of carried state
        decay_q = jnp.exp(cum)  # [B,Q,H]
        Y_off = jnp.einsum("bqn,bhpn,bqh->bqhp", C_q, h_state, decay_q)
        # state update
        total = cum[:, -1:, :]  # [B,1,H]
        w = jnp.exp(total - cum)  # decay from t to chunk end
        h_new = h_state * jnp.exp(total[:, 0, :])[:, :, None, None] + jnp.einsum(
            "btn,bthp,bth->bhpn", B_q, dtx_q, w
        )
        return h_new, Y_diag + Y_off

    h0 = jnp.zeros((B, H, P, N), jnp.float32)
    h_final, Y = jax.lax.scan(
        chunk_step,
        h0,
        (
            jnp.moveaxis(a_c, 1, 0),
            jnp.moveaxis(dtx_c, 1, 0),
            jnp.moveaxis(B_c, 1, 0),
            jnp.moveaxis(C_c, 1, 0),
        ),
    )
    Y = jnp.moveaxis(Y, 0, 1).reshape(B, S, H, P)  # [B,S,H,P]
    Y = Y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = Y.reshape(B, S, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = dense(y, p["w_out"])
    if not return_state:
        return out
    K = cfg.ssm_conv
    state = {
        "conv_x": _tail_window(xs_raw, K - 1).astype(x.dtype),
        "conv_bc": _tail_window(bc_raw, K - 1).astype(x.dtype),
        "state": h_final,
    }
    return out, state


def _tail_window(x: jax.Array, k: int) -> jax.Array:
    """Last k positions of [B,S,C] (S >= k assumed in prefill)."""
    return x[:, x.shape[1] - k :, :]


def init_ssm_state(cfg: ArchConfig, tp: int, batch: int, dtype=jnp.float32):
    dims = ssm_dims(cfg, tp)
    return {
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, dims["di"]), dtype),
        "conv_bc": jnp.zeros((batch, cfg.ssm_conv - 1, 2 * dims["N"]), dtype),
        "state": jnp.zeros((batch, dims["H_pad"], dims["P"], dims["N"]), jnp.float32),
    }


def ssm_decode(
    p: Params, x_t: jax.Array, state: Params, cfg: ArchConfig, tp: int
) -> tuple[jax.Array, Params]:
    """One recurrent step. x_t [B,1,D] → (y [B,1,D], new state)."""
    dims = ssm_dims(cfg, tp)
    B = x_t.shape[0]
    di, N, H, P = dims["di"], dims["N"], dims["H_pad"], dims["P"]
    x = x_t[:, 0, :]
    xz = dense(x, p["w_xz"])
    xs, z = xz[..., :di], xz[..., di:]
    bc = dense(x, p["w_bc"])
    dt = jax.nn.softplus(dense(x, p["w_dt"]).astype(jnp.float32) + p["dt_bias"])  # [B,H]

    # conv state update (window = last K-1 inputs + current)
    win_x = jnp.concatenate([state["conv_x"], xs[:, None, :].astype(state["conv_x"].dtype)], axis=1)
    xs = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_x, p["conv_x"]) + p["conv_x_b"])
    win_bc = jnp.concatenate([state["conv_bc"], bc[:, None, :].astype(state["conv_bc"].dtype)], axis=1)
    bc_c = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_bc, p["conv_bc"]) + p["conv_bc_b"])
    Bv, Cv = bc_c[..., :N].astype(jnp.float32), bc_c[..., N:].astype(jnp.float32)

    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * A)  # [B,H]
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    dtx = xh * dt[..., None]
    h_new = state["state"] * decay[:, :, None, None] + jnp.einsum("bn,bhp->bhpn", Bv, dtx)
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cv) + p["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x_t.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = dense(y, p["w_out"])[:, None, :]
    new_state = {"conv_x": win_x[:, 1:], "conv_bc": win_bc[:, 1:], "state": h_new}
    return out, new_state
