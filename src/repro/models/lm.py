"""Unified decoder LM over all assigned architectures.

Layers are organized into *stacks* (homogeneous runs of blocks executed
with `lax.scan` over stacked params — HLO size is depth-independent).
Most archs have one stack; DeepSeek-V3 has a dense prefix stack + an MoE
stack. Per-layer sliding windows (Hymba) ride along the scan as a traced
array.

Entry points:
  init_lm / lm_axes       — params pytree + logical sharding axes
  forward_train           — full-seq forward → (loss, metrics)
  prefill                 — full-seq forward → (last-token logits, cache)
  decode_step             — one token + cache → (logits, cache)
  init_cache              — zeroed cache pytree for a given (B, S_alloc)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.padding import PaddedDims, padded_dims
from repro.parallel.pipeline import pipeline_available, pipeline_stack_forward
from repro.parallel.sharding import MeshCtx
from . import blocks as blk
from . import ssm as ssm_mod
from .config import ArchConfig
from .layers import Params, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = [
    "StackSpec",
    "stacks_for",
    "init_lm",
    "lm_axes",
    "forward_train",
    "prefill",
    "decode_step",
    "init_cache",
    "cache_axes",
]


#: when True, stacks run as unrolled python loops instead of lax.scan —
#: set by launch.roofline so HLO cost_analysis (which counts a while-loop
#: body once, ignoring trip count) sees every layer. Production always
#: uses the scan (depth-independent HLO).
ANALYSIS_UNROLL_LAYERS = False


@dataclasses.dataclass(frozen=True)
class StackSpec:
    name: str
    kind: str  # "attn" | "moe" | "ssm" | "hybrid"
    n_layers: int
    d_ff: int  # dense-MLP width inside the block (0 = none)


def stacks_for(cfg: ArchConfig) -> list[StackSpec]:
    if cfg.family == "ssm":
        return [StackSpec("main", "ssm", cfg.n_layers, 0)]
    if cfg.family == "hybrid":
        return [StackSpec("main", "hybrid", cfg.n_layers, cfg.d_ff)]
    if cfg.is_moe:
        stacks = []
        if cfg.first_k_dense:
            stacks.append(StackSpec("dense", "attn", cfg.first_k_dense, cfg.dense_d_ff))
        stacks.append(StackSpec("moe", "moe", cfg.n_layers - cfg.first_k_dense, 0))
        return stacks
    return [StackSpec("main", "attn", cfg.n_layers, cfg.d_ff)]


def _windows_for(cfg: ArchConfig, spec: StackSpec, layer_offset: int) -> np.ndarray | int:
    """Per-layer sliding windows; 0 → global (static skip of the mask)."""
    if cfg.sliding_window <= 0:
        return 0
    glob = set(cfg.global_layers)
    return np.array(
        [
            blk.GLOBAL_WINDOW if (layer_offset + i) in glob else cfg.sliding_window
            for i in range(spec.n_layers)
        ],
        dtype=np.int32,
    )


# ---------------------------------------------------------------- init --------


def init_lm(key, cfg: ArchConfig, tp: int = 1) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    pd = padded_dims(cfg, tp)
    keys = jax.random.split(key, 8)
    params: Params = {}
    if cfg.input_mode == "tokens":
        params["embed"] = (
            jax.random.normal(keys[0], (pd.vocab_size, cfg.d_model)) * 0.02
        ).astype(dtype)
    off = 0
    for i, spec in enumerate(stacks_for(cfg)):
        lkeys = jax.random.split(jax.random.fold_in(keys[1], i), spec.n_layers)
        params[f"stack_{spec.name}"] = jax.vmap(
            lambda k: blk.init_block(k, cfg, pd, tp, dtype, spec.kind, spec.d_ff)
        )(lkeys)
        off += spec.n_layers
    params["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        if cfg.n_codebooks > 1:
            params["head"] = jax.vmap(
                lambda k: dense_init(k, cfg.d_model, pd.vocab_size, dtype)
            )(jax.random.split(keys[2], cfg.n_codebooks))
        else:
            params["head"] = dense_init(keys[2], cfg.d_model, pd.vocab_size, dtype)
    if cfg.mtp:
        params["mtp"] = {
            "proj": dense_init(keys[3], 2 * cfg.d_model, cfg.d_model, dtype),
            "block": blk.init_block(
                keys[4], cfg, pd, tp, dtype, "attn", cfg.dense_d_ff or cfg.d_ff or 4 * cfg.d_model
            ),
            "norm": rmsnorm_init(cfg.d_model),
        }
    return params


def lm_axes(cfg: ArchConfig, tp: int = 1, *, serve: bool = False,
            ep_over_data: bool = False) -> Params:
    """Logical sharding axes. ``serve=True`` switches to inference-time
    placement: no ZeRO/FSDP gathers (weights pure-TP: "fsdp"→replicated,
    expert d_ff unsharded); ``ep_over_data`` additionally spreads experts
    over (data, model) — global EP for big-MoE serving (caller checks the
    expert count divides the mesh)."""
    pd = padded_dims(cfg, tp)
    ax: Params = {}
    if cfg.input_mode == "tokens":
        ax["embed"] = ("vocab", None)  # row-sharded: gather → select+psum
    for spec in stacks_for(cfg):
        bax = blk.block_axes(cfg, pd, tp, spec.kind, spec.d_ff)
        # prepend the stacked layer axis
        ax[f"stack_{spec.name}"] = jax.tree.map(
            lambda axes: ("layers",) + tuple(axes),
            bax,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
        )
    ax["final_norm"] = (None,)
    if not cfg.tie_embeddings:
        ax["head"] = (None, "fsdp", "vocab") if cfg.n_codebooks > 1 else ("fsdp", "vocab")
    if cfg.mtp:
        ax["mtp"] = {
            "proj": ("fsdp", None),
            "block": blk.block_axes(cfg, pd, tp, "attn", cfg.dense_d_ff or cfg.d_ff or 4 * cfg.d_model),
            "norm": (None,),
        }
    if serve:
        def _serve_axes(axes):
            return tuple(
                None if a in ("fsdp", "expert_mlp")
                else ("experts_serve" if (a == "experts" and ep_over_data) else a)
                for a in axes
            )

        ax = jax.tree.map(
            _serve_axes, ax,
            is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x),
        )
    return ax


# ---------------------------------------------------------------- embed/head ---


def _embed_in(params: Params, batch: dict, cfg: ArchConfig) -> jax.Array:
    if cfg.input_mode == "tokens":
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    if cfg.embed_scale:
        x = x * jnp.sqrt(jnp.float32(cfg.d_model)).astype(x.dtype)
    return x


def _logits(params: Params, x: jax.Array, cfg: ArchConfig, pd: PaddedDims) -> jax.Array:
    """x [..., D] → logits [..., V_pad] (or [..., CB, V_pad]); pad vocab
    masked to -inf so it never receives probability mass."""
    if cfg.tie_embeddings:
        w = params["embed"].T  # [D, V_pad]
        logits = dense(x, w)
    elif cfg.n_codebooks > 1:
        logits = jnp.einsum("...d,cdv->...cv", x, params["head"]).astype(x.dtype)
    else:
        logits = dense(x, params["head"])
    if pd.vocab_size != cfg.vocab_size:
        mask = jnp.arange(pd.vocab_size) < cfg.vocab_size
        logits = jnp.where(mask, logits, -1e30)
    return logits


def _xent(logits: jax.Array, labels: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Mean CE over labels >= 0. logits [..., V], labels [...]."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = lse - ll
    valid = (labels >= 0).astype(jnp.float32)
    total = jnp.maximum(valid.sum(), 1.0)
    return jnp.sum(nll * valid) / total, total


# ---------------------------------------------------------------- stacks -------


def _run_stack_forward(
    stack_params,
    spec: StackSpec,
    x,
    positions,
    cfg,
    pd,
    ctx,
    *,
    remat: str,
    collect_cache: bool,
    q_chunk: int,
    kv_chunk: int,
    layer_offset: int,
):
    windows = _windows_for(cfg, spec, layer_offset)
    static_window = isinstance(windows, int)

    def body(x, inp):
        p_l = inp[0]
        window = 0 if static_window else inp[1]
        x, cache, aux = blk.block_forward(
            p_l, x, positions, cfg, pd, ctx,
            kind=spec.kind, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        return x, ((cache if collect_cache else ()), aux)

    if remat == "full":
        body = jax.checkpoint(body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False,
        )

    xs = (stack_params,) if static_window else (stack_params, jnp.asarray(windows))
    if ANALYSIS_UNROLL_LAYERS:
        caches_l, aux_l = [], []
        for i in range(spec.n_layers):
            inp = jax.tree.map(lambda a: a[i], xs)
            x, (c, a) = body(x, inp)
            caches_l.append(c)
            aux_l.append(a)
        caches = jax.tree.map(lambda *ls: jnp.stack(ls), *caches_l) if caches_l and caches_l[0] else ()
        return x, caches, jnp.sum(jnp.stack(aux_l))
    x, (caches, auxes) = jax.lax.scan(body, x, xs)
    return x, caches, jnp.sum(auxes)


# ---------------------------------------------------------------- train --------


def forward_train(
    params: Params,
    batch: dict,
    cfg: ArchConfig,
    ctx: MeshCtx | None = None,
    *,
    remat: str = "dots",
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    pipeline_micro: int = 0,
) -> tuple[jax.Array, dict]:
    """batch: {"tokens" | "embeds", "labels"} → (loss, metrics).

    ``pipeline_micro > 0`` runs eligible stacks as a GPipe pipeline over
    the ``pod`` mesh axis with that many microbatches (see
    parallel/pipeline.py for scope)."""
    tp = ctx.tp_size if ctx else 1
    pd = padded_dims(cfg, tp)
    x = _embed_in(params, batch, cfg)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    aux_total = jnp.zeros((), jnp.float32)
    off = 0
    for spec in stacks_for(cfg):
        use_pp = (
            pipeline_micro > 0
            and pipeline_available(ctx, spec.kind, spec.n_layers)
            and isinstance(_windows_for(cfg, spec, off), int)
        )
        if use_pp:
            def body(p_l, xb, pos):
                y, _, _ = blk.block_forward(
                    p_l, xb, pos, cfg, pd, ctx, kind=spec.kind, window=0,
                    q_chunk=q_chunk, kv_chunk=kv_chunk,
                )
                return y

            if remat in ("full", "dots"):
                body = jax.checkpoint(body, prevent_cse=False)
            x = pipeline_stack_forward(
                params[f"stack_{spec.name}"], body, x, positions, ctx,
                n_micro=pipeline_micro,
            )
            off += spec.n_layers
            continue
        x, _, aux = _run_stack_forward(
            params[f"stack_{spec.name}"], spec, x, positions, cfg, pd, ctx,
            remat=remat, collect_cache=False, q_chunk=q_chunk, kv_chunk=kv_chunk,
            layer_offset=off,
        )
        off += spec.n_layers
        aux_total = aux_total + aux

    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, h, cfg, pd)
    loss, n_tok = _xent(logits, batch["labels"])
    metrics = {"ce": loss, "aux": aux_total, "tokens": n_tok}

    if cfg.mtp and "mtp" in params:
        # predict token t+2: combine h_t with the embedding of token t+1
        emb_next = _embed_in(params, batch, cfg)  # embeddings of input tokens
        comb = jnp.concatenate([h[:, :-1], emb_next[:, 1:]], axis=-1)
        hm = dense(comb, params["mtp"]["proj"])
        hm, _, _ = blk.block_forward(
            params["mtp"]["block"], hm, positions[:, :-1], cfg, pd, ctx,
            kind="attn", window=0, q_chunk=q_chunk, kv_chunk=kv_chunk,
        )
        hm = rmsnorm(hm, params["mtp"]["norm"], cfg.norm_eps)
        mtp_logits = _logits(params, hm, cfg, pd)
        mtp_labels = batch["labels"][:, 1:]
        mtp_loss, _ = _xent(mtp_logits, mtp_labels)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss

    return loss + aux_total, metrics


# ---------------------------------------------------------------- cache --------


def _cache_entry_shapes(cfg: ArchConfig, pd: PaddedDims, spec: StackSpec, B: int, S: int, tp: int):
    """(name, shape, dtype, logical_axes) per cache leaf of one stack."""
    dt = jnp.dtype(cfg.dtype)
    L = spec.n_layers
    out = []
    if spec.kind in ("attn", "moe", "hybrid"):
        if cfg.attention == "mla":
            out.append(("ckv", (L, B, S, cfg.kv_lora_rank), dt, ("layers", "batch", "kv_seq", None)))
            out.append(("krope", (L, B, S, cfg.qk_rope_dim), dt, ("layers", "batch", "kv_seq", None)))
        else:
            kv = (L, B, S, pd.n_kv_heads, cfg.d_head)
            ax = ("layers", "batch", "kv_seq", None, None)
            out.append(("k", kv, dt, ax))
            out.append(("v", kv, dt, ax))
    if spec.kind in ("ssm", "hybrid"):
        dims = ssm_mod.ssm_dims(cfg, tp)
        out.append(
            ("conv_x", (L, B, cfg.ssm_conv - 1, dims["di"]), dt, ("layers", "batch", None, "heads"))
        )
        out.append(
            ("conv_bc", (L, B, cfg.ssm_conv - 1, 2 * dims["N"]), dt, ("layers", "batch", None, None))
        )
        out.append(
            (
                "state",
                (L, B, dims["H_pad"], dims["P"], dims["N"]),
                jnp.float32,
                ("layers", "batch", "heads", None, None),
            )
        )
    return out


def init_cache(cfg: ArchConfig, B: int, S_alloc: int, tp: int = 1) -> Params:
    pd = padded_dims(cfg, tp)
    cache: Params = {}
    for spec in stacks_for(cfg):
        for name, shape, dt, _ in _cache_entry_shapes(cfg, pd, spec, B, S_alloc, tp):
            cache[f"{spec.name}/{name}"] = jnp.zeros(shape, dt)
    return cache


def cache_axes(cfg: ArchConfig, B: int, S_alloc: int, tp: int = 1) -> Params:
    pd = padded_dims(cfg, tp)
    ax: Params = {}
    for spec in stacks_for(cfg):
        for name, _, _, axes in _cache_entry_shapes(cfg, pd, spec, B, S_alloc, tp):
            ax[f"{spec.name}/{name}"] = axes
    return ax


# ---------------------------------------------------------------- prefill ------


def prefill(
    params: Params,
    batch: dict,
    cfg: ArchConfig,
    ctx: MeshCtx | None = None,
    *,
    s_alloc: int | None = None,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, Params]:
    """Full-prompt forward → (logits of last position, primed cache)."""
    tp = ctx.tp_size if ctx else 1
    pd = padded_dims(cfg, tp)
    x = _embed_in(params, batch, cfg)
    B, S = x.shape[:2]
    s_alloc = s_alloc or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    cache: Params = {}
    off = 0
    for spec in stacks_for(cfg):
        x, caches, _ = _run_stack_forward(
            params[f"stack_{spec.name}"], spec, x, positions, cfg, pd, ctx,
            remat="none", collect_cache=True, q_chunk=q_chunk, kv_chunk=kv_chunk,
            layer_offset=off,
        )
        off += spec.n_layers
        names = [e[0] for e in _cache_entry_shapes(cfg, pd, spec, B, s_alloc, tp)]
        # caches: tuple of stacked [L, B, S, ...] arrays in block order; the
        # SSD state is not produced by the full-seq path (prefill of SSM
        # archs re-runs the tail step-wise or uses return_state) — attn
        # entries first, in order.
        for name, arr in zip(names, caches):
            if arr.shape[2] != s_alloc and name in ("k", "v", "ckv", "krope"):
                pad = [(0, 0)] * arr.ndim
                pad[2] = (0, s_alloc - arr.shape[2])
                arr = jnp.pad(arr, pad)
            cache[f"{spec.name}/{name}"] = arr

    h = rmsnorm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = _logits(params, h, cfg, pd)[:, 0]
    return logits, cache


# ---------------------------------------------------------------- decode -------


def decode_step(
    params: Params,
    cache: Params,
    batch_t: dict,  # {"tokens": [B,1]} or {"embeds": [B,1,D]}
    pos: jax.Array,  # scalar int32 — position being generated
    cfg: ArchConfig,
    ctx: MeshCtx | None = None,
) -> tuple[jax.Array, Params]:
    """One decode step → (logits [B, V(·CB)], updated cache)."""
    tp = ctx.tp_size if ctx else 1
    pd = padded_dims(cfg, tp)
    x = _embed_in(params, batch_t, cfg)

    new_cache: Params = {}
    off = 0
    for spec in stacks_for(cfg):
        names = [e[0] for e in _cache_entry_shapes(cfg, pd, spec, 1, 1, tp)]
        stack_cache = tuple(cache[f"{spec.name}/{n}"] for n in names)
        windows = _windows_for(cfg, spec, off)
        static_window = isinstance(windows, int)

        def body(x, inp):
            p_l = inp[0]
            cache_l = tuple(inp[1])
            window = 0 if static_window else inp[2]
            x, cache_out = blk.block_decode(
                p_l, x, cache_l, pos, cfg, pd, ctx, kind=spec.kind, window=window
            )
            return x, cache_out

        xs = (params[f"stack_{spec.name}"], list(stack_cache))
        if not static_window:
            xs = xs + (jnp.asarray(windows),)
        if ANALYSIS_UNROLL_LAYERS:
            outs_l = []
            for i in range(spec.n_layers):
                inp = jax.tree.map(lambda a: a[i], xs)
                x, out_i = body(x, inp)
                outs_l.append(out_i)
            outs = jax.tree.map(lambda *ls: jnp.stack(ls), *outs_l)
        else:
            x, outs = jax.lax.scan(body, x, xs)
        for n, arr in zip(names, outs):
            new_cache[f"{spec.name}/{n}"] = arr
        off += spec.n_layers

    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, h, cfg, pd)[:, 0]
    return logits, new_cache
