"""Transformer blocks: kinds "attn", "moe", "ssm", "hybrid".

  attn   — pre-norm attention (GQA or MLA) + gated MLP
  moe    — pre-norm attention + (shared-expert MLP ∥ routed MoE)
  ssm    — pre-norm Mamba-2 SSD only (no MLP — Mamba blocks carry none)
  hybrid — Hymba: attention ∥ SSD on the same normed input, per-branch
           RMSNorm then averaged, + gated MLP

All kinds share the same (params, x, positions) calling convention so a
stack can run under `lax.scan`. Decode attention goes through shard_map
when the KV cache sequence is sharded (SP) — see `_attend_*_sharded`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.padding import PaddedDims
from repro.parallel.sharding import MeshCtx
from . import attention as attn_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .config import ArchConfig
from .layers import Params, dense, mlp_apply, mlp_init, rmsnorm, rmsnorm_init

__all__ = [
    "init_block",
    "block_axes",
    "block_forward",
    "block_decode",
    "GLOBAL_WINDOW",
]

#: sentinel window for "global attention" layers inside a SWA arch
GLOBAL_WINDOW = 1 << 30


def _mlp_like_axes(gated: bool) -> Params:
    ax = {"wi": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}
    if gated:
        ax["wg"] = ("fsdp", "mlp")
    return ax


def init_block(key, cfg: ArchConfig, pd: PaddedDims, tp: int, dtype, kind: str, d_ff: int) -> Params:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p: Params = {"ln1": rmsnorm_init(d)}
    if kind in ("attn", "moe", "hybrid"):
        if cfg.attention == "mla":
            p["attn"] = attn_mod.init_mla(ks[0], cfg, pd, dtype)
        else:
            p["attn"] = attn_mod.init_gqa(ks[0], cfg, pd, dtype)
    if kind in ("ssm", "hybrid"):
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg, tp, dtype)
    if kind == "hybrid":
        p["norm_attn"] = rmsnorm_init(d)
        p["norm_ssm"] = rmsnorm_init(d)
    if kind == "moe":
        p["moe"] = moe_mod.init_moe(ks[2], cfg, tp, dtype)
        if cfg.n_shared_experts:
            p["shared"] = mlp_init(
                ks[3], d, cfg.n_shared_experts * cfg.moe_d_ff, dtype, cfg.gated_mlp
            )
        p["ln2"] = rmsnorm_init(d)
    elif kind in ("attn", "hybrid") and d_ff > 0:
        p["mlp"] = mlp_init(ks[4], d, d_ff, dtype, cfg.gated_mlp)
        p["ln2"] = rmsnorm_init(d)
    return p


def block_axes(cfg: ArchConfig, pd: PaddedDims, tp: int, kind: str, d_ff: int) -> Params:
    ax: Params = {"ln1": (None,)}
    if kind in ("attn", "moe", "hybrid"):
        ax["attn"] = (
            attn_mod.mla_axes(cfg, pd) if cfg.attention == "mla" else attn_mod.gqa_axes(cfg, pd)
        )
    if kind in ("ssm", "hybrid"):
        ax["ssm"] = ssm_mod.ssm_axes(cfg, tp)
    if kind == "hybrid":
        ax["norm_attn"] = (None,)
        ax["norm_ssm"] = (None,)
    if kind == "moe":
        ax["moe"] = moe_mod.moe_axes(cfg, tp)
        if cfg.n_shared_experts:
            ax["shared"] = _mlp_like_axes(cfg.gated_mlp)
        ax["ln2"] = (None,)
    elif kind in ("attn", "hybrid") and d_ff > 0:
        ax["mlp"] = _mlp_like_axes(cfg.gated_mlp)
        ax["ln2"] = (None,)
    return ax


# ---------------------------------------------------------------- forward -----


def block_forward(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    pd: PaddedDims,
    ctx: MeshCtx | None,
    *,
    kind: str,
    window,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, tuple, jax.Array]:
    """Returns (x_out, cache_entries, aux_loss). ``window`` may be a
    static int (0 = global) or a traced per-layer scalar."""
    xn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    cache_entries: tuple = ()

    attn_out = None
    if kind in ("attn", "moe", "hybrid"):
        if cfg.attention == "mla":
            attn_out, (ckv, krope) = attn_mod.mla_forward(
                p["attn"], xn, positions, cfg, pd, q_chunk=q_chunk, kv_chunk=kv_chunk,
                window=window,
            )
            cache_entries += (ckv, krope)
        else:
            attn_out, (k, v) = attn_mod.gqa_forward(
                p["attn"], xn, positions, cfg, pd, window=window,
                q_chunk=q_chunk, kv_chunk=kv_chunk,
            )
            cache_entries += (k, v)

    ssm_out = None
    if kind in ("ssm", "hybrid"):
        ssm_out, ssm_state = ssm_mod.ssm_forward(
            p["ssm"], xn, cfg, ctx.tp_size if ctx else 1, return_state=True
        )
        cache_entries += (ssm_state["conv_x"], ssm_state["conv_bc"], ssm_state["state"])

    if kind == "hybrid":
        mix = 0.5 * (
            rmsnorm(attn_out, p["norm_attn"], cfg.norm_eps)
            + rmsnorm(ssm_out, p["norm_ssm"], cfg.norm_eps)
        )
        x = x + mix
    elif kind == "ssm":
        x = x + ssm_out
    else:
        x = x + attn_out

    if "ln2" in p:
        xn2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        h = jnp.zeros_like(x)
        if "shared" in p:
            h = h + mlp_apply(p["shared"], xn2, cfg.act, cfg.gated_mlp)
        if "moe" in p:
            y, a = moe_mod.moe_forward(p["moe"], xn2, cfg, ctx)
            h = h + y.astype(x.dtype)
            aux = aux + cfg.moe_aux_alpha * a
        if "mlp" in p:
            h = h + mlp_apply(p["mlp"], xn2, cfg.act, cfg.gated_mlp)
        x = x + h
    return x, cache_entries, aux


# ---------------------------------------------------------------- decode ------


def _dp_spec(ctx: MeshCtx):
    if not ctx.shard_batch:
        return None
    return ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]


def _attend_gqa_sharded(
    ctx: MeshCtx | None,
    q,  # [B,1,Hq,Dh]
    k_new,  # [B,Hkv,Dh]
    v_new,
    ck,  # [B,S,Hkv,Dh]
    cv,
    pos,
    cfg: ArchConfig,
    pd: PaddedDims,
    window,
):
    """Cache write + flash-decoding, seq-sharded over the model axis."""

    def local(q, k_new, v_new, ck, cv, pos, axis_name):
        B, S_loc = ck.shape[0], ck.shape[1]
        base = (
            jax.lax.axis_index(axis_name) * S_loc if axis_name is not None else 0
        )
        slot = pos - base
        owns = (slot >= 0) & (slot < S_loc)
        slot_c = jnp.clip(slot, 0, S_loc - 1)
        old_k = jax.lax.dynamic_slice(ck, (0, slot_c, 0, 0), (B, 1) + ck.shape[2:])
        old_v = jax.lax.dynamic_slice(cv, (0, slot_c, 0, 0), (B, 1) + cv.shape[2:])
        wk = jnp.where(owns, k_new[:, None].astype(ck.dtype), old_k)
        wv = jnp.where(owns, v_new[:, None].astype(cv.dtype), old_v)
        ck = jax.lax.dynamic_update_slice(ck, wk, (0, slot_c, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, wv, (0, slot_c, 0, 0))
        kv_pos = base + jnp.arange(S_loc, dtype=jnp.int32)
        o = attn_mod.gqa_attend_decode(
            q, ck, cv, kv_pos, pos, cfg, pd, window=window, axis_name=axis_name
        )
        return o, ck, cv

    if ctx is None:
        return local(q, k_new, v_new, ck, cv, pos, None)

    dp = _dp_spec(ctx)
    f = jax.shard_map(
        lambda *a: local(*a, ctx.tp_axis),
        mesh=ctx.mesh,
        in_specs=(
            P(dp, None, None, None),
            P(dp, None, None),
            P(dp, None, None),
            P(dp, "model", None, None),
            P(dp, "model", None, None),
            P(),
        ),
        out_specs=(P(dp, None, None), P(dp, "model", None, None), P(dp, "model", None, None)),
        check_vma=False,
    )
    return f(q, k_new, v_new, ck, cv, pos)


def _attend_mla_sharded(
    ctx: MeshCtx | None,
    q_eff,  # [B,1,H,rkv] — q_nope absorbed through W_uk
    q_rope,  # [B,1,H,dr]
    c_new,  # [B,rkv]
    kr_new,  # [B,dr]
    ckv,  # [B,S,rkv]
    krope,  # [B,S,dr]
    pos,
    cfg: ArchConfig,
    pd: PaddedDims,
):
    def local(q_eff, q_rope, c_new, kr_new, ckv, krope, pos, axis_name):
        B, S_loc = ckv.shape[0], ckv.shape[1]
        base = jax.lax.axis_index(axis_name) * S_loc if axis_name is not None else 0
        slot = pos - base
        owns = (slot >= 0) & (slot < S_loc)
        slot_c = jnp.clip(slot, 0, S_loc - 1)
        old_c = jax.lax.dynamic_slice(ckv, (0, slot_c, 0), (B, 1, ckv.shape[2]))
        old_r = jax.lax.dynamic_slice(krope, (0, slot_c, 0), (B, 1, krope.shape[2]))
        wc = jnp.where(owns, c_new[:, None].astype(ckv.dtype), old_c)
        wr = jnp.where(owns, kr_new[:, None].astype(krope.dtype), old_r)
        ckv = jax.lax.dynamic_update_slice(ckv, wc, (0, slot_c, 0))
        krope = jax.lax.dynamic_update_slice(krope, wr, (0, slot_c, 0))
        kv_pos = base + jnp.arange(S_loc, dtype=jnp.int32)
        ctx_lat = attn_mod.mla_attend_decode(
            q_eff, q_rope, ckv, krope, kv_pos, pos, cfg, pd, axis_name=axis_name
        )
        return ctx_lat, ckv, krope

    if ctx is None:
        return local(q_eff, q_rope, c_new, kr_new, ckv, krope, pos, None)

    dp = _dp_spec(ctx)
    f = jax.shard_map(
        lambda *a: local(*a, ctx.tp_axis),
        mesh=ctx.mesh,
        in_specs=(
            P(dp, None, None, None),
            P(dp, None, None, None),
            P(dp, None),
            P(dp, None),
            P(dp, "model", None),
            P(dp, "model", None),
            P(),
        ),
        out_specs=(P(dp, None, None), P(dp, "model", None), P(dp, "model", None)),
        check_vma=False,
    )
    return f(q_eff, q_rope, c_new, kr_new, ckv, krope, pos)


def block_decode(
    p: Params,
    x: jax.Array,  # [B, 1, D]
    cache: tuple,  # per-layer cache entries (matches block_forward order)
    pos,
    cfg: ArchConfig,
    pd: PaddedDims,
    ctx: MeshCtx | None,
    *,
    kind: str,
    window,
) -> tuple[jax.Array, tuple]:
    xn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    new_cache: tuple = ()

    attn_out = None
    ssm_in_cache_offset = 0
    if kind in ("attn", "moe", "hybrid"):
        if cfg.attention == "mla":
            ckv, krope = cache[0], cache[1]
            ssm_in_cache_offset = 2
            q_nope, q_rope, c_new, kr_new = attn_mod.mla_project_decode(
                p["attn"], xn, pos, cfg, pd
            )
            rkv, h = cfg.kv_lora_rank, pd.n_heads
            wk_b = p["attn"]["wk_b"].reshape(rkv, h, cfg.qk_nope_dim)
            q_eff = jnp.einsum(
                "bqhd,rhd->bqhr", q_nope.astype(jnp.float32), wk_b.astype(jnp.float32)
            )
            ctx_lat, ckv, krope = _attend_mla_sharded(
                ctx, q_eff, q_rope, c_new, kr_new, ckv, krope, pos, cfg, pd
            )
            wv_b = p["attn"]["wv_b"].reshape(rkv, h, cfg.v_head_dim)
            o_heads = jnp.einsum(
                "bhr,rhd->bhd", ctx_lat.astype(jnp.float32), wv_b.astype(jnp.float32)
            )
            attn_out = dense(
                o_heads.astype(x.dtype).reshape(x.shape[0], 1, h * cfg.v_head_dim),
                p["attn"]["wo"],
            )
            new_cache += (ckv, krope)
        else:
            ck, cv = cache[0], cache[1]
            ssm_in_cache_offset = 2
            q, k_new, v_new = attn_mod.gqa_project_decode(p["attn"], xn, pos, cfg, pd)
            o, ck, cv = _attend_gqa_sharded(
                ctx, q, k_new, v_new, ck, cv, pos, cfg, pd, window
            )
            attn_out = dense(o, p["attn"]["wo"])
            new_cache += (ck, cv)

    ssm_out = None
    if kind in ("ssm", "hybrid"):
        state = {
            "conv_x": cache[ssm_in_cache_offset],
            "conv_bc": cache[ssm_in_cache_offset + 1],
            "state": cache[ssm_in_cache_offset + 2],
        }
        ssm_out, ns = ssm_mod.ssm_decode(p["ssm"], xn, state, cfg, ctx.tp_size if ctx else 1)
        new_cache += (ns["conv_x"], ns["conv_bc"], ns["state"])

    if kind == "hybrid":
        mix = 0.5 * (
            rmsnorm(attn_out, p["norm_attn"], cfg.norm_eps)
            + rmsnorm(ssm_out, p["norm_ssm"], cfg.norm_eps)
        )
        x = x + mix
    elif kind == "ssm":
        x = x + ssm_out
    else:
        x = x + attn_out

    if "ln2" in p:
        xn2 = rmsnorm(x, p["ln2"], cfg.norm_eps)
        h = jnp.zeros_like(x)
        if "shared" in p:
            h = h + mlp_apply(p["shared"], xn2, cfg.act, cfg.gated_mlp)
        if "moe" in p:
            y, _ = moe_mod.moe_forward(p["moe"], xn2, cfg, ctx)
            h = h + y.astype(x.dtype)
        if "mlp" in p:
            h = h + mlp_apply(p["mlp"], xn2, cfg.act, cfg.gated_mlp)
        x = x + h
    return x, new_cache
