"""Shared neural layers (pure JAX; params are plain pytrees).

Conventions: activations ``[B, S, D]``; attention heads ``[B, S, H, Dh]``;
params created by ``init_*`` helpers return nested dicts of jnp arrays in
``cfg.dtype`` (norm scales fp32). Matmuls accumulate in fp32 via
``preferred_element_type`` where it matters.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = [
    "Params",
    "dense_init",
    "rmsnorm_init",
    "rmsnorm",
    "dense",
    "rope_freqs",
    "apply_rope",
    "mlp_init",
    "mlp_apply",
    "flash_attention",
    "combine_partial_softmax",
]

Params = dict[str, Any]

#: when True, dense() keeps matmul outputs in bf16 so cross-chip partial
#: sums (TP all-reduces) move half the bytes. MXU accumulation stays f32
#: internally; only the inter-chip reduction is bf16 (§Perf measured
#: quality-neutral at smoke scale, flagged for large-scale validation).
TP_REDUCE_BF16 = False


# -- init ---------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, *, scale: float | None = None):
    """Truncated-normal fan-in init (stddev 1/sqrt(d_in) unless given)."""
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * std).astype(dtype)


def rmsnorm_init(d: int):
    return jnp.ones((d,), jnp.float32)


# -- primitives -----------------------------------------------------------------


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale
    return y.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array) -> jax.Array:
    pet = x.dtype if TP_REDUCE_BF16 else jnp.float32
    return jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=pet
    ).astype(x.dtype)


def _act(name: str):
    return jax.nn.silu if name == "silu" else jax.nn.gelu


# -- RoPE -----------------------------------------------------------------------


def rope_freqs(d_rot: int, theta: float) -> jax.Array:
    """Inverse frequencies for a rotary block of width d_rot (even)."""
    return 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))


def apply_rope(
    x: jax.Array,  # [B, S, H, Dh]
    positions: jax.Array,  # [B, S] int32
    theta: float,
    rotary_pct: float = 1.0,
) -> jax.Array:
    """Rotate the first ``rotary_pct`` of head dims (pairwise halves).

    ``rotary_pct=0.5`` reproduces ChatGLM3's 2-d RoPE (half the dims
    carry position, half are untouched).
    """
    dh = x.shape[-1]
    d_rot = int(dh * rotary_pct)
    d_rot -= d_rot % 2
    if d_rot == 0:
        return x
    x_rot, x_pass = x[..., :d_rot], x[..., d_rot:]
    inv = rope_freqs(d_rot, theta)  # [d_rot/2]
    ang = positions.astype(jnp.float32)[..., None] * inv  # [B, S, d_rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return jnp.concatenate([rot.astype(x.dtype), x_pass], axis=-1)


# -- MLP --------------------------------------------------------------------------


def mlp_init(key, d: int, d_ff: int, dtype, gated: bool) -> Params:
    ks = jax.random.split(key, 3)
    p = {"wi": dense_init(ks[0], d, d_ff, dtype), "wo": dense_init(ks[1], d_ff, d, dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d, d_ff, dtype)
    return p


def mlp_apply(p: Params, x: jax.Array, act: str, gated: bool) -> jax.Array:
    h = dense(x, p["wi"])
    if gated:
        h = _act(act)(dense(x, p["wg"])) * h
    else:
        h = _act(act)(h)
    return dense(h, p["wo"])


# -- chunked (flash-style) attention -----------------------------------------------


def combine_partial_softmax(o_a, m_a, l_a, o_b, m_b, l_b):
    """Merge two partial softmax accumulations (o: weighted values,
    m: running max, l: running denominator)."""
    m = jnp.maximum(m_a, m_b)
    sa = jnp.exp(m_a - m)
    sb = jnp.exp(m_b - m)
    return o_a * sa[..., None] + o_b * sb[..., None], m, l_a * sa + l_b * sb


def flash_attention(
    q: jax.Array,  # [B, Sq, Hq, Dh]
    k: jax.Array,  # [B, Skv, Hkv, Dh]
    v: jax.Array,  # [B, Skv, Hkv, Dv]
    q_positions: jax.Array,  # [B, Sq] global positions of the queries
    kv_positions: jax.Array,  # [B, Skv]
    *,
    causal: bool = True,
    window: int = 0,  # sliding window size; 0 = unbounded
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    scale: float | None = None,
    score_bias: float = 0.0,
) -> jax.Array:
    """Online-softmax attention, O(q_chunk·kv_chunk) score memory.

    GQA is handled by reshaping q to [B, Sq, Hkv, G, Dh]. The outer loop
    (q chunks) is ``lax.map``; the inner loop (kv chunks) is ``lax.scan``
    carrying (o, m, l). Masks come from global positions, so the function
    is correct under any sharding and for rolling buffers (positions need
    not be sorted).
    """
    B, Sq, Hq, Dh = q.shape
    _, Skv, Hkv, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hkv
    sc = scale if scale is not None else 1.0 / math.sqrt(Dh)
    # window may be a traced per-layer scalar (Hymba); only a *static* 0
    # disables the mask entirely.
    apply_window = not (isinstance(window, int) and window == 0)

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    nq = -(-Sq // q_chunk)
    nk = -(-Skv // kv_chunk)
    Sq_pad, Skv_pad = nq * q_chunk, nk * kv_chunk

    NEG = jnp.float32(-1e30)

    def pad_seq(x, S_pad, fill=0):
        pad = S_pad - x.shape[1]
        if pad == 0:
            return x
        w = [(0, 0)] * x.ndim
        w[1] = (0, pad)
        return jnp.pad(x, w, constant_values=fill)

    qp = pad_seq(q, Sq_pad).reshape(B, nq, q_chunk, Hkv, G, Dh)
    qpos = pad_seq(q_positions, Sq_pad, fill=-1).reshape(B, nq, q_chunk)
    kp = pad_seq(k, Skv_pad).reshape(B, nk, kv_chunk, Hkv, Dh)
    vp = pad_seq(v, Skv_pad).reshape(B, nk, kv_chunk, Hkv, Dv)
    kpos = pad_seq(kv_positions, Skv_pad, fill=jnp.iinfo(jnp.int32).max).reshape(B, nk, kv_chunk)

    def q_block(args):
        qb, qposb = args  # [B, qc, Hkv, G, Dh], [B, qc]

        def kv_step(carry, kv):
            o, m, l = carry
            kb, vb, kposb = kv  # [B, kc, Hkv, Dh/v], [B, kc]
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qb, kb, preferred_element_type=jnp.float32
            ) * sc + score_bias
            ok = jnp.ones((), jnp.bool_)
            mask = (qposb[:, None, None, :, None] >= 0)
            if causal:
                mask &= kposb[:, None, None, None, :] <= qposb[:, None, None, :, None]
            else:
                mask &= kposb[:, None, None, None, :] < jnp.iinfo(jnp.int32).max
            if apply_window:
                mask &= kposb[:, None, None, None, :] > (
                    qposb[:, None, None, :, None] - window
                )
            s = jnp.where(mask, s, NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            # dead rows (fully masked) have m_new == -1e30 → p == 1; zero them
            p = jnp.where(m_new[..., None] <= NEG / 2, 0.0, p)
            l_new = l * jnp.exp(m - m_new) + jnp.sum(p, axis=-1)
            o_new = o * jnp.exp(m - m_new)[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb.astype(jnp.float32)
            )
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, Hkv, G, q_chunk, Dv), jnp.float32)
        m0 = jnp.full((B, Hkv, G, q_chunk), NEG, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk), jnp.float32)
        (o, m, l), _ = jax.lax.scan(
            kv_step,
            (o0, m0, l0),
            (
                jnp.moveaxis(kp, 1, 0),
                jnp.moveaxis(vp, 1, 0),
                jnp.moveaxis(kpos, 1, 0),
            ),
        )
        o = o / jnp.maximum(l[..., None], 1e-30)
        return o  # [B, Hkv, G, qc, Dv]

    out = jax.lax.map(q_block, (jnp.moveaxis(qp, 1, 0), jnp.moveaxis(qpos, 1, 0)))
    # [nq, B, Hkv, G, qc, Dv] → [B, Sq_pad, Hq, Dv]
    out = jnp.moveaxis(out, 0, 3).reshape(B, Hkv, G, Sq_pad, Dv)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(B, Sq_pad, Hq, Dv)
    return out[:, :Sq].astype(q.dtype)
