"""Attention: GQA (with RoPE variants, sliding window) and MLA (DeepSeek).

Two execution paths per variant:
  * full-sequence (train / prefill): `flash_attention` (chunked online
    softmax, pure jnp — any sharding, any head count).
  * decode: one query token against a cache whose *sequence* dim may be
    sharded over the ``model`` mesh axis (SP). Each shard computes a
    partial (o, m, l) and the result is combined with an exp-rescaled
    psum — flash-decoding across chips. ``axis_name=None`` degrades to
    local compute (single-device smoke tests).

MLA decode uses the absorbed form: scores are taken against the latent
cache directly (q_nope absorbed through W_uk, attention output through
W_uv), so per-step work is O(S · kv_lora_rank) instead of
O(S · n_heads · d_head).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.parallel.padding import PaddedDims
from .config import ArchConfig
from .layers import Params, apply_rope, dense, dense_init, flash_attention

__all__ = [
    "init_gqa",
    "gqa_axes",
    "gqa_forward",
    "gqa_project_decode",
    "gqa_attend_decode",
    "init_mla",
    "mla_axes",
    "mla_forward",
    "mla_project_decode",
    "mla_attend_decode",
]

NEG = jnp.float32(-1e30)


# ---------------------------------------------------------------- GQA --------


def init_gqa(key, cfg: ArchConfig, pd: PaddedDims, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, dh = cfg.d_model, cfg.d_head
    hq, hkv = pd.n_heads, pd.n_kv_heads
    wq = dense_init(ks[0], d, hq * dh, dtype)
    if pd.n_heads != cfg.n_heads:  # zero-out padded q heads
        wq = wq.reshape(d, hq, dh).at[:, cfg.n_heads :, :].set(0.0).reshape(d, hq * dh)
    return {
        "wq": wq,
        "wk": dense_init(ks[1], d, hkv * dh, dtype),
        "wv": dense_init(ks[2], d, hkv * dh, dtype),
        "wo": dense_init(ks[3], hq * dh, d, dtype),
    }


def gqa_axes(cfg: ArchConfig, pd: PaddedDims) -> Params:
    kv_sharded = pd.n_kv_heads % 8 == 0  # replicate tiny KV projections
    kv = ("fsdp", "heads") if kv_sharded else ("fsdp", None)
    return {"wq": ("fsdp", "heads"), "wk": kv, "wv": kv, "wo": ("heads", "fsdp")}


def _split_heads(x, n_heads, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n_heads, d_head)


def gqa_forward(
    p: Params,
    x: jax.Array,  # [B, S, D]
    positions: jax.Array,  # [B, S]
    cfg: ArchConfig,
    pd: PaddedDims,
    *,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Returns (output [B,S,D], (k, v) for cache seeding)."""
    dh = cfg.d_head
    q = _split_heads(dense(x, p["wq"]), pd.n_heads, dh)
    k = _split_heads(dense(x, p["wk"]), pd.n_kv_heads, dh)
    v = _split_heads(dense(x, p["wv"]), pd.n_kv_heads, dh)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rotary_pct)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rotary_pct)
    o = flash_attention(
        q, k, v, positions, positions,
        causal=True, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    out = dense(o.reshape(*x.shape[:2], pd.n_heads * dh), p["wo"])
    return out, (k, v)


def _partial_softmax(scores: jax.Array, values: jax.Array):
    """scores [..., S], values [..., S, Dv] → (o, m, l) partials (fp32)."""
    m = jnp.max(scores, axis=-1)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(m[..., None] <= NEG / 2, 0.0, p)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("...k,...kd->...d", p, values.astype(jnp.float32))
    return o, m, l


def _combine_over_axis(o, m, l, axis_name):
    """Exp-rescaled psum combine of softmax partials over a mesh axis."""
    if axis_name is None:
        return o / jnp.maximum(l[..., None], 1e-30)
    g_m = jax.lax.pmax(m, axis_name)
    scale = jnp.exp(m - g_m)
    g_l = jax.lax.psum(l * scale, axis_name)
    g_o = jax.lax.psum(o * scale[..., None], axis_name)
    return g_o / jnp.maximum(g_l[..., None], 1e-30)


def gqa_project_decode(
    p: Params, x_t: jax.Array, pos: jax.Array, cfg: ArchConfig, pd: PaddedDims
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Decode-step projections: q [B,1,Hq,Dh], k_new/v_new [B,Hkv,Dh].

    The caller writes (k_new, v_new) into the cache slot for ``pos``
    BEFORE attending, so the current token attends to itself via the
    cache — exactly once, on the shard owning the slot.
    """
    dh = cfg.d_head
    B = x_t.shape[0]
    q = _split_heads(dense(x_t, p["wq"]), pd.n_heads, dh)
    k_new = _split_heads(dense(x_t, p["wk"]), pd.n_kv_heads, dh)
    v_new = _split_heads(dense(x_t, p["wv"]), pd.n_kv_heads, dh)
    posb = jnp.broadcast_to(pos, (B, 1))
    q = apply_rope(q, posb, cfg.rope_theta, cfg.rotary_pct)
    k_new = apply_rope(k_new, posb, cfg.rope_theta, cfg.rotary_pct)
    return q, k_new[:, 0], v_new[:, 0]


def gqa_attend_decode(
    q: jax.Array,  # [B, 1, Hq, Dh]
    k_cache: jax.Array,  # [B, S_loc, Hkv, Dh]  (seq-sharded under shard_map)
    v_cache: jax.Array,
    kv_pos: jax.Array,  # [S_loc] global positions (-1 = empty slot)
    pos: jax.Array,  # scalar — current decode position
    cfg: ArchConfig,
    pd: PaddedDims,
    *,
    window: int = 0,
    axis_name: str | None = None,
) -> jax.Array:
    """Flash-decoding over a (possibly seq-sharded) cache → heads [B,1,Hq·Dh]."""
    dh = cfg.d_head
    B = q.shape[0]
    hq, hkv = pd.n_heads, pd.n_kv_heads
    G = hq // hkv
    qh = q.reshape(B, hkv, G, dh)
    s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache, preferred_element_type=jnp.float32)
    s = s / math.sqrt(dh)
    valid = (kv_pos >= 0) & (kv_pos <= pos)
    if isinstance(window, int) and window == 0:
        pass
    else:
        valid &= kv_pos > pos - window
    s = jnp.where(valid[None, None, None, :], s, NEG)
    o, m, l = _partial_softmax(s, v_cache.transpose(0, 2, 1, 3)[:, :, None, :, :])
    o = _combine_over_axis(o, m, l, axis_name)
    return o.astype(q.dtype).reshape(B, 1, hq * dh)  # caller applies wo


# ---------------------------------------------------------------- MLA --------


def init_mla(key, cfg: ArchConfig, pd: PaddedDims, dtype) -> Params:
    ks = jax.random.split(key, 7)
    d = cfg.d_model
    h = pd.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wq_a": dense_init(ks[0], d, rq, dtype),
        "q_a_norm": jnp.ones((rq,), jnp.float32),
        "wq_b": dense_init(ks[1], rq, h * (dn + dr), dtype),
        "wkv_a": dense_init(ks[2], d, rkv + dr, dtype),
        "kv_a_norm": jnp.ones((rkv,), jnp.float32),
        "wk_b": dense_init(ks[3], rkv, h * dn, dtype),
        "wv_b": dense_init(ks[4], rkv, h * dv, dtype),
        "wo": dense_init(ks[5], h * dv, d, dtype),
    }


def mla_axes(cfg: ArchConfig, pd: PaddedDims) -> Params:
    return {
        "wq_a": ("fsdp", None),
        "q_a_norm": (None,),
        "wq_b": (None, "heads"),
        "wkv_a": ("fsdp", None),
        "kv_a_norm": (None,),
        "wk_b": (None, "heads"),
        "wv_b": (None, "heads"),
        "wo": ("heads", "fsdp"),
    }


def _mla_qkv(p, x, positions, cfg, pd):
    from .layers import rmsnorm

    B, S, _ = x.shape
    h = pd.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = dense(rmsnorm(dense(x, p["wq_a"]), p["q_a_norm"]), p["wq_b"])
    q = q.reshape(B, S, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    kv = dense(x, p["wkv_a"])  # [B,S,rkv+dr]
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank :]
    c_kv = rmsnorm(c_kv, p["kv_a_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    pd: PaddedDims,
    *,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    window: int = 0,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Materialized (prefill/train) MLA; caches (c_kv, k_rope)."""
    B, S, _ = x.shape
    h = pd.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(p, x, positions, cfg, pd)
    k_nope = dense(c_kv, p["wk_b"]).reshape(B, S, h, dn)
    v = dense(c_kv, p["wv_b"]).reshape(B, S, h, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, h, dr))], axis=-1)
    o = flash_attention(
        q, k, v, positions, positions,
        causal=True, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
        scale=1.0 / math.sqrt(dn + dr),
    )
    out = dense(o.reshape(B, S, h * dv), p["wo"])
    return out, (c_kv, k_rope)


def mla_project_decode(
    p: Params, x_t: jax.Array, pos: jax.Array, cfg: ArchConfig, pd: PaddedDims
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Decode projections: q_nope [B,1,H,dn], q_rope [B,1,H,dr],
    c_new [B,rkv], krope_new [B,dr] (cache entries for slot ``pos``)."""
    B = x_t.shape[0]
    posb = jnp.broadcast_to(pos, (B, 1))
    q_nope, q_rope, c_new, krope_new = _mla_qkv(p, x_t, posb, cfg, pd)
    return q_nope, q_rope, c_new[:, 0], krope_new[:, 0]


def mla_attend_decode(
    q_nope: jax.Array,  # [B, 1, H, dn]
    q_rope: jax.Array,  # [B, 1, H, dr]
    ckv_cache: jax.Array,  # [B, S_loc, rkv]
    krope_cache: jax.Array,  # [B, S_loc, dr]
    kv_pos: jax.Array,  # [S_loc]
    pos: jax.Array,
    cfg: ArchConfig,
    pd: PaddedDims,
    *,
    axis_name: str | None = None,
) -> jax.Array:
    """Absorbed-form MLA flash-decoding → latent ctx [B, H, rkv].

    The caller applies W_uv (absorbed value up-proj) + wo; both are
    TP-sharded over heads so they stay in pjit-land.
    """
    B = q_nope.shape[0]
    h = pd.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    # q_nope already absorbed through W_uk by the caller → q_eff [B,h,rkv]
    q_eff = q_nope[:, 0].astype(jnp.float32)
    s_nope = jnp.einsum("bhr,bsr->bhs", q_eff, ckv_cache.astype(jnp.float32))
    s_rope = jnp.einsum(
        "bhd,bsd->bhs", q_rope[:, 0].astype(jnp.float32), krope_cache.astype(jnp.float32)
    )
    s = (s_nope + s_rope) / math.sqrt(dn + dr)  # [B,h,S_loc]
    valid = (kv_pos >= 0) & (kv_pos <= pos)
    s = jnp.where(valid[None, None, :], s, NEG)
    o, m, l = _partial_softmax(s, ckv_cache[:, None, :, :])  # ctx over latent [B,h,rkv]
    ctx = _combine_over_axis(o, m, l, axis_name)  # [B,h,rkv]
    return ctx.astype(krope_cache.dtype)
