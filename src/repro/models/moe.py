"""Mixture-of-Experts: shared + routed experts, EP over the model axis.

Design (replicated-activation expert parallelism):
  * activations [B,S,D] are data-parallel over (pod, data) and replicated
    over ``model``; every model shard routes the same local tokens but
    owns only E/tp experts, so dispatch/combine are LOCAL (no all_to_all)
    and one psum over ``model`` merges the partial outputs — the same
    collective cost as a TP MLP.
  * expert weights are additionally FSDP-sharded on d_ff over (pod,data)
    and all-gathered in-layer (ZeRO-3), so a 671B MoE fits 512 chips.
  * capacity-factor dispatch (static shapes): per-shard capacity
    C = ceil(T_loc · top_k · cf / E); overflow tokens drop (scatter mode
    'drop'), underflow slots are zero rows.
  * shared experts are an always-on dense MLP in plain pjit-land.

``ctx=None`` (smoke tests) runs the identical code with E_loc = E and no
collectives.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import MeshCtx
from .config import ArchConfig
from .layers import Params, dense_init

__all__ = ["init_moe", "moe_axes", "moe_forward"]


def _e_pad(cfg: ArchConfig, tp: int) -> int:
    return -(-cfg.n_experts // tp) * tp


def init_moe(key, cfg: ArchConfig, tp: int, dtype) -> Params:
    ks = jax.random.split(key, 4)
    d, f = cfg.d_model, cfg.moe_d_ff
    E = _e_pad(cfg, tp)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, E, jnp.float32),
        "wi": (jax.random.truncated_normal(ks[1], -2, 2, (E, d, f)) * std).astype(dtype),
        "wg": (jax.random.truncated_normal(ks[2], -2, 2, (E, d, f)) * std).astype(dtype),
        "wo": (
            jax.random.truncated_normal(ks[3], -2, 2, (E, f, d)) * (1.0 / math.sqrt(f))
        ).astype(dtype),
    }
    return p


def moe_axes(cfg: ArchConfig, tp: int) -> Params:
    return {
        "router": (None, None),
        "wi": ("experts", None, "expert_mlp"),
        "wg": ("experts", None, "expert_mlp"),
        "wo": ("experts", "expert_mlp", None),
    }


def _route(cfg: ArchConfig, logits: jax.Array, e_valid: int):
    """Top-k routing. Returns (expert_idx [T,k], gates [T,k], probs [T,E])."""
    mask = jnp.arange(logits.shape[-1]) < e_valid  # pad experts never win
    logits = jnp.where(mask, logits, -1e30)
    if cfg.router_score == "sigmoid":
        scores = jax.nn.sigmoid(logits)
        probs = scores / jnp.maximum(scores.sum(-1, keepdims=True), 1e-9)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
        probs = scores
    top, idx = jax.lax.top_k(scores, cfg.moe_top_k)
    gates = top / jnp.maximum(top.sum(-1, keepdims=True), 1e-9)
    return idx, gates.astype(jnp.float32), probs


def _moe_local(
    x: jax.Array,  # [T, D] local tokens (replicated over model)
    p: Params,  # wi/wg/wo already gathered to full d_ff; router full
    e_offset,  # scalar — first expert id owned by this shard
    E_local: int,
    cfg: ArchConfig,
    act,
) -> tuple[jax.Array, jax.Array]:
    """Partial MoE output using only the local experts + aux-loss stats."""
    T, D = x.shape
    E = p["router"].shape[-1]
    k = cfg.moe_top_k
    C = max(1, int(math.ceil(T * k * cfg.capacity_factor / cfg.n_experts)))

    logits = x.astype(jnp.float32) @ p["router"]
    idx, gates, probs = _route(cfg, logits, cfg.n_experts)

    # local expert slot for each (token, k): in [0, E_local) or out-of-range
    local_e = idx - e_offset  # [T, k]
    is_local = (local_e >= 0) & (local_e < E_local)
    flat_e = jnp.where(is_local, local_e, E_local).reshape(-1)  # E_local = drop row
    # position of each assignment within its expert (over T·k flattened order)
    onehot = jax.nn.one_hot(flat_e, E_local + 1, dtype=jnp.int32)  # [T*k, E+1]
    pos = jnp.cumsum(onehot, axis=0) - onehot  # exclusive prefix count
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # [T*k]
    slot = jnp.where(flat_e == E_local, C, slot)  # force drop

    tok = jnp.repeat(jnp.arange(T), k)
    x_buf = jnp.zeros((E_local, C, D), x.dtype)
    x_buf = x_buf.at[flat_e, slot].set(x[tok], mode="drop")

    h = jnp.einsum("ecd,edf->ecf", x_buf, p["wi"], preferred_element_type=jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", x_buf, p["wg"], preferred_element_type=jnp.float32)
    h = (act(g) * h).astype(x.dtype)
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"], preferred_element_type=jnp.float32)

    y_tok = y_buf.at[flat_e, slot].get(mode="fill", fill_value=0.0)  # [T*k, D]
    gate_flat = gates.reshape(-1) * is_local.reshape(-1)
    y = jnp.zeros((T, D), jnp.float32).at[tok].add(y_tok * gate_flat[:, None])

    # aux load-balance stats (fraction routed, mean prob) — psum'd by caller
    sel = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1)  # [T, E]
    frac = sel.mean(0)
    mean_p = probs.mean(0)
    aux = jnp.sum(frac * mean_p) * cfg.n_experts
    return y, aux


def ep_over_data_ok(cfg: ArchConfig, ctx: MeshCtx | None) -> bool:
    """Global EP (experts over data×model) requires divisibility."""
    if ctx is None or "data" not in ctx.mesh.axis_names:
        return False
    E = _e_pad(cfg, ctx.tp_size)
    return E % (ctx.mesh.shape["data"] * ctx.tp_size) == 0


def moe_forward(
    p: Params,
    x: jax.Array,  # [B, S, D]
    cfg: ArchConfig,
    ctx: MeshCtx | None,
) -> tuple[jax.Array, jax.Array]:
    """Routed-expert output (shared experts handled by the caller's MLP).

    Returns (y [B,S,D], aux_loss scalar).
    """
    B, S, D = x.shape
    E = p["router"].shape[-1]

    if ctx is None:
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
        y, aux = _moe_local(x.reshape(B * S, D), p, 0, E, cfg, act)
        return y.reshape(B, S, D).astype(x.dtype), aux

    if ctx.serve_ep and ep_over_data_ok(cfg, ctx):
        return _moe_global_ep(p, x, cfg, ctx)

    tp = ctx.tp_size
    E_local = E // tp
    dp_spec = (
        (ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]) if ctx.shard_batch else None
    )
    fsdp = ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]

    def shard_fn(x_loc, router, wi, wg, wo):
        # gather the FSDP-sharded d_ff in-layer (ZeRO-3)
        wi = jax.lax.all_gather(wi, ctx.dp_axes, axis=2, tiled=True)
        wg = jax.lax.all_gather(wg, ctx.dp_axes, axis=2, tiled=True)
        wo = jax.lax.all_gather(wo, ctx.dp_axes, axis=1, tiled=True)
        e_offset = jax.lax.axis_index(ctx.tp_axis) * E_local
        Bl, Sl, Dl = x_loc.shape
        y, aux = _moe_local(
            x_loc.reshape(Bl * Sl, Dl),
            {"router": router, "wi": wi, "wg": wg, "wo": wo},
            e_offset,
            E_local,
            cfg,
            jax.nn.silu if cfg.act == "silu" else jax.nn.gelu,
        )
        y = jax.lax.psum(y, ctx.tp_axis)
        aux = jax.lax.psum(aux, ctx.tp_axis) / tp  # same stats on every shard
        return y.reshape(Bl, Sl, Dl).astype(x_loc.dtype), aux

    if ctx.serve_ep:
        # serving without global EP: expert weights model-sharded only,
        # no FSDP gather — skip the in-layer all_gathers.
        def shard_fn_serve(x_loc, router, wi, wg, wo):
            e_offset = jax.lax.axis_index(ctx.tp_axis) * E_local
            Bl, Sl, Dl = x_loc.shape
            y, aux = _moe_local(
                x_loc.reshape(Bl * Sl, Dl),
                {"router": router, "wi": wi, "wg": wg, "wo": wo},
                e_offset, E_local, cfg,
                jax.nn.silu if cfg.act == "silu" else jax.nn.gelu,
            )
            y = jax.lax.psum(y, ctx.tp_axis)
            return y.reshape(Bl, Sl, Dl).astype(x_loc.dtype), jax.lax.psum(aux, ctx.tp_axis) / tp

        y, aux = jax.shard_map(
            shard_fn_serve,
            mesh=ctx.mesh,
            in_specs=(
                P(dp_spec, None, None),
                P(None, None),
                P("model", None, None),
                P("model", None, None),
                P("model", None, None),
            ),
            out_specs=(P(dp_spec, None, None), P()),
            check_vma=False,
        )(x, p["router"], p["wi"], p["wg"], p["wo"])
        return y, aux

    y, aux = jax.shard_map(
        shard_fn,
        mesh=ctx.mesh,
        in_specs=(
            P(dp_spec, None, None),
            P(None, None),
            P("model", None, fsdp),
            P("model", None, fsdp),
            P("model", fsdp, None),
        ),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return y, aux


def _moe_global_ep(p: Params, x: jax.Array, cfg: ArchConfig, ctx: MeshCtx):
    """Serving-time global EP: experts sharded over (data, model); token
    activations are all-gathered across the dp axes (tiny at decode),
    every chip computes partials for ALL tokens with its E/(data·tp)
    experts, one psum over (data, model) rebuilds the output, and each
    dp row keeps its own slice. Collectives: one small all-gather + one
    [T_global, D] psum — no per-layer weight movement at all."""
    B, S, D = x.shape
    E = p["router"].shape[-1]
    tp = ctx.tp_size
    data = ctx.mesh.shape["data"]
    E_local = E // (data * tp)
    dp_spec = (
        (ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0]) if ctx.shard_batch else None
    )
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    has_pod = "pod" in ctx.mesh.axis_names

    def shard_fn(x_loc, router, wi, wg, wo):
        if dp_spec is not None:
            x_all = jax.lax.all_gather(x_loc, ctx.dp_axes, axis=0, tiled=True)
        else:
            x_all = x_loc
        Bg, Sl, Dl = x_all.shape
        e_offset = (
            jax.lax.axis_index("data") * tp + jax.lax.axis_index(ctx.tp_axis)
        ) * E_local
        y, aux = _moe_local(
            x_all.reshape(Bg * Sl, Dl),
            {"router": router, "wi": wi, "wg": wg, "wo": wo},
            e_offset, E_local, cfg, act,
        )
        y = jax.lax.psum(y, ("data", ctx.tp_axis))
        aux = jax.lax.psum(aux, ("data", ctx.tp_axis)) / (data * tp)
        y = y.reshape(Bg, Sl, Dl)
        if dp_spec is not None:
            # keep this dp row's slice
            idx = jax.lax.axis_index("data")
            if has_pod:
                idx = jax.lax.axis_index("pod") * data + idx
            Bl = x_loc.shape[0]
            y = jax.lax.dynamic_slice(y, (idx * Bl, 0, 0), (Bl, Sl, Dl))
        return y.astype(x_loc.dtype), aux

    y, aux = jax.shard_map(
        shard_fn,
        mesh=ctx.mesh,
        in_specs=(
            P(dp_spec, None, None),
            P(None, None),
            P(("data", "model"), None, None),
            P(("data", "model"), None, None),
            P(("data", "model"), None, None),
        ),
        out_specs=(P(dp_spec, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return y, aux
