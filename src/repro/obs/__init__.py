"""Observability layer: metrics registry, structured tracing,
slow-query forensics.

Three zero-dependency modules threaded through every tier of the
stack:

* :mod:`repro.obs.metrics` — counters, gauges, and log-bucketed
  latency histograms behind a get-or-create
  :class:`~repro.obs.metrics.MetricsRegistry`.  ``HREngine.stats`` and
  ``FrontDoor.stats`` are read-through views over their registries;
  ``reset_stats()`` on either is one registry reset.
* :mod:`repro.obs.trace` — explicit-parent spans (context is a call
  argument, never a thread-local) with a pluggable clock;
  :class:`~repro.obs.trace.TickClock` makes traces byte-deterministic
  for seeded chaos replay.  The span taxonomy (stage names are a
  public, stable contract) is documented in that module's docstring.
* :mod:`repro.obs.export` — K-slowest span-tree log, deterministic
  JSON-lines dump/load, and the ``python -m repro.obs`` report CLI.
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Span, TickClock, Tracer, walk
from .export import (
    SlowQueryLog,
    dump_jsonl,
    format_tree,
    load_jsonl,
    render_report,
    span_to_line,
    stage_totals,
)

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Span",
    "TickClock",
    "Tracer",
    "walk",
    "SlowQueryLog",
    "dump_jsonl",
    "load_jsonl",
    "span_to_line",
    "stage_totals",
    "format_tree",
    "render_report",
]
