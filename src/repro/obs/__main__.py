"""Trace report CLI: ``python -m repro.obs TRACE.jsonl``.

Loads a JSON-lines trace dump (as written by ``dump_jsonl`` — e.g.
``python -m repro.ft.chaos --overload --trace out.jsonl`` or
``examples/serve_batch.py --frontdoor --trace-out out.jsonl``) and
prints the stage breakdown plus the slowest span trees.  Exits
non-zero on malformed JSON-lines or an empty dump, which is exactly
the contract the CI traced-smoke step relies on.
"""

from __future__ import annotations

import argparse
import sys

from .export import load_jsonl, render_report


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="render a human-readable report from a JSON-lines "
                    "trace dump",
    )
    ap.add_argument("trace", help="JSON-lines trace file (one span tree per line)")
    ap.add_argument("--top", type=int, default=5,
                    help="how many slowest trees to print in full (default 5)")
    ap.add_argument("--unit", choices=("s", "ms", "us", "ticks"), default="ms",
                    help="time unit for rendering (default ms; use 'ticks' "
                         "for TickClock traces)")
    args = ap.parse_args(argv)

    try:
        docs = load_jsonl(args.trace)
    except (OSError, ValueError) as e:
        print(f"[obs] ERROR: {e}", file=sys.stderr)
        return 1
    if not docs:
        print(f"[obs] ERROR: {args.trace} holds no span trees "
              "(empty slow-query log?)", file=sys.stderr)
        return 1
    print(render_report(docs, top=args.top, unit=args.unit), end="")
    return 0


if __name__ == "__main__":
    sys.exit(main())
