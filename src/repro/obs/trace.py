"""Structured tracing: explicit-parent spans carried through call
arguments.

Context propagation is *explicit*: a caller that wants a subtree
passes its span as the ``trace=`` argument and the callee creates
children with :meth:`Span.child`.  There are no thread-locals and no
ambient "current span" — the FrontDoor's virtual-clock event loop
interleaves many requests in one thread, and deterministic replay
(the chaos byte-identity property) requires that span identity be a
pure function of the call tree, not of scheduler interleaving.

Span taxonomy
=============

Stage names are a public, stable contract — exporters, the bench
gate's stage breakdown, and downstream dashboards key on them.

Serving tier (virtual-clock timestamps from the FrontDoor event loop):

== ========================== ===========================================
.. ``frontdoor.request``      root, one per submitted request; attrs
                              ``idx``, ``priority``, ``level``; ends at
                              completion with ``status`` and
                              ``latency_s``
.. ``frontdoor.admission``    admission-guard verdict; attr ``outcome``
                              (admitted / throttle / bulkhead /
                              queue_full)
.. ``frontdoor.queue``        arrival -> batch launch wait
.. ``frontdoor.shed``         overload or deadline shed verdict
.. ``frontdoor.service``      batch launch -> completion; engine subtree
                              hangs below
.. ``frontdoor.batch``        root for a multi-request batch launch;
                              member request roots carry ``batch`` attrs
                              pointing at its ``span_id``
== ========================== ===========================================

Engine read path (tracer-clock timestamps):

== =============================== ======================================
.. ``engine.read``                 scalar fast-path read; attrs ``cf``,
                                   ``level``
.. ``engine.read_many``            attrs ``cf``, ``queries``, ``level``
.. ``engine.plan``                 cost-model routing + schedule pick
.. ``engine.scatter``              partition routing (partitioned CFs);
                                   per-partition ``engine.partition``
                                   children with attr ``partition``
.. ``engine.group_scan``           one (replica, node) group execution;
                                   attrs ``replica``, ``node``,
                                   ``queries``, ``hedged``, ``retry``
.. ``engine.flush_barrier``        read-barrier flush of staged writes
.. ``engine.cache_probe``          result-cache lookup; attrs ``hits``,
                                   ``misses``
.. ``engine.scan``                 memtable + sorted-run scan of the
                                   cache misses; attr ``rows``
.. ``view.serve``                  view-eligible aggregates answered
                                   from the materialized per-block
                                   partials (O(blocks touched), no full
                                   scan); attrs ``queries``,
                                   ``boundary_rows``,
                                   ``boundary_blocks``
.. ``engine.host_scan``            NumPy fallback when the column family
                                   is not device-resident
.. ``kernel.scan_launch``          fused device locate+scan launch wall
                                   (includes the host sync)
.. ``kernel.select_compact``       device select-index compaction launch
.. ``engine.digest``               digest-read consistency pass; attrs
                                   ``level``, ``replicas``
.. ``engine.read_repair``          one replica repair; attr ``replica``
.. ``engine.gather``               scatter results stitched back to
                                   request order
== =============================== ======================================

Engine write path:

== =========================== ==========================================
.. ``engine.write``            attrs ``cf``, ``rows``
.. ``engine.log_append``       commit-log appends (attr ``partitions``)
.. ``engine.memtable_stage``   per-replica memtable staging + hints
.. ``engine.flush``            one replica flush; attrs ``replica``,
                               ``rows``
.. ``engine.flush_merge``      sorted-run merge inside a flush
.. ``view.build``              per-block partial (re)build; attr
                               ``rows``, plus ``incremental=True`` when
                               a flush extended the existing partials
                               in O(run) instead of rebuilding
.. ``engine.compaction``       run-stack compaction triggered by a flush
== =========================== ==========================================

Harness roots:

== ==================== =================================================
.. ``chaos.probe``      one per chaos-harness QUORUM victim probe; attrs
                        ``tag`` (step label), ``probe`` (query index);
                        the byte-determinism fixture uses these with a
                        :class:`TickClock` tracer
== ==================== =================================================

Timestamps come from the tracer's clock: ``time.perf_counter`` by
default (honest walls for benchmarks), the FrontDoor's virtual clock
for ``frontdoor.*`` spans (passed explicitly via ``t=``), or
:class:`TickClock` — a deterministic integer counter — when byte-exact
trace equality across runs matters (chaos replay). Span ids are
sequential per tracer, so identity is also deterministic.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Iterator

__all__ = ["Span", "TickClock", "Tracer", "walk"]


class TickClock:
    """Deterministic clock: each read returns the next integer tick.

    Used by the chaos determinism tests — span timestamps become a
    pure function of the number of prior clock reads, so two runs of
    the same seeded schedule export byte-identical traces.
    """

    __slots__ = ("_t",)

    def __init__(self, start: int = 0):
        self._t = start

    def __call__(self) -> float:
        t = self._t
        self._t = t + 1
        return float(t)


class Span:
    """One timed stage. Children are created via :meth:`child`, never
    by mutating ``parent_id`` — the tree is built top-down and stays
    consistent by construction."""

    __slots__ = ("tracer", "name", "span_id", "parent_id", "t_start",
                 "t_end", "attrs", "children")

    def __init__(self, tracer: "Tracer", name: str, span_id: int,
                 parent_id: int | None, t_start: float,
                 attrs: dict[str, Any]):
        self.tracer = tracer
        self.name = name
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.t_end: float | None = None
        self.attrs = attrs
        self.children: list[Span] = []

    def child(self, name: str, *, t: float | None = None, **attrs: Any) -> "Span":
        """Open a child span (explicit parent: ``self``)."""
        s = self.tracer._make(name, self.span_id, t, attrs)
        self.children.append(s)
        return s

    def end(self, *, t: float | None = None, **attrs: Any) -> "Span":
        """Close the span; extra attrs merge in. Returns self."""
        self.t_end = self.tracer.now() if t is None else float(t)
        if attrs:
            self.attrs.update(attrs)
        return self

    def annotate(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def wall(self) -> float:
        """Duration in the span's own time base (0 while open)."""
        return 0.0 if self.t_end is None else self.t_end - self.t_start

    def to_dict(self) -> dict[str, Any]:
        """JSON-ready nested dict (deterministic: attrs sorted)."""
        return {
            "name": self.name,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "t_start": self.t_start,
            "t_end": self.t_end,
            "attrs": {k: self.attrs[k] for k in sorted(self.attrs)},
            "children": [c.to_dict() for c in self.children],
        }

    def find(self, name: str) -> "Span | None":
        """First span named ``name`` in pre-order, self included."""
        for s in walk(self):
            if s.name == name:
                return s
        return None

    def find_all(self, name: str) -> list["Span"]:
        return [s for s in walk(self) if s.name == name]

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        state = f"{self.wall:g}" if self.t_end is not None else "open"
        return f"Span({self.name}#{self.span_id} {state})"


def walk(span: Span) -> Iterator[Span]:
    """Pre-order iteration over a span tree."""
    stack = [span]
    while stack:
        s = stack.pop()
        yield s
        stack.extend(reversed(s.children))


class Tracer:
    """Span factory with a pluggable clock and sequential ids.

    ``clock`` is any zero-arg callable returning a float; the default
    is ``time.perf_counter``.  ``Tracer(clock=TickClock())`` gives
    fully deterministic traces.  The tracer keeps a list of root spans
    (``roots``) so a harness can export everything it produced.
    """

    def __init__(self, clock: Callable[[], float] | None = None):
        self.clock = clock if clock is not None else time.perf_counter
        self.roots: list[Span] = []
        self._next_id = 0
        self.spans_started = 0

    def now(self) -> float:
        return self.clock()

    def _make(self, name: str, parent_id: int | None,
              t: float | None, attrs: dict[str, Any]) -> Span:
        sid = self._next_id
        self._next_id = sid + 1
        self.spans_started += 1
        t0 = self.now() if t is None else float(t)
        return Span(self, name, sid, parent_id, t0, attrs)

    def root(self, name: str, *, t: float | None = None, **attrs: Any) -> Span:
        """Open a new root span (one per request / probe / batch)."""
        s = self._make(name, None, t, attrs)
        self.roots.append(s)
        return s

    def clear(self) -> None:
        """Drop accumulated roots (ids keep counting up)."""
        self.roots.clear()
