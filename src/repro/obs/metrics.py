"""Zero-dependency metrics registry: counters, gauges, histograms.

The registry is the single home for every operational counter in the
stack.  :class:`~repro.core.engine.HREngine` and
:class:`~repro.serving.frontdoor.FrontDoor` register their counters
here at construction and keep their legacy ``stats`` dict views as
read-through projections, so nothing upstream has to change while new
consumers (the chaos harnesses' accounting cross-checks, the bench
gate's overhead guard, ``python -m repro.obs``) get a uniform catalog.

Three metric kinds:

* :class:`Counter` — monotonically increasing float (``inc``).
* :class:`Gauge` — last-write-wins level (``set`` / ``max``).
* :class:`Histogram` — log-bucketed latency distribution with
  p50/p95/p99 readout.  Buckets are powers of two split into
  ``2**SUB_BITS`` sub-buckets via ``math.frexp`` — pure integer
  arithmetic on the exponent/mantissa, so bucketing is exact and
  deterministic on every platform and under the virtual clock (no
  float ``log`` whose last ulp could differ between libms).

Everything here is deliberately dependency-free and allocation-light:
``Counter.inc`` is one float add on a ``__slots__`` object, cheap
enough for the engine's per-query hot path.

Determinism contract: metric state is a pure function of the sequence
of ``inc``/``set``/``observe`` calls — no wall-clock reads, no
randomness — so two identical seeded runs produce identical
registries (the chaos byte-identity tests rely on this).
"""

from __future__ import annotations

import math
from typing import Iterator

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
]


class Counter:
    """Monotonic counter. ``value`` is a float (rows, seconds, events)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0.0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Counter({self.name}={self.value:g})"


class Gauge:
    """Last-write-wins level (queue depth high-water marks etc.)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def max(self, v: float) -> None:
        """Raise the gauge to ``v`` if above the current value."""
        if v > self.value:
            self.value = float(v)

    def reset(self) -> None:
        self.value = 0.0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"Gauge({self.name}={self.value:g})"


class Histogram:
    """Log-bucketed distribution with deterministic integer bucketing.

    A positive sample ``v = m * 2**e`` (``math.frexp``, ``m`` in
    [0.5, 1)) lands in bucket ``e * 2**SUB_BITS + floor((m - 0.5) *
    2**(SUB_BITS + 1))`` — each power-of-two octave is split into
    ``2**SUB_BITS`` equal-width sub-buckets, giving a worst-case
    relative quantile error of ``2**-SUB_BITS`` (~12% at the default
    ``SUB_BITS = 3``), plenty for p50/p95/p99 readout.  Non-positive
    samples are pooled in a dedicated zero bucket.  Quantiles report
    the *upper* bound of the bucket holding the target rank (clamped
    to the observed max) — conservative, never flattering.
    """

    SUB_BITS = 3
    _SUB = 1 << SUB_BITS

    __slots__ = ("name", "count", "total", "_counts", "_zero", "_min", "_max")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self._counts: dict[int, int] = {}
        self._zero = 0
        self._min = math.inf
        self._max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        if v < self._min:
            self._min = v
        if v > self._max:
            self._max = v
        if v <= 0.0:
            self._zero += 1
            return
        m, e = math.frexp(v)
        idx = (e << self.SUB_BITS) + int((m - 0.5) * (self._SUB << 1))
        self._counts[idx] = self._counts.get(idx, 0) + 1

    def quantile(self, q: float) -> float:
        """Upper bucket bound at rank ``ceil(q * count)`` (0 when empty)."""
        if self.count == 0:
            return 0.0
        target = q * self.count
        seen = self._zero
        if seen >= target and self._zero:
            return 0.0
        for idx in sorted(self._counts):
            seen += self._counts[idx]
            if seen >= target:
                e, j = idx >> self.SUB_BITS, idx & (self._SUB - 1)
                hi = math.ldexp(0.5 + (j + 1) / (self._SUB << 1), e)
                return min(hi, self._max)
        return self._max  # pragma: no cover - ranks always land above

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self) -> dict[str, float]:
        """Flat summary used by ``MetricsRegistry.as_dict`` and reports."""
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "p50": 0.0, "p95": 0.0,
                    "p99": 0.0, "max": 0.0}
        return {
            "count": self.count,
            "sum": self.total,
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
            "max": self._max,
        }

    def reset(self) -> None:
        self.count = 0
        self.total = 0.0
        self._counts.clear()
        self._zero = 0
        self._min = math.inf
        self._max = -math.inf

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        s = self.snapshot()
        return (f"Histogram({self.name} n={s['count']} p50={s['p50']:g} "
                f"p99={s['p99']:g})")


_Metric = Counter | Gauge | Histogram


class MetricsRegistry:
    """Get-or-create registry keyed by metric name.

    Names are dotted lowercase (``engine.read_repairs``,
    ``frontdoor.queue_wait_s``) but the registry itself imposes no
    scheme — the owners do.  Asking for an existing name with a
    different kind is a bug and raises ``TypeError`` rather than
    silently shadowing.
    """

    def __init__(self):
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, name: str, cls: type) -> _Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(name)
        elif type(m) is not cls:
            raise TypeError(
                f"metric {name!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}"
            )
        return m

    def counter(self, name: str) -> Counter:
        return self._get_or_create(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get_or_create(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get_or_create(name, Histogram)

    def get(self, name: str) -> _Metric | None:
        return self._metrics.get(name)

    def value(self, name: str) -> float:
        """Scalar value of a counter/gauge (KeyError if absent)."""
        m = self._metrics[name]
        if isinstance(m, Histogram):
            raise TypeError(f"{name!r} is a histogram; use get().snapshot()")
        return m.value

    def catalog(self) -> tuple[str, ...]:
        """Sorted names of every registered metric — the audit surface
        the counter-coverage tests walk."""
        return tuple(sorted(self._metrics))

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __iter__(self) -> Iterator[_Metric]:
        for name in sorted(self._metrics):
            yield self._metrics[name]

    def as_dict(self) -> dict[str, float]:
        """Flat snapshot: counters/gauges as ``{name: value}``,
        histograms exploded to ``{name.p50, name.p95, name.p99,
        name.count, name.sum, name.max}``."""
        out: dict[str, float] = {}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if isinstance(m, Histogram):
                for k, v in m.snapshot().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = m.value
        return out

    def reset(self) -> None:
        """Zero every metric in place (handles held by owners stay
        valid — this is the single ``reset_stats()`` primitive)."""
        for m in self._metrics.values():
            m.reset()
