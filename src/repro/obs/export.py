"""Slow-query log and trace exporters.

:class:`SlowQueryLog` keeps the K slowest span trees seen so far (a
bounded min-heap keyed on root latency — the "ring buffer" of
forensics targets).  Export is JSON-lines: one span tree per line,
``sort_keys=True`` and no whitespace variance, so identical traces
serialize to identical bytes (the chaos determinism property is
asserted on these bytes).  ``python -m repro.obs`` renders the
human-readable report.

The stage names rendered here are the public taxonomy documented in
:mod:`repro.obs.trace` — ``stage_totals`` aggregates by exactly those
names, which is what ``benchmarks/serving_latency.py`` publishes as
the per-stage breakdown.
"""

from __future__ import annotations

import heapq
import io
import json
from typing import Any, Iterable, TextIO

from .trace import Span

__all__ = [
    "SlowQueryLog",
    "dump_jsonl",
    "load_jsonl",
    "span_to_line",
    "stage_totals",
    "format_tree",
    "render_report",
]


class SlowQueryLog:
    """Bounded log of the K slowest span trees.

    ``offer(span, latency)`` keeps the tree iff it ranks among the K
    slowest so far; ``latency`` defaults to the root span's wall.
    Ties break on insertion order (earlier entry survives), keeping
    the contents deterministic for equal-latency streams.
    """

    def __init__(self, k: int = 32):
        if k <= 0:
            raise ValueError("k must be positive")
        self.k = k
        self._seq = 0
        # min-heap of (latency, seq, span): root is the *fastest* kept
        # entry, evicted first when a slower tree arrives
        self._heap: list[tuple[float, int, Span]] = []

    def __len__(self) -> int:
        return len(self._heap)

    def offer(self, span: Span, latency: float | None = None) -> bool:
        """Consider a finished span tree; True if kept."""
        lat = span.wall if latency is None else float(latency)
        item = (lat, self._seq, span)
        self._seq += 1
        if len(self._heap) < self.k:
            heapq.heappush(self._heap, item)
            return True
        # evict-or-reject against the fastest kept entry; strict > so
        # an equal-latency newcomer loses to the incumbent
        if lat > self._heap[0][0]:
            heapq.heapreplace(self._heap, item)
            return True
        return False

    def entries(self) -> list[tuple[float, Span]]:
        """(latency, tree) pairs, slowest first (stable order)."""
        return [(lat, span) for lat, _seq, span
                in sorted(self._heap, key=lambda it: (-it[0], it[1]))]

    def reset(self) -> None:
        self._heap.clear()
        self._seq = 0


def span_to_line(span: Span, latency: float | None = None) -> str:
    """One deterministic JSON line for a span tree."""
    doc: dict[str, Any] = span.to_dict()
    if latency is not None:
        doc = {"latency": latency, "tree": doc}
    return json.dumps(doc, sort_keys=True, separators=(",", ":"))


def dump_jsonl(entries: Iterable[tuple[float, Span] | Span],
               out: TextIO | str) -> int:
    """Write span trees (or ``(latency, tree)`` pairs) as JSON-lines.

    ``out`` is a path or an open text file; returns the line count.
    """
    if isinstance(out, str):
        with open(out, "w") as f:
            return dump_jsonl(entries, f)
    n = 0
    for e in entries:
        if isinstance(e, Span):
            out.write(span_to_line(e))
        else:
            lat, span = e
            out.write(span_to_line(span, lat))
        out.write("\n")
        n += 1
    return n


def load_jsonl(src: TextIO | str) -> list[dict[str, Any]]:
    """Parse a JSON-lines trace dump back into span-tree dicts.

    Raises ``ValueError`` naming the offending line on malformed input
    — the CI traced-smoke gate depends on this being loud.
    """
    if isinstance(src, str):
        with open(src) as f:
            return load_jsonl(f)
    out: list[dict[str, Any]] = []
    for lineno, line in enumerate(src, 1):
        line = line.strip()
        if not line:
            continue
        try:
            doc = json.loads(line)
        except json.JSONDecodeError as e:
            raise ValueError(f"malformed JSON-lines trace at line {lineno}: {e}")
        if not isinstance(doc, dict):
            raise ValueError(f"line {lineno}: expected an object, got "
                             f"{type(doc).__name__}")
        tree = doc.get("tree", doc)
        if "name" not in tree or "span_id" not in tree:
            raise ValueError(f"line {lineno}: not a span tree (missing "
                             "name/span_id)")
        out.append(doc)
    return out


def _walk_dict(tree: dict[str, Any]):
    stack = [tree]
    while stack:
        node = stack.pop()
        yield node
        stack.extend(reversed(node.get("children", ())))


def _node_wall(node: dict[str, Any]) -> float:
    t0, t1 = node.get("t_start"), node.get("t_end")
    if t0 is None or t1 is None:
        return 0.0
    return float(t1) - float(t0)


def stage_totals(trees: Iterable[dict[str, Any] | Span]) -> dict[str, dict[str, float]]:
    """Aggregate wall time by stage name across span trees.

    Returns ``{stage: {"count": n, "total": seconds}}`` sorted by
    descending total — the per-stage breakdown the serving benchmark
    publishes.  Accepts live ``Span`` roots or exported dicts.
    """
    agg: dict[str, list[float]] = {}
    for t in trees:
        if isinstance(t, Span):
            t = t.to_dict()
        t = t.get("tree", t)
        for node in _walk_dict(t):
            slot = agg.setdefault(node["name"], [0, 0.0])
            slot[0] += 1
            slot[1] += _node_wall(node)
    ordered = sorted(agg.items(), key=lambda kv: (-kv[1][1], kv[0]))
    return {name: {"count": c, "total": tot} for name, (c, tot) in ordered}


def format_tree(tree: dict[str, Any] | Span, *, unit: str = "s") -> str:
    """Indented one-tree rendering: name, wall, attrs."""
    if isinstance(tree, Span):
        tree = tree.to_dict()
    tree = tree.get("tree", tree)
    scale = {"s": 1.0, "ms": 1e3, "us": 1e6, "ticks": 1.0}[unit]
    lines: list[str] = []

    def rec(node: dict[str, Any], depth: int) -> None:
        wall = _node_wall(node) * scale
        attrs = node.get("attrs") or {}
        attr_s = " ".join(f"{k}={attrs[k]}" for k in sorted(attrs))
        lines.append(
            f"{'  ' * depth}{node['name']}  {wall:,.3f}{unit}"
            + (f"  [{attr_s}]" if attr_s else "")
        )
        for c in node.get("children", ()):
            rec(c, depth + 1)

    rec(tree, 0)
    return "\n".join(lines)


def render_report(docs: list[dict[str, Any]], *, top: int = 5,
                  unit: str = "ms") -> str:
    """Human-readable report over an exported trace dump: stage
    breakdown table plus the ``top`` slowest trees in full."""
    buf = io.StringIO()
    totals = stage_totals(docs)
    scale = {"s": 1.0, "ms": 1e3, "us": 1e6, "ticks": 1.0}[unit]
    buf.write(f"trace report: {len(docs)} span trees\n\n")
    buf.write("stage breakdown (total wall, descending):\n")
    width = max((len(n) for n in totals), default=10)
    for name, row in totals.items():
        buf.write(
            f"  {name:<{width}}  n={row['count']:>6}  "
            f"total={row['total'] * scale:>12,.3f}{unit}\n"
        )

    def latency(doc: dict[str, Any]) -> float:
        if "latency" in doc:
            return float(doc["latency"])
        return _node_wall(doc.get("tree", doc))

    slowest = sorted(docs, key=latency, reverse=True)[:top]
    if slowest:
        buf.write(f"\nslowest {len(slowest)} trees:\n")
        for i, doc in enumerate(slowest, 1):
            buf.write(f"\n#{i}  latency={latency(doc) * scale:,.3f}{unit}\n")
            buf.write(format_tree(doc, unit=unit))
            buf.write("\n")
    return buf.getvalue()
