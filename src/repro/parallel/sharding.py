"""Mesh context and logical-axis sharding rules.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod. Parallelism map:

  batch (activations)      → (pod, data)           [DP]
  d_model dim of params    → (pod, data)           [FSDP / ZeRO-3]
  heads / d_ff / experts   → model                 [TP / EP]
  vocab (output head)      → model
  embedding table d_model  → model (gather stays local; no FSDP needed)
  KV-cache sequence        → model                 [SP — decode LSE-combine]

``MeshCtx`` is threaded through the model code; ``None`` means
single-device (smoke tests) and all shard_map/collective paths degrade to
local compute.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["MeshCtx", "make_ctx", "logical_to_spec", "param_specs_for_tree"]


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: Mesh
    dp_axes: tuple[str, ...]  # ("pod","data") or ("data",)
    tp_axis: str  # "model"
    shard_batch: bool = True  # False for global_batch < dp_size (long_500k)
    serve_ep: bool = False  # serving: global expert-parallel MoE dispatch
    fsdp_all: bool = False  # train: pure FSDP over every mesh axis (no TP)
    fsdp_axes_override: tuple | None = None  # fsdp_all: narrower weight shard

    @property
    def axis_names(self) -> tuple[str, ...]:
        return tuple(self.mesh.axis_names)

    @property
    def dp_size(self) -> int:
        s = 1
        for a in self.dp_axes:
            s *= self.mesh.shape[a]
        return s

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))


def make_ctx(mesh: Mesh, *, shard_batch: bool = True) -> MeshCtx:
    names = tuple(mesh.axis_names)
    if "model" not in names:
        raise ValueError(f"mesh must have a 'model' axis, got {names}")
    dp = tuple(a for a in names if a != "model")
    return MeshCtx(mesh=mesh, dp_axes=dp, tp_axis="model", shard_batch=shard_batch)


#: logical axis name → mesh axes (None = replicated). The FSDP entry is
#: filled per-mesh because the pod axis may be absent.
_LOGICAL_RULES = {
    "batch": "__dp__",
    "fsdp": "__dp__",  # d_model dim of transformer params
    "model_dim": None,  # activations' d_model — replicated
    "seq": None,  # train/prefill activations sequence
    "kv_seq": "model",  # decode KV cache sequence (SP)
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "experts_serve": "__ep_serve__",  # (data, model) when it divides, else model
    "vocab": "model",
    "embed_tp": "model",  # embedding table d_model
    "expert_mlp": "__dp__",  # MoE expert d_ff — FSDP'd, gathered in-layer
    "layers": None,
    "layers_pp": "pod",  # pipeline stage axis
    "stats": None,
}


def logical_to_spec(ctx: MeshCtx | None, axes: Sequence[str | None]) -> P:
    """Map logical axis names to a PartitionSpec under ``ctx``."""
    if ctx is None:
        return P()
    out = []
    all_axes = tuple(ctx.mesh.axis_names)
    for ax in axes:
        if ax is None:
            out.append(None)
            continue
        if ax == "batch" and not ctx.shard_batch:
            out.append(None)
            continue
        if ctx.fsdp_all:
            # pure-FSDP placement: batch and the param d_model dim cover
            # the WHOLE mesh; every TP-ish axis is replicated. Converts
            # per-layer TP activation all-reduces into per-layer weight
            # all-gathers + grad reduce-scatters (§Perf hillclimb).
            if ax == "batch":
                out.append(all_axes)
                continue
            if ax == "fsdp":
                out.append(ctx.fsdp_axes_override or all_axes)
                continue
            if ax in ("heads", "kv_heads", "mlp", "vocab", "embed_tp",
                      "experts", "expert_mlp", "kv_seq"):
                out.append(None)
                continue
        rule = _LOGICAL_RULES.get(ax, None)
        if rule == "__dp__":
            out.append(ctx.dp_axes if len(ctx.dp_axes) > 1 else ctx.dp_axes[0])
        elif rule == "__ep_serve__":
            out.append(("data", ctx.tp_axis))
        else:
            out.append(rule)
    return P(*out)


def param_specs_for_tree(ctx: MeshCtx | None, logical_tree) -> object:
    """Map a pytree of logical-axis tuples to PartitionSpecs/shardings."""
    return jax.tree.map(
        lambda axes: logical_to_spec(ctx, axes),
        logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(a, (str, type(None))) for a in x),
    )
