"""TP-divisibility padding.

Published configs are kept verbatim in ``repro.configs``; when a sharded
dimension does not divide the mesh axis it is padded at *model-build*
time with inert slots (zero-init heads / masked experts / never-sampled
vocab rows). ``padding_report`` documents every delta for DESIGN.md.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

__all__ = ["PaddedDims", "padded_dims", "padding_report"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class PaddedDims:
    n_heads: int
    n_kv_heads: int
    vocab_size: int
    n_experts: int
    d_ff: int
    moe_d_ff: int

    def head_pad(self, cfg: ArchConfig) -> int:
        return self.n_heads - cfg.n_heads


def padded_dims(cfg: ArchConfig, tp: int) -> PaddedDims:
    """Padded sizes for a given tensor-parallel degree.

    - q heads → multiple of tp (zero-initialized pad heads; their output
      contribution is exactly zero through the out-projection).
    - kv heads → if >= tp, round up to multiple of tp; else keep (the
      small KV projections are replicated).
    - vocab → multiple of 128·? we use lcm(tp, 128) so the padded rows
      also satisfy MXU lane alignment; pad logits are masked to -inf in
      the loss.
    - experts → multiple of tp (pad experts get -inf router logits).
    - d_ff → multiple of tp (all assigned configs already divide; guard).
    """
    heads = _round_up(cfg.n_heads, tp) if cfg.uses_attention else cfg.n_heads
    kv = cfg.n_kv_heads
    if cfg.uses_attention and cfg.attention == "gqa":
        if kv >= tp:
            kv = _round_up(kv, tp)
        # else replicated — but the GQA group structure must stay integral:
        # ensure padded q heads divide by kv
        if heads % max(kv, 1):
            heads = _round_up(heads, max(kv, 1) * tp // _gcd(tp, max(kv, 1)))
    vocab_mult = 128 * tp // _gcd(128, tp)
    vocab = _round_up(cfg.vocab_size, vocab_mult)
    experts = _round_up(cfg.n_experts, tp) if cfg.is_moe else 0
    d_ff = _round_up(cfg.d_ff, tp) if cfg.d_ff else 0
    moe_ff = cfg.moe_d_ff  # sharded on the FSDP axis in-layer, not on tp
    return PaddedDims(
        n_heads=heads,
        n_kv_heads=kv,
        vocab_size=vocab,
        n_experts=experts,
        d_ff=d_ff,
        moe_d_ff=moe_ff,
    )


def _gcd(a: int, b: int) -> int:
    while b:
        a, b = b, a % b
    return a


def padding_report(cfg: ArchConfig, tp: int) -> dict[str, tuple[int, int]]:
    p = padded_dims(cfg, tp)
    rep = {}
    if p.n_heads != cfg.n_heads:
        rep["n_heads"] = (cfg.n_heads, p.n_heads)
    if p.n_kv_heads != cfg.n_kv_heads:
        rep["n_kv_heads"] = (cfg.n_kv_heads, p.n_kv_heads)
    if p.vocab_size != cfg.vocab_size:
        rep["vocab_size"] = (cfg.vocab_size, p.vocab_size)
    if cfg.is_moe and p.n_experts != cfg.n_experts:
        rep["n_experts"] = (cfg.n_experts, p.n_experts)
    if cfg.d_ff and p.d_ff != cfg.d_ff:
        rep["d_ff"] = (cfg.d_ff, p.d_ff)
    return rep
