"""Pipeline parallelism over the ``pod`` mesh axis (GPipe schedule).

The stacked layer axis of a homogeneous stack is sharded over ``pod``
(each pod owns a contiguous run of layers); activations flow pod→pod
with `lax.ppermute` on a microbatch schedule. The shard_map is *manual
only over pod* (`axis_names={"pod"}`) — data/model sharding inside the
body stays under the automatic partitioner, so TP/FSDP compose with PP.

The forward is fully differentiable: JAX transposes the ppermutes, so
the backward runs the reverse pipeline automatically (GPipe with
activation stashing; combine with remat for the usual memory trade).

v1 scope: train-time, uniform-window stacks without MoE (the MoE layer
carries its own full-mesh shard_map, which cannot nest inside a manual
pod axis). Covers the dense/SSM/audio/VLM archs; DeepSeek/Qwen keep
ZeRO-3+EP on the pod axis instead. Inter-pod traffic per step is exactly
one [microbatch, S, D] activation per pipeline tick — the DCI-friendly
pattern pods want (vs. FSDP's per-layer weight gathers crossing pods).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .sharding import MeshCtx

__all__ = ["pipeline_available", "pipeline_stack_forward"]


def pipeline_available(ctx: MeshCtx | None, kind: str, n_layers: int) -> bool:
    if ctx is None or "pod" not in ctx.mesh.axis_names:
        return False
    if kind in ("moe",):  # nested full-mesh shard_map — see module docstring
        return False
    return n_layers % ctx.mesh.shape["pod"] == 0


def pipeline_stack_forward(
    stack_params,
    body_fn,  # body_fn(p_layer, x, positions) -> x   (aux-free fast path)
    x: jax.Array,  # [B, S, D] — batch sharded over data, replicated over pod
    positions: jax.Array,  # [B, S]
    ctx: MeshCtx,
    *,
    n_micro: int = 4,
) -> jax.Array:
    """Run a layer stack as a GPipe pipeline over the pod axis."""
    mesh = ctx.mesh
    n_pods = mesh.shape["pod"]
    B, S, D = x.shape
    assert B % n_micro == 0, f"batch {B} not divisible by {n_micro} microbatches"
    mb = B // n_micro
    T = n_micro + n_pods - 1  # pipeline ticks incl. fill/drain bubble

    # XLA:CPU hard-crashes ("Invalid binary instruction opcode copy") on
    # bf16 select/ppermute/psum inside a manual-pod shard_map. All
    # schedule plumbing (carry, permutes, masks, psum) therefore runs in
    # f32; layer COMPUTE stays in the model dtype. CPU-only overhead —
    # on TPU the plumbing dtype can be the model dtype.
    plumb = jnp.float32

    def shard_fn(params_local, x_full, pos_full):
        p_idx = jax.lax.axis_index("pod")
        xm = x_full.astype(plumb).reshape(n_micro, mb, S, D)
        pos_mb = pos_full[:mb]

        def run_local_layers(x_in):
            def layer(x, p_l):
                return body_fn(p_l, x, pos_mb), None

            y, _ = jax.lax.scan(layer, x_in.astype(x_full.dtype), params_local)
            return y.astype(plumb)

        def tick(buf, t):
            # pod 0 ingests microbatch t (clamped in the drain phase —
            # those results never reach the collection window)
            x_t = jax.lax.dynamic_index_in_dim(
                xm, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            first = (p_idx == 0).astype(plumb)
            x_in = first * x_t + (1.0 - first) * buf
            y = run_local_layers(x_in)
            nxt = jax.lax.ppermute(
                y, "pod", [(i, (i + 1) % n_pods) for i in range(n_pods)]
            )
            return nxt, y

        buf0 = jnp.zeros((mb, S, D), plumb)
        _, ys = jax.lax.scan(tick, buf0, jnp.arange(T))
        # the LAST pod's outputs at ticks [n_pods-1, T) are microbatches 0..n_micro-1
        out = ys[n_pods - 1 :]  # [n_micro, mb, S, D]
        mask = (p_idx == n_pods - 1).astype(plumb)
        out = jax.lax.psum(out * mask, "pod")
        return out.astype(x_full.dtype).reshape(B, S, D)

    # stacked layer axis over pod; everything else stays auto-partitioned
    n_leaf_spec = jax.tree.map(lambda _: P("pod"), stack_params)
    return jax.shard_map(
        shard_fn,
        mesh=mesh,
        in_specs=(n_leaf_spec, P(), P()),
        out_specs=P(),
        axis_names={"pod"},
        check_vma=False,
    )(stack_params, x, positions)
