"""Optimizers (pure JAX): AdamW with memory-tiered second-moment storage.

Variants (``kind``):
  adamw       — fp32 m, v (baseline)
  adamw_bf16  — m, v stored bf16 (halves optimizer HBM; update math fp32)
  adafactor   — factored second moment for ndim≥2 params (row/col running
                means à la Adafactor) + fp32 m; at 671B this shrinks v
                from ~2.7 TB to a few GB — the distributed-optimization
                memory trick used for the deepseek dry-run fit.

The optimizer state mirrors the param tree so the same logical-axis
sharding rules apply leaf-wise (factored leaves drop the reduced axis).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt", "opt_update", "opt_state_axes"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"  # adamw | adamw_bf16 | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def _factored(leaf: jax.Array) -> bool:
    return leaf.ndim >= 2 and leaf.shape[-1] >= 8 and leaf.shape[-2] >= 8


def init_opt(params, cfg: OptConfig):
    def leaf_state(p):
        if cfg.kind == "adafactor" and _factored(p):
            return {
                "m": jnp.zeros_like(p, jnp.float32),
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # reduce last
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),  # reduce -2
            }
        dt = jnp.bfloat16 if cfg.kind == "adamw_bf16" else jnp.float32
        return {"m": jnp.zeros_like(p, dt), "v": jnp.zeros_like(p, dt)}

    return {
        "step": jnp.zeros((), jnp.int32),
        "state": jax.tree.map(leaf_state, params),
    }


def opt_state_axes(param_axes, cfg: OptConfig, params_shape) -> Any:
    """Logical axes for the optimizer state, derived from param axes.

    ``params_shape`` — pytree of jax.ShapeDtypeStruct (to detect the
    factored leaves the same way init_opt does).
    """

    def leaf_axes(axes, p):
        axes = tuple(axes)
        if cfg.kind == "adafactor" and _factored(p):
            return {"m": axes, "vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
        return {"m": axes, "v": axes}

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )
    return {
        "step": (),
        "state": jax.tree.map(leaf_axes, param_axes, params_shape, is_leaf=is_axes_leaf),
    }


def _schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def opt_update(grads, opt_state, params, cfg: OptConfig):
    """One step: clip → Adam(-factor) → weight decay → cosine-LR apply.
    Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = _schedule(cfg, step)
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) if cfg.grad_clip else 1.0

    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def leaf_update(g, s, p):
        g = g.astype(jnp.float32) * scale
        m = s["m"].astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        if "vr" in s:  # factored second moment
            g2 = jnp.square(g) + 1e-30
            vr = cfg.b2 * s["vr"] + (1 - cfg.b2) * jnp.mean(g2, axis=-1)
            vc = cfg.b2 * s["vc"] + (1 - cfg.b2) * jnp.mean(g2, axis=-2)
            # rank-1 reconstruction of v (Adafactor): vr ⊗ vc / mean(vr)
            denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), 1e-30)
            v_hat = (vr[..., None] * vc[..., None, :]) / denom[..., None]
            upd = (m / bc1) / (jnp.sqrt(v_hat / bc2) + cfg.eps)
            new_s = {"m": m.astype(s["m"].dtype), "vr": vr, "vc": vc}
        else:
            v = s["v"].astype(jnp.float32)
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            new_s = {"m": m.astype(s["m"].dtype), "v": v.astype(s["v"].dtype)}
        if cfg.weight_decay and p.ndim >= 2:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return new_p, new_s

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(opt_state["state"])
    out = [leaf_update(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = treedef.unflatten([o[1] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_params, {"step": step, "state": new_state}, stats
