"""jit-compiled train / eval steps with explicit shardings.

``make_train_step`` builds the pjit'd update for a (cfg, mesh) pair:
  * params FSDP+TP sharded per `lm_axes` (ZeRO-3: XLA all-gathers
    per-layer inside the scan, reduce-scatters grads),
  * gradient accumulation over microbatches via `lax.scan`,
  * remat policy on the layer body (none | dots | full),
  * AdamW / Adafactor update with cosine schedule.

The returned callable is `jax.jit`-wrapped with in/out shardings and is
what `launch/dryrun.py` lowers for the dry-run matrix.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ArchConfig
from repro.parallel.sharding import MeshCtx, logical_to_spec, param_specs_for_tree
from .optimizer import OptConfig, init_opt, opt_state_axes, opt_update

__all__ = ["TrainSettings", "batch_specs", "make_train_step", "train_state_shapes"]


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    microbatches: int = 1
    remat: str = "dots"  # none | dots | full
    q_chunk: int = 512
    kv_chunk: int = 1024
    param_mode: str = "fsdp"  # fsdp (ZeRO-3 + TP) | fsdp_all (no TP)
    pipeline_micro: int = 0  # >0: GPipe over the pod axis
    opt: OptConfig = dataclasses.field(default_factory=OptConfig)


def batch_specs(cfg: ArchConfig, ctx: MeshCtx | None):
    """PartitionSpecs for a training batch dict."""
    bspec = logical_to_spec(ctx, ("batch", None))
    out = {"labels": bspec if cfg.n_codebooks == 1 else logical_to_spec(ctx, ("batch", None, None))}
    if cfg.input_mode == "tokens":
        out["tokens"] = bspec
    else:
        out["embeds"] = logical_to_spec(ctx, ("batch", None, None))
    return out


def train_state_shapes(cfg: ArchConfig, settings: TrainSettings, tp: int):
    """abstract (params, opt_state) via eval_shape — no allocation."""
    p_shape = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg, tp))
    o_shape = jax.eval_shape(lambda: init_opt(p_shape, settings.opt))
    return p_shape, o_shape


def make_train_step(cfg: ArchConfig, ctx: MeshCtx | None, settings: TrainSettings):
    """Returns (train_step, in_shardings, out_shardings).

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    """
    if settings.pipeline_micro > 0 and ctx is not None and "pod" in ctx.mesh.axis_names:
        # the pod axis becomes the pipeline-stage axis: batch and FSDP
        # sharding retreat to the data axis
        ctx = dataclasses.replace(ctx, dp_axes=tuple(a for a in ctx.dp_axes if a != "pod"))
    if settings.param_mode == "fsdp_all" and ctx is not None:
        if cfg.is_moe:
            raise ValueError("fsdp_all is for dense/ssm archs (MoE needs EP)")
        # weights shard over the whole mesh when d_model divides it,
        # else over the data axis only (weights then replicate across
        # model — plain DP there; batch still covers the full mesh)
        override = None
        if cfg.d_model % ctx.mesh.size != 0:
            override = tuple(a for a in ctx.mesh.axis_names if a != "model")
        ctx = dataclasses.replace(ctx, fsdp_all=True, fsdp_axes_override=override)

    def loss_fn(params, mb):
        loss, metrics = lm.forward_train(
            params, mb, cfg, ctx,
            remat=settings.remat, q_chunk=settings.q_chunk, kv_chunk=settings.kv_chunk,
            pipeline_micro=settings.pipeline_micro,
        )
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        n_mb = settings.microbatches
        if n_mb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def split(x):
                return x.reshape((n_mb, x.shape[0] // n_mb) + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def acc(carry, mb):
                g_acc, loss_acc = carry
                (loss, metrics), g = grad_fn(params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            (g_sum, loss_sum), metrics = jax.lax.scan(acc, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / n_mb, g_sum)
            loss = loss_sum / n_mb
            metrics = jax.tree.map(lambda m: m[-1], metrics)

        new_params, new_opt, stats = opt_update(grads, opt_state, params, settings.opt)
        metrics = dict(metrics, loss=loss, **stats)
        return new_params, new_opt, metrics

    # shardings
    tp = ctx.tp_size if ctx else 1
    p_axes = lm.lm_axes(cfg, tp)
    if settings.pipeline_micro > 0 and ctx is not None and "pod" in ctx.mesh.axis_names:
        from repro.parallel.pipeline import pipeline_available

        def _pp_axes(stack_axes, kind, n_layers):
            if not pipeline_available(ctx, kind, n_layers):
                return stack_axes
            return jax.tree.map(
                lambda axes: ("layers_pp",) + tuple(axes)[1:],
                stack_axes,
                is_leaf=lambda x: isinstance(x, tuple)
                and all(isinstance(a, (str, type(None))) for a in x),
            )

        for spec in lm.stacks_for(cfg):
            key = f"stack_{spec.name}"
            p_axes[key] = _pp_axes(p_axes[key], spec.kind, spec.n_layers)
    p_shapes, _ = train_state_shapes(cfg, settings, tp)
    o_axes = opt_state_axes(p_axes, settings.opt, p_shapes)
    p_spec = param_specs_for_tree(ctx, p_axes)
    o_spec = param_specs_for_tree(ctx, o_axes)
    b_spec = batch_specs(cfg, ctx)
    from jax.sharding import PartitionSpec as P

    m_spec = None  # metrics replicated

    if ctx is None:
        return train_step, None, None

    to_sh = lambda tree: jax.tree.map(
        lambda s: ctx.sharding(*s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    in_sh = (to_sh(p_spec), to_sh(o_spec), to_sh(b_spec))
    out_sh = (to_sh(p_spec), to_sh(o_spec), None)
    step = jax.jit(
        train_step,
        in_shardings=in_sh,
        out_shardings=out_sh,
        donate_argnums=(0, 1),
    )
    return step, in_sh, out_sh
