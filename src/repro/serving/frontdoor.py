"""Overload-safe serving front door: continuous batching with admission
control, deadlines, and graceful degradation.

Everything below :meth:`HREngine.read_many` is batched and
fault-tolerant, but callers hand it pre-built query lists. This module
is the missing serving layer: single queries arrive with per-request
deadlines and priorities, the front door coalesces them into dynamic
``read_many`` batches (continuous batching — a batch launches when
``max_batch`` fills or ``max_wait`` expires, and requests arriving
while a batch is in flight join the *next* batch), and a stack of
overload guards keeps the engine answerable when offered load exceeds
capacity.

The degradation ladder
======================

Pressure is measured in queue-wait units of ``max_wait`` (the knob the
operator already reasons in). Each rung engages at a higher threshold
and disengages automatically when the queue drains — recovery needs no
operator action:

1. **Hedge** (``queue-wait EWMA > hedge_wait_factor × max_wait``,
   default 1.5×): batches launch with hedged reads so one straggler
   node stops stretching every batch. Observed queue latency — the
   :class:`~repro.ft.detector.LatencyEWMA` feed — drives this, not a
   static per-call ``hedge_ratio``.
2. **Degrade** (``oldest queued wait > degrade_wait_factor ×
   max_wait``, default 4×): QUORUM/ALL requests in the batch are
   served at ONE, each counted in ``stats["consistency_degraded"]``
   and flagged ``degraded=True`` on its response. Latency is bought
   with consistency, openly (Zhu et al.; McKenzie et al. — the
   consistency level as a latency dial).
3. **Shed** (``queue depth > shed_fill × max_queue``, default 0.9×):
   the lowest-priority, youngest requests are dropped with an explicit
   ``shed`` response until the backlog is back at the threshold.
   Priority decides who pays for overload; nobody waits unboundedly.
4. **Deadline** (always on): a request whose budget is already spent
   at launch is shed before wasting engine work; the remaining batch
   budget is threaded into the engine (``deadline_s``), where required
   work raises :class:`~repro.core.DeadlineExceeded` and optional work
   (hedges) is skipped; a request whose answer lands after its budget
   gets a ``deadline`` response, not a silently slow answer.

Ahead of the ladder sit the admission guards
(:mod:`repro.serving.admission`): a token bucket (rate + burst) and
per-``(column family, pinned partition)`` bulkheads, both rejecting
with :class:`~repro.serving.admission.RetryAfter` instead of queuing
without bound; a full queue likewise rejects at admission. Every
decision on every rung increments a ``frontdoor.stats`` counter.

Observability
=============

The decision counters live on a
:class:`~repro.obs.metrics.MetricsRegistry` (``stats`` is a
read-through dict view; ``reset_stats()`` is one registry reset), and
the registry additionally carries virtual-time latency histograms
(``queue_wait_seconds``, ``request_latency_seconds``,
``batch_service_seconds``). :data:`REFUSAL_COUNTERS` and
:data:`RUNG_COUNTERS` are the audit inventories: every typed refusal
kind and every ladder rung maps onto a registry counter name, and a
test walks those inventories against the catalog.

Pass ``tracer=`` (a :class:`~repro.obs.trace.Tracer`) to record one
``frontdoor.request`` span tree per request: admission → queue →
shed/service children at *virtual* timestamps, with the engine subtree
(``engine.read_many`` down to ``kernel.scan_launch``) hanging under
the ``frontdoor.service`` span when the launched group has one member,
or under a shared ``frontdoor.batch`` root (cross-linked by a
``batch`` attribute) when several requests coalesce. Completed trees
feed a :class:`~repro.obs.export.SlowQueryLog` keeping the K slowest
by virtual latency. Frontdoor span timestamps are virtual-clock
quantities; engine/kernel spans below them use the tracer's own clock,
so within one tree the frontdoor stage walls (queue + service) sum to
the client-observed ``latency_s`` while engine spans carry honest
measured walls.

Determinism
===========

The front door runs a single-threaded discrete-event loop over a
*virtual* clock: requests carry arrival timestamps, queue waits and
latency percentiles are virtual-time quantities, and a ``timeline`` of
``(virtual_time, callback)`` events injects faults mid-run (the chaos
harness drives node slowdowns this way). Engine calls are real — a
batch's virtual service time is the larger of its measured wall and
the engine-reported per-query walls, so an injected straggler slows
the virtual drain exactly as it inflates reported walls. Given a
fixed arrival stream and fixed service times every scheduling,
admission, degradation, and shedding decision is reproducible — but
service times are *measured*, so counters shift with machine speed
between runs; what is invariant is the acceptance contract (every
request answers correctly or is explicitly refused), not the exact
split between refusal kinds.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

from repro.core import (
    CONSISTENCY_LEVELS,
    DeadlineExceeded,
    HREngine,
    ONE,
    Query,
    ReadReport,
    ScanResult,
    slab_bounds_many,
)
from repro.ft.detector import LatencyEWMA
from repro.obs import MetricsRegistry, SlowQueryLog, Span, Tracer
from repro.serving.admission import Bulkhead, RetryAfter, TokenBucket

__all__ = [
    "FrontDoor",
    "Request",
    "Response",
    "FRONTDOOR_COUNTERS",
    "REFUSAL_COUNTERS",
    "RUNG_COUNTERS",
]

#: response statuses — every request ends in exactly one of these
OK = "ok"
REJECTED = "rejected"  # refused at admission (RetryAfter)
SHED = "shed"  # dropped under overload (priority shed)
DEADLINE = "deadline"  # budget spent (DeadlineExceeded)

#: every decision counter the front door maintains, in ``stats`` order
#: (``max_queue_depth`` is a high-water :class:`~repro.obs.metrics.Gauge`,
#: the rest are counters)
FRONTDOOR_COUNTERS = (
    "submitted",
    "admitted",
    "served_ok",
    "rejected_throttle",
    "rejected_bulkhead",
    "rejected_queue_full",
    "shed_overload",
    "shed_deadline",
    "consistency_degraded",
    "degraded_batches",
    "degrade_recoveries",
    "hedged_batches",
    "batches",
)

#: typed-refusal audit inventory: every :class:`RetryAfter` ``kind``
#: (plus the front door's own queue-bound refusal) and the two
#: non-admission refusal paths map onto a registry counter — the
#: coverage test walks this against the registry catalog
REFUSAL_COUNTERS = {
    "rate": "rejected_throttle",  # TokenBucket RetryAfter
    "bulkhead": "rejected_bulkhead",  # Bulkhead RetryAfter
    "queue": "rejected_queue_full",  # queue-bound RetryAfter
    "shed": "shed_overload",  # priority shed (rung 3)
    "deadline": "shed_deadline",  # DeadlineExceeded (rung 4)
}

#: degradation-ladder audit inventory: every rung transition that can
#: fire increments one of these
RUNG_COUNTERS = {
    "hedge": "hedged_batches",  # rung 1 engaged for a batch
    "degrade": "degraded_batches",  # rung 2 engaged for a batch
    "recover": "degrade_recoveries",  # rung 2 disengaged
    "consistency": "consistency_degraded",  # per-request rung-2 effect
    "shed": "shed_overload",  # rung 3 victims
    "deadline": "shed_deadline",  # rung 4 refusals
}


@dataclasses.dataclass(frozen=True)
class Request:
    """One client query: what to read, when it arrived, how long it may
    take (``deadline_s`` is a budget relative to arrival; None =
    unbounded), and how important it is (higher ``priority`` sheds
    last)."""

    cf_name: str
    query: Query
    arrival_s: float = 0.0
    deadline_s: float | None = None
    priority: int = 0
    consistency: str = ONE

    def __post_init__(self) -> None:
        if self.consistency not in CONSISTENCY_LEVELS:
            raise ValueError(
                f"unknown consistency {self.consistency!r} "
                f"(expected one of {CONSISTENCY_LEVELS})"
            )
        if self.arrival_s < 0.0:
            raise ValueError(f"arrival_s must be >= 0, got {self.arrival_s}")


@dataclasses.dataclass(frozen=True)
class Response:
    """The front door's answer: a result or an *explicit* refusal.
    ``status`` is one of ``ok`` / ``rejected`` / ``shed`` /
    ``deadline``; there is no silent path."""

    status: str
    result: ScanResult | None = None
    report: ReadReport | None = None
    error: str | None = None
    retry_after_s: float | None = None  # set on ``rejected``
    latency_s: float = 0.0  # virtual completion - arrival
    queue_wait_s: float = 0.0  # virtual launch - arrival
    consistency_used: str | None = None
    degraded: bool = False  # served below the requested consistency

    @property
    def ok(self) -> bool:
        return self.status == OK


@dataclasses.dataclass(eq=False)  # identity semantics: queue.remove()
class _Queued:
    """A request holding a queue slot (and its bulkhead admission)."""

    idx: int
    req: Request
    compartment: tuple[str, int] | None
    span: Span | None = None  # frontdoor.request root (tracing on)
    queue_span: Span | None = None  # open frontdoor.queue child


class FrontDoor:
    """Continuous-batching, overload-safe serving layer over one
    :class:`~repro.core.HREngine` (see module docstring for the
    degradation ladder, observability, and determinism model).

    Parameters
    ----------
    max_batch, max_wait:
        Continuous-batching knobs: a batch launches as soon as
        ``max_batch`` requests wait, or when the oldest has waited
        ``max_wait`` seconds — whichever comes first.
    max_queue:
        Hard queue bound; arrivals beyond it are rejected with
        :class:`RetryAfter` (backpressure, not buffering).
    rate, burst:
        Token-bucket admission (requests/second + burst capacity);
        ``rate=None`` disables throttling.
    bulkhead_inflight:
        Outstanding-request bound per ``(cf_name, partition)``
        compartment; ``None`` disables bulkheads.
    hedge_wait_factor, degrade_wait_factor, shed_fill:
        The ladder thresholds, in units of ``max_wait`` (rungs 1–3
        above).
    metrics:
        Registry for the decision counters and latency histograms; a
        private one is created when omitted.
    tracer, slow_log, slow_log_k:
        Optional request tracing: with ``tracer`` set, every request
        grows a ``frontdoor.request`` span tree and completed trees
        are offered to ``slow_log`` (a fresh
        :class:`~repro.obs.export.SlowQueryLog` of capacity
        ``slow_log_k`` when not supplied).
    """

    def __init__(
        self,
        engine: HREngine,
        *,
        max_batch: int = 64,
        max_wait: float = 2e-3,
        max_queue: int = 256,
        rate: float | None = None,
        burst: float = 32.0,
        bulkhead_inflight: int | None = None,
        hedge_wait_factor: float = 1.5,
        degrade_wait_factor: float = 4.0,
        shed_fill: float = 0.9,
        ewma_alpha: float = 0.2,
        ewma_warmup: int = 8,
        metrics: MetricsRegistry | None = None,
        tracer: Tracer | None = None,
        slow_log: SlowQueryLog | None = None,
        slow_log_k: int = 16,
    ) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait <= 0.0:
            raise ValueError(f"max_wait must be > 0, got {max_wait}")
        if max_queue < max_batch:
            raise ValueError(
                f"max_queue ({max_queue}) must be >= max_batch ({max_batch})"
            )
        if not 0.0 < shed_fill <= 1.0:
            raise ValueError(f"shed_fill must be in (0, 1], got {shed_fill}")
        if hedge_wait_factor <= 0.0 or degrade_wait_factor <= 0.0:
            raise ValueError("ladder factors must be > 0")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.max_queue = int(max_queue)
        self.bucket = TokenBucket(rate, burst) if rate is not None else None
        self.bulkhead = (
            Bulkhead(bulkhead_inflight, retry_after_s=max_wait)
            if bulkhead_inflight is not None
            else None
        )
        self.hedge_after = float(hedge_wait_factor) * self.max_wait
        self.degrade_after = float(degrade_wait_factor) * self.max_wait
        self.shed_trigger = max(1, int(float(shed_fill) * self.max_queue))
        self.queue_wait = LatencyEWMA(alpha=ewma_alpha)
        self.ewma_warmup = int(ewma_warmup)
        self._degraded = False  # current ladder state (for recovery count)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._ctr = {n: self.metrics.counter(n) for n in FRONTDOOR_COUNTERS}
        self._depth_gauge = self.metrics.gauge("max_queue_depth")
        self._h_queue_wait = self.metrics.histogram("queue_wait_seconds")
        self._h_latency = self.metrics.histogram("request_latency_seconds")
        self._h_service = self.metrics.histogram("batch_service_seconds")
        self.tracer = tracer
        if slow_log is not None:
            self.slow_log = slow_log
        else:
            self.slow_log = SlowQueryLog(slow_log_k) if tracer is not None else None

    @property
    def stats(self) -> dict[str, float]:
        """Read-through dict view of the decision counters (every
        ladder rung and every admission refusal increments one of
        these; ``max_queue_depth`` is the queue-depth high-water
        mark)."""
        d: dict[str, float] = {n: int(c.value) for n, c in self._ctr.items()}
        d["max_queue_depth"] = int(self._depth_gauge.value)
        return d

    def reset_stats(self) -> None:
        """Zero every counter, gauge, and histogram in one registry
        reset (handles stay live)."""
        self.metrics.reset()

    # -- tracing helpers ---------------------------------------------------

    def _finish(self, entry: _Queued, t: float, latency: float, **attrs: Any) -> None:
        """End a request's root span at virtual time ``t`` and offer
        the completed tree to the slow-query log."""
        if entry.span is None:
            return
        entry.span.end(t=t, **attrs)
        if self.slow_log is not None:
            self.slow_log.offer(entry.span, latency=latency)

    # -- admission ---------------------------------------------------------

    def _compartment(self, req: Request) -> tuple[str, int]:
        """Bulkhead compartment key: the request's column family plus
        the partition its slab pins (-1 when it fans out over several —
        fan-out queries share one per-CF compartment)."""
        cf = self.engine.column_families[req.cf_name]
        if cf.ring.n_partitions <= 1:
            return (req.cf_name, 0)
        bounds = slab_bounds_many([req.query], cf.key_names, cf.schema)
        p_lo, p_hi = cf.ring.span_partitions(bounds)
        pid = int(p_lo[0]) if int(p_lo[0]) == int(p_hi[0]) else -1
        return (req.cf_name, pid)

    def _admit(
        self, idx: int, req: Request, queue: list[_Queued], responses: list
    ) -> None:
        """Admission at virtual arrival time: queue bound, token
        bucket, bulkhead — first refusal wins and becomes an explicit
        ``rejected`` response."""
        self._ctr["submitted"].inc()
        root: Span | None = None
        adm: Span | None = None
        if self.tracer is not None:
            root = self.tracer.root(
                "frontdoor.request",
                t=req.arrival_s,
                idx=idx,
                level=req.consistency,
            )
            adm = root.child("frontdoor.admission", t=req.arrival_s)

        def _reject(kind: str, error: str, retry_after_s: float) -> None:
            self._ctr[REFUSAL_COUNTERS[kind]].inc()
            if root is not None:
                adm.end(t=req.arrival_s, outcome=f"rejected_{kind}")
                root.end(t=req.arrival_s, error="RetryAfter", status=REJECTED)
                if self.slow_log is not None:
                    self.slow_log.offer(root, latency=0.0)
            responses[idx] = Response(
                status=REJECTED,
                error=error,
                retry_after_s=retry_after_s,
            )

        if len(queue) >= self.max_queue:
            e = RetryAfter(self.max_wait, "queue full", kind="queue")
            _reject(e.kind, f"RetryAfter: {e.reason}", e.retry_after_s)
            return
        if self.bucket is not None:
            try:
                self.bucket.admit(req.arrival_s)
            except RetryAfter as e:
                _reject(e.kind, f"RetryAfter: {e.reason}", e.retry_after_s)
                return
        comp = None
        if self.bulkhead is not None:
            # the slab walk is only worth paying when a bulkhead will
            # actually compartment by it
            comp = self._compartment(req)
            try:
                self.bulkhead.acquire(comp)
            except RetryAfter as e:
                _reject(e.kind, f"RetryAfter: {e.reason}", e.retry_after_s)
                return
        self._ctr["admitted"].inc()
        entry = _Queued(idx, req, comp)
        if root is not None:
            adm.end(t=req.arrival_s, outcome="admitted")
            entry.span = root
            entry.queue_span = root.child("frontdoor.queue", t=req.arrival_s)
        queue.append(entry)
        self._depth_gauge.max(len(queue))

    def _release(self, entry: _Queued) -> None:
        if self.bulkhead is not None and entry.compartment is not None:
            self.bulkhead.release(entry.compartment)

    def _refuse_queued(self, entry: _Queued, now: float, reason: str) -> float:
        """Shared shed/deadline bookkeeping for a queued entry: release
        the bulkhead slot, close its spans at virtual ``now``, and
        return the virtual wait (== latency for a queue refusal)."""
        self._release(entry)
        wait = now - entry.req.arrival_s
        if entry.queue_span is not None:
            entry.queue_span.end(t=now, outcome=reason)
            entry.queue_span = None
            entry.span.child("frontdoor.shed", t=now, reason=reason).end(t=now)
        self._finish(
            entry, now, wait, status=SHED if reason == "overload" else DEADLINE
        )
        return wait

    # -- the event loop ----------------------------------------------------

    def serve(
        self,
        requests: Sequence[Request],
        *,
        timeline: Sequence[tuple[float, Callable[[], Any]]] = (),
    ) -> list[Response]:
        """Run the open-loop simulation to completion and return one
        :class:`Response` per request, in input order.

        ``timeline`` entries ``(virtual_time, callback)`` fire once the
        virtual clock first reaches their time — the chaos harness uses
        them to inject/clear node slowdowns and fault budgets mid-run.
        """
        order = sorted(range(len(requests)), key=lambda i: (requests[i].arrival_s, i))
        responses: list[Response | None] = [None] * len(requests)
        events = sorted(timeline, key=lambda e: e[0])
        queue: list[_Queued] = []
        now = 0.0
        ai = ei = 0

        def fire_events(upto: float) -> None:
            nonlocal ei
            while ei < len(events) and events[ei][0] <= upto:
                events[ei][1]()
                ei += 1

        def admit_upto(t: float) -> None:
            nonlocal ai
            while ai < len(order) and requests[order[ai]].arrival_s <= t:
                idx = order[ai]
                fire_events(requests[idx].arrival_s)
                self._admit(idx, requests[idx], queue, responses)
                ai += 1

        while True:
            admit_upto(now)
            if not queue:
                if ai >= len(order):
                    break
                now = requests[order[ai]].arrival_s  # idle: jump to next arrival
                continue

            # -- continuous batching: launch at max_batch or max_wait --
            if len(queue) >= self.max_batch:
                launch = now
            else:
                launch = max(now, queue[0].req.arrival_s + self.max_wait)
                # arrivals before the timer expires may fill the batch early
                while (
                    ai < len(order)
                    and requests[order[ai]].arrival_s <= launch
                    and len(queue) < self.max_batch
                ):
                    idx = order[ai]
                    fire_events(requests[idx].arrival_s)
                    self._admit(idx, requests[idx], queue, responses)
                    ai += 1
                if len(queue) >= self.max_batch:
                    launch = max(now, queue[-1].req.arrival_s)
            fire_events(launch)
            now = launch

            # -- rung 3: priority shed when the queue is nearly full --
            if len(queue) > self.shed_trigger:
                target = max(self.max_batch, self.shed_trigger)
                victims = sorted(
                    queue, key=lambda e: (e.req.priority, -e.req.arrival_s)
                )
                for entry in victims:
                    if len(queue) <= target:
                        break
                    queue.remove(entry)
                    self._ctr[REFUSAL_COUNTERS["shed"]].inc()
                    wait = self._refuse_queued(entry, now, "overload")
                    responses[entry.idx] = Response(
                        status=SHED,
                        error="Shed: queue over shed_fill, lower priority",
                        latency_s=wait,
                        queue_wait_s=wait,
                    )
                if not queue:
                    continue

            # -- ladder state for this batch --
            oldest_wait = now - queue[0].req.arrival_s
            degrade = oldest_wait > self.degrade_after
            hedge = (
                self.queue_wait.count >= self.ewma_warmup
                and self.queue_wait.mean() > self.hedge_after
            )
            if degrade:
                self._ctr[RUNG_COUNTERS["degrade"]].inc()
                self._degraded = True
            elif self._degraded:
                self._degraded = False
                self._ctr[RUNG_COUNTERS["recover"]].inc()
            if hedge:
                self._ctr[RUNG_COUNTERS["hedge"]].inc()

            # -- pick the batch: highest priority, then oldest --
            chosen = sorted(
                queue, key=lambda e: (-e.req.priority, e.req.arrival_s, e.idx)
            )[: self.max_batch]
            for entry in chosen:
                queue.remove(entry)

            # -- rung 4a: shed members whose budget is already spent --
            ready: list[_Queued] = []
            for entry in chosen:
                d = entry.req.deadline_s
                if d is not None and now - entry.req.arrival_s >= d:
                    self._ctr[REFUSAL_COUNTERS["deadline"]].inc()
                    wait = self._refuse_queued(entry, now, "deadline")
                    responses[entry.idx] = Response(
                        status=DEADLINE,
                        error=str(DeadlineExceeded(d)),
                        latency_s=wait,
                        queue_wait_s=wait,
                    )
                else:
                    ready.append(entry)

            # -- launch: one read_many per (cf, effective consistency) --
            self._ctr["batches"].inc()
            groups: dict[tuple[str, str], list[_Queued]] = {}
            for entry in ready:
                level = ONE if degrade else entry.req.consistency
                groups.setdefault((entry.req.cf_name, level), []).append(entry)
            service = 0.0
            for (cf_name, level), members in sorted(groups.items()):
                service += self._run_group(
                    cf_name, level, members, now, hedge=hedge,
                    degrade=degrade, responses=responses,
                )
            now += service
        return responses  # type: ignore[return-value]

    def _run_group(
        self,
        cf_name: str,
        level: str,
        members: list[_Queued],
        launch: float,
        *,
        hedge: bool,
        degrade: bool,
        responses: list,
    ) -> float:
        """Execute one homogeneous sub-batch and write its responses.
        Returns the group's virtual service time: the larger of the
        measured wall and the engine-reported walls, so injected node
        slowdowns (which inflate reported walls without sleeping) slow
        the virtual drain."""
        # the engine budget is the LARGEST remaining member budget, and
        # only when every member carries one: an engine DeadlineExceeded
        # then implies every member's budget is spent — shed them all,
        # requeue none
        budgets = [
            m.req.deadline_s - (launch - m.req.arrival_s)
            for m in members
            if m.req.deadline_s is not None
        ]
        deadline_s = max(budgets) if len(budgets) == len(members) else None

        # span plumbing: one frontdoor.service child per traced member;
        # the engine subtree parents under the sole service span when
        # the group has one member (one tree per request, down to the
        # kernel launch), or under a shared frontdoor.batch root that
        # each member's service span points at via its ``batch`` attr
        svc_spans: list[Span] = []
        batch_span: Span | None = None
        trace: Span | None = None
        if self.tracer is not None:
            for m in members:
                if m.span is None:
                    continue
                if m.queue_span is not None:
                    m.queue_span.end(t=launch, outcome="launched")
                    m.queue_span = None
                svc_spans.append(
                    m.span.child(
                        "frontdoor.service",
                        t=launch,
                        cf=cf_name,
                        level=level,
                        hedged=hedge,
                        degraded=degrade,
                        queries=len(members),
                    )
                )
            if len(svc_spans) == 1:
                trace = svc_spans[0]
            elif svc_spans:
                batch_span = self.tracer.root(
                    "frontdoor.batch",
                    t=launch,
                    cf=cf_name,
                    level=level,
                    queries=len(members),
                    hedged=hedge,
                )
                for s in svc_spans:
                    s.annotate(batch=batch_span.span_id)
                trace = batch_span

        t0 = time.perf_counter()
        try:
            out = self.engine.read_many(
                cf_name,
                [m.req.query for m in members],
                hedge=hedge,
                hedge_ratio=1.0 if hedge else 2.0,
                consistency=level,
                deadline_s=deadline_s,
                trace=trace,
            )
        except DeadlineExceeded as e:
            wall = time.perf_counter() - t0
            done = launch + wall
            if batch_span is not None:
                batch_span.end(t=done, error="DeadlineExceeded")
            for s in svc_spans:
                s.end(t=done, outcome="deadline")
            for m in members:
                self._release(m)
                self._ctr[REFUSAL_COUNTERS["deadline"]].inc()
                latency = done - m.req.arrival_s
                self._h_latency.observe(latency)
                self._finish(m, done, latency, status=DEADLINE)
                responses[m.idx] = Response(
                    status=DEADLINE,
                    error=str(e),
                    latency_s=latency,
                    queue_wait_s=launch - m.req.arrival_s,
                )
            return wall
        wall = time.perf_counter() - t0
        reported = sum(rep.wall_seconds for _sr, rep in out)
        service = max(wall, reported)
        self._h_service.observe(service)
        done = launch + service
        if batch_span is not None:
            batch_span.end(t=done)
        for s in svc_spans:
            s.end(t=done)
        for m, (sr, rep) in zip(members, out):
            self._release(m)
            q_wait = launch - m.req.arrival_s
            self.queue_wait.record(q_wait)
            self._h_queue_wait.observe(q_wait)
            latency = done - m.req.arrival_s
            self._h_latency.observe(latency)
            d = m.req.deadline_s
            if d is not None and latency > d:
                # the answer exists but landed late — refuse it openly
                self._ctr[REFUSAL_COUNTERS["deadline"]].inc()
                self._finish(m, done, latency, status=DEADLINE)
                responses[m.idx] = Response(
                    status=DEADLINE,
                    error=str(DeadlineExceeded(d)),
                    latency_s=latency,
                    queue_wait_s=q_wait,
                )
                continue
            self._ctr["served_ok"].inc()
            was_degraded = degrade and m.req.consistency != level
            if was_degraded:
                self._ctr[RUNG_COUNTERS["consistency"]].inc()
            self._finish(m, done, latency, status=OK)
            responses[m.idx] = Response(
                status=OK,
                result=sr,
                report=rep,
                latency_s=latency,
                queue_wait_s=q_wait,
                consistency_used=level,
                degraded=was_degraded,
            )
        return service
