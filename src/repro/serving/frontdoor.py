"""Overload-safe serving front door: continuous batching with admission
control, deadlines, and graceful degradation.

Everything below :meth:`HREngine.read_many` is batched and
fault-tolerant, but callers hand it pre-built query lists. This module
is the missing serving layer: single queries arrive with per-request
deadlines and priorities, the front door coalesces them into dynamic
``read_many`` batches (continuous batching — a batch launches when
``max_batch`` fills or ``max_wait`` expires, and requests arriving
while a batch is in flight join the *next* batch), and a stack of
overload guards keeps the engine answerable when offered load exceeds
capacity.

The degradation ladder
======================

Pressure is measured in queue-wait units of ``max_wait`` (the knob the
operator already reasons in). Each rung engages at a higher threshold
and disengages automatically when the queue drains — recovery needs no
operator action:

1. **Hedge** (``queue-wait EWMA > hedge_wait_factor × max_wait``,
   default 1.5×): batches launch with hedged reads so one straggler
   node stops stretching every batch. Observed queue latency — the
   :class:`~repro.ft.detector.LatencyEWMA` feed — drives this, not a
   static per-call ``hedge_ratio``.
2. **Degrade** (``oldest queued wait > degrade_wait_factor ×
   max_wait``, default 4×): QUORUM/ALL requests in the batch are
   served at ONE, each counted in ``stats["consistency_degraded"]``
   and flagged ``degraded=True`` on its response. Latency is bought
   with consistency, openly (Zhu et al.; McKenzie et al. — the
   consistency level as a latency dial).
3. **Shed** (``queue depth > shed_fill × max_queue``, default 0.9×):
   the lowest-priority, youngest requests are dropped with an explicit
   ``shed`` response until the backlog is back at the threshold.
   Priority decides who pays for overload; nobody waits unboundedly.
4. **Deadline** (always on): a request whose budget is already spent
   at launch is shed before wasting engine work; the remaining batch
   budget is threaded into the engine (``deadline_s``), where required
   work raises :class:`~repro.core.DeadlineExceeded` and optional work
   (hedges) is skipped; a request whose answer lands after its budget
   gets a ``deadline`` response, not a silently slow answer.

Ahead of the ladder sit the admission guards
(:mod:`repro.serving.admission`): a token bucket (rate + burst) and
per-``(column family, pinned partition)`` bulkheads, both rejecting
with :class:`~repro.serving.admission.RetryAfter` instead of queuing
without bound; a full queue likewise rejects at admission. Every
decision on every rung increments a ``frontdoor.stats`` counter.

Determinism
===========

The front door runs a single-threaded discrete-event loop over a
*virtual* clock: requests carry arrival timestamps, queue waits and
latency percentiles are virtual-time quantities, and a ``timeline`` of
``(virtual_time, callback)`` events injects faults mid-run (the chaos
harness drives node slowdowns this way). Engine calls are real — a
batch's virtual service time is the larger of its measured wall and
the engine-reported per-query walls, so an injected straggler slows
the virtual drain exactly as it inflates reported walls. Given a
fixed arrival stream and fixed service times every scheduling,
admission, degradation, and shedding decision is reproducible — but
service times are *measured*, so counters shift with machine speed
between runs; what is invariant is the acceptance contract (every
request answers correctly or is explicitly refused), not the exact
split between refusal kinds.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

from repro.core import (
    CONSISTENCY_LEVELS,
    DeadlineExceeded,
    HREngine,
    ONE,
    Query,
    ReadReport,
    ScanResult,
    slab_bounds_many,
)
from repro.ft.detector import LatencyEWMA
from repro.serving.admission import Bulkhead, RetryAfter, TokenBucket

__all__ = ["FrontDoor", "Request", "Response"]

#: response statuses — every request ends in exactly one of these
OK = "ok"
REJECTED = "rejected"  # refused at admission (RetryAfter)
SHED = "shed"  # dropped under overload (priority shed)
DEADLINE = "deadline"  # budget spent (DeadlineExceeded)


@dataclasses.dataclass(frozen=True)
class Request:
    """One client query: what to read, when it arrived, how long it may
    take (``deadline_s`` is a budget relative to arrival; None =
    unbounded), and how important it is (higher ``priority`` sheds
    last)."""

    cf_name: str
    query: Query
    arrival_s: float = 0.0
    deadline_s: float | None = None
    priority: int = 0
    consistency: str = ONE

    def __post_init__(self) -> None:
        if self.consistency not in CONSISTENCY_LEVELS:
            raise ValueError(
                f"unknown consistency {self.consistency!r} "
                f"(expected one of {CONSISTENCY_LEVELS})"
            )
        if self.arrival_s < 0.0:
            raise ValueError(f"arrival_s must be >= 0, got {self.arrival_s}")


@dataclasses.dataclass(frozen=True)
class Response:
    """The front door's answer: a result or an *explicit* refusal.
    ``status`` is one of ``ok`` / ``rejected`` / ``shed`` /
    ``deadline``; there is no silent path."""

    status: str
    result: ScanResult | None = None
    report: ReadReport | None = None
    error: str | None = None
    retry_after_s: float | None = None  # set on ``rejected``
    latency_s: float = 0.0  # virtual completion - arrival
    queue_wait_s: float = 0.0  # virtual launch - arrival
    consistency_used: str | None = None
    degraded: bool = False  # served below the requested consistency

    @property
    def ok(self) -> bool:
        return self.status == OK


@dataclasses.dataclass(eq=False)  # identity semantics: queue.remove()
class _Queued:
    """A request holding a queue slot (and its bulkhead admission)."""

    idx: int
    req: Request
    compartment: tuple[str, int] | None


class FrontDoor:
    """Continuous-batching, overload-safe serving layer over one
    :class:`~repro.core.HREngine` (see module docstring for the
    degradation ladder and determinism model).

    Parameters
    ----------
    max_batch, max_wait:
        Continuous-batching knobs: a batch launches as soon as
        ``max_batch`` requests wait, or when the oldest has waited
        ``max_wait`` seconds — whichever comes first.
    max_queue:
        Hard queue bound; arrivals beyond it are rejected with
        :class:`RetryAfter` (backpressure, not buffering).
    rate, burst:
        Token-bucket admission (requests/second + burst capacity);
        ``rate=None`` disables throttling.
    bulkhead_inflight:
        Outstanding-request bound per ``(cf_name, partition)``
        compartment; ``None`` disables bulkheads.
    hedge_wait_factor, degrade_wait_factor, shed_fill:
        The ladder thresholds, in units of ``max_wait`` (rungs 1–3
        above).
    """

    def __init__(
        self,
        engine: HREngine,
        *,
        max_batch: int = 64,
        max_wait: float = 2e-3,
        max_queue: int = 256,
        rate: float | None = None,
        burst: float = 32.0,
        bulkhead_inflight: int | None = None,
        hedge_wait_factor: float = 1.5,
        degrade_wait_factor: float = 4.0,
        shed_fill: float = 0.9,
        ewma_alpha: float = 0.2,
        ewma_warmup: int = 8,
    ) -> None:
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if max_wait <= 0.0:
            raise ValueError(f"max_wait must be > 0, got {max_wait}")
        if max_queue < max_batch:
            raise ValueError(
                f"max_queue ({max_queue}) must be >= max_batch ({max_batch})"
            )
        if not 0.0 < shed_fill <= 1.0:
            raise ValueError(f"shed_fill must be in (0, 1], got {shed_fill}")
        if hedge_wait_factor <= 0.0 or degrade_wait_factor <= 0.0:
            raise ValueError("ladder factors must be > 0")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        self.max_queue = int(max_queue)
        self.bucket = TokenBucket(rate, burst) if rate is not None else None
        self.bulkhead = (
            Bulkhead(bulkhead_inflight, retry_after_s=max_wait)
            if bulkhead_inflight is not None
            else None
        )
        self.hedge_after = float(hedge_wait_factor) * self.max_wait
        self.degrade_after = float(degrade_wait_factor) * self.max_wait
        self.shed_trigger = max(1, int(float(shed_fill) * self.max_queue))
        self.queue_wait = LatencyEWMA(alpha=ewma_alpha)
        self.ewma_warmup = int(ewma_warmup)
        self._degraded = False  # current ladder state (for recovery count)
        self._stats: dict[str, float] = {
            "submitted": 0,
            "admitted": 0,
            "served_ok": 0,
            "rejected_throttle": 0,
            "rejected_bulkhead": 0,
            "rejected_queue_full": 0,
            "shed_overload": 0,
            "shed_deadline": 0,
            "consistency_degraded": 0,
            "degraded_batches": 0,
            "degrade_recoveries": 0,
            "hedged_batches": 0,
            "batches": 0,
            "max_queue_depth": 0,
        }

    @property
    def stats(self) -> dict[str, float]:
        """Copy of the decision counters (every ladder rung and every
        admission refusal increments one of these)."""
        return dict(self._stats)

    # -- admission ---------------------------------------------------------

    def _compartment(self, req: Request) -> tuple[str, int]:
        """Bulkhead compartment key: the request's column family plus
        the partition its slab pins (-1 when it fans out over several —
        fan-out queries share one per-CF compartment)."""
        cf = self.engine.column_families[req.cf_name]
        if cf.ring.n_partitions <= 1:
            return (req.cf_name, 0)
        bounds = slab_bounds_many([req.query], cf.key_names, cf.schema)
        p_lo, p_hi = cf.ring.span_partitions(bounds)
        pid = int(p_lo[0]) if int(p_lo[0]) == int(p_hi[0]) else -1
        return (req.cf_name, pid)

    def _admit(
        self, idx: int, req: Request, queue: list[_Queued], responses: list
    ) -> None:
        """Admission at virtual arrival time: queue bound, token
        bucket, bulkhead — first refusal wins and becomes an explicit
        ``rejected`` response."""
        self._stats["submitted"] += 1
        if len(queue) >= self.max_queue:
            self._stats["rejected_queue_full"] += 1
            responses[idx] = Response(
                status=REJECTED,
                error="RetryAfter: queue full",
                retry_after_s=self.max_wait,
                consistency_used=None,
            )
            return
        if self.bucket is not None:
            try:
                self.bucket.admit(req.arrival_s)
            except RetryAfter as e:
                self._stats["rejected_throttle"] += 1
                responses[idx] = Response(
                    status=REJECTED,
                    error=f"RetryAfter: {e.reason}",
                    retry_after_s=e.retry_after_s,
                )
                return
        comp = None
        if self.bulkhead is not None:
            # the slab walk is only worth paying when a bulkhead will
            # actually compartment by it
            comp = self._compartment(req)
            try:
                self.bulkhead.acquire(comp)
            except RetryAfter as e:
                self._stats["rejected_bulkhead"] += 1
                responses[idx] = Response(
                    status=REJECTED,
                    error=f"RetryAfter: {e.reason}",
                    retry_after_s=e.retry_after_s,
                )
                return
        self._stats["admitted"] += 1
        queue.append(_Queued(idx, req, comp))
        self._stats["max_queue_depth"] = max(
            self._stats["max_queue_depth"], len(queue)
        )

    def _release(self, entry: _Queued) -> None:
        if self.bulkhead is not None and entry.compartment is not None:
            self.bulkhead.release(entry.compartment)

    # -- the event loop ----------------------------------------------------

    def serve(
        self,
        requests: Sequence[Request],
        *,
        timeline: Sequence[tuple[float, Callable[[], Any]]] = (),
    ) -> list[Response]:
        """Run the open-loop simulation to completion and return one
        :class:`Response` per request, in input order.

        ``timeline`` entries ``(virtual_time, callback)`` fire once the
        virtual clock first reaches their time — the chaos harness uses
        them to inject/clear node slowdowns and fault budgets mid-run.
        """
        order = sorted(range(len(requests)), key=lambda i: (requests[i].arrival_s, i))
        responses: list[Response | None] = [None] * len(requests)
        events = sorted(timeline, key=lambda e: e[0])
        queue: list[_Queued] = []
        now = 0.0
        ai = ei = 0

        def fire_events(upto: float) -> None:
            nonlocal ei
            while ei < len(events) and events[ei][0] <= upto:
                events[ei][1]()
                ei += 1

        def admit_upto(t: float) -> None:
            nonlocal ai
            while ai < len(order) and requests[order[ai]].arrival_s <= t:
                idx = order[ai]
                fire_events(requests[idx].arrival_s)
                self._admit(idx, requests[idx], queue, responses)
                ai += 1

        while True:
            admit_upto(now)
            if not queue:
                if ai >= len(order):
                    break
                now = requests[order[ai]].arrival_s  # idle: jump to next arrival
                continue

            # -- continuous batching: launch at max_batch or max_wait --
            if len(queue) >= self.max_batch:
                launch = now
            else:
                launch = max(now, queue[0].req.arrival_s + self.max_wait)
                # arrivals before the timer expires may fill the batch early
                while (
                    ai < len(order)
                    and requests[order[ai]].arrival_s <= launch
                    and len(queue) < self.max_batch
                ):
                    idx = order[ai]
                    fire_events(requests[idx].arrival_s)
                    self._admit(idx, requests[idx], queue, responses)
                    ai += 1
                if len(queue) >= self.max_batch:
                    launch = max(now, queue[-1].req.arrival_s)
            fire_events(launch)
            now = launch

            # -- rung 3: priority shed when the queue is nearly full --
            if len(queue) > self.shed_trigger:
                target = max(self.max_batch, self.shed_trigger)
                victims = sorted(
                    queue, key=lambda e: (e.req.priority, -e.req.arrival_s)
                )
                for entry in victims:
                    if len(queue) <= target:
                        break
                    queue.remove(entry)
                    self._release(entry)
                    self._stats["shed_overload"] += 1
                    responses[entry.idx] = Response(
                        status=SHED,
                        error="Shed: queue over shed_fill, lower priority",
                        latency_s=now - entry.req.arrival_s,
                        queue_wait_s=now - entry.req.arrival_s,
                    )
                if not queue:
                    continue

            # -- ladder state for this batch --
            oldest_wait = now - queue[0].req.arrival_s
            degrade = oldest_wait > self.degrade_after
            hedge = (
                self.queue_wait.count >= self.ewma_warmup
                and self.queue_wait.mean() > self.hedge_after
            )
            if degrade:
                self._stats["degraded_batches"] += 1
                self._degraded = True
            elif self._degraded:
                self._degraded = False
                self._stats["degrade_recoveries"] += 1
            if hedge:
                self._stats["hedged_batches"] += 1

            # -- pick the batch: highest priority, then oldest --
            chosen = sorted(
                queue, key=lambda e: (-e.req.priority, e.req.arrival_s, e.idx)
            )[: self.max_batch]
            for entry in chosen:
                queue.remove(entry)

            # -- rung 4a: shed members whose budget is already spent --
            ready: list[_Queued] = []
            for entry in chosen:
                d = entry.req.deadline_s
                if d is not None and now - entry.req.arrival_s >= d:
                    self._release(entry)
                    self._stats["shed_deadline"] += 1
                    responses[entry.idx] = Response(
                        status=DEADLINE,
                        error=str(DeadlineExceeded(d)),
                        latency_s=now - entry.req.arrival_s,
                        queue_wait_s=now - entry.req.arrival_s,
                    )
                else:
                    ready.append(entry)

            # -- launch: one read_many per (cf, effective consistency) --
            self._stats["batches"] += 1
            groups: dict[tuple[str, str], list[_Queued]] = {}
            for entry in ready:
                level = ONE if degrade else entry.req.consistency
                groups.setdefault((entry.req.cf_name, level), []).append(entry)
            service = 0.0
            for (cf_name, level), members in sorted(groups.items()):
                service += self._run_group(
                    cf_name, level, members, now, hedge=hedge,
                    degrade=degrade, responses=responses,
                )
            now += service
        return responses  # type: ignore[return-value]

    def _run_group(
        self,
        cf_name: str,
        level: str,
        members: list[_Queued],
        launch: float,
        *,
        hedge: bool,
        degrade: bool,
        responses: list,
    ) -> float:
        """Execute one homogeneous sub-batch and write its responses.
        Returns the group's virtual service time: the larger of the
        measured wall and the engine-reported walls, so injected node
        slowdowns (which inflate reported walls without sleeping) slow
        the virtual drain."""
        # the engine budget is the LARGEST remaining member budget, and
        # only when every member carries one: an engine DeadlineExceeded
        # then implies every member's budget is spent — shed them all,
        # requeue none
        budgets = [
            m.req.deadline_s - (launch - m.req.arrival_s)
            for m in members
            if m.req.deadline_s is not None
        ]
        deadline_s = max(budgets) if len(budgets) == len(members) else None
        t0 = time.perf_counter()
        try:
            out = self.engine.read_many(
                cf_name,
                [m.req.query for m in members],
                hedge=hedge,
                hedge_ratio=1.0 if hedge else 2.0,
                consistency=level,
                deadline_s=deadline_s,
            )
        except DeadlineExceeded as e:
            wall = time.perf_counter() - t0
            for m in members:
                self._release(m)
                self._stats["shed_deadline"] += 1
                responses[m.idx] = Response(
                    status=DEADLINE,
                    error=str(e),
                    latency_s=launch + wall - m.req.arrival_s,
                    queue_wait_s=launch - m.req.arrival_s,
                )
            return wall
        wall = time.perf_counter() - t0
        reported = sum(rep.wall_seconds for _sr, rep in out)
        service = max(wall, reported)
        done = launch + service
        for m, (sr, rep) in zip(members, out):
            self._release(m)
            q_wait = launch - m.req.arrival_s
            self.queue_wait.record(q_wait)
            latency = done - m.req.arrival_s
            d = m.req.deadline_s
            if d is not None and latency > d:
                # the answer exists but landed late — refuse it openly
                self._stats["shed_deadline"] += 1
                responses[m.idx] = Response(
                    status=DEADLINE,
                    error=str(DeadlineExceeded(d)),
                    latency_s=latency,
                    queue_wait_s=q_wait,
                )
                continue
            self._stats["served_ok"] += 1
            was_degraded = degrade and m.req.consistency != level
            if was_degraded:
                self._stats["consistency_degraded"] += 1
            responses[m.idx] = Response(
                status=OK,
                result=sr,
                report=rep,
                latency_s=latency,
                queue_wait_s=q_wait,
                consistency_used=level,
                degraded=was_degraded,
            )
        return service
