"""jit-compiled serving steps: prefill (prompt → cache) and decode.

Decode sharding: batch over (pod, data); KV-cache sequence over ``model``
(SP) — the per-layer attention runs as flash-decoding across chips with
an exp-rescaled psum combine (see models/blocks.py). The cache is donated
so decode is in-place at steady state.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models import moe as moe_mod
from repro.models.config import ArchConfig
from repro.parallel.sharding import MeshCtx, logical_to_spec, param_specs_for_tree

__all__ = ["cache_shardings", "make_prefill_step", "make_decode_step", "token_specs",
           "serve_ctx_and_axes"]


def serve_ctx_and_axes(cfg: ArchConfig, ctx: MeshCtx | None, serve_sharding: str):
    """(ctx, param_axes) for the chosen serving placement.

    "fsdp" — training placement reused (ZeRO gathers every layer; the
             baseline recorded in §Roofline).
    "tp"   — inference placement: weights pure-TP, experts global-EP when
             they divide (data × model). The §Perf hillclimb measures the
             collective-term drop between the two.
    """
    if ctx is None or serve_sharding == "fsdp":
        return ctx, lm.lm_axes(cfg, ctx.tp_size if ctx else 1)
    ctx = dataclasses.replace(ctx, serve_ep=True)
    epd = cfg.is_moe and moe_mod.ep_over_data_ok(cfg, ctx)
    return ctx, lm.lm_axes(cfg, ctx.tp_size, serve=True, ep_over_data=epd)


def cache_shardings(cfg: ArchConfig, ctx: MeshCtx | None, B: int, S_alloc: int):
    tp = ctx.tp_size if ctx else 1
    axes = lm.cache_axes(cfg, B, S_alloc, tp)
    return param_specs_for_tree(ctx, axes)


def token_specs(cfg: ArchConfig, ctx: MeshCtx | None):
    if cfg.input_mode == "tokens":
        return {"tokens": logical_to_spec(ctx, ("batch", None))}
    return {"embeds": logical_to_spec(ctx, ("batch", None, None))}


def make_prefill_step(cfg: ArchConfig, ctx: MeshCtx | None, *, s_alloc: int,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      serve_sharding: str = "fsdp"):
    """prefill_step(params, batch) -> (last logits, cache)."""
    ctx, p_axes = serve_ctx_and_axes(cfg, ctx, serve_sharding)

    def prefill_step(params, batch):
        return lm.prefill(params, batch, cfg, ctx, s_alloc=s_alloc,
                          q_chunk=q_chunk, kv_chunk=kv_chunk)

    if ctx is None:
        return jax.jit(prefill_step)
    tp = ctx.tp_size
    from jax.sharding import PartitionSpec as P

    to_sh = lambda tree: jax.tree.map(
        lambda s: ctx.sharding(*s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    p_spec = to_sh(param_specs_for_tree(ctx, p_axes))
    b_spec = to_sh(token_specs(cfg, ctx))
    # outs: logits replicated-over-model but batch-sharded; cache per axes
    logits_spec = ctx.sharding(*logical_to_spec(ctx, ("batch", None) if cfg.n_codebooks == 1 else ("batch", None, None)))
    return jax.jit(
        prefill_step,
        in_shardings=(p_spec, b_spec),
        out_shardings=(logits_spec, to_sh(cache_shardings(cfg, ctx, 0, 0))),
    )


def make_decode_step(cfg: ArchConfig, ctx: MeshCtx | None, *,
                     serve_sharding: str = "fsdp"):
    """decode_step(params, cache, batch_t, pos) -> (logits, cache)."""
    ctx, p_axes = serve_ctx_and_axes(cfg, ctx, serve_sharding)

    def decode_step(params, cache, batch_t, pos):
        return lm.decode_step(params, cache, batch_t, pos, cfg, ctx)

    if ctx is None:
        return jax.jit(decode_step, donate_argnums=(1,))
    tp = ctx.tp_size
    from jax.sharding import PartitionSpec as P

    to_sh = lambda tree: jax.tree.map(
        lambda s: ctx.sharding(*s), tree, is_leaf=lambda x: isinstance(x, P)
    )
    p_spec = to_sh(param_specs_for_tree(ctx, p_axes))
    c_spec = to_sh(cache_shardings(cfg, ctx, 0, 0))
    b_spec = to_sh(token_specs(cfg, ctx))
    logits_spec = ctx.sharding(
        *logical_to_spec(ctx, ("batch", None) if cfg.n_codebooks == 1 else ("batch", None, None))
    )
    return jax.jit(
        decode_step,
        in_shardings=(p_spec, c_spec, b_spec, None),
        out_shardings=(logits_spec, c_spec),
        donate_argnums=(1,),
    )
