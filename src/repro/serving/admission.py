"""Admission control primitives for the serving front door.

Two classic overload guards, both deterministic over a *virtual* clock
(the caller passes ``now`` explicitly — no wall-clock reads, so seeded
serving simulations replay exactly):

* :class:`TokenBucket` — rate limiting with burst tolerance. A request
  that arrives with no token available is rejected with
  :class:`RetryAfter` carrying the exact time until a token refills;
  the front door turns that into an explicit backpressure response
  instead of letting the queue grow without bound.
* :class:`Bulkhead` — bounded outstanding work per *compartment* (the
  front door compartments by ``(column family, pinned partition)``).
  One hot column family or hot partition fills only its own
  compartment and starts drawing :class:`RetryAfter`; requests for
  everything else keep their queue slots. Named after the watertight
  ship walls: a flood stays in the flooded compartment.

Rejecting at admission is the point — work that will not finish in
time is cheapest to refuse *before* it holds a queue slot. Everything
past admission (batch forming, degradation, shedding) lives in
:mod:`repro.serving.frontdoor`.
"""

from __future__ import annotations

__all__ = ["RetryAfter", "TokenBucket", "Bulkhead"]


class RetryAfter(RuntimeError):
    """Explicit backpressure: the request was refused at admission and
    the client should retry no sooner than ``retry_after_s`` from now.
    Deliberately an error type, not a silent drop — every refusal is
    visible to the caller and counted in ``frontdoor.stats``.

    ``kind`` names which guard refused (``"rate"``, ``"bulkhead"``, or
    ``"queue"``); the front door maps each kind onto its registry
    counter via :data:`repro.serving.frontdoor.REFUSAL_COUNTERS`, so
    every typed refusal is observable by construction."""

    def __init__(
        self, retry_after_s: float, reason: str, *, kind: str = "admission"
    ) -> None:
        super().__init__(
            f"admission refused ({reason}); retry after {retry_after_s * 1e3:.3f} ms"
        )
        self.retry_after_s = float(retry_after_s)
        self.reason = reason
        self.kind = kind


class TokenBucket:
    """Token-bucket rate limiter over a caller-supplied virtual clock.

    ``rate`` tokens/second refill continuously up to ``burst`` capacity;
    each admitted request spends one token. The bucket starts full, so
    a cold burst of up to ``burst`` requests is admitted before the
    rate binds.
    """

    def __init__(self, rate: float, burst: float) -> None:
        if rate <= 0.0:
            raise ValueError(f"rate must be > 0 tokens/s, got {rate}")
        if burst < 1.0:
            raise ValueError(f"burst must be >= 1 token, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)
        self._last = 0.0

    def _refill(self, now: float) -> None:
        # monotone virtual clock: a caller stepping backwards would
        # mint tokens out of nothing, so clamp to the last seen time
        if now > self._last:
            self._tokens = min(self.burst, self._tokens + (now - self._last) * self.rate)
            self._last = now

    def admit(self, now: float) -> None:
        """Spend one token at virtual time ``now`` or raise
        :class:`RetryAfter` with the exact refill wait."""
        self._refill(now)
        if self._tokens >= 1.0:
            self._tokens -= 1.0
            return
        raise RetryAfter((1.0 - self._tokens) / self.rate, "rate limit", kind="rate")

    def tokens(self, now: float) -> float:
        """Tokens available at ``now`` (observability only)."""
        self._refill(now)
        return self._tokens


class Bulkhead:
    """Bounded outstanding admissions per compartment.

    ``acquire(key)`` admits one unit of work into compartment ``key``
    (any hashable — the front door uses ``(cf_name, partition_id)``)
    and must be paired with ``release(key)`` when the work completes,
    is shed, or fails. A full compartment raises :class:`RetryAfter`;
    other compartments are unaffected.
    """

    def __init__(self, max_inflight: int, *, retry_after_s: float) -> None:
        if max_inflight < 1:
            raise ValueError(f"max_inflight must be >= 1, got {max_inflight}")
        if retry_after_s <= 0.0:
            raise ValueError(f"retry_after_s must be > 0, got {retry_after_s}")
        self.max_inflight = int(max_inflight)
        self.retry_after_s = float(retry_after_s)
        self._inflight: dict[object, int] = {}

    def acquire(self, key: object) -> None:
        n = self._inflight.get(key, 0)
        if n >= self.max_inflight:
            raise RetryAfter(
                self.retry_after_s, f"bulkhead full for {key!r}", kind="bulkhead"
            )
        self._inflight[key] = n + 1

    def release(self, key: object) -> None:
        n = self._inflight.get(key, 0)
        if n <= 0:
            raise RuntimeError(f"release without acquire for compartment {key!r}")
        if n == 1:
            del self._inflight[key]
        else:
            self._inflight[key] = n - 1

    def inflight(self, key: object) -> int:
        return self._inflight.get(key, 0)
