"""repro: Heterogeneous Replica (HR) for Query — multi-pod JAX framework.

Paper: "Heterogeneous Replica for Query on Cassandra", Qiao, Huang, Rui,
Wang (Tsinghua, 2018). The `core` package is the paper-faithful HR
mechanism; the rest is the production training/serving framework that
consumes it (data pipeline routing, checkpoint replica layouts, hedged
scheduling).
"""

__version__ = "1.0.0"
