"""HR replica layouts for checkpoint restore routing.

One row per checkpoint FILE with keys (stack_id, layer, kind_id); the RF
replica manifests are SortedTables in different key orders. A restore
query (full / layer-range / stack / kind subset) is costed with Eq (1)
and routed to the replica whose order makes the touched file span
contiguous — the paper's Request Scheduler applied to restore I/O.
"""

from __future__ import annotations

import dataclasses
import json
import os

import numpy as np

from repro.core import CostModel, Query, SortedTable, estimate_rows
from repro.core.ecdf import TableStats
from repro.core.keys import KeySchema
from .manager import REPLICA_LAYOUTS, manifest_key_columns

__all__ = ["RestorePlan", "CheckpointRouter"]


@dataclasses.dataclass
class RestorePlan:
    replica: int
    layout: tuple[str, ...]
    files_span: int  # contiguous files streamed (slab size — the cost)
    files_needed: int  # files actually matching the query
    file_indices: np.ndarray


class CheckpointRouter:
    """Routes restore queries over a step's replica manifests."""

    def __init__(self, directory: str, step: int) -> None:
        d = os.path.join(directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest_r0.json")) as f:
            manifest = json.load(f)
        cols = manifest_key_columns(manifest["leaves"])
        keys = {k: cols[k] for k in ("stack_id", "layer", "kind_id")}
        vals = {"file_idx": cols["file_idx"].astype(np.float64)}
        self.schema = KeySchema.for_columns(keys)
        self.stats = TableStats.from_columns(keys, self.schema)
        self.model = CostModel(stats=self.stats)
        self.layouts = []
        self.tables = []
        r = 0
        while os.path.exists(os.path.join(d, f"manifest_r{r}.json")):
            with open(os.path.join(d, f"manifest_r{r}.json")) as f:
                layout = tuple(json.load(f)["layout"])
            self.layouts.append(layout)
            self.tables.append(SortedTable.from_columns(keys, vals, layout, self.schema))
            r += 1

    def plan(self, query: Query) -> RestorePlan:
        """Pick the min-cost replica (Eq 3) and return its streamed span."""
        costs = [self.model.query_cost(a, query) for a in self.layouts]
        j = int(np.argmin(costs))
        res = self.tables[j].execute(
            Query(filters=query.filters, agg="select")
        )
        return RestorePlan(
            replica=j,
            layout=self.layouts[j],
            files_span=res.rows_scanned,
            files_needed=res.rows_matched,
            file_indices=self.tables[j].value_cols["file_idx"][res.selected].astype(np.int64),
        )

    def worst_plan(self, query: Query) -> RestorePlan:
        """Span on the WORST replica (what a homogeneous layout risks)."""
        costs = [self.model.query_cost(a, query) for a in self.layouts]
        j = int(np.argmax(costs))
        res = self.tables[j].execute(Query(filters=query.filters, agg="select"))
        return RestorePlan(
            replica=j,
            layout=self.layouts[j],
            files_span=res.rows_scanned,
            files_needed=res.rows_matched,
            file_indices=self.tables[j].value_cols["file_idx"][res.selected].astype(np.int64),
        )
