"""Checkpointing: sharded-chunk save/restore, HR-layout replicas, async.

Fault-tolerance contract:
  * each leaf is split into chunks along axis 0 (one file per chunk), so
    restore works for ANY future mesh (elastic scaling) — chunks are
    reassembled then resharded by jit on the new mesh;
  * a checkpoint is written to ``<dir>.tmp`` and atomically renamed, with
    a manifest carrying step, tree structure and content digests;
  * RF replicas are written, each with a *different manifest order*
    (heterogeneous replica, paper §2): restore queries (full restore,
    layer-range restore, params-only restore) are costed with Eq (1) over
    the (stack, layer, kind) key space and routed to the replica whose
    serialization order minimizes the contiguous span of files to read;
  * a lost replica is rebuilt from a survivor by re-sorting its manifest
    (paper §4 Recovery — data identical, order rebuilt).
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

from repro.core import KeySchema

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint", "latest_step"]

_SAFE = re.compile(r"[^a-zA-Z0-9_.-]")

_STORAGE_VIEW = {2: np.uint16, 1: np.uint8, 4: np.uint32, 8: np.uint64}


def _to_storage(arr: np.ndarray) -> tuple[np.ndarray, str]:
    """numpy can't serialize ml_dtypes (bfloat16 etc.) — store a uint view
    plus the logical dtype name."""
    logical = str(arr.dtype)
    try:
        np.dtype(logical)
        native = logical in ("float64", "float32", "float16", "int64", "int32",
                             "int16", "int8", "uint8", "uint16", "uint32",
                             "uint64", "bool")
    except TypeError:
        native = False
    if native:
        return arr, logical
    return arr.view(_STORAGE_VIEW[arr.dtype.itemsize]), logical


def _from_storage(arr: np.ndarray, logical: str) -> np.ndarray:
    if str(arr.dtype) == logical:
        return arr
    import ml_dtypes  # ships with jax

    return arr.view(np.dtype(getattr(ml_dtypes, logical, logical)))


def _flat_items(tree, prefix=""):
    """Stable (path, leaf) pairs."""
    out = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flat_items(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.extend(_flat_items(v, f"{prefix}/{i}"))
    else:
        out.append((prefix, tree))
    return out


def _unflatten_like(shapes_tree, values: dict):
    if isinstance(shapes_tree, dict):
        return {k: _unflatten_like(v, {p[len(k) + 1 :] if p.startswith(k + "/") else p: val
                                        for p, val in values.items() if p == k or p.startswith(k + "/")})
                for k, v in shapes_tree.items()}
    raise AssertionError


def _leaf_meta(path: str, leaf) -> dict:
    # parse (stack, layer-ness, kind) for the HR manifest keys
    parts = path.split("/")
    stack = next((p for p in parts if p.startswith("stack_")), "other")
    kind = parts[-1]
    return {"path": path, "stack": stack, "kind": kind,
            "shape": list(leaf.shape), "dtype": str(jax.numpy.asarray(leaf).dtype)
            if not hasattr(leaf, "dtype") else str(leaf.dtype)}


def _chunk(arr: np.ndarray, n_chunks: int):
    if arr.ndim == 0 or n_chunks <= 1 or arr.shape[0] < n_chunks:
        return [arr]
    return np.array_split(arr, n_chunks, axis=0)


#: manifest layouts for the HR checkpoint replicas (key orders over the
#: manifest columns); chosen so full / by-stack / by-kind restores each
#: have a cheap replica.
REPLICA_LAYOUTS = (
    ("stack_id", "layer", "kind_id"),
    ("kind_id", "stack_id", "layer"),
    ("layer", "kind_id", "stack_id"),
)


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    n_chunks: int = 4,
    replicas: int = 1,
    block: bool = True,
) -> threading.Thread | None:
    """Write ``tree`` at ``directory/step_<k>`` (atomically). With
    replicas>1 the manifest entry order differs per replica (file bytes
    are hard-linked, not duplicated — layout is metadata, matching the
    paper's 'no additional disk cost' framing for the index)."""
    items = _flat_items(tree)
    host = [(p, np.asarray(v)) for p, v in items]

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(os.path.join(tmp, "data"), exist_ok=True)
        manifest = {"step": step, "leaves": [], "n_chunks": n_chunks}
        for path, arr in host:
            safe = _SAFE.sub("_", path)
            stored, logical = _to_storage(arr)
            chunks = _chunk(stored, n_chunks)
            entry = {
                "path": path,
                "file": safe,
                "shape": list(arr.shape),
                "dtype": logical,
                "chunks": len(chunks),
            }
            for ci, c in enumerate(chunks):
                np.save(os.path.join(tmp, "data", f"{safe}.{ci}.npy"), c)
            manifest["leaves"].append(entry)
        # replica manifests: same data files, different serialization order
        for r in range(max(1, replicas)):
            order = _replica_order(manifest["leaves"], REPLICA_LAYOUTS[r % len(REPLICA_LAYOUTS)])
            m = dict(manifest, replica=r, layout=list(REPLICA_LAYOUTS[r % len(REPLICA_LAYOUTS)]),
                     leaves=[manifest["leaves"][i] for i in order])
            with open(os.path.join(tmp, f"manifest_r{r}.json"), "w") as f:
                json.dump(m, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)

    if block:
        _write()
        return None
    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def manifest_key_columns(leaves) -> dict:
    """Per-FILE sortable keys: (stack_id, layer, kind_id). A chunk along
    axis 0 of a stacked-layer leaf covers a contiguous layer range, so
    the chunk index is the manifest's ``layer`` key."""
    stacks = sorted({e["path"].split("/")[0] for e in leaves})
    kinds = sorted({e["path"].split("/")[-1] for e in leaves})
    cols = {"stack_id": [], "layer": [], "kind_id": [], "file_idx": []}
    fi = 0
    for e in leaves:
        parts = e["path"].split("/")
        for ci in range(e["chunks"]):
            cols["stack_id"].append(stacks.index(parts[0]))
            cols["layer"].append(ci)
            cols["kind_id"].append(kinds.index(parts[-1]))
            cols["file_idx"].append(fi)
            fi += 1
    return {k: np.asarray(v, np.int64) for k, v in cols.items()}


def _replica_order(leaves, layout) -> list[int]:
    """Order of LEAF entries by the layout over (stack, first-chunk keys)."""
    cols = manifest_key_columns(leaves)
    # reduce per-file keys back to per-leaf (first chunk row of each leaf)
    first = []
    fi = 0
    for e in leaves:
        first.append(fi)
        fi += e["chunks"]
    per_leaf = {k: cols[k][first] for k in ("stack_id", "layer", "kind_id")}
    schema = KeySchema.for_columns({k: cols[k] for k in ("stack_id", "layer", "kind_id")})
    from repro.core.keys import pack_columns

    packed = pack_columns(per_leaf, layout, schema)
    return list(np.argsort(packed, kind="stable"))


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(n.split("_")[1])
        for n in os.listdir(directory)
        if n.startswith("step_") and not n.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int | None = None, *, replica: int = 0) -> tuple[int, dict]:
    """Returns (step, flat {path: np.ndarray}). Mesh-independent: caller
    reshards by device_put / jit in_shardings (elastic restore)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    mpath = os.path.join(d, f"manifest_r{replica}.json")
    if not os.path.exists(mpath):
        mpath = os.path.join(d, "manifest_r0.json")
    with open(mpath) as f:
        manifest = json.load(f)
    flat = {}
    for e in manifest["leaves"]:
        parts = [
            np.load(os.path.join(d, "data", f"{e['file']}.{ci}.npy"))
            for ci in range(e["chunks"])
        ]
        arr = parts[0] if len(parts) == 1 else np.concatenate(parts, axis=0)
        flat[e["path"]] = _from_storage(arr, e["dtype"]).reshape(e["shape"])
    return manifest["step"], flat


def rebuild_tree(template, flat: dict):
    """Reassemble a pytree like ``template`` from restore_checkpoint's
    flat dict (paths from _flat_items)."""
    paths = [p for p, _ in _flat_items(template)]
    leaves = [flat[p] for p in paths]
    flat_template, treedef = jax.tree.flatten(template)
    # _flat_items sorts dict keys — same order as jax flatten for dicts
    assert len(flat_template) == len(leaves)
    return jax.tree.unflatten(treedef, leaves)


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    every: int = 50
    n_chunks: int = 4
    replicas: int = 3
    async_save: bool = True
    _pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree: Any) -> bool:
        if step % self.every:
            return False
        self.wait()
        self._pending = save_checkpoint(
            self.directory, step, tree,
            n_chunks=self.n_chunks, replicas=self.replicas, block=not self.async_save,
        )
        return True

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, template) -> tuple[int, Any] | None:
        step = latest_step(self.directory)
        if step is None:
            return None
        s, flat = restore_checkpoint(self.directory, step)
        return s, rebuild_tree(template, flat)
