"""Synthetic training corpus with queryable document metadata.

Documents carry sortable attributes — (domain, length bucket, quality
decile, ingest day) — exactly the kind of multi-dimensional sortable
metadata the paper targets. Token content is generated deterministically
from the doc id (hash-seeded), so the corpus needs no storage and any
node can materialize any document — which is what lets heterogeneous
*index* replicas stand in for heterogeneous data replicas.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CorpusSpec", "SyntheticCorpus"]

N_DOMAINS = 8
N_LENGTH_BUCKETS = 16
N_QUALITY = 10
N_DAYS = 1024


@dataclasses.dataclass(frozen=True)
class CorpusSpec:
    n_docs: int = 100_000
    vocab_size: int = 50_000
    seed: int = 0


class SyntheticCorpus:
    def __init__(self, spec: CorpusSpec) -> None:
        self.spec = spec
        rng = np.random.default_rng(spec.seed)
        n = spec.n_docs
        # correlated attributes (quality skews by domain, length by domain)
        domain = rng.integers(0, N_DOMAINS, n)
        length_bucket = np.clip(
            rng.poisson(3 + 1.5 * (domain % 4), n), 0, N_LENGTH_BUCKETS - 1
        )
        quality = np.clip(
            rng.normal(5 + (domain % 3), 2.0, n).astype(np.int64), 0, N_QUALITY - 1
        )
        day = rng.integers(0, N_DAYS, n)
        self.key_cols = {
            "domain": domain.astype(np.int64),
            "length_bucket": length_bucket.astype(np.int64),
            "quality": quality.astype(np.int64),
            "day": day.astype(np.int64),
        }
        self.value_cols = {"doc_id": np.arange(n, dtype=np.float64)}

    def tokens(self, doc_ids: np.ndarray, seq_len: int) -> np.ndarray:
        """Deterministic per-doc token stream: [len(doc_ids), seq_len]."""
        out = np.empty((len(doc_ids), seq_len), dtype=np.int32)
        for i, d in enumerate(np.asarray(doc_ids, np.int64)):
            rng = np.random.default_rng(self.spec.seed * 1_000_003 + int(d))
            out[i] = rng.integers(0, self.spec.vocab_size, seq_len, dtype=np.int32)
        return out
