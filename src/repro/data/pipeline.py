"""HR-routed training-data pipeline (the paper's technique as a
first-class framework feature).

The corpus *index* (doc metadata) is replicated RF× with HRCA-chosen key
orders; curriculum sampling queries ("quality ≥ 8", "domain=code ∧
length∈[2k,4k)") are routed by the engine's Request Scheduler to the
replica whose layout minimizes the scan (Eq 3). The pipeline then
materializes token batches from the selected doc ids.

``mechanism="TR"`` builds the single expert layout instead — the paper's
baseline — so benchmarks compare both under identical queries.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np

from repro.core import Eq, HREngine, Query, Range, Workload
from .corpus import CorpusSpec, SyntheticCorpus

__all__ = ["curriculum_workload", "HRDataPipeline", "BatchReport"]


def curriculum_workload(rng: np.random.Generator, n: int = 60) -> Workload:
    """Curriculum query mix: phase filters over quality/length/domain/day."""
    qs = []
    for i in range(n):
        r = i % 3
        if r == 0:  # quality-gated domain slice (equality-heavy)
            qs.append(
                Query(
                    filters={
                        "domain": Eq(int(rng.integers(0, 8))),
                        "quality": Range(7, 10),
                    },
                    agg="select",
                )
            )
        elif r == 1:  # length curriculum window
            lo = int(rng.integers(0, 12))
            qs.append(
                Query(
                    filters={
                        "length_bucket": Range(lo, lo + 4),
                        "quality": Eq(int(rng.integers(4, 10))),
                    },
                    agg="select",
                )
            )
        else:  # freshness window within a domain
            d0 = int(rng.integers(0, 900))
            qs.append(
                Query(
                    filters={
                        "day": Range(d0, d0 + 64),
                        "domain": Eq(int(rng.integers(0, 8))),
                    },
                    agg="select",
                )
            )
    return Workload(qs)


@dataclasses.dataclass
class BatchReport:
    replica_id: int
    rows_scanned: int
    rows_matched: int
    estimated_rows: float


class HRDataPipeline:
    def __init__(
        self,
        corpus: SyntheticCorpus,
        *,
        replication_factor: int = 3,
        mechanism: str = "HR",
        n_nodes: int = 6,
        workload: Workload | None = None,
        seed: int = 0,
        hrca_kwargs: dict | None = None,
    ) -> None:
        self.corpus = corpus
        self.rng = np.random.default_rng(seed)
        self.workload = workload or curriculum_workload(np.random.default_rng(seed + 1))
        self.engine = HREngine(n_nodes=n_nodes)
        self.cf = self.engine.create_column_family(
            "corpus_index",
            corpus.key_cols,
            corpus.value_cols,
            replication_factor=replication_factor,
            mechanism=mechanism,
            workload=self.workload,
            hrca_kwargs=hrca_kwargs or {"k_max": 2000, "seed": 0},
        )
        self.total_rows_scanned = 0
        self.n_reads = 0

    def layouts(self):
        return self.engine.layouts("corpus_index")

    def sample_batch(
        self, batch_size: int, seq_len: int, query: Query | None = None, *, hedge: bool = False
    ) -> tuple[dict, BatchReport]:
        """Route one curriculum query, draw ``batch_size`` docs from the
        matches (with replacement if needed), materialize tokens/labels."""
        if query is None:
            qi = int(self.rng.integers(0, len(self.workload)))
            query = self.workload.queries[qi]
        result, report = self.engine.read("corpus_index", query, hedge=hedge)
        self.total_rows_scanned += report.rows_scanned
        self.n_reads += 1
        if result.selected is None or len(result.selected) == 0:
            doc_ids = self.rng.integers(0, self.corpus.spec.n_docs, batch_size)
        else:
            table = self.engine._table(self.cf, self.cf.replicas[report.replica_id])
            matched_docs = table.value_cols["doc_id"][result.selected].astype(np.int64)
            idx = self.rng.integers(0, len(matched_docs), batch_size)
            doc_ids = matched_docs[idx]
        toks = self.corpus.tokens(doc_ids, seq_len + 1)
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }
        return batch, BatchReport(
            replica_id=report.replica_id,
            rows_scanned=report.rows_scanned,
            rows_matched=result.rows_matched,
            estimated_rows=report.estimated_rows,
        )

    def batches(self, n: int, batch_size: int, seq_len: int) -> Iterator[tuple[dict, BatchReport]]:
        for _ in range(n):
            yield self.sample_batch(batch_size, seq_len)
