"""Pallas TPU kernel: histogram / ECDF builder for the Cost Evaluator.

Builds per-column bin counts (``ColumnStats.counts``) on-device so stats
refresh keeps up with the write path at corpus scale. One grid step loads
a (1, block_n) slice of the column, computes bin ids, and accumulates a
one-hot-compare partial histogram of shape (block_rows, n_bins) reduced
over rows — compare+sum on the VPU, no scatter (TPU-friendly: scatters
serialize; broadcast-compare vectorizes).

VMEM budget: the (block_n/128, 128?) reshape is avoided — the compare is
(sub_block, n_bins_pad) per sub-row chunk; with block_n=512 and
n_bins≤1024 the intermediate is ≤ 2 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["ecdf_hist_kernel", "ecdf_hist_pallas"]


def ecdf_hist_kernel(col_ref, out_ref, *, bin_width: int, n_bins_pad: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    col = col_ref[...]  # (1, block_n) int32; padding = -1
    bins = col // bin_width  # (1, block_n); padding → negative
    bin_ids = jax.lax.broadcasted_iota(jnp.int32, (n_bins_pad, 1), 0)
    onehot = (bins == bin_ids).astype(jnp.float32)  # (n_bins_pad, block_n)
    part = jnp.sum(onehot, axis=1, keepdims=True)  # (n_bins_pad, 1)
    out_ref[...] = out_ref[...] + part


@functools.partial(jax.jit, static_argnames=("n_bins", "bin_width", "block_n", "interpret"))
def ecdf_hist_pallas(
    col: jax.Array,  # int32[N], values ≥ 0
    *,
    n_bins: int,
    bin_width: int,
    block_n: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns float32[n_bins] bin counts of ``col // bin_width``."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if n_bins > 4096:
        raise ValueError("kernel path supports n_bins ≤ 4096; use the ref")
    N = col.shape[0]
    N_pad = -(-max(N, 1) // block_n) * block_n
    n_bins_pad = max(8, -(-n_bins // 8) * 8)

    col_p = jnp.pad(col.astype(jnp.int32)[None, :], ((0, 0), (0, N_pad - N)), constant_values=-1)

    grid = (N_pad // block_n,)
    kern = functools.partial(ecdf_hist_kernel, bin_width=bin_width, n_bins_pad=n_bins_pad)
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((1, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((n_bins_pad, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_bins_pad, 1), jnp.float32),
        interpret=interpret,
    )(col_p)
    return out[:n_bins, 0]
