"""Pure-jnp oracles for every kernel (tested with assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["scan_agg_ref", "scan_agg_batched_ref", "ecdf_hist_ref"]


def scan_agg_ref(
    keys: jax.Array,  # int32[K, N]
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[K]
    col_hi: jax.Array,  # int32[K]
    slab: jax.Array,  # int32[2]
) -> jax.Array:
    """float32[2] = (masked sum, matched count) over the slab."""
    K, N = keys.shape
    ridx = jnp.arange(N, dtype=jnp.int32)
    in_slab = (ridx >= slab[0]) & (ridx < slab[1])
    ok = jnp.all((keys >= col_lo[:, None]) & (keys < col_hi[:, None]), axis=0)
    mask = (ok & in_slab).astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(values.astype(jnp.float32) * mask), jnp.sum(mask)]
    )


def scan_agg_batched_ref(
    keys: jax.Array,  # int32[K, N]
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[Q, K]
    col_hi: jax.Array,  # int32[Q, K]
    slabs: jax.Array,  # int32[Q, 2]
) -> jax.Array:
    """float32[Q, 2]: per query, (masked sum, matched count) over its slab."""
    K, N = keys.shape
    ridx = jnp.arange(N, dtype=jnp.int32)
    in_slab = (ridx[None, :] >= slabs[:, 0:1]) & (ridx[None, :] < slabs[:, 1:2])  # (Q, N)
    ok = jnp.all(
        (keys[None, :, :] >= col_lo[:, :, None]) & (keys[None, :, :] < col_hi[:, :, None]),
        axis=1,
    )  # (Q, N)
    mask = (ok & in_slab).astype(jnp.float32)
    vals = values.astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(vals[None, :] * mask, axis=1), jnp.sum(mask, axis=1)], axis=1
    )


def ecdf_hist_ref(col: jax.Array, *, n_bins: int, bin_width: int) -> jax.Array:
    """float32[n_bins] counts of col // bin_width."""
    bins = col.astype(jnp.int32) // bin_width
    oh = jax.nn.one_hot(bins, n_bins, dtype=jnp.float32)  # out-of-range → all-zero row
    return jnp.sum(oh, axis=0)
