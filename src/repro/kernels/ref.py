"""Pure-jnp oracles for every kernel (tested with assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "scan_agg_ref",
    "scan_agg_batched_ref",
    "slab_locate_batched_ref",
    "scan_agg_locate_batched_ref",
    "select_compact_batched_ref",
    "merge_run_positions_ref",
    "ecdf_hist_ref",
    "block_sums_ref",
]


def scan_agg_ref(
    keys: jax.Array,  # int32[K, N]
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[K]
    col_hi: jax.Array,  # int32[K]
    slab: jax.Array,  # int32[2]
) -> jax.Array:
    """float32[2] = (masked sum, matched count) over the slab."""
    K, N = keys.shape
    ridx = jnp.arange(N, dtype=jnp.int32)
    in_slab = (ridx >= slab[0]) & (ridx < slab[1])
    ok = jnp.all((keys >= col_lo[:, None]) & (keys < col_hi[:, None]), axis=0)
    mask = (ok & in_slab).astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(values.astype(jnp.float32) * mask), jnp.sum(mask)]
    )


def scan_agg_batched_ref(
    keys: jax.Array,  # int32[K_ex, N] — key lanes (wide columns use two)
    values: jax.Array,  # float32[N] or float32[V, N] — value rows
    col_lo: jax.Array,  # int32[Q, K_ex] inclusive per-query/lane bounds
    col_hi: jax.Array,  # int32[Q, K_ex] exclusive per-query/lane bounds
    slabs: jax.Array,  # int32[Q, 2]
    value_sel: jax.Array | None = None,  # int32[Q] value-row selector
    col_parts: tuple[int, ...] | None = None,  # lanes per logical column
) -> jax.Array:
    """float32[Q, 2]: per query, (masked sum, matched count) over its slab.

    Oracle for the row-streaming batched kernel: multi-row value tiles
    with a per-query selector (mixed sum/count batches) and wide key
    columns split into (hi, lo) int32 lane pairs compared
    lexicographically (``col_parts`` gives each logical column's lane
    count). Defaults reproduce the PR 1 signature: one value row, all
    columns narrow.
    """
    K_ex, N = keys.shape
    Q = col_lo.shape[0]
    values = values.astype(jnp.float32)
    if values.ndim == 1:
        values = values[None, :]
    if value_sel is None:
        value_sel = jnp.zeros(Q, jnp.int32)
    if col_parts is None:
        col_parts = (1,) * K_ex
    ridx = jnp.arange(N, dtype=jnp.int32)
    ok = (ridx[None, :] >= slabs[:, 0:1]) & (ridx[None, :] < slabs[:, 1:2])  # (Q, N)
    lane = 0
    for parts in col_parts:
        if parts == 1:
            k = keys[lane][None, :]  # (1, N)
            ok &= (k >= col_lo[:, lane : lane + 1]) & (k < col_hi[:, lane : lane + 1])
        else:  # wide column: lexicographic [lo, hi) on the lane pair
            kh = keys[lane][None, :]
            kl = keys[lane + 1][None, :]
            bh, bl = col_lo[:, lane : lane + 1], col_lo[:, lane + 1 : lane + 2]
            ok &= (kh > bh) | ((kh == bh) & (kl >= bl))
            bh, bl = col_hi[:, lane : lane + 1], col_hi[:, lane + 1 : lane + 2]
            ok &= (kh < bh) | ((kh == bh) & (kl < bl))
        lane += parts
    mask = ok.astype(jnp.float32)
    vq = values[value_sel]  # (Q, N) — each query's value row
    return jnp.stack([jnp.sum(vq * mask, axis=1), jnp.sum(mask, axis=1)], axis=1)


def _lex_tuple_masks(keys, slab_lo, slab_hi, n_lanes):
    """(Q, N) ``key >= slab_lo`` and ``key <= slab_hi`` masks, tuple-
    lexicographic over the first ``n_lanes`` lanes (MSB lane first)."""
    ge = le = None
    for lane in reversed(range(n_lanes)):
        k = keys[lane][None, :]  # (1, N)
        bl = slab_lo[:, lane : lane + 1]
        bh = slab_hi[:, lane : lane + 1]
        ge = (k >= bl) if ge is None else (k > bl) | ((k == bl) & ge)
        le = (k <= bh) if le is None else (k < bh) | ((k == bh) & le)
    return ge, le


def _residual_mask(keys, col_lo, col_hi, col_parts, base):
    """(Q, N) residual predicate: per logical column, value in [lo, hi)
    (wide columns compared lexicographically over their lane pair)."""
    ok = base
    lane = 0
    for parts in col_parts:
        if parts == 1:
            k = keys[lane][None, :]
            ok &= (k >= col_lo[:, lane : lane + 1]) & (k < col_hi[:, lane : lane + 1])
        else:
            kh = keys[lane][None, :]
            kl = keys[lane + 1][None, :]
            bh, bl = col_lo[:, lane : lane + 1], col_lo[:, lane + 1 : lane + 2]
            ok &= (kh > bh) | ((kh == bh) & (kl >= bl))
            bh, bl = col_hi[:, lane : lane + 1], col_hi[:, lane + 1 : lane + 2]
            ok &= (kh < bh) | ((kh == bh) & (kl < bl))
        lane += parts
    return ok


def _window_mask(limits, N):
    ridx = jnp.arange(N, dtype=jnp.int32)
    return (ridx[None, :] >= limits[:, 0:1]) & (ridx[None, :] < limits[:, 1:2])


def slab_locate_batched_ref(
    keys: jax.Array,  # int32[K_ex, N] — key lanes
    slab_lo: jax.Array,  # int32[Q, K_ex] lower slab key (inclusive)
    slab_hi: jax.Array,  # int32[Q, K_ex] upper slab key (INCLUSIVE)
    limits: jax.Array,  # int32[Q, 2] row window
    n_lanes: int | None = None,
) -> jax.Array:
    """int32[Q, 2] searchsorted ranks in rank (count) form: lane 0 is
    the number of window rows strictly below the lower slab key, lane 1
    the number at-or-below the upper slab key."""
    K_ex, N = keys.shape
    if n_lanes is None:
        n_lanes = K_ex
    valid = _window_mask(limits, N)
    ge, le = _lex_tuple_masks(keys, slab_lo, slab_hi, n_lanes)
    lo_idx = jnp.sum((valid & ~ge).astype(jnp.int32), axis=1)
    hi_idx = jnp.sum((valid & le).astype(jnp.int32), axis=1)
    return jnp.stack([lo_idx, hi_idx], axis=1)


def scan_agg_locate_batched_ref(
    keys: jax.Array,  # int32[K_ex, N]
    values: jax.Array,  # float32[N] or float32[V, N]
    res_lo: jax.Array,  # int32[Q, K_ex] residual bounds (inclusive)
    res_hi: jax.Array,  # int32[Q, K_ex] residual bounds (EXCLUSIVE)
    slab_lo: jax.Array,  # int32[Q, K_ex] slab key (inclusive)
    slab_hi: jax.Array,  # int32[Q, K_ex] slab key (INCLUSIVE)
    limits: jax.Array,  # int32[Q, 2] row window
    value_sel: jax.Array | None = None,
    col_parts: tuple[int, ...] | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Oracle for the fused locate+scan kernel: ``(sum f32[Q], matched
    i32[Q], slab_rows i32[Q])``."""
    K_ex, N = keys.shape
    Q = res_lo.shape[0]
    values = values.astype(jnp.float32)
    if values.ndim == 1:
        values = values[None, :]
    if value_sel is None:
        value_sel = jnp.zeros(Q, jnp.int32)
    if col_parts is None:
        col_parts = (1,) * K_ex
    valid = _window_mask(limits, N)
    ge, le = _lex_tuple_masks(keys, slab_lo, slab_hi, sum(col_parts))
    slab_ok = valid & ge & le
    matched = _residual_mask(keys, res_lo, res_hi, col_parts, valid)
    vq = values[value_sel]  # (Q, N)
    return (
        jnp.sum(vq * matched.astype(jnp.float32), axis=1),
        jnp.sum(matched.astype(jnp.int32), axis=1),
        jnp.sum(slab_ok.astype(jnp.int32), axis=1),
    )


def select_compact_batched_ref(
    keys: jax.Array,  # int32[K_ex, N]
    res_lo: jax.Array,  # int32[Q, K_ex]
    res_hi: jax.Array,  # int32[Q, K_ex]
    limits: jax.Array,  # int32[Q, 2]
    *,
    col_parts: tuple[int, ...] | None = None,
    out_width: int = 128,
) -> jax.Array:
    """Oracle for the select compaction kernel: int32[Q, out_width] with
    each query's matched row indices compacted to the front."""
    K_ex, N = keys.shape
    Q = res_lo.shape[0]
    if col_parts is None:
        col_parts = (1,) * K_ex
    matched = _residual_mask(keys, res_lo, res_hi, col_parts, _window_mask(limits, N))
    m = matched.astype(jnp.int32)
    pos = jnp.minimum(jnp.cumsum(m, axis=1) - m, out_width - 1)
    ridx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[None, :], m.shape)
    qidx = jnp.broadcast_to(jnp.arange(Q, dtype=jnp.int32)[:, None], m.shape)
    out = jnp.zeros((Q, out_width), jnp.int32)
    return out.at[qidx, pos].add(jnp.where(matched, ridx, 0))


def merge_run_positions_ref(
    keys,  # int32[K_ex(+pad), N(+pad)] — key lanes, device row order
    run_starts,  # run start offsets (run 0 = base at 0)
    n_rows: int,
    *,
    n_lanes: int,
) -> np.ndarray:
    """Oracle for the k-way merge kernel: int64[n_rows] merged position
    of each device row, ascending by (key tuple, run index DESCENDING,
    within-run position). Later runs precede equal keys of earlier runs
    — the host ``merge_run`` order, where a new row lands before equal
    existing rows. Computed independently of the kernel math via one
    ``np.lexsort`` + inverse permutation."""
    k = np.asarray(keys)[:n_lanes, :n_rows]
    starts = np.asarray(tuple(run_starts) + (n_rows,), dtype=np.int64)
    run_id = np.searchsorted(starts, np.arange(n_rows), side="right") - 1
    local = np.arange(n_rows, dtype=np.int64) - starts[run_id]
    # np.lexsort sorts by the LAST key first: MSB lane is primary, then
    # the remaining lanes, then -run_id (later runs first), then local
    order = np.lexsort(
        (local, -run_id) + tuple(k[lane] for lane in reversed(range(n_lanes)))
    )
    pos = np.empty(n_rows, np.int64)
    pos[order] = np.arange(n_rows, dtype=np.int64)
    return pos


def block_sums_ref(values: jax.Array, *, block_n: int) -> jax.Array:
    """Oracle for the per-block partial-sum kernel: float32[V, B] with
    column ``b`` the sum of rows ``[b * block_n, (b + 1) * block_n)``
    of each value row (rows past N are zero pads)."""
    values = jnp.asarray(values, jnp.float32)
    V, N = values.shape
    n_pad = -(-max(N, 1) // block_n) * block_n
    v = jnp.pad(values, ((0, 0), (0, n_pad - N)))
    return jnp.sum(v.reshape(V, n_pad // block_n, block_n), axis=2)


def ecdf_hist_ref(col: jax.Array, *, n_bins: int, bin_width: int) -> jax.Array:
    """float32[n_bins] counts of col // bin_width."""
    bins = col.astype(jnp.int32) // bin_width
    oh = jax.nn.one_hot(bins, n_bins, dtype=jnp.float32)  # out-of-range → all-zero row
    return jnp.sum(oh, axis=0)
