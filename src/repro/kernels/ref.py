"""Pure-jnp oracles for every kernel (tested with assert_allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["scan_agg_ref", "scan_agg_batched_ref", "ecdf_hist_ref"]


def scan_agg_ref(
    keys: jax.Array,  # int32[K, N]
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[K]
    col_hi: jax.Array,  # int32[K]
    slab: jax.Array,  # int32[2]
) -> jax.Array:
    """float32[2] = (masked sum, matched count) over the slab."""
    K, N = keys.shape
    ridx = jnp.arange(N, dtype=jnp.int32)
    in_slab = (ridx >= slab[0]) & (ridx < slab[1])
    ok = jnp.all((keys >= col_lo[:, None]) & (keys < col_hi[:, None]), axis=0)
    mask = (ok & in_slab).astype(jnp.float32)
    return jnp.stack(
        [jnp.sum(values.astype(jnp.float32) * mask), jnp.sum(mask)]
    )


def scan_agg_batched_ref(
    keys: jax.Array,  # int32[K_ex, N] — key lanes (wide columns use two)
    values: jax.Array,  # float32[N] or float32[V, N] — value rows
    col_lo: jax.Array,  # int32[Q, K_ex] inclusive per-query/lane bounds
    col_hi: jax.Array,  # int32[Q, K_ex] exclusive per-query/lane bounds
    slabs: jax.Array,  # int32[Q, 2]
    value_sel: jax.Array | None = None,  # int32[Q] value-row selector
    col_parts: tuple[int, ...] | None = None,  # lanes per logical column
) -> jax.Array:
    """float32[Q, 2]: per query, (masked sum, matched count) over its slab.

    Oracle for the row-streaming batched kernel: multi-row value tiles
    with a per-query selector (mixed sum/count batches) and wide key
    columns split into (hi, lo) int32 lane pairs compared
    lexicographically (``col_parts`` gives each logical column's lane
    count). Defaults reproduce the PR 1 signature: one value row, all
    columns narrow.
    """
    K_ex, N = keys.shape
    Q = col_lo.shape[0]
    values = values.astype(jnp.float32)
    if values.ndim == 1:
        values = values[None, :]
    if value_sel is None:
        value_sel = jnp.zeros(Q, jnp.int32)
    if col_parts is None:
        col_parts = (1,) * K_ex
    ridx = jnp.arange(N, dtype=jnp.int32)
    ok = (ridx[None, :] >= slabs[:, 0:1]) & (ridx[None, :] < slabs[:, 1:2])  # (Q, N)
    lane = 0
    for parts in col_parts:
        if parts == 1:
            k = keys[lane][None, :]  # (1, N)
            ok &= (k >= col_lo[:, lane : lane + 1]) & (k < col_hi[:, lane : lane + 1])
        else:  # wide column: lexicographic [lo, hi) on the lane pair
            kh = keys[lane][None, :]
            kl = keys[lane + 1][None, :]
            bh, bl = col_lo[:, lane : lane + 1], col_lo[:, lane + 1 : lane + 2]
            ok &= (kh > bh) | ((kh == bh) & (kl >= bl))
            bh, bl = col_hi[:, lane : lane + 1], col_hi[:, lane + 1 : lane + 2]
            ok &= (kh < bh) | ((kh == bh) & (kl < bl))
        lane += parts
    mask = ok.astype(jnp.float32)
    vq = values[value_sel]  # (Q, N) — each query's value row
    return jnp.stack([jnp.sum(vq * mask, axis=1), jnp.sum(mask, axis=1)], axis=1)


def ecdf_hist_ref(col: jax.Array, *, n_bins: int, bin_width: int) -> jax.Array:
    """float32[n_bins] counts of col // bin_width."""
    bins = col.astype(jnp.int32) // bin_width
    oh = jax.nn.one_hot(bins, n_bins, dtype=jnp.float32)  # out-of-range → all-zero row
    return jnp.sum(oh, axis=0)
