"""Public jit'd wrappers around the Pallas kernels.

``scan_agg`` executes a located slab scan (the engine's read path on
device); ``ecdf_hist`` refreshes Cost-Evaluator statistics. Both take the
same arguments as their ``ref.py`` oracles and dispatch to Pallas
(interpret-mode on CPU, compiled on TPU).

``table_execute_device_many`` is the batched read fast path: one *fused
locate+scan* launch (``slab_locate`` module) answers a whole sum/count/
select query group against a replica's device-resident columns — slab
location happens inside the scan predicate (no host searchsorted, no
host sync between locate and scan), counts accumulate in int32 lanes
(exact to 2**31 rows), and "select" queries get their matched row
indices from a second prefix-sum compaction launch sized by the first's
counts. ``table_slab_locate_many`` exposes the standalone vectorized
binary search behind ``SortedTable.slab_many``; ``device_state_append``
extends a resident table's arrays with a merged write run in place of a
full re-upload. Key columns up to 60 bits are packed into two int32
lanes; wider columns raise a precise error naming the column.

``table_scan_device_many`` (PR 2) remains as the slab-mask row-streaming
launch over host-located slabs — the benchmark baseline the fused path
is measured against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .ecdf_hist import ecdf_hist_pallas
from .merge_runs import merge_rank_batched, merge_run_positions
from .scan_agg import (
    WIDE_LANE_BITS,
    scan_agg_batched_pallas,
    scan_agg_batched_qgrid_pallas,
    scan_agg_pallas,
)
from .slab_locate import (
    residual_membership_batched,
    scan_agg_locate_batched,
    select_compact_batched,
    slab_locate_batched,
)

__all__ = [
    "scan_agg",
    "scan_agg_batched",
    "scan_agg_locate_batched",
    "slab_locate_batched",
    "select_compact_batched",
    "ecdf_hist",
    "scan_agg_ref",
    "scan_agg_batched_ref",
    "scan_agg_locate_batched_ref",
    "slab_locate_batched_ref",
    "select_compact_batched_ref",
    "merge_rank_batched",
    "merge_run_positions",
    "merge_run_positions_ref",
    "ecdf_hist_ref",
    "device_key_plan",
    "build_device_state",
    "device_state_append",
    "merge_device_runs",
    "table_scan_device",
    "table_scan_device_many",
    "table_execute_device_many",
    "table_slab_locate_many",
]

scan_agg_ref = ref.scan_agg_ref
scan_agg_batched_ref = ref.scan_agg_batched_ref
scan_agg_locate_batched_ref = ref.scan_agg_locate_batched_ref
slab_locate_batched_ref = ref.slab_locate_batched_ref
select_compact_batched_ref = ref.select_compact_batched_ref
merge_run_positions_ref = ref.merge_run_positions_ref
ecdf_hist_ref = ref.ecdf_hist_ref

# Keys and filter bounds live in int32 lanes on device; one lane holds a
# ≤30-bit column (its exclusive global bound 2**bits must still fit), a
# lane *pair* holds up to 60 bits split as (value >> 30, value & mask).
MAX_DEVICE_COL_BITS = 2 * WIDE_LANE_BITS
_LANE_MASK = (1 << WIDE_LANE_BITS) - 1


def scan_agg(keys, values, col_lo, col_hi, slab, *, block_n: int = 2048, use_pallas: bool = True):
    """(sum, count) over the slab with residual predicates. Arrays may be
    numpy or jax; returns a float32[2] jax array."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slab = jnp.asarray(slab, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_ref(keys, values, col_lo, col_hi, slab)
    return scan_agg_pallas(keys, values, col_lo, col_hi, slab, block_n=block_n)


def ecdf_hist(col, *, n_bins: int, bin_width: int, block_n: int = 512, use_pallas: bool = True):
    col = jnp.asarray(col, jnp.int32)
    if not use_pallas or n_bins > 4096:
        return ref.ecdf_hist_ref(col, n_bins=n_bins, bin_width=bin_width)
    return ecdf_hist_pallas(col, n_bins=n_bins, bin_width=bin_width, block_n=block_n)


def scan_agg_batched(
    keys,
    values,
    col_lo,
    col_hi,
    slabs,
    value_sel=None,
    *,
    col_parts: tuple[int, ...] | None = None,
    block_n: int = 2048,
    use_pallas: bool = True,
    grid: str = "rows_outer",
):
    """Per-query (sum, count) for a query batch sharing one replica's
    columns. Arrays may be numpy or jax; returns float32[Q, 2].

    ``grid="rows_outer"`` (default) is the row-streaming launch: key and
    value tiles are fetched from HBM once per batch, per-query
    accumulators are revisited at every row step. ``values`` may be a
    (V, N) tile with ``value_sel`` routing each query to its row, and
    ``col_parts`` marks wide (two-lane) key columns.

    ``grid="queries_outer"`` dispatches the legacy PR 1 grid (queries ×
    row blocks, row axis fastest; key traffic scales with Q). It only
    supports a single value row and narrow columns — kept as the
    benchmark baseline for the perf trajectory.
    """
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slabs = jnp.asarray(slabs, jnp.int32)
    if grid == "queries_outer":
        if values.ndim != 1:
            raise ValueError("queries_outer grid supports a single value row")
        if value_sel is not None or (col_parts and any(p != 1 for p in col_parts)):
            raise ValueError(
                "queries_outer grid supports neither value selectors nor wide columns"
            )
        if not use_pallas:
            return ref.scan_agg_batched_ref(keys, values, col_lo, col_hi, slabs)
        return scan_agg_batched_qgrid_pallas(
            keys, values, col_lo, col_hi, slabs, block_n=block_n
        )
    if grid != "rows_outer":
        raise ValueError(f"unknown grid {grid!r}")
    if value_sel is not None:
        value_sel = jnp.asarray(value_sel, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_batched_ref(
            keys, values, col_lo, col_hi, slabs, value_sel=value_sel, col_parts=col_parts
        )
    return scan_agg_batched_pallas(
        keys, values, col_lo, col_hi, slabs, value_sel,
        col_parts=col_parts, block_n=block_n,
    )


# -- device-resident table scans ---------------------------------------------


def device_key_plan(table) -> tuple[int, ...]:
    """Lane count (1 or 2) per layout column for the device scan path.

    Raises a precise ``ValueError`` naming the offending column when a
    key column exceeds the two-lane budget (> 60 bits) — wider schemas
    are served by the numpy engine.
    """
    parts = []
    for c in table.layout:
        bits = table.schema.bits[c]
        if bits <= WIDE_LANE_BITS:
            parts.append(1)
        elif bits <= MAX_DEVICE_COL_BITS:
            parts.append(2)
        else:
            raise ValueError(
                f"device scan path: key column {c!r} needs {bits} bits, more "
                f"than the {MAX_DEVICE_COL_BITS}-bit two-lane budget "
                f"(2 × {WIDE_LANE_BITS}-bit int32 lanes); use "
                "SortedTable.execute/execute_many (numpy) for this schema"
            )
    return tuple(parts)


def _expand_key_cols(
    key_cols, layout, col_parts: tuple[int, ...], n: int
) -> np.ndarray:
    """int32[K_ex, n] key lanes in layout order: narrow columns as one
    lane, wide columns as (value >> 30, value & mask) pairs whose
    lexicographic order equals the numeric order."""
    rows: list[np.ndarray] = []
    for c, parts in zip(layout, col_parts):
        v = np.asarray(key_cols[c], np.int64)
        if parts == 1:
            rows.append(v.astype(np.int32))
        else:
            rows.append((v >> WIDE_LANE_BITS).astype(np.int32))
            rows.append((v & _LANE_MASK).astype(np.int32))
    return np.stack(rows) if rows else np.zeros((0, n), np.int32)


def _expand_key_planes(table, col_parts: tuple[int, ...]) -> np.ndarray:
    return _expand_key_cols(table.key_cols, table.layout, col_parts, len(table))


def _expand_bounds(
    bounds: np.ndarray, col_parts: tuple[int, ...]
) -> tuple[np.ndarray, np.ndarray]:
    """Split int64[Q, K, 2] per-column bounds into int32[Q, K_ex] lane
    bounds. An exclusive upper bound splits the same way — comparing the
    lane pair lexicographically against (hi >> 30, hi & mask) is exactly
    ``value < hi``."""
    los: list[np.ndarray] = []
    his: list[np.ndarray] = []
    for j, parts in enumerate(col_parts):
        lo, hi = bounds[:, j, 0], bounds[:, j, 1]
        if parts == 1:
            los.append(lo.astype(np.int32))
            his.append(hi.astype(np.int32))
        else:
            los.append((lo >> WIDE_LANE_BITS).astype(np.int32))
            los.append((lo & _LANE_MASK).astype(np.int32))
            his.append((hi >> WIDE_LANE_BITS).astype(np.int32))
            his.append((hi & _LANE_MASK).astype(np.int32))
    return np.stack(los, axis=1), np.stack(his, axis=1)


# Row-axis padding granularity of the resident arrays AND the device
# read path's kernel block size: jit-time pads are no-ops for every
# block_n that divides it, so the per-batch work is O(Q), not O(N).
# 8192 rows × 8 int32 lanes ≈ 256 KB per key tile — comfortably VMEM-
# sized with double buffering, and measured ~2-3× faster than 2048 in
# interpret mode (fewer grid steps amortize the per-step overhead).
DEVICE_BLOCK_N = 8192


# The fused kernel accumulates matched/slab counts in int32 lanes and
# addresses rows with int32 indices, so the device path is exact up to
# the int32 range (the old float32 count lane capped it at 2**24).
MAX_DEVICE_ROWS = (1 << 31) - DEVICE_BLOCK_N

# Count exactness bound of the LEGACY float32 count lane still used by
# table_scan_device_many (rowgrid/qgrid scan_agg kernels); the fused
# path is unaffected. Guarded at that entry point, not at placement.
FLOAT32_EXACT_ROWS = 1 << 24


# The select compaction kernel's (Q_pad, width) int32 output block
# stays VMEM-resident across every grid step, so it must be bounded in
# BOTH dimensions: per query (width, pow-2 of the batch's largest match
# count) and as a whole. Queries matching more than MAX_WIDTH rows take
# the membership-mask fallback (device mask + per-query sized
# flatnonzero, so only the indices reach host — still zero host
# searchsorted and zero residual scans); the rest launch in chunks of
# at most MAX_ELEMS // width queries (~4 MB of output block per
# launch, comfortably VMEM-sized next to the key tiles).
SELECT_COMPACT_MAX_WIDTH = 1 << 16
SELECT_COMPACT_MAX_ELEMS = 1 << 20


def _check_device_rows(n: int) -> None:
    if n >= MAX_DEVICE_ROWS:
        raise ValueError(
            f"device scan path: {n} rows exceeds the int32 row-index/"
            f"count budget ({MAX_DEVICE_ROWS}); use the numpy engine "
            "for tables this large"
        )


def build_device_state(table, value_cols=None) -> dict:
    """Materialize a table's device-resident arrays: expanded int32 key
    lanes and a float32 value tile (one row per value column + a ones
    row for counts), both pre-padded to the kernel's sublane/block
    granularity so repeated batches ship only O(Q) bounds/selector
    data — no per-call stack or pad of the N-sized columns.
    ``SortedTable.place_on_device`` stores the result; host-only tables
    build it ephemerally per call, passing ``value_cols`` to materialize
    only the batch's columns.

    A fresh build holds one sorted run (``n_runs == 1``, device row
    order == host row order, ``row_map is None``);
    :func:`device_state_append` extends it with merged write runs."""
    col_parts = device_key_plan(table)
    n = len(table)
    _check_device_rows(n)
    n_pad = -(-max(n, 1) // DEVICE_BLOCK_N) * DEVICE_BLOCK_N
    keys = _expand_key_planes(table, col_parts)
    k_ex = keys.shape[0]
    k_pad = max(8, -(-k_ex // 8) * 8)
    keys_p = np.zeros((k_pad, n_pad), np.int32)
    keys_p[:k_ex, :n] = keys
    if value_cols is None:
        vnames = list(table.value_cols)
    else:
        wanted = set(value_cols)
        vnames = [c for c in table.value_cols if c in wanted]
    n_value_rows = len(vnames) + 1  # + ones row
    v_pad = max(8, -(-n_value_rows // 8) * 8)
    tile = np.zeros((v_pad, n_pad), np.float32)
    for i, c in enumerate(vnames):
        tile[i, :n] = np.asarray(table.value_cols[c], np.float32)
    tile[len(vnames), :n] = 1.0  # padded rows stay 0 and are window-masked
    return {
        "col_parts": col_parts,
        "keys": jnp.asarray(keys_p),
        "values_tile": jnp.asarray(tile),
        "value_rows": {c: i for i, c in enumerate(vnames)},
        "ones_row": len(vnames),
        "n_value_rows": n_value_rows,
        "n_rows": n,
        "n_runs": 1,
        # start offset of each resident run (run 0 = the sorted base);
        # device_state_append extends it, merge_device_runs resets it
        "run_starts": (0,),
        # device row -> host row translation for "select"; None == identity
        "row_map": None,
    }


def device_state_append(state, table, run_key_cols, run_value_cols, positions) -> dict:
    """Incrementally extend a device-resident column cache with a merged
    write run (LSM append): the run's rows land right after the existing
    rows in the resident arrays — two O(run) device updates, no
    re-upload of the N-sized columns. Device row order then differs from
    the host (fully merged) order; only "select" observes row order, and
    ``row_map`` translates emitted device row indices back to host row
    indices. Maintaining ``row_map`` is the host cost floor: an O(N)
    arange + searchsorted per append (plus an O(N) gather once runs
    chain), and ``n_runs`` grows until ``place_on_device(rebuild=True)``
    collapses the runs — see the ROADMAP "compaction policy" open item
    for the automatic threshold that would bound both. Aggregate and slab-row counts are order-
    independent (the fused kernel decides slab membership by key), so
    they stay exact across appends.

    ``table`` is the *merged* table (for layout/schema), ``run_key_cols``
    / ``run_value_cols`` the run already sorted in table layout order,
    and ``positions`` the ``np.searchsorted`` merge positions of the run
    into the previous packed column. Returns a new state dict; the input
    state (still referenced by the pre-merge table) is untouched."""
    col_parts = state["col_parts"]
    positions = np.asarray(positions, np.int64)
    m = int(positions.shape[0])
    if m == 0:
        # an empty run must not cost a run: growing n_runs/row_map here
        # would permanently kick the table off the single-run fast paths
        # (device slab_many, the no-gather select) for no rows at all
        return dict(state)
    n_old = state["n_rows"]
    n_new = n_old + m
    _check_device_rows(n_new)
    keys = state["keys"]
    tile = state["values_tile"]
    cap = keys.shape[1]
    if n_new > cap:
        new_cap = -(-n_new // DEVICE_BLOCK_N) * DEVICE_BLOCK_N
        keys = jnp.pad(keys, ((0, 0), (0, new_cap - cap)))
        tile = jnp.pad(tile, ((0, 0), (0, new_cap - cap)))
    run_lanes = _expand_key_cols(run_key_cols, table.layout, col_parts, m)
    k_block = np.zeros((keys.shape[0], m), np.int32)
    k_block[: run_lanes.shape[0]] = run_lanes
    v_block = np.zeros((tile.shape[0], m), np.float32)
    for c, i in state["value_rows"].items():
        v_block[i] = np.asarray(run_value_cols[c], np.float32)
    v_block[state["ones_row"]] = 1.0
    keys = jax.lax.dynamic_update_slice(keys, jnp.asarray(k_block), (0, n_old))
    tile = jax.lax.dynamic_update_slice(tile, jnp.asarray(v_block), (0, n_old))
    new = dict(state)
    if n_old == 0:
        # appending to an empty base (a freshly-split partition that
        # owns no CREATE-time rows): the sorted run IS the base run —
        # device row order equals host order, so the table keeps the
        # single-run fast paths instead of paying a phantom run
        new.update(
            keys=keys, values_tile=tile, n_rows=n_new,
            n_runs=1, run_starts=(0,), row_map=None,
        )
        return new
    # host index of old row i after the merge: i + |{j : positions[j] <= i}|;
    # run row j (sorted order) lands at positions[j] + j (np.insert layout)
    old_to_merged = np.arange(n_old, dtype=np.int64) + np.searchsorted(
        positions, np.arange(n_old, dtype=np.int64), side="right"
    )
    rm = state["row_map"]
    base = old_to_merged if rm is None else old_to_merged[rm]
    row_map = np.concatenate([base, positions + np.arange(m, dtype=np.int64)])
    new.update(
        keys=keys,
        values_tile=tile,
        n_rows=n_new,
        n_runs=state.get("n_runs", 1) + 1,
        run_starts=tuple(state.get("run_starts", (0,))) + (n_old,),
        row_map=row_map,
    )
    return new


def merge_device_runs(
    state, *, block_n: int = DEVICE_BLOCK_N, use_pallas: bool = True
) -> dict:
    """Collapse a state's appended runs into one sorted run on device
    (automatic compaction's storage move): the k-way merge-path kernel
    (``merge_run_positions``) computes every row's merged position, and
    one scatter per resident array reorders keys and value tile — the
    N-sized columns never round-trip to host. The merge tie rule equals
    the host ``merge_run`` order, so afterwards device row order ==
    host row order: ``row_map`` collapses to identity (``None``),
    ``n_runs`` to 1, and the single-run fast paths (device ``slab_many``,
    the no-gather select) apply again. Returns a new state dict; the
    input state is untouched."""
    if state.get("n_runs", 1) <= 1:
        return dict(state)
    n = state["n_rows"]
    pos = jnp.asarray(
        merge_run_positions(
            state["keys"], state["run_starts"], n,
            n_lanes=sum(state["col_parts"]), block_n=block_n,
            use_pallas=use_pallas,
        )
    )
    keys = state["keys"]
    tile = state["values_tile"]
    merged_keys = jnp.zeros_like(keys).at[:, pos].set(keys[:, :n])
    merged_tile = jnp.zeros_like(tile).at[:, pos].set(tile[:, :n])
    new = dict(state)
    new.update(
        keys=merged_keys,
        values_tile=merged_tile,
        n_runs=1,
        run_starts=(0,),
        row_map=None,
    )
    return new


def table_scan_device(table, query, *, use_pallas: bool = True) -> tuple[float, float]:
    """Device-side execution of ``SortedTable.execute`` (sum/count aggs):
    slab via packed-key searchsorted, then the batched scan kernel at
    Q = 1. Used by the serving/data layers when tables are resident as
    jax arrays."""
    (out,) = table_scan_device_many(table, [query], use_pallas=use_pallas)
    return out


def table_scan_device_many(
    table,
    queries,
    *,
    slabs: np.ndarray | None = None,
    block_n: int = 2048,
    use_pallas: bool = True,
    grid: str = "rows_outer",
) -> list[tuple[float, float]]:
    """Batched ``table_scan_device``: all queries against one replica in
    a single row-streaming launch. Returns ``[(value, count)]`` per query
    in batch order.

    Heterogeneous groups ride together: "sum" queries over any mix of
    value columns and "count" queries share the launch — each distinct
    value column becomes one row of the value tile, counts select a ones
    row, and a per-query selector routes the aggregation. ``slabs``
    accepts precomputed ``slab_many`` output so callers that already
    located the slabs (``SortedTable.execute_many``) skip the second
    searchsorted. ``grid="queries_outer"`` dispatches the legacy PR 1
    grid (uniform-agg, narrow-key batches only) for benchmarking.
    """
    queries = list(queries)
    if not queries:
        return []
    # this legacy entry point accumulates counts in a float32 lane,
    # exact only to 2**24 — the fused int32 path has no such cap
    if table.n_rows > FLOAT32_EXACT_ROWS:
        raise ValueError(
            f"table has {table.n_rows} rows but the float32 count lane of "
            f"table_scan_device_many is exact only to {FLOAT32_EXACT_ROWS} "
            "matches; use table_execute_device_many (int32 counts)"
        )
    for q in queries:
        if q.agg not in ("sum", "count"):
            raise ValueError(f"device path supports sum/count aggs, got {q.agg!r}")
        if q.agg == "sum" and q.value_col is None:
            raise ValueError("sum aggregation requires value_col")
    state = getattr(table, "_device", None)
    if state is None:  # host table: materialize only this batch's columns
        state = build_device_state(
            table, value_cols={q.value_col for q in queries if q.agg == "sum"}
        )
    elif state.get("n_runs", 1) > 1:
        raise ValueError(
            "device state holds appended write runs (device row order is "
            "not sorted); row-slab scans need a single sorted run — use "
            "table_execute_device_many or place_on_device(rebuild=True)"
        )
    col_parts: tuple[int, ...] = state["col_parts"]
    if slabs is None:
        slabs = table.slab_many(queries)

    # the resident value tile already holds every value column + the
    # ones row; the per-query selector routes each aggregation to its row
    value_rows: dict[str, int] = state["value_rows"]
    values = state["values_tile"]
    sel = np.array(
        [
            value_rows[q.value_col] if q.agg == "sum" else state["ones_row"]
            for q in queries
        ],
        np.int32,
    )

    names = list(table.layout)
    bounds = np.array(
        [[q.filter_bounds(table.schema, c) for c in names] for q in queries],
        np.int64,
    )  # (Q, K, 2) — lo inclusive, hi exclusive
    lo, hi = _expand_bounds(bounds, col_parts)
    slabs32 = np.asarray(slabs, np.int64).astype(np.int32)

    if grid == "queries_outer":
        if len(set(sel)) > 1 or any(p != 1 for p in col_parts):
            raise ValueError(
                "queries_outer grid requires a uniform-agg, narrow-key batch"
            )
    elif grid != "rows_outer":
        raise ValueError(f"unknown grid {grid!r}")
    if not use_pallas:  # one oracle covers both grids
        out = np.asarray(
            ref.scan_agg_batched_ref(
                state["keys"], jnp.asarray(values), jnp.asarray(lo, jnp.int32),
                jnp.asarray(hi, jnp.int32), jnp.asarray(slabs32),
                jnp.asarray(sel), col_parts=col_parts,
            )
        )
    elif grid == "queries_outer":
        out = np.asarray(
            scan_agg_batched_qgrid_pallas(
                state["keys"], values[int(sel[0])], lo, hi, slabs32,
                block_n=block_n,
            )
        )
    else:
        out = np.asarray(
            scan_agg_batched_pallas(
                state["keys"], values, lo, hi, slabs32, sel,
                col_parts=col_parts, block_n=block_n,
                n_vals=state["n_value_rows"],
            )
        )
    return [
        (float(s) if q.agg == "sum" else float(c), float(c))
        for q, (s, c) in zip(queries, out)
    ]


# -- fused device read path ---------------------------------------------------


def _device_query_bounds(table, queries, col_parts, n_rows):
    """Host-side O(Q·K) operand prep for the device read kernels: the
    residual per-lane bounds (exclusive hi), the slab key lane bounds
    (inclusive hi, from the same walk ``slab_bounds_many`` packs), and
    the per-query [start, stop) row windows. Empty queries are encoded
    as an impossible slab key (hi lanes = −1) and a (0, 0) window.
    Raises exactly where the host walk raises (out-of-domain bounds on a
    nonempty query); performs zero searchsorted calls."""
    from repro.core.table import _slab_col_bounds

    names = list(table.layout)
    # the slab walk first: it owns bound validation, so the device path
    # raises (or not) exactly like the scalar host walk
    los, his, nonempty = _slab_col_bounds(queries, names, table.schema)
    slab_lo, slab_hi = _expand_bounds(np.stack([los, his], axis=2), col_parts)
    slab_lo[~nonempty] = 0
    slab_hi[~nonempty] = -1
    bounds = np.array(
        [[q.filter_bounds(table.schema, c) for c in names] for q in queries],
        np.int64,
    )  # (Q, K, 2) — lo inclusive, hi exclusive
    res_lo, res_hi = _expand_bounds(bounds, col_parts)
    limits = np.zeros((len(queries), 2), np.int64)
    limits[:, 1] = np.where(nonempty, n_rows, 0)
    return res_lo, res_hi, slab_lo, slab_hi, limits


def table_slab_locate_many(
    table, queries, *, block_n: int = DEVICE_BLOCK_N, use_pallas: bool = True
) -> np.ndarray:
    """Device-side ``SortedTable.slab_many``: int64[Q, 2] row slabs from
    the vectorized binary-search kernel (:func:`slab_locate_batched`)
    over the resident key lanes. Requires the resident arrays to hold a
    single sorted run — with appended write runs device row order is not
    the table order and ranks would be meaningless."""
    queries = list(queries)
    state = getattr(table, "_device", None)
    if state is None:
        raise ValueError("table_slab_locate_many needs a device-resident table")
    if state.get("n_runs", 1) > 1:
        raise ValueError(
            "device state holds appended write runs; slab ranks need a "
            "single sorted run — use place_on_device(rebuild=True)"
        )
    col_parts = state["col_parts"]
    _, _, slab_lo, slab_hi, limits = _device_query_bounds(
        table, queries, col_parts, state["n_rows"]
    )
    fn = slab_locate_batched if use_pallas else ref.slab_locate_batched_ref
    kw = {"block_n": block_n} if use_pallas else {}
    out = fn(
        state["keys"], jnp.asarray(slab_lo), jnp.asarray(slab_hi),
        jnp.asarray(limits, jnp.int32), n_lanes=sum(col_parts), **kw,
    )
    return np.asarray(out).astype(np.int64)


def table_execute_device_many(
    table, queries, *, block_n: int = DEVICE_BLOCK_N, use_pallas: bool = True,
    trace=None,
) -> list:
    """Serve a sum/count/select batch entirely from a table's resident
    device arrays: one fused locate+scan launch computes every query's
    aggregate, matched count and slab row count (``rows_scanned``), and
    — only when the batch contains selects with matches — one prefix-sum
    compaction launch emits the matched row indices (two-pass: the
    fused counts size its output). Returns ``list[ScanResult]`` in batch
    order, equal to the numpy engine's results (counts/rows exactly,
    sums to float32 accumulation).

    The only host↔device syncs are the result fetches; no host
    searchsorted and no numpy residual scan run at any batch
    composition. On append-structured states (after ``merge_insert`` on
    a resident table) ``row_map`` translates select indices back to
    host row order.

    ``trace`` (an open ``repro.obs.Span``, or None) wraps each device
    launch *wall* — launch plus the ``np.asarray`` result fetch, i.e.
    including the host sync — as ``kernel.scan_launch`` /
    ``kernel.select_compact`` child spans."""
    from repro.core.table import ScanResult

    queries = list(queries)
    if not queries:
        return []
    state = getattr(table, "_device", None)
    if state is None:
        raise ValueError("table_execute_device_many needs a device-resident table")
    value_rows: dict[str, int] = state["value_rows"]
    for q in queries:
        if q.agg not in ("sum", "count", "select"):
            raise ValueError(
                f"device path supports sum/count/select aggs, got {q.agg!r}"
            )
        if q.agg == "sum":
            if q.value_col is None:
                raise ValueError("sum aggregation requires value_col")
            if q.value_col not in value_rows:
                raise KeyError(q.value_col)
    col_parts = state["col_parts"]
    res_lo, res_hi, slab_lo, slab_hi, limits = _device_query_bounds(
        table, queries, col_parts, state["n_rows"]
    )
    sel = np.array(
        [
            value_rows[q.value_col] if q.agg == "sum" else state["ones_row"]
            for q in queries
        ],
        np.int32,
    )
    ks = (
        trace.child(
            "kernel.scan_launch", queries=len(queries),
            n_rows=int(state["n_rows"]), fused=bool(use_pallas),
        )
        if trace is not None
        else None
    )
    if use_pallas:
        sums, matched, slab_rows = scan_agg_locate_batched(
            state["keys"], state["values_tile"], res_lo, res_hi, slab_lo,
            slab_hi, limits, sel, col_parts=col_parts,
            n_vals=state["n_value_rows"], block_n=block_n,
        )
    else:
        sums, matched, slab_rows = ref.scan_agg_locate_batched_ref(
            state["keys"], state["values_tile"], jnp.asarray(res_lo),
            jnp.asarray(res_hi), jnp.asarray(slab_lo), jnp.asarray(slab_hi),
            jnp.asarray(limits, jnp.int32), jnp.asarray(sel),
            col_parts=col_parts,
        )
    sums = np.asarray(sums)
    matched = np.asarray(matched, np.int64)
    slab_rows = np.asarray(slab_rows, np.int64)
    if ks is not None:
        ks.end()

    sel_idx = [i for i, q in enumerate(queries) if q.agg == "select"]
    selected: dict[int, np.ndarray] = {}
    rm = state["row_map"]

    def _host_rows(dev_rows: np.ndarray) -> np.ndarray:
        rows = dev_rows.astype(np.int64)
        if rm is not None:
            # appended runs: translate device row order to host
            # (merged) order; numpy emits ascending indices
            rows = np.sort(rm[rows])
        return rows

    wide = [i for i in sel_idx if int(matched[i]) > SELECT_COMPACT_MAX_WIDTH]
    if wide:
        # too many matches for a VMEM-resident compaction output: build
        # the membership mask on device, pull back only the indices
        wmask = residual_membership_batched(
            state["keys"], res_lo[wide], res_hi[wide], limits[wide],
            col_parts=col_parts,
        )
        for j, i in enumerate(wide):
            rows = jnp.flatnonzero(wmask[j], size=int(matched[i]))
            selected[i] = _host_rows(np.asarray(rows))
        sel_idx = [i for i in sel_idx if int(matched[i]) <= SELECT_COMPACT_MAX_WIDTH]
    kc = (
        trace.child("kernel.select_compact", queries=len(sel_idx))
        if trace is not None and sel_idx
        else None
    )
    if sel_idx:
        mmax = int(matched[sel_idx].max())
        if mmax == 0:
            for i in sel_idx:
                selected[i] = np.empty(0, np.int64)
        else:
            width = 128
            while width < mmax:  # pow-2 lanes bucket the jit cache
                width *= 2
            # bound the whole output block, not just its width: chunk the
            # batch so Q_pad * width stays inside the element budget
            q_chunk = max(8, (SELECT_COMPACT_MAX_ELEMS // width) // 8 * 8)
            for s in range(0, len(sel_idx), q_chunk):
                chunk = sel_idx[s : s + q_chunk]
                if use_pallas:
                    idx = select_compact_batched(
                        state["keys"], res_lo[chunk], res_hi[chunk],
                        limits[chunk], col_parts=col_parts, out_width=width,
                        block_n=block_n,
                    )
                else:
                    idx = ref.select_compact_batched_ref(
                        state["keys"], jnp.asarray(res_lo[chunk]),
                        jnp.asarray(res_hi[chunk]),
                        jnp.asarray(limits[chunk], jnp.int32),
                        col_parts=col_parts, out_width=width,
                    )
                idx = np.asarray(idx)
                for j, i in enumerate(chunk):
                    selected[i] = _host_rows(idx[j, : int(matched[i])])
    if kc is not None:
        kc.end()

    out = []
    for i, q in enumerate(queries):
        value = float(sums[i]) if q.agg == "sum" else float(matched[i])
        out.append(
            ScanResult(value, int(slab_rows[i]), int(matched[i]), selected.get(i))
        )
    return out
