"""Public jit'd wrappers around the Pallas kernels.

``scan_agg`` executes a located slab scan (the engine's read path on
device); ``ecdf_hist`` refreshes Cost-Evaluator statistics. Both take the
same arguments as their ``ref.py`` oracles and dispatch to Pallas
(interpret-mode on CPU, compiled on TPU).

``table_scan_device_many`` is the batched read fast path: one
row-streaming launch answers a whole query group against a replica's
device-resident columns, mixing sum and count aggregations over any set
of value columns in the same batch (multi-row value tiles + a per-query
selector). Key columns up to 60 bits are packed into two int32 lanes;
wider columns raise a precise error naming the column.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import ref
from .ecdf_hist import ecdf_hist_pallas
from .scan_agg import (
    WIDE_LANE_BITS,
    scan_agg_batched_pallas,
    scan_agg_batched_qgrid_pallas,
    scan_agg_pallas,
)

__all__ = [
    "scan_agg",
    "scan_agg_batched",
    "ecdf_hist",
    "scan_agg_ref",
    "scan_agg_batched_ref",
    "ecdf_hist_ref",
    "device_key_plan",
    "build_device_state",
    "table_scan_device",
    "table_scan_device_many",
]

scan_agg_ref = ref.scan_agg_ref
scan_agg_batched_ref = ref.scan_agg_batched_ref
ecdf_hist_ref = ref.ecdf_hist_ref

# Keys and filter bounds live in int32 lanes on device; one lane holds a
# ≤30-bit column (its exclusive global bound 2**bits must still fit), a
# lane *pair* holds up to 60 bits split as (value >> 30, value & mask).
MAX_DEVICE_COL_BITS = 2 * WIDE_LANE_BITS
_LANE_MASK = (1 << WIDE_LANE_BITS) - 1


def scan_agg(keys, values, col_lo, col_hi, slab, *, block_n: int = 2048, use_pallas: bool = True):
    """(sum, count) over the slab with residual predicates. Arrays may be
    numpy or jax; returns a float32[2] jax array."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slab = jnp.asarray(slab, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_ref(keys, values, col_lo, col_hi, slab)
    return scan_agg_pallas(keys, values, col_lo, col_hi, slab, block_n=block_n)


def ecdf_hist(col, *, n_bins: int, bin_width: int, block_n: int = 512, use_pallas: bool = True):
    col = jnp.asarray(col, jnp.int32)
    if not use_pallas or n_bins > 4096:
        return ref.ecdf_hist_ref(col, n_bins=n_bins, bin_width=bin_width)
    return ecdf_hist_pallas(col, n_bins=n_bins, bin_width=bin_width, block_n=block_n)


def scan_agg_batched(
    keys,
    values,
    col_lo,
    col_hi,
    slabs,
    value_sel=None,
    *,
    col_parts: tuple[int, ...] | None = None,
    block_n: int = 2048,
    use_pallas: bool = True,
    grid: str = "rows_outer",
):
    """Per-query (sum, count) for a query batch sharing one replica's
    columns. Arrays may be numpy or jax; returns float32[Q, 2].

    ``grid="rows_outer"`` (default) is the row-streaming launch: key and
    value tiles are fetched from HBM once per batch, per-query
    accumulators are revisited at every row step. ``values`` may be a
    (V, N) tile with ``value_sel`` routing each query to its row, and
    ``col_parts`` marks wide (two-lane) key columns.

    ``grid="queries_outer"`` dispatches the legacy PR 1 grid (queries ×
    row blocks, row axis fastest; key traffic scales with Q). It only
    supports a single value row and narrow columns — kept as the
    benchmark baseline for the perf trajectory.
    """
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slabs = jnp.asarray(slabs, jnp.int32)
    if grid == "queries_outer":
        if values.ndim != 1:
            raise ValueError("queries_outer grid supports a single value row")
        if value_sel is not None or (col_parts and any(p != 1 for p in col_parts)):
            raise ValueError(
                "queries_outer grid supports neither value selectors nor wide columns"
            )
        if not use_pallas:
            return ref.scan_agg_batched_ref(keys, values, col_lo, col_hi, slabs)
        return scan_agg_batched_qgrid_pallas(
            keys, values, col_lo, col_hi, slabs, block_n=block_n
        )
    if grid != "rows_outer":
        raise ValueError(f"unknown grid {grid!r}")
    if value_sel is not None:
        value_sel = jnp.asarray(value_sel, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_batched_ref(
            keys, values, col_lo, col_hi, slabs, value_sel=value_sel, col_parts=col_parts
        )
    return scan_agg_batched_pallas(
        keys, values, col_lo, col_hi, slabs, value_sel,
        col_parts=col_parts, block_n=block_n,
    )


# -- device-resident table scans ---------------------------------------------


def device_key_plan(table) -> tuple[int, ...]:
    """Lane count (1 or 2) per layout column for the device scan path.

    Raises a precise ``ValueError`` naming the offending column when a
    key column exceeds the two-lane budget (> 60 bits) — wider schemas
    are served by the numpy engine.
    """
    parts = []
    for c in table.layout:
        bits = table.schema.bits[c]
        if bits <= WIDE_LANE_BITS:
            parts.append(1)
        elif bits <= MAX_DEVICE_COL_BITS:
            parts.append(2)
        else:
            raise ValueError(
                f"device scan path: key column {c!r} needs {bits} bits, more "
                f"than the {MAX_DEVICE_COL_BITS}-bit two-lane budget "
                f"(2 × {WIDE_LANE_BITS}-bit int32 lanes); use "
                "SortedTable.execute/execute_many (numpy) for this schema"
            )
    return tuple(parts)


def _expand_key_planes(table, col_parts: tuple[int, ...]) -> np.ndarray:
    """int32[K_ex, N] key lanes in layout order: narrow columns as one
    lane, wide columns as (value >> 30, value & mask) pairs whose
    lexicographic order equals the numeric order."""
    rows: list[np.ndarray] = []
    for c, parts in zip(table.layout, col_parts):
        v = np.asarray(table.key_cols[c], np.int64)
        if parts == 1:
            rows.append(v.astype(np.int32))
        else:
            rows.append((v >> WIDE_LANE_BITS).astype(np.int32))
            rows.append((v & _LANE_MASK).astype(np.int32))
    return np.stack(rows) if rows else np.zeros((0, len(table)), np.int32)


def _expand_bounds(
    bounds: np.ndarray, col_parts: tuple[int, ...]
) -> tuple[np.ndarray, np.ndarray]:
    """Split int64[Q, K, 2] per-column bounds into int32[Q, K_ex] lane
    bounds. An exclusive upper bound splits the same way — comparing the
    lane pair lexicographically against (hi >> 30, hi & mask) is exactly
    ``value < hi``."""
    los: list[np.ndarray] = []
    his: list[np.ndarray] = []
    for j, parts in enumerate(col_parts):
        lo, hi = bounds[:, j, 0], bounds[:, j, 1]
        if parts == 1:
            los.append(lo.astype(np.int32))
            his.append(hi.astype(np.int32))
        else:
            los.append((lo >> WIDE_LANE_BITS).astype(np.int32))
            los.append((lo & _LANE_MASK).astype(np.int32))
            his.append((hi >> WIDE_LANE_BITS).astype(np.int32))
            his.append((hi & _LANE_MASK).astype(np.int32))
    return np.stack(los, axis=1), np.stack(his, axis=1)


# Row-axis padding granularity of the resident arrays. Matches the
# default kernel block so the jit-time pads become no-ops for every
# block_n that divides it — the per-batch work is then O(Q), not O(N).
DEVICE_BLOCK_N = 2048


# The kernel accumulates the matched count in a float32 lane: exact up
# to 2**24, beyond which additions round. Tables that could exceed it
# stay on the numpy engine (exact integer counts) until the kernel
# grows a two-lane carry accumulator.
MAX_DEVICE_ROWS = 1 << 24


def build_device_state(table, value_cols=None) -> dict:
    """Materialize a table's device-resident arrays: expanded int32 key
    lanes and a float32 value tile (one row per value column + a ones
    row for counts), both pre-padded to the kernel's sublane/block
    granularity so repeated batches ship only O(Q) bounds/slabs/selector
    data — no per-call stack or pad of the N-sized columns.
    ``SortedTable.place_on_device`` stores the result; host-only tables
    build it ephemerally per call, passing ``value_cols`` to materialize
    only the batch's columns."""
    col_parts = device_key_plan(table)
    n = len(table)
    if n >= MAX_DEVICE_ROWS:
        raise ValueError(
            f"device scan path: {n} rows exceeds the float32 count "
            f"accumulator's exact range ({MAX_DEVICE_ROWS}); use the "
            "numpy engine for tables this large"
        )
    n_pad = -(-max(n, 1) // DEVICE_BLOCK_N) * DEVICE_BLOCK_N
    keys = _expand_key_planes(table, col_parts)
    k_ex = keys.shape[0]
    k_pad = max(8, -(-k_ex // 8) * 8)
    keys_p = np.zeros((k_pad, n_pad), np.int32)
    keys_p[:k_ex, :n] = keys
    if value_cols is None:
        vnames = list(table.value_cols)
    else:
        wanted = set(value_cols)
        vnames = [c for c in table.value_cols if c in wanted]
    n_value_rows = len(vnames) + 1  # + ones row
    v_pad = max(8, -(-n_value_rows // 8) * 8)
    tile = np.zeros((v_pad, n_pad), np.float32)
    for i, c in enumerate(vnames):
        tile[i, :n] = np.asarray(table.value_cols[c], np.float32)
    tile[len(vnames), :n] = 1.0  # padded rows stay 0 and are slab-masked
    return {
        "col_parts": col_parts,
        "keys": jnp.asarray(keys_p),
        "values_tile": jnp.asarray(tile),
        "value_rows": {c: i for i, c in enumerate(vnames)},
        "ones_row": len(vnames),
        "n_value_rows": n_value_rows,
    }


def table_scan_device(table, query, *, use_pallas: bool = True) -> tuple[float, float]:
    """Device-side execution of ``SortedTable.execute`` (sum/count aggs):
    slab via packed-key searchsorted, then the batched scan kernel at
    Q = 1. Used by the serving/data layers when tables are resident as
    jax arrays."""
    (out,) = table_scan_device_many(table, [query], use_pallas=use_pallas)
    return out


def table_scan_device_many(
    table,
    queries,
    *,
    slabs: np.ndarray | None = None,
    block_n: int = 2048,
    use_pallas: bool = True,
    grid: str = "rows_outer",
) -> list[tuple[float, float]]:
    """Batched ``table_scan_device``: all queries against one replica in
    a single row-streaming launch. Returns ``[(value, count)]`` per query
    in batch order.

    Heterogeneous groups ride together: "sum" queries over any mix of
    value columns and "count" queries share the launch — each distinct
    value column becomes one row of the value tile, counts select a ones
    row, and a per-query selector routes the aggregation. ``slabs``
    accepts precomputed ``slab_many`` output so callers that already
    located the slabs (``SortedTable.execute_many``) skip the second
    searchsorted. ``grid="queries_outer"`` dispatches the legacy PR 1
    grid (uniform-agg, narrow-key batches only) for benchmarking.
    """
    queries = list(queries)
    if not queries:
        return []
    for q in queries:
        if q.agg not in ("sum", "count"):
            raise ValueError(f"device path supports sum/count aggs, got {q.agg!r}")
        if q.agg == "sum" and q.value_col is None:
            raise ValueError("sum aggregation requires value_col")
    state = getattr(table, "_device", None)
    if state is None:  # host table: materialize only this batch's columns
        state = build_device_state(
            table, value_cols={q.value_col for q in queries if q.agg == "sum"}
        )
    col_parts: tuple[int, ...] = state["col_parts"]
    if slabs is None:
        slabs = table.slab_many(queries)

    # the resident value tile already holds every value column + the
    # ones row; the per-query selector routes each aggregation to its row
    value_rows: dict[str, int] = state["value_rows"]
    values = state["values_tile"]
    sel = np.array(
        [
            value_rows[q.value_col] if q.agg == "sum" else state["ones_row"]
            for q in queries
        ],
        np.int32,
    )

    names = list(table.layout)
    bounds = np.array(
        [[q.filter_bounds(table.schema, c) for c in names] for q in queries],
        np.int64,
    )  # (Q, K, 2) — lo inclusive, hi exclusive
    lo, hi = _expand_bounds(bounds, col_parts)
    slabs32 = np.asarray(slabs, np.int64).astype(np.int32)

    if grid == "queries_outer":
        if len(set(sel)) > 1 or any(p != 1 for p in col_parts):
            raise ValueError(
                "queries_outer grid requires a uniform-agg, narrow-key batch"
            )
    elif grid != "rows_outer":
        raise ValueError(f"unknown grid {grid!r}")
    if not use_pallas:  # one oracle covers both grids
        out = np.asarray(
            ref.scan_agg_batched_ref(
                state["keys"], jnp.asarray(values), jnp.asarray(lo, jnp.int32),
                jnp.asarray(hi, jnp.int32), jnp.asarray(slabs32),
                jnp.asarray(sel), col_parts=col_parts,
            )
        )
    elif grid == "queries_outer":
        out = np.asarray(
            scan_agg_batched_qgrid_pallas(
                state["keys"], values[int(sel[0])], lo, hi, slabs32,
                block_n=block_n,
            )
        )
    else:
        out = np.asarray(
            scan_agg_batched_pallas(
                state["keys"], values, lo, hi, slabs32, sel,
                col_parts=col_parts, block_n=block_n,
                n_vals=state["n_value_rows"],
            )
        )
    return [
        (float(s) if q.agg == "sum" else float(c), float(c))
        for q, (s, c) in zip(queries, out)
    ]
