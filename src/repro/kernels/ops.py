"""Public jit'd wrappers around the Pallas kernels.

``scan_agg`` executes a located slab scan (the engine's read path on
device); ``ecdf_hist`` refreshes Cost-Evaluator statistics. Both take the
same arguments as their ``ref.py`` oracles and dispatch to Pallas
(interpret-mode on CPU, compiled on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .ecdf_hist import ecdf_hist_pallas
from .scan_agg import scan_agg_batched_pallas, scan_agg_pallas

__all__ = [
    "scan_agg",
    "scan_agg_batched",
    "ecdf_hist",
    "scan_agg_ref",
    "scan_agg_batched_ref",
    "ecdf_hist_ref",
    "table_scan_device",
    "table_scan_device_many",
]

scan_agg_ref = ref.scan_agg_ref
scan_agg_batched_ref = ref.scan_agg_batched_ref
ecdf_hist_ref = ref.ecdf_hist_ref


def scan_agg(keys, values, col_lo, col_hi, slab, *, block_n: int = 2048, use_pallas: bool = True):
    """(sum, count) over the slab with residual predicates. Arrays may be
    numpy or jax; returns a float32[2] jax array."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slab = jnp.asarray(slab, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_ref(keys, values, col_lo, col_hi, slab)
    return scan_agg_pallas(keys, values, col_lo, col_hi, slab, block_n=block_n)


def ecdf_hist(col, *, n_bins: int, bin_width: int, block_n: int = 512, use_pallas: bool = True):
    col = jnp.asarray(col, jnp.int32)
    if not use_pallas or n_bins > 4096:
        return ref.ecdf_hist_ref(col, n_bins=n_bins, bin_width=bin_width)
    return ecdf_hist_pallas(col, n_bins=n_bins, bin_width=bin_width, block_n=block_n)


def scan_agg_batched(
    keys, values, col_lo, col_hi, slabs, *, block_n: int = 2048, use_pallas: bool = True
):
    """Per-query (sum, count) for a query batch sharing one replica's
    columns: one grid of (queries × row blocks) instead of Q kernel
    launches. Arrays may be numpy or jax; returns float32[Q, 2]."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slabs = jnp.asarray(slabs, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_batched_ref(keys, values, col_lo, col_hi, slabs)
    return scan_agg_batched_pallas(keys, values, col_lo, col_hi, slabs, block_n=block_n)


def _check_device_width(table) -> None:
    """The device path stores keys and filter bounds as int32; a column
    needs bits ≤ 30 so that max_value + 1 (the exclusive global upper
    bound, 2**bits) still fits. Wider schemas are served by the numpy
    engine."""
    wide = [c for c in table.layout if table.schema.bits[c] > 30]
    if wide:
        raise ValueError(
            f"device scan path requires ≤30-bit key columns, got {wide}; "
            "use SortedTable.execute/execute_many for wider schemas"
        )


def table_scan_device(table, query, *, use_pallas: bool = True) -> tuple[float, float]:
    """Device-side execution of ``SortedTable.execute`` (sum/count aggs):
    slab via packed-key searchsorted, then the scan_agg kernel. Used by
    the serving/data layers when tables are resident as jax arrays."""
    _check_device_width(table)
    lo_idx, hi_idx = table.slab(query)
    names = list(table.layout)
    keys = np.stack([table.key_cols[c] for c in names]).astype(np.int32)
    if query.agg == "sum":
        vals = np.asarray(table.value_cols[query.value_col], np.float32)
    else:
        vals = np.ones(len(table), np.float32)
    lo = np.array([query.filter_bounds(table.schema, c)[0] for c in names], np.int32)
    hi = np.array([query.filter_bounds(table.schema, c)[1] for c in names], np.int32)
    out = scan_agg(keys, vals, lo, hi, np.array([lo_idx, hi_idx]), use_pallas=use_pallas)
    s, c = float(out[0]), float(out[1])
    return (s if query.agg == "sum" else c), c


def table_scan_device_many(
    table, queries, *, block_n: int = 2048, use_pallas: bool = True
) -> list[tuple[float, float]]:
    """Batched ``table_scan_device``: all queries against one replica in
    a single ``scan_agg_batched`` invocation. Queries must share the
    aggregation kind (all "count", or all "sum" over one value column —
    the batch shares a single values array on device)."""
    queries = list(queries)
    if not queries:
        return []
    aggs = {q.agg for q in queries}
    if not aggs <= {"sum", "count"}:
        raise ValueError(f"device path supports sum/count aggs, got {aggs}")
    vcols = {q.value_col for q in queries if q.agg == "sum"}
    if len(aggs) > 1 or len(vcols) > 1:
        raise ValueError("batch must share one aggregation and value column")
    _check_device_width(table)
    names = list(table.layout)
    slabs = table.slab_many(queries)
    keys = np.stack([table.key_cols[c] for c in names]).astype(np.int32)
    if vcols:
        vals = np.asarray(table.value_cols[next(iter(vcols))], np.float32)
    else:
        vals = np.ones(len(table), np.float32)
    bounds = np.array(
        [[q.filter_bounds(table.schema, c) for c in names] for q in queries],
        np.int32,
    )  # (Q, K, 2)
    out = np.asarray(
        scan_agg_batched(
            keys, vals, bounds[:, :, 0], bounds[:, :, 1],
            slabs.astype(np.int32), block_n=block_n, use_pallas=use_pallas,
        )
    )
    want_sum = "sum" in aggs
    return [
        ((float(s) if want_sum else float(c)), float(c)) for s, c in out
    ]
