"""Public jit'd wrappers around the Pallas kernels.

``scan_agg`` executes a located slab scan (the engine's read path on
device); ``ecdf_hist`` refreshes Cost-Evaluator statistics. Both take the
same arguments as their ``ref.py`` oracles and dispatch to Pallas
(interpret-mode on CPU, compiled on TPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .ecdf_hist import ecdf_hist_pallas
from .scan_agg import scan_agg_pallas

__all__ = ["scan_agg", "ecdf_hist", "scan_agg_ref", "ecdf_hist_ref", "table_scan_device"]

scan_agg_ref = ref.scan_agg_ref
ecdf_hist_ref = ref.ecdf_hist_ref


def scan_agg(keys, values, col_lo, col_hi, slab, *, block_n: int = 2048, use_pallas: bool = True):
    """(sum, count) over the slab with residual predicates. Arrays may be
    numpy or jax; returns a float32[2] jax array."""
    keys = jnp.asarray(keys, jnp.int32)
    values = jnp.asarray(values, jnp.float32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slab = jnp.asarray(slab, jnp.int32)
    if not use_pallas:
        return ref.scan_agg_ref(keys, values, col_lo, col_hi, slab)
    return scan_agg_pallas(keys, values, col_lo, col_hi, slab, block_n=block_n)


def ecdf_hist(col, *, n_bins: int, bin_width: int, block_n: int = 512, use_pallas: bool = True):
    col = jnp.asarray(col, jnp.int32)
    if not use_pallas or n_bins > 4096:
        return ref.ecdf_hist_ref(col, n_bins=n_bins, bin_width=bin_width)
    return ecdf_hist_pallas(col, n_bins=n_bins, bin_width=bin_width, block_n=block_n)


def table_scan_device(table, query, *, use_pallas: bool = True) -> tuple[float, float]:
    """Device-side execution of ``SortedTable.execute`` (sum/count aggs):
    slab via packed-key searchsorted, then the scan_agg kernel. Used by
    the serving/data layers when tables are resident as jax arrays."""
    lo_idx, hi_idx = table.slab(query)
    names = list(table.layout)
    keys = np.stack([table.key_cols[c] for c in names]).astype(np.int32)
    if query.agg == "sum":
        vals = np.asarray(table.value_cols[query.value_col], np.float32)
    else:
        vals = np.ones(len(table), np.float32)
    lo = np.array([query.filter_bounds(table.schema, c)[0] for c in names], np.int32)
    hi = np.array([query.filter_bounds(table.schema, c)[1] for c in names], np.int32)
    out = scan_agg(keys, vals, lo, hi, np.array([lo_idx, hi_idx]), use_pallas=use_pallas)
    s, c = float(out[0]), float(out[1])
    return (s if query.agg == "sum" else c), c
