"""Pallas TPU kernels for the HR hot paths.

scan_agg  — predicated slab scan + aggregate (the paper's query loop)
ecdf_hist — histogram/ECDF build for the Cost Evaluator

Each kernel ships a pure-jnp oracle in ``ref.py``; ``ops.py`` exposes the
jit'd public API with CPU interpret-mode fallback.
"""

from .ops import ecdf_hist, ecdf_hist_ref, scan_agg, scan_agg_ref, table_scan_device

__all__ = ["ecdf_hist", "ecdf_hist_ref", "scan_agg", "scan_agg_ref", "table_scan_device"]
