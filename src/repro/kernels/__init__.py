"""Pallas TPU kernels for the HR hot paths.

scan_agg         — predicated slab scan + aggregate (the paper's query loop)
scan_agg_batched — one launch over a (queries × row blocks) grid: a
                   whole query batch shares a replica's device-resident
                   columns (the ``read_many`` device path)
ecdf_hist        — histogram/ECDF build for the Cost Evaluator

Each kernel ships a pure-jnp oracle in ``ref.py``; ``ops.py`` exposes the
jit'd public API with CPU interpret-mode fallback.
"""

from .ops import (
    ecdf_hist,
    ecdf_hist_ref,
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_ref,
    table_scan_device,
    table_scan_device_many,
)

__all__ = [
    "ecdf_hist",
    "ecdf_hist_ref",
    "scan_agg",
    "scan_agg_batched",
    "scan_agg_batched_ref",
    "scan_agg_ref",
    "table_scan_device",
    "table_scan_device_many",
]
