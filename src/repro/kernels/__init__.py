"""Pallas TPU kernels for the HR hot paths.

scan_agg                — predicated slab scan + aggregate (the paper's
                          query loop)
scan_agg_batched        — row-streaming batched scan over host-located
                          slabs (PR 2; kept as the benchmark baseline)
slab_locate_batched     — vectorized (rank-form) binary search over the
                          resident key lanes: the device replacement for
                          host ``searchsorted`` slab location
scan_agg_locate_batched — FUSED locate+scan: one launch returns per-query
                          float32 aggregates plus int32 matched/slab-row
                          counts (the ``read_many`` device path; int32
                          counts lift the old 2**24-row cap)
select_compact_batched  — device "select": block-local prefix-sum
                          compaction of matched row indices
merge_rank_batched      — merge-path popcount ranks (strict/inclusive
                          windows) behind the k-way run merge
ecdf_hist               — histogram/ECDF build for the Cost Evaluator
                          (wired into ``TableStats.merge_rows``)
block_sums              — per-block partial sums of the resident value
                          tile (the materialized per-slab views;
                          ``boundary_block_sums`` rescans the two
                          window-edge blocks with the same reduction
                          shape, keeping view answers bit-identical to
                          the fused full scan)

Each kernel ships a pure-jnp oracle in ``ref.py``; ``ops.py`` exposes the
jit'd public API with CPU interpret-mode fallback. ``build_device_state``
materializes a SortedTable's device-resident arrays (wide key columns
packed into two int32 lanes per ``device_key_plan``) and
``device_state_append`` extends them incrementally with merged write
runs and ``merge_device_runs`` collapses the run stack on device (the
automatic-compaction storage move: ``merge_run_positions`` k-way
merge-path ranks + one scatter per resident array, no host re-upload);
``table_execute_device_many`` serves whole sum/count/select query
batches from those arrays with no host searchsorted and no numpy
fallback.
"""

from .block_agg import block_sums, boundary_block_sums
from .ref import block_sums_ref
from .ops import (
    DEVICE_BLOCK_N,
    build_device_state,
    device_key_plan,
    device_state_append,
    ecdf_hist,
    ecdf_hist_ref,
    merge_device_runs,
    merge_rank_batched,
    merge_run_positions,
    merge_run_positions_ref,
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_locate_batched,
    scan_agg_locate_batched_ref,
    scan_agg_ref,
    select_compact_batched,
    select_compact_batched_ref,
    slab_locate_batched,
    slab_locate_batched_ref,
    table_execute_device_many,
    table_scan_device,
    table_scan_device_many,
    table_slab_locate_many,
)

__all__ = [
    "DEVICE_BLOCK_N",
    "block_sums",
    "block_sums_ref",
    "boundary_block_sums",
    "build_device_state",
    "device_key_plan",
    "device_state_append",
    "ecdf_hist",
    "ecdf_hist_ref",
    "merge_device_runs",
    "merge_rank_batched",
    "merge_run_positions",
    "merge_run_positions_ref",
    "scan_agg",
    "scan_agg_batched",
    "scan_agg_batched_ref",
    "scan_agg_locate_batched",
    "scan_agg_locate_batched_ref",
    "scan_agg_ref",
    "select_compact_batched",
    "select_compact_batched_ref",
    "slab_locate_batched",
    "slab_locate_batched_ref",
    "table_execute_device_many",
    "table_scan_device",
    "table_scan_device_many",
    "table_slab_locate_many",
]
