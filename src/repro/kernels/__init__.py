"""Pallas TPU kernels for the HR hot paths.

scan_agg         — predicated slab scan + aggregate (the paper's query loop)
scan_agg_batched — one row-streaming launch over a replica's
                   device-resident columns: row blocks are the outer grid
                   axis, per-query accumulators are revisited every step,
                   mixed sum/count batches share multi-row value tiles
                   (the ``read_many`` device path)
ecdf_hist        — histogram/ECDF build for the Cost Evaluator

Each kernel ships a pure-jnp oracle in ``ref.py``; ``ops.py`` exposes the
jit'd public API with CPU interpret-mode fallback. ``build_device_state``
materializes a SortedTable's device-resident arrays (wide key columns
packed into two int32 lanes per ``device_key_plan``).
"""

from .ops import (
    build_device_state,
    device_key_plan,
    ecdf_hist,
    ecdf_hist_ref,
    scan_agg,
    scan_agg_batched,
    scan_agg_batched_ref,
    scan_agg_ref,
    table_scan_device,
    table_scan_device_many,
)

__all__ = [
    "build_device_state",
    "device_key_plan",
    "ecdf_hist",
    "ecdf_hist_ref",
    "scan_agg",
    "scan_agg_batched",
    "scan_agg_batched_ref",
    "scan_agg_ref",
    "table_scan_device",
    "table_scan_device_many",
]
