"""Pallas TPU kernels: device slab location, fused locate+scan, "select".

Together with ``scan_agg`` these put the *entire* read path of a
device-resident replica on the accelerator — after PR 2 the scan itself
ran on device but every batch still round-tripped to host numpy for slab
location (``np.searchsorted`` over the packed key column), for "select"
aggregations, and for re-placement after writes. The three kernels here
remove those host hops.

``slab_locate_batched``
    The device replacement for the host ``searchsorted`` in
    ``SortedTable.slab_many``. A gather-per-probe binary search is
    hostile to the TPU vector unit, so the binary search is vectorized
    into its branch-free *rank* form over the sorted key lanes: for a
    query whose packed slab bounds are ``[lo, hi]`` (inclusive),

        lo_idx = |{rows r : key(r) <  lo  (lex)}|
        hi_idx = |{rows r : key(r) <= hi  (lex)}|

    two masked popcounts the VPU evaluates for every query of the batch
    while the key lanes stream through VMEM once (the same row-block
    grid as the scan kernel). On a sorted column these ranks equal
    ``np.searchsorted(packed, lo, "left")`` / ``(packed, hi, "right")``
    exactly (property-tested against that oracle). The output is a
    device array that feeds ``scan_agg_batched``'s ``slabs`` operand
    directly — a locate→scan device pipeline with no host sync.

``scan_agg_locate_batched``
    The fused form used by the batched read fast path. Because rows are
    compared against the packed slab bounds *by key*, a row's slab
    membership ("would the sorted scan stream it") is decided inside the
    scan predicate itself — the locate disappears into the scan and one
    launch returns, per query, the masked float32 aggregate **and** the
    int32 matched/slab-row counts. Counts ride an int32 output (exact to
    2**31), which is what lifts the old float32 2**24-row device cap —
    and because slab membership is a per-row key predicate, the counts
    stay correct even when the resident arrays hold appended (unsorted)
    write runs.

``select_compact_batched``
    Device "select": emit the matched row indices by block-local
    prefix-sum compaction. Two passes: the fused kernel counts matches
    (sizing the output), then this kernel walks the row blocks keeping a
    per-query running base in a VMEM-resident carry accumulator; each
    block computes an exclusive prefix sum of its match mask and
    scatters row indices into ``base + local`` of a pre-sized
    ``(Q, out_width)`` output. The scatter is windowed (one writer per
    slot, masked lanes contribute +0), exact in interpret mode; a Mosaic
    lowering would swap it for the one-hot matmul form.

Lane layout, ``col_parts`` (wide two-lane columns) and padding
conventions are shared with ``scan_agg`` — lexicographic comparison
over the lane sequence equals numeric order on the packed key.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .scan_agg import _lex_ge, _lex_lt, _pad_to

__all__ = [
    "slab_locate_kernel",
    "slab_locate_batched",
    "scan_agg_locate_kernel",
    "scan_agg_locate_batched",
    "select_compact_kernel",
    "select_compact_batched",
    "residual_membership_batched",
]


def _lex_tuple_ge(keys, bounds, n_lanes):
    """(Q, block_n) mask: key lane tuple >= per-query bound tuple,
    lexicographic over the first ``n_lanes`` lanes (MSB lane first, so
    it equals numeric order on the packed composite key)."""
    acc = None
    for lane in reversed(range(n_lanes)):
        k = keys[lane : lane + 1, :]  # (1, block_n)
        b = bounds[:, lane : lane + 1]  # (Q, 1)
        acc = (k >= b) if acc is None else (k > b) | ((k == b) & acc)
    return acc


def _lex_tuple_le(keys, bounds, n_lanes):
    acc = None
    for lane in reversed(range(n_lanes)):
        k = keys[lane : lane + 1, :]
        b = bounds[:, lane : lane + 1]
        acc = (k <= b) if acc is None else (k < b) | ((k == b) & acc)
    return acc


def _residual_pred(keys, lo, hi, col_parts, base):
    """AND the per-column residual range predicate ([lo, hi) per logical
    column, wide columns as lexicographic lane pairs) onto ``base``."""
    pred = base
    lane = 0
    for parts in col_parts:
        if parts == 1:
            k = keys[lane : lane + 1, :]
            pred &= (k >= lo[:, lane : lane + 1]) & (k < hi[:, lane : lane + 1])
        else:
            kh = keys[lane : lane + 1, :]
            kl = keys[lane + 1 : lane + 2, :]
            pred &= _lex_ge(kh, kl, lo[:, lane : lane + 1], lo[:, lane + 1 : lane + 2])
            pred &= _lex_lt(kh, kl, hi[:, lane : lane + 1], hi[:, lane + 1 : lane + 2])
        lane += parts
    return pred


def _row_window(limits, block_n, i):
    """(Q, block_n) row-validity mask for grid step ``i``: row index in
    the query's [start, stop) window. Padded queries carry (0, 0)."""
    ridx = i * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    return ridx, (ridx >= limits[:, 0:1]) & (ridx < limits[:, 1:2])


def residual_membership_batched(
    keys: jax.Array,  # int32[K_ex(+pad), N]
    res_lo: jax.Array,  # int32[Q, K_ex] residual bounds, inclusive
    res_hi: jax.Array,  # int32[Q, K_ex] residual bounds, EXCLUSIVE
    limits: jax.Array,  # int32[Q, 2] row window
    *,
    col_parts: tuple[int, ...] | None = None,
) -> jax.Array:
    """bool[Q, N] device membership mask — the kernels' own residual
    predicate evaluated whole-array. This is the wide-select fallback:
    when a compaction output block cannot stay VMEM-sized, callers take
    this mask and pull back only the matched indices via per-query
    ``jnp.flatnonzero(mask[j], size=count)`` (counts come from the fused
    pass), never the mask itself."""
    keys = jnp.asarray(keys, jnp.int32)
    res_lo = jnp.asarray(res_lo, jnp.int32)
    res_hi = jnp.asarray(res_hi, jnp.int32)
    limits = jnp.asarray(limits, jnp.int32)
    Q, K_ex = res_lo.shape
    if col_parts is None:
        col_parts = (1,) * K_ex
    col_parts = tuple(int(p) for p in col_parts)
    if sum(col_parts) != K_ex or not all(p in (1, 2) for p in col_parts):
        raise ValueError(f"col_parts {col_parts} does not tile {K_ex} bound lanes")
    ridx = jnp.arange(keys.shape[1], dtype=jnp.int32)[None, :]
    valid = (ridx >= limits[:, 0:1]) & (ridx < limits[:, 1:2])
    return _residual_pred(keys, res_lo, res_hi, col_parts, valid)


# -- rank-form binary search --------------------------------------------------


def slab_locate_kernel(n_lanes, limits_ref, keys_ref, lo_ref, hi_ref, out_ref):
    """One row-block step: every query counts the window rows lying
    strictly below its lower slab key (lane 0) and at-or-below its upper
    slab key (lane 1) — the two searchsorted ranks."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]
    _, valid = _row_window(limits_ref[...], keys.shape[1], i)

    below = valid & ~_lex_tuple_ge(keys, lo, n_lanes)
    at_or_below = valid & _lex_tuple_le(keys, hi, n_lanes)
    cnt_lo = jnp.sum(below.astype(jnp.int32), axis=1, keepdims=True)
    cnt_hi = jnp.sum(at_or_below.astype(jnp.int32), axis=1, keepdims=True)
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
    out_ref[...] = (
        out_ref[...]
        + jnp.where(lane_idx == 0, cnt_lo, 0)
        + jnp.where(lane_idx == 1, cnt_hi, 0)
    )


@functools.partial(jax.jit, static_argnames=("n_lanes", "block_n", "interpret"))
def _slab_locate_call(keys, slab_lo, slab_hi, limits, *, n_lanes, block_n, interpret):
    N = keys.shape[1]
    Q = slab_lo.shape[0]
    K_pad = max(8, -(-keys.shape[0] // 8) * 8)
    Q_pad = max(8, -(-Q // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    lo_p = _pad_to(_pad_to(slab_lo.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    hi_p = _pad_to(_pad_to(slab_hi.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    lim_p = _pad_to(limits.astype(jnp.int32), Q_pad, 0, 0)

    out = pl.pallas_call(
        functools.partial(slab_locate_kernel, n_lanes),
        grid=(N_pad // block_n,),
        in_specs=[
            pl.BlockSpec((Q_pad, 2), lambda i: (0, 0)),
            pl.BlockSpec((K_pad, block_n), lambda i: (0, i)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Q_pad, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Q_pad, 128), jnp.int32),
        interpret=interpret,
    )(lim_p, keys_p, lo_p, hi_p)
    return out[:Q, :2]


def slab_locate_batched(
    keys: jax.Array,  # int32[K_ex(+pad), N] — key lanes
    slab_lo: jax.Array,  # int32[Q, K_ex] — lower slab key, per lane (inclusive)
    slab_hi: jax.Array,  # int32[Q, K_ex] — upper slab key, per lane (INCLUSIVE)
    limits: jax.Array,  # int32[Q, 2] — [start, stop) row window (usually [0, N))
    *,
    n_lanes: int | None = None,
    block_n: int = 2048,
    max_q: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """int32[Q, 2] = (lo_idx, hi_idx) row slabs — the vectorized binary
    search. On a sorted key column this equals ``searchsorted(packed,
    lo, "left")`` / ``searchsorted(packed, hi, "right")``. An empty
    query is encoded as ``slab_lo = 0``-lanes, ``slab_hi = -1``-lanes
    (or a ``(0, 0)`` window) and yields ``(0, 0)``."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    keys = jnp.asarray(keys, jnp.int32)
    slab_lo = jnp.asarray(slab_lo, jnp.int32)
    slab_hi = jnp.asarray(slab_hi, jnp.int32)
    limits = jnp.asarray(limits, jnp.int32)
    Q, K_ex = slab_lo.shape
    if n_lanes is None:
        n_lanes = K_ex
    if not 0 < n_lanes <= keys.shape[0]:
        raise ValueError(f"n_lanes {n_lanes} out of range for {keys.shape[0]} key lanes")
    call = functools.partial(
        _slab_locate_call, keys, n_lanes=n_lanes, block_n=block_n, interpret=interpret
    )
    if Q <= max_q:
        return call(slab_lo, slab_hi, limits)
    return jnp.concatenate(
        [
            call(slab_lo[s : s + max_q], slab_hi[s : s + max_q], limits[s : s + max_q])
            for s in range(0, Q, max_q)
        ],
        axis=0,
    )


# -- fused locate + scan ------------------------------------------------------


def scan_agg_locate_kernel(
    col_parts,
    n_vals,
    limits_ref,
    sel_ref,
    keys_ref,
    vals_ref,
    res_lo_ref,
    res_hi_ref,
    slab_lo_ref,
    slab_hi_ref,
    out_f_ref,
    out_i_ref,
):
    """One row-block step serving every query: float32 masked aggregate
    (out_f lane 0) plus int32 matched count (out_i lane 0) and slab row
    count (out_i lane 1). Slab membership is the lexicographic key-range
    test, so no row-index slab input exists at all."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_f_ref[...] = jnp.zeros_like(out_f_ref)
        out_i_ref[...] = jnp.zeros_like(out_i_ref)

    keys = keys_ref[...]
    vals = vals_ref[...]
    sel = sel_ref[...]
    _, valid = _row_window(limits_ref[...], keys.shape[1], i)

    n_lanes = sum(col_parts)
    slab_ok = (
        valid
        & _lex_tuple_ge(keys, slab_lo_ref[...], n_lanes)
        & _lex_tuple_le(keys, slab_hi_ref[...], n_lanes)
    )
    matched = _residual_pred(keys, res_lo_ref[...], res_hi_ref[...], col_parts, valid)

    fmask = matched.astype(jnp.float32)
    vq = jnp.zeros(fmask.shape, jnp.float32)
    for v in range(n_vals):
        vq += jnp.where(sel == v, vals[v : v + 1, :], 0.0)
    part_sum = jnp.sum(vq * fmask, axis=1, keepdims=True)
    cnt = jnp.sum(matched.astype(jnp.int32), axis=1, keepdims=True)
    slab_cnt = jnp.sum(slab_ok.astype(jnp.int32), axis=1, keepdims=True)

    lane_f = jax.lax.broadcasted_iota(jnp.int32, out_f_ref.shape, 1)
    out_f_ref[...] = out_f_ref[...] + jnp.where(lane_f == 0, part_sum, 0.0)
    lane_i = jax.lax.broadcasted_iota(jnp.int32, out_i_ref.shape, 1)
    out_i_ref[...] = (
        out_i_ref[...]
        + jnp.where(lane_i == 0, cnt, 0)
        + jnp.where(lane_i == 1, slab_cnt, 0)
    )


@functools.partial(
    jax.jit, static_argnames=("col_parts", "n_vals", "block_n", "interpret")
)
def _fused_call(
    keys,
    values,
    res_lo,
    res_hi,
    slab_lo,
    slab_hi,
    limits,
    value_sel,
    *,
    col_parts,
    n_vals,
    block_n,
    interpret,
):
    N = keys.shape[1]
    Q = res_lo.shape[0]
    K_pad = max(8, -(-keys.shape[0] // 8) * 8)
    V_pad = max(8, -(-values.shape[0] // 8) * 8)
    Q_pad = max(8, -(-Q // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    vals_p = _pad_to(_pad_to(values.astype(jnp.float32), N_pad, 1, 0.0), V_pad, 0, 0.0)
    res_lo_p = _pad_to(_pad_to(res_lo.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    res_hi_p = _pad_to(_pad_to(res_hi.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    slab_lo_p = _pad_to(_pad_to(slab_lo.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    slab_hi_p = _pad_to(_pad_to(slab_hi.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    lim_p = _pad_to(limits.astype(jnp.int32), Q_pad, 0, 0)
    sel_p = _pad_to(value_sel.astype(jnp.int32)[:, None], Q_pad, 0, 0)

    kernel = functools.partial(scan_agg_locate_kernel, col_parts, n_vals)
    out_f, out_i = pl.pallas_call(
        kernel,
        grid=(N_pad // block_n,),
        in_specs=[
            pl.BlockSpec((Q_pad, 2), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((K_pad, block_n), lambda i: (0, i)),
            pl.BlockSpec((V_pad, block_n), lambda i: (0, i)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((Q_pad, 128), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, 128), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Q_pad, 128), jnp.float32),
            jax.ShapeDtypeStruct((Q_pad, 128), jnp.int32),
        ),
        interpret=interpret,
    )(lim_p, sel_p, keys_p, vals_p, res_lo_p, res_hi_p, slab_lo_p, slab_hi_p)
    return out_f[:Q, 0], out_i[:Q, 0], out_i[:Q, 1]


def scan_agg_locate_batched(
    keys: jax.Array,  # int32[K_ex(+pad), N]
    values: jax.Array,  # float32[N] or float32[V(+pad), N]
    res_lo: jax.Array,  # int32[Q, K_ex] residual bounds, inclusive
    res_hi: jax.Array,  # int32[Q, K_ex] residual bounds, EXCLUSIVE
    slab_lo: jax.Array,  # int32[Q, K_ex] slab key, inclusive
    slab_hi: jax.Array,  # int32[Q, K_ex] slab key, INCLUSIVE
    limits: jax.Array,  # int32[Q, 2] row window ([0, N) for live queries)
    value_sel: jax.Array | None = None,  # int32[Q]
    *,
    col_parts: tuple[int, ...] | None = None,
    n_vals: int | None = None,
    block_n: int = 2048,
    max_q: int = 1024,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused locate+scan: ``(sum f32[Q], matched i32[Q], slab_rows
    i32[Q])`` in one launch, columns streamed from HBM once per batch.
    ``slab_rows`` is the number of rows a sorted scan of the slab would
    stream (== ``hi_idx - lo_idx`` of :func:`slab_locate_batched`);
    matched/sum use the residual per-column predicate only, which the
    slab provably contains."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    values = jnp.asarray(values, jnp.float32)
    if values.ndim == 1:
        values = values[None, :]
    keys = jnp.asarray(keys, jnp.int32)
    res_lo = jnp.asarray(res_lo, jnp.int32)
    res_hi = jnp.asarray(res_hi, jnp.int32)
    slab_lo = jnp.asarray(slab_lo, jnp.int32)
    slab_hi = jnp.asarray(slab_hi, jnp.int32)
    limits = jnp.asarray(limits, jnp.int32)
    Q, K_ex = res_lo.shape
    if value_sel is None:
        value_sel = jnp.zeros(Q, jnp.int32)
    else:
        value_sel = jnp.asarray(value_sel, jnp.int32)
    if col_parts is None:
        col_parts = (1,) * K_ex
    col_parts = tuple(int(p) for p in col_parts)
    if sum(col_parts) != K_ex or not all(p in (1, 2) for p in col_parts):
        raise ValueError(f"col_parts {col_parts} does not tile {K_ex} bound lanes")
    if K_ex > keys.shape[0]:
        raise ValueError(f"bounds cover {K_ex} lanes but keys carry {keys.shape[0]}")
    if n_vals is None:
        n_vals = int(values.shape[0])
    if not 0 < n_vals <= values.shape[0]:
        raise ValueError(f"n_vals {n_vals} out of range for {values.shape[0]} rows")
    call = functools.partial(
        _fused_call,
        keys,
        values,
        col_parts=col_parts,
        n_vals=n_vals,
        block_n=block_n,
        interpret=interpret,
    )
    if Q <= max_q:
        return call(res_lo, res_hi, slab_lo, slab_hi, limits, value_sel)
    parts = [
        call(
            res_lo[s : s + max_q],
            res_hi[s : s + max_q],
            slab_lo[s : s + max_q],
            slab_hi[s : s + max_q],
            limits[s : s + max_q],
            value_sel[s : s + max_q],
        )
        for s in range(0, Q, max_q)
    ]
    return tuple(jnp.concatenate([p[j] for p in parts], axis=0) for j in range(3))


# -- "select": block-local prefix-sum compaction ------------------------------


def select_compact_kernel(
    col_parts, limits_ref, keys_ref, res_lo_ref, res_hi_ref, out_ref, carry_ref
):
    """One row-block step of the two-pass select: the carry accumulator
    (lane 0) holds each query's match count over earlier blocks; this
    block's matches land at ``carry + exclusive-prefix-sum`` of the
    match mask. The scatter is windowed — every matched row owns its
    output slot, masked lanes add 0 — so the result is exact regardless
    of duplicate clamped positions."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        carry_ref[...] = jnp.zeros_like(carry_ref)

    keys = keys_ref[...]
    ridx, valid = _row_window(limits_ref[...], keys.shape[1], i)
    matched = _residual_pred(keys, res_lo_ref[...], res_hi_ref[...], col_parts, valid)

    m = matched.astype(jnp.int32)  # (Q, block_n)
    local = jnp.cumsum(m, axis=1) - m  # exclusive prefix sum per query
    base = carry_ref[:, 0:1]
    width = out_ref.shape[1]
    # clamp keeps masked positions in range; their contribution is +0
    pos = jnp.minimum(base + local, width - 1)
    qidx = jax.lax.broadcasted_iota(jnp.int32, m.shape, 0)
    rmat = jnp.broadcast_to(ridx, m.shape)
    out_ref[...] = out_ref[...].at[qidx, pos].add(jnp.where(matched, rmat, 0))
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, carry_ref.shape, 1)
    carry_ref[...] = carry_ref[...] + jnp.where(
        lane_idx == 0, jnp.sum(m, axis=1, keepdims=True), 0
    )


@functools.partial(
    jax.jit, static_argnames=("col_parts", "out_width", "block_n", "interpret")
)
def _select_call(keys, res_lo, res_hi, limits, *, col_parts, out_width, block_n, interpret):
    N = keys.shape[1]
    Q = res_lo.shape[0]
    K_pad = max(8, -(-keys.shape[0] // 8) * 8)
    Q_pad = max(8, -(-Q // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    lo_p = _pad_to(_pad_to(res_lo.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    hi_p = _pad_to(_pad_to(res_hi.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    lim_p = _pad_to(limits.astype(jnp.int32), Q_pad, 0, 0)

    kernel = functools.partial(select_compact_kernel, col_parts)
    out, _carry = pl.pallas_call(
        kernel,
        grid=(N_pad // block_n,),
        in_specs=[
            pl.BlockSpec((Q_pad, 2), lambda i: (0, 0)),
            pl.BlockSpec((K_pad, block_n), lambda i: (0, i)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
        ],
        out_specs=(
            pl.BlockSpec((Q_pad, out_width), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, 128), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((Q_pad, out_width), jnp.int32),
            jax.ShapeDtypeStruct((Q_pad, 128), jnp.int32),
        ),
        interpret=interpret,
    )(lim_p, keys_p, lo_p, hi_p)
    return out[:Q]


def select_compact_batched(
    keys: jax.Array,  # int32[K_ex(+pad), N]
    res_lo: jax.Array,  # int32[Q, K_ex] residual bounds, inclusive
    res_hi: jax.Array,  # int32[Q, K_ex] residual bounds, EXCLUSIVE
    limits: jax.Array,  # int32[Q, 2] row window
    *,
    col_parts: tuple[int, ...] | None = None,
    out_width: int = 128,
    block_n: int = 2048,
    max_q: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """int32[Q, out_width]: per query, its matched row indices compacted
    to the front (slots past the match count stay 0 — callers slice with
    the counts from the fused pass). ``out_width`` must cover the
    largest match count in the batch; lanes prefer multiples of 128."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    keys = jnp.asarray(keys, jnp.int32)
    res_lo = jnp.asarray(res_lo, jnp.int32)
    res_hi = jnp.asarray(res_hi, jnp.int32)
    limits = jnp.asarray(limits, jnp.int32)
    Q, K_ex = res_lo.shape
    if col_parts is None:
        col_parts = (1,) * K_ex
    col_parts = tuple(int(p) for p in col_parts)
    if sum(col_parts) != K_ex or not all(p in (1, 2) for p in col_parts):
        raise ValueError(f"col_parts {col_parts} does not tile {K_ex} bound lanes")
    call = functools.partial(
        _select_call,
        keys,
        col_parts=col_parts,
        out_width=out_width,
        block_n=block_n,
        interpret=interpret,
    )
    if Q <= max_q:
        return call(res_lo, res_hi, limits)
    return jnp.concatenate(
        [
            call(res_lo[s : s + max_q], res_hi[s : s + max_q], limits[s : s + max_q])
            for s in range(0, Q, max_q)
        ],
        axis=0,
    )
