"""Pallas TPU kernel: k-way merge of device-resident sorted runs.

After memtable flushes a device-resident replica holds a *stack* of
sorted runs (base + appended) in its resident arrays; compaction must
collapse them into one sorted run **on device** — no host re-upload of
the N-sized columns. A gather-driven merge is hostile to the TPU vector
unit, so (like the binary search in ``slab_locate``) the merge is
vectorized into its branch-free *merge-path rank* form: a row's merged
position is its within-run position plus its rank in every other run,

    merged_pos(e ∈ run r) = local_pos(e)
                          + |{rows j in runs before r : key_j <  key_e}|
                          + |{rows j in runs after  r : key_j <= key_e}|

two masked popcounts per element, evaluated for a whole probe block
while the key lanes stream through VMEM (the same row-block grid as the
scan kernels). Runs are contiguous in device order — run r's
predecessors occupy ``[0, start_r)`` and its successors ``[end_r, N)``
— so the k-way merge needs exactly one strict-rank window and one
inclusive-rank window per element, independent of the run count.

The tie rule (strict below for earlier runs, at-or-below for later
runs, arrival order within a run) is precisely the host merge order of
``SortedTable.merge_run`` — a freshly written row lands *before* equal
existing rows — so the computed permutation equals the incremental
``row_map`` and the compacted device order equals the host row order
(``row_map`` collapses to identity; property-tested).

Work is O(N_base · M + M · N) popcounts for M appended rows (the base
probes only stream the appended suffix: their strict window is empty
and their inclusive window starts at the base boundary, so the grid is
launched from that block onward). The numpy/lexsort oracle lives in
``ref.merge_run_positions_ref``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .scan_agg import _pad_to
from .slab_locate import _lex_tuple_ge, _lex_tuple_le

__all__ = [
    "merge_rank_kernel",
    "merge_rank_batched",
    "merge_run_positions",
]


def merge_rank_kernel(
    n_lanes, row_off, lim_lt_ref, lim_le_ref, probes_ref, keys_ref, out_ref
):
    """One row-block step: every probe counts the rows of its strict
    window lying lexicographically below its key tuple (lane 0) and the
    rows of its inclusive window at-or-below it (lane 1). ``row_off``
    (static) is the grid's starting block — probe sets whose windows
    live in a suffix of the rows skip the prefix blocks entirely."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (K_pad, block_n) int32 key lanes
    probes = probes_ref[...]  # (Q_pad, K_pad) int32 probe key tuples
    lim_lt = lim_lt_ref[...]  # (Q_pad, 2) strict-rank row window
    lim_le = lim_le_ref[...]  # (Q_pad, 2) inclusive-rank row window

    block_n = keys.shape[1]
    ridx = (i + row_off) * block_n + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_n), 1
    )
    in_lt = (ridx >= lim_lt[:, 0:1]) & (ridx < lim_lt[:, 1:2])
    in_le = (ridx >= lim_le[:, 0:1]) & (ridx < lim_le[:, 1:2])

    below = in_lt & ~_lex_tuple_ge(keys, probes, n_lanes)
    at_or_below = in_le & _lex_tuple_le(keys, probes, n_lanes)
    cnt_lt = jnp.sum(below.astype(jnp.int32), axis=1, keepdims=True)
    cnt_le = jnp.sum(at_or_below.astype(jnp.int32), axis=1, keepdims=True)
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
    out_ref[...] = (
        out_ref[...]
        + jnp.where(lane_idx == 0, cnt_lt, 0)
        + jnp.where(lane_idx == 1, cnt_le, 0)
    )


@functools.partial(
    jax.jit, static_argnames=("n_lanes", "row_off", "block_n", "interpret")
)
def _merge_rank_call(
    keys, probes, lim_lt, lim_le, *, n_lanes, row_off, block_n, interpret
):
    N = keys.shape[1]
    Q = probes.shape[0]
    K_pad = max(8, -(-keys.shape[0] // 8) * 8)
    Q_pad = max(8, -(-Q // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    probes_p = _pad_to(_pad_to(probes.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    # padded probes carry (0, 0) windows and count nothing
    lt_p = _pad_to(lim_lt.astype(jnp.int32), Q_pad, 0, 0)
    le_p = _pad_to(lim_le.astype(jnp.int32), Q_pad, 0, 0)

    n_blocks = N_pad // block_n - row_off
    out = pl.pallas_call(
        functools.partial(merge_rank_kernel, n_lanes, row_off),
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((Q_pad, 2), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, 2), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((K_pad, block_n), lambda i, _off=row_off: (0, i + _off)),
        ],
        out_specs=pl.BlockSpec((Q_pad, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Q_pad, 128), jnp.int32),
        interpret=interpret,
    )(lt_p, le_p, probes_p, keys_p)
    return out[:Q, :2]


def merge_rank_batched(
    keys: jax.Array,  # int32[K_ex(+pad), N] — key lanes, device row order
    probes: jax.Array,  # int32[Q, n_lanes] — probe key tuples
    lim_lt: jax.Array,  # int32[Q, 2] — strict-rank row window per probe
    lim_le: jax.Array,  # int32[Q, 2] — inclusive-rank row window per probe
    *,
    n_lanes: int,
    row_start: int = 0,
    block_n: int = 2048,
    max_q: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """int32[Q, 2] = per probe, (strict rank in its lt window, inclusive
    rank in its le window). ``row_start`` drops whole leading row blocks
    from the stream when every window lies at or past it."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    keys = jnp.asarray(keys, jnp.int32)
    probes = jnp.asarray(probes, jnp.int32)
    lim_lt = jnp.asarray(lim_lt, jnp.int32)
    lim_le = jnp.asarray(lim_le, jnp.int32)
    if not 0 < n_lanes <= keys.shape[0]:
        raise ValueError(f"n_lanes {n_lanes} out of range for {keys.shape[0]} key lanes")
    if probes.shape[1] < n_lanes:
        raise ValueError(f"probes carry {probes.shape[1]} lanes, need {n_lanes}")
    row_off = row_start // block_n
    call = functools.partial(
        _merge_rank_call,
        keys,
        n_lanes=n_lanes,
        row_off=row_off,
        block_n=block_n,
        interpret=interpret,
    )
    Q = probes.shape[0]
    if Q <= max_q:
        return call(probes, lim_lt, lim_le)
    return jnp.concatenate(
        [
            call(probes[s : s + max_q], lim_lt[s : s + max_q], lim_le[s : s + max_q])
            for s in range(0, Q, max_q)
        ],
        axis=0,
    )


def merge_run_positions(
    keys: jax.Array,  # int32[K_ex(+pad), N(+pad)] — resident key lanes
    run_starts,  # sequence of run start offsets (run 0 = base at 0)
    n_rows: int,
    *,
    n_lanes: int,
    block_n: int = 2048,
    use_pallas: bool = True,
    interpret: bool | None = None,
) -> np.ndarray:
    """int64[n_rows] merged position of every device row — the k-way
    merge permutation (see module docstring for the tie rule). Two rank
    launches: one for the appended rows (strict prefix + inclusive
    suffix windows), one for the base rows (inclusive window over the
    appended suffix only, streamed from the base boundary onward)."""
    starts = np.asarray(tuple(run_starts) + (n_rows,), dtype=np.int64)
    n_runs = len(starts) - 1
    if n_runs <= 1:
        return np.arange(n_rows, dtype=np.int64)
    if not use_pallas:
        from . import ref

        return ref.merge_run_positions_ref(keys, run_starts, n_rows, n_lanes=n_lanes)
    base_end = int(starts[1])
    m = n_rows - base_end
    run_lens = np.diff(starts)[1:]  # appended runs only

    # appended probes: strict rank over their predecessors [0, start_r),
    # inclusive rank over their successors [end_r, n_rows)
    probes_app = jnp.asarray(keys)[:n_lanes, base_end:n_rows].T
    lim_lt = np.zeros((m, 2), np.int64)
    lim_lt[:, 1] = np.repeat(starts[1:-1], run_lens)
    lim_le = np.empty((m, 2), np.int64)
    lim_le[:, 0] = np.repeat(starts[2:], run_lens)
    lim_le[:, 1] = n_rows
    ranks_app = np.asarray(
        merge_rank_batched(
            keys, probes_app, lim_lt, lim_le, n_lanes=n_lanes, block_n=block_n,
            interpret=interpret,
        ),
        np.int64,
    )
    local = np.arange(m, dtype=np.int64) - np.repeat(starts[1:-1] - base_end, run_lens)
    pos_app = local + ranks_app[:, 0] + ranks_app[:, 1]

    # base probes: inclusive rank over the appended suffix only — the
    # grid starts at the base boundary's block, skipping the base rows
    probes_base = jnp.asarray(keys)[:n_lanes, :base_end].T
    zeros = np.zeros((base_end, 2), np.int64)
    lim_le_b = np.empty((base_end, 2), np.int64)
    lim_le_b[:, 0] = base_end
    lim_le_b[:, 1] = n_rows
    ranks_base = np.asarray(
        merge_rank_batched(
            keys, probes_base, zeros, lim_le_b, n_lanes=n_lanes,
            row_start=base_end, block_n=block_n, interpret=interpret,
        ),
        np.int64,
    )
    pos_base = np.arange(base_end, dtype=np.int64) + ranks_base[:, 1]
    return np.concatenate([pos_base, pos_app])
