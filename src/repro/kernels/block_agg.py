"""Pallas per-block partial aggregates — the device half of the
materialized per-slab views (``repro.core.storage.views``).

``block_sums`` folds a replica's resident value tile into one float32
partial sum per ``block_n`` row block, in that replica's own sort
order. The view serve path then answers a range aggregate as
interior-blocks-from-partials plus boundary-block rescans instead of
an O(N) stream — O(blocks touched) work.

Bit-identity contract
=====================

The fused full-scan kernel (``scan_agg_locate_kernel``) accumulates
per row block: ``part = jnp.sum(vq * fmask, axis=1)`` over a
``(·, block_n)`` tile, added into the float32 output lane in ascending
block order. The view path must reproduce those bits exactly, so every
reduction here is the *same shape family* — a minor-axis ``jnp.sum``
over a ``(rows-padded-to-8, block_n)`` tile:

* an **interior** block (every real row inside the query's row-window
  union) contributes its stored ``block_sums`` column — elementwise
  the tile values times an all-ones mask, bitwise the fused product
  (value pads are 0.0);
* a **boundary** block recomputes ``jnp.sum(vals * window_mask,
  axis=1)`` via :func:`boundary_block_sums` — the fused per-block
  partial restricted to one block;
* the host then folds the touched blocks' partials sequentially in
  float32, ascending block order (``np.cumsum`` — strictly
  sequential, unlike numpy's pairwise ``np.sum``). Untouched blocks
  contribute exactly 0.0 in the fused scan, and adding 0.0 is the
  float32 identity, so skipping them preserves the accumulator bits.

(The one tolerated divergence is the sign of zero: the fused kernel's
``vq`` accumulation can turn a stored ``-0.0`` into ``+0.0``. IEEE
``==`` treats them equal, which is what the bit-identity property
tests assert.)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .scan_agg import _pad_to

__all__ = ["block_sums", "block_sums_kernel", "boundary_block_sums"]


def block_sums_kernel(vals_ref, out_ref):
    """One row-block step: fold this block's value tile into its output
    column. The accumulator block is revisited every step (same idiom
    as the fused scan kernel's query lanes); lane ``i`` of the output
    receives block ``i``'s partial, pads stay 0."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    part = jnp.sum(vals_ref[...], axis=1, keepdims=True)  # (V_pad, 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
    out_ref[...] = out_ref[...] + jnp.where(lane == i, part, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _block_sums_call(values, *, block_n, interpret):
    V, N = values.shape
    V_pad = max(8, -(-V // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n
    n_blocks = N_pad // block_n
    B_pad = max(128, -(-n_blocks // 128) * 128)
    vals_p = _pad_to(_pad_to(values.astype(jnp.float32), N_pad, 1, 0.0), V_pad, 0, 0.0)
    out = pl.pallas_call(
        block_sums_kernel,
        grid=(n_blocks,),
        in_specs=[pl.BlockSpec((V_pad, block_n), lambda i: (0, i))],
        out_specs=pl.BlockSpec((V_pad, B_pad), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((V_pad, B_pad), jnp.float32),
        interpret=interpret,
    )(vals_p)
    return out[:V, :n_blocks]


def block_sums(
    values: jax.Array,  # float32[V, N] value tile (device row order)
    *,
    block_n: int,
    interpret: bool | None = None,
) -> jax.Array:
    """float32[V, ceil(N / block_n)] per-block partial sums, one row
    per value row of the tile (rows past N are zero pads and contribute
    +0.0). Each column's bits equal the fused scan kernel's per-block
    partial for a query whose window covers the whole block."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    return _block_sums_call(
        jnp.asarray(values, jnp.float32), block_n=block_n, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def _boundary_call(values, sel, blocks, win_lo, win_hi, *, block_n):
    bn = jnp.arange(block_n, dtype=jnp.int32)[None, :]
    cols = blocks[:, None] * block_n + bn  # (P, block_n) global row idx
    vals = values[sel[:, None], cols]  # (P, block_n) each pair's value row
    inw = (cols[:, None, :] >= win_lo[:, :, None]) & (
        cols[:, None, :] < win_hi[:, :, None]
    )
    fmask = jnp.any(inw, axis=1).astype(jnp.float32)  # (P, block_n)
    return jnp.sum(vals * fmask, axis=1)


def boundary_block_sums(
    values: jax.Array,  # float32[V, N_cap] resident value tile
    sel,  # int[P] value-row selector per (query, block) pair
    blocks,  # int[P] block index per pair
    win_lo,  # int[P, W] window starts (global row idx, inclusive)
    win_hi,  # int[P, W] window stops (global row idx, exclusive)
    *,
    block_n: int,
) -> jax.Array:
    """float32[P] masked partial sums of boundary blocks: pair ``p``
    gets ``sum(values[sel[p], rows of block blocks[p] inside any
    [win_lo[p, w], win_hi[p, w]) window])`` — the fused kernel's
    per-block ``jnp.sum(vq * fmask, axis=1)`` restricted to one block
    (same ``(pairs-padded-to-8, block_n)`` reduction shape). Empty
    window slots are encoded ``lo >= hi``."""
    sel = jnp.asarray(sel, jnp.int32)
    blocks = jnp.asarray(blocks, jnp.int32)
    win_lo = jnp.asarray(win_lo, jnp.int32)
    win_hi = jnp.asarray(win_hi, jnp.int32)
    P = int(sel.shape[0])
    P_pad = max(8, -(-P // 8) * 8)
    sel = _pad_to(sel[:, None], P_pad, 0, 0)[:, 0]
    blocks = _pad_to(blocks[:, None], P_pad, 0, 0)[:, 0]
    win_lo = _pad_to(win_lo, P_pad, 0, 0)
    win_hi = _pad_to(win_hi, P_pad, 0, 0)  # pad pairs: lo == hi == 0 → empty
    out = _boundary_call(
        jnp.asarray(values, jnp.float32), sel, blocks, win_lo, win_hi,
        block_n=block_n,
    )
    return out[:P]
