"""Pallas TPU kernel: predicated slab scan + aggregate.

This is the paper's hot loop — the SSTable scan of Fig 2 — adapted to the
TPU memory hierarchy. The storage layout is *columnar* with rows along
the 128-lane axis (keys: int32[K, N]), so one VMEM tile holds a block of
rows for every clustering key and the residual predicate evaluates as a
vectorized compare + AND-reduce over the (tiny) K sublane axis; the
aggregation is a masked reduction feeding a scalar accumulator that lives
in the output block across grid steps.

HBM→VMEM traffic is exactly rows × row_bytes, which is what Eq (1) of the
paper counts — the kernel makes Row() the literal unit of memory cost.

The batched form serves a whole query batch with one kernel launch over
a replica's device-resident columns (the ``read_many`` device path); the
single-query form is its Q = 1 special case. Grid: (queries, row
blocks), row axis fastest. Block shapes:
  keys   (K_pad, block_n)  — K_pad a multiple of 8 sublanes, shared by
                             every query in the batch
  values (1, block_n)      — shared likewise
  bounds (K_pad, 1) ×2     — this query's column, broadcast against rows
  slabs  (1, 2)            — this query's [lo, hi) row slab
  out    (1, 128)          — lane 0: Σ value·mask, lane 1: Σ mask
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "scan_agg_pallas",
    "scan_agg_batched_kernel",
    "scan_agg_batched_pallas",
]


def scan_agg_batched_kernel(slabs_ref, keys_ref, vals_ref, lo_ref, hi_ref, out_ref):
    """One (query, row block) grid step. A query's (1, 128) output block
    stays resident across its row blocks (row axis iterates fastest).
    Bounds arrive pre-transposed as (K_pad, Q) so the per-query column is
    a (K_pad, 1) slice that broadcasts against the keys tile."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (K_pad, block_n) int32
    vals = vals_ref[...]  # (1, block_n) float32
    lo = lo_ref[...]  # (K_pad, 1) int32, inclusive — this query's column
    hi = hi_ref[...]  # (K_pad, 1) int32, exclusive

    block_n = keys.shape[1]
    row0 = i * block_n
    ridx = row0 + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    slab_lo = slabs_ref[0, 0]
    slab_hi = slabs_ref[0, 1]
    in_slab = (ridx >= slab_lo) & (ridx < slab_hi)  # (1, block_n)

    col_ok = (keys >= lo) & (keys < hi)  # (K_pad, block_n)
    pred = jnp.all(col_ok, axis=0, keepdims=True) & in_slab  # (1, block_n)

    fmask = pred.astype(vals.dtype)
    part_sum = jnp.sum(vals * fmask)
    part_cnt = jnp.sum(fmask)

    acc = out_ref[...]
    upd = jnp.zeros_like(acc)
    upd = upd.at[0, 0].set(part_sum)
    upd = upd.at[0, 1].set(part_cnt)
    out_ref[...] = acc + upd


def _pad_to(x: jax.Array, size: int, axis: int, fill) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def scan_agg_batched_pallas(
    keys: jax.Array,  # int32[K, N] — columnar clustering keys, replica order
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[Q, K] inclusive per-query/column lower bounds
    col_hi: jax.Array,  # int32[Q, K] exclusive per-query/column upper bounds
    slabs: jax.Array,  # int32[Q, 2] — per-query [lo, hi) row slabs
    *,
    block_n: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns float32[Q, 2]: per query, (masked sum of values, count).

    One kernel launch serves the whole batch: queries share the same
    device-resident key/value arrays and ship their bounds/slabs
    together, versus Q separate dispatches on the sequential path. Note
    the row axis is the *inner* grid dimension (so each query's output
    block stays resident while it scans), which means key tiles are
    re-fetched per query — HBM key traffic still scales with Q. A
    keys-resident ordering (row blocks outer, accumulators revisited)
    would amortize that too and is left as a follow-up.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    K, N = keys.shape
    Q = col_lo.shape[0]
    K_pad = max(8, -(-K // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    vals_p = _pad_to(values.astype(jnp.float32)[None, :], N_pad, 1, 0.0)
    # transpose bounds to (K_pad, Q): per-query column slices broadcast
    # against the keys tile. Padded K rows get always-true bounds; padded
    # N rows are killed by the slab mask (row index ≥ N ≥ slab hi).
    lo_p = _pad_to(col_lo.astype(jnp.int32).T, K_pad, 0, jnp.iinfo(jnp.int32).min)
    hi_p = _pad_to(col_hi.astype(jnp.int32).T, K_pad, 0, jnp.iinfo(jnp.int32).max)
    slabs_p = slabs.astype(jnp.int32)

    grid = (Q, N_pad // block_n)
    out = pl.pallas_call(
        scan_agg_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda q, i: (q, 0)),
            pl.BlockSpec((K_pad, block_n), lambda q, i: (0, i)),
            pl.BlockSpec((1, block_n), lambda q, i: (0, i)),
            pl.BlockSpec((K_pad, 1), lambda q, i: (0, q)),
            pl.BlockSpec((K_pad, 1), lambda q, i: (0, q)),
        ],
        out_specs=pl.BlockSpec((1, 128), lambda q, i: (q, 0)),
        out_shape=jax.ShapeDtypeStruct((Q, 128), jnp.float32),
        interpret=interpret,
    )(slabs_p, keys_p, vals_p, lo_p, hi_p)
    return out[:, :2]


def scan_agg_pallas(
    keys: jax.Array,  # int32[K, N]
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[K] inclusive per-column lower bounds
    col_hi: jax.Array,  # int32[K] exclusive per-column upper bounds
    slab: jax.Array,  # int32[2] = [lo, hi) row slab
    *,
    block_n: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns float32[2] = (masked sum of values, matched row count).

    The Q = 1 case of :func:`scan_agg_batched_pallas`.
    """
    out = scan_agg_batched_pallas(
        keys, values, col_lo[None, :], col_hi[None, :], slab[None, :],
        block_n=block_n, interpret=interpret,
    )
    return out[0]
