"""Pallas TPU kernel: predicated slab scan + aggregate.

This is the paper's hot loop — the SSTable scan of Fig 2 — adapted to the
TPU memory hierarchy. The storage layout is *columnar* with rows along
the 128-lane axis (keys: int32[K, N]), so one VMEM tile holds a block of
rows for every clustering key and the residual predicate evaluates as a
vectorized compare + AND-reduce over the (tiny) K sublane axis; the
aggregation is a masked reduction feeding per-query scalar accumulators.

HBM→VMEM traffic is exactly rows × row_bytes, which is what Eq (1) of the
paper counts — the kernel makes Row() the literal unit of memory cost.

Row-streaming grid (the PR 2 batched form)
------------------------------------------
``scan_agg_batched_pallas`` serves a whole query batch with one kernel
launch over a replica's device-resident columns, given host-located
row slabs. (The engine's ``read_many`` device path now routes through
the FUSED locate+scan variant in ``slab_locate.py``, which decides slab
membership inside the predicate; this kernel is kept as the pre-fusion
baseline and general slab-mask scan.) Row blocks are the **outer**
(and only) grid axis: each
key/value tile is fetched from HBM exactly once per batch and every
query's accumulator is *revisited* at every row step — the accumulators
live in a single (Q_pad, 128) output block whose index map is constant
across the grid, so it stays resident in VMEM for the whole launch (the
standard Pallas reduction pattern). HBM traffic is therefore
``N × (K_ex + V) × 4`` bytes regardless of Q — the paper's "pay the
serialization cost once, amortize across queries" applied to HBM instead
of disk. Block shapes:

  keys   (K_ex_pad, block_n) — key *lanes* (wide columns occupy two)
  values (V_pad, block_n)    — one sublane per distinct value column
                               (+ a ones row for counts)
  lo/hi  (Q_pad, K_ex_pad)   — per-query per-lane bounds, resident
  slabs  (Q_pad, 2)          — per-query [lo, hi) row slabs, resident
  sel    (Q_pad, 1)          — per-query value-row selector, resident
  out    (Q_pad, 128)        — lane 0: Σ value·mask, lane 1: Σ mask

Mixed aggregations ride in one launch: a "count" query selects the ones
value row, a "sum" query selects its value column's row.

Wide keys: a column wider than 30 bits ships as two int32 lanes
(hi = v >> 30, lo = v & (2^30−1)); ``col_parts`` marks how many lanes
each logical column occupies and the predicate compares lane pairs
lexicographically, which equals the numeric order on the int64 value.

The legacy queries-outer grid (grid = (queries, row blocks), row axis
fastest, key tiles re-fetched per query so HBM key traffic scales with
Q) is kept as ``scan_agg_batched_qgrid_pallas`` for the perf trajectory
benchmark (`benchmarks/batched_read.py --device`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "WIDE_LANE_BITS",
    "scan_agg_rowstream_kernel",
    "scan_agg_batched_pallas",
    "scan_agg_qgrid_kernel",
    "scan_agg_batched_qgrid_pallas",
    "scan_agg_pallas",
]

# A key lane is an int32; columns wider than this many bits are split
# into (hi, lo) lane pairs compared lexicographically.
WIDE_LANE_BITS = 30


def _lex_ge(h, l, bh, bl):
    """(h, l) >= (bh, bl) lexicographically (== numeric >= on the
    recombined value when l, bl < 2**WIDE_LANE_BITS)."""
    return (h > bh) | ((h == bh) & (l >= bl))


def _lex_lt(h, l, bh, bl):
    return (h < bh) | ((h == bh) & (l < bl))


def scan_agg_rowstream_kernel(
    col_parts, n_vals, slabs_ref, sel_ref, keys_ref, vals_ref, lo_ref, hi_ref, out_ref
):
    """One row-block grid step serving *every* query in the batch.

    ``col_parts`` (static) lists the lane count (1 or 2) of each logical
    key column; ``n_vals`` (static) is the number of live value rows.
    The output block's index map is constant, so ``out_ref`` is the same
    VMEM-resident accumulator at every step — initialized at step 0,
    accumulated into at every step (revisited-accumulator pattern).
    """
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (K_ex_pad, block_n) int32 key lanes
    vals = vals_ref[...]  # (V_pad, block_n) float32 value rows
    lo = lo_ref[...]  # (Q_pad, K_ex_pad) int32, inclusive (per lane)
    hi = hi_ref[...]  # (Q_pad, K_ex_pad) int32, exclusive (per lane)
    slabs = slabs_ref[...]  # (Q_pad, 2) int32
    sel = sel_ref[...]  # (Q_pad, 1) int32 value-row selector

    block_n = keys.shape[1]
    row0 = i * block_n
    ridx = row0 + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    # (Q_pad, block_n); padded queries carry slab (0, 0) → all-false
    pred = (ridx >= slabs[:, 0:1]) & (ridx < slabs[:, 1:2])

    lane = 0
    for parts in col_parts:  # static unroll over logical key columns
        if parts == 1:
            k = keys[lane : lane + 1, :]  # (1, block_n)
            pred &= (k >= lo[:, lane : lane + 1]) & (k < hi[:, lane : lane + 1])
        else:  # wide column: (hi, lo) lane pair, lexicographic range
            kh = keys[lane : lane + 1, :]
            kl = keys[lane + 1 : lane + 2, :]
            pred &= _lex_ge(kh, kl, lo[:, lane : lane + 1], lo[:, lane + 1 : lane + 2])
            pred &= _lex_lt(kh, kl, hi[:, lane : lane + 1], hi[:, lane + 1 : lane + 2])
        lane += parts

    fmask = pred.astype(jnp.float32)  # (Q_pad, block_n)
    # per-query value row: one masked pass per live value row (n_vals is
    # tiny — the distinct value columns of the batch plus a ones row)
    vq = jnp.zeros(fmask.shape, jnp.float32)
    for v in range(n_vals):
        vq += jnp.where(sel == v, vals[v : v + 1, :], 0.0)

    part_sum = jnp.sum(vq * fmask, axis=1, keepdims=True)  # (Q_pad, 1)
    part_cnt = jnp.sum(fmask, axis=1, keepdims=True)
    lane_idx = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)
    upd = jnp.where(lane_idx == 0, part_sum, 0.0) + jnp.where(
        lane_idx == 1, part_cnt, 0.0
    )
    out_ref[...] = out_ref[...] + upd


def _pad_to(x: jax.Array, size: int, axis: int, fill) -> jax.Array:
    pad = size - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=fill)


@functools.partial(
    jax.jit, static_argnames=("col_parts", "n_vals", "block_n", "interpret")
)
def _rowstream_call(
    keys: jax.Array,  # int32[K_ex(+pad), N] — key lanes, replica order
    values: jax.Array,  # float32[V(+pad), N] — value rows (ones row for counts)
    col_lo: jax.Array,  # int32[Q, K_ex] inclusive per-query/lane bounds
    col_hi: jax.Array,  # int32[Q, K_ex] exclusive per-query/lane bounds
    slabs: jax.Array,  # int32[Q, 2] — per-query [lo, hi) row slabs
    value_sel: jax.Array,  # int32[Q] — per-query value-row index
    *,
    col_parts: tuple[int, ...],
    n_vals: int,  # live value rows (the selector's range)
    block_n: int,
    interpret: bool,
) -> jax.Array:
    N = keys.shape[1]
    Q = col_lo.shape[0]
    K_pad = max(8, -(-keys.shape[0] // 8) * 8)
    V_pad = max(8, -(-values.shape[0] // 8) * 8)
    Q_pad = max(8, -(-Q // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    # for device-resident tables these pads are no-ops: build_device_state
    # pre-pads keys/values to the same granularity, so the N-sized arrays
    # pass through untouched and only the O(Q) operands are prepared here
    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    vals_p = _pad_to(_pad_to(values.astype(jnp.float32), N_pad, 1, 0.0), V_pad, 0, 0.0)
    # padded key lanes are never referenced (col_parts covers only the
    # real lanes); padded queries get empty slabs and all-zero bounds
    lo_p = _pad_to(_pad_to(col_lo.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    hi_p = _pad_to(_pad_to(col_hi.astype(jnp.int32), K_pad, 1, 0), Q_pad, 0, 0)
    slabs_p = _pad_to(slabs.astype(jnp.int32), Q_pad, 0, 0)
    sel_p = _pad_to(value_sel.astype(jnp.int32)[:, None], Q_pad, 0, 0)

    grid = (N_pad // block_n,)
    kernel = functools.partial(scan_agg_rowstream_kernel, col_parts, n_vals)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((Q_pad, 2), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, 1), lambda i: (0, 0)),
            pl.BlockSpec((K_pad, block_n), lambda i: (0, i)),
            pl.BlockSpec((V_pad, block_n), lambda i: (0, i)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
            pl.BlockSpec((Q_pad, K_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Q_pad, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Q_pad, 128), jnp.float32),
        interpret=interpret,
    )(slabs_p, sel_p, keys_p, vals_p, lo_p, hi_p)
    return out[:Q, :2]


def scan_agg_batched_pallas(
    keys: jax.Array,  # int32[K_ex, N]
    values: jax.Array,  # float32[N] or float32[V, N]
    col_lo: jax.Array,  # int32[Q, K_ex]
    col_hi: jax.Array,  # int32[Q, K_ex]
    slabs: jax.Array,  # int32[Q, 2]
    value_sel: jax.Array | None = None,  # int32[Q], default all zeros
    *,
    col_parts: tuple[int, ...] | None = None,
    n_vals: int | None = None,
    block_n: int = 2048,
    max_q: int = 1024,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns float32[Q, 2]: per query, (masked sum of values, count).

    One row-streaming launch serves the whole batch (see module
    docstring); batches larger than ``max_q`` are chunked so the
    resident accumulator/bounds blocks stay within VMEM — each chunk
    still streams the columns exactly once. ``keys``/``values`` may
    carry pre-padded sublane rows beyond the ``col_parts`` lanes /
    ``n_vals`` live value rows (the device-resident layout); padded rows
    are never referenced.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    values = jnp.asarray(values, jnp.float32)
    if values.ndim == 1:
        values = values[None, :]
    keys = jnp.asarray(keys, jnp.int32)
    col_lo = jnp.asarray(col_lo, jnp.int32)
    col_hi = jnp.asarray(col_hi, jnp.int32)
    slabs = jnp.asarray(slabs, jnp.int32)
    Q, K_ex = col_lo.shape
    if value_sel is None:
        value_sel = jnp.zeros(Q, jnp.int32)
    else:
        value_sel = jnp.asarray(value_sel, jnp.int32)
    if col_parts is None:
        col_parts = (1,) * K_ex
    col_parts = tuple(int(p) for p in col_parts)
    if sum(col_parts) != K_ex or not all(p in (1, 2) for p in col_parts):
        raise ValueError(f"col_parts {col_parts} does not tile {K_ex} bound lanes")
    if K_ex > keys.shape[0]:
        raise ValueError(
            f"bounds cover {K_ex} lanes but keys carry {keys.shape[0]}"
        )
    if n_vals is None:
        n_vals = int(values.shape[0])
    if not 0 < n_vals <= values.shape[0]:
        raise ValueError(f"n_vals {n_vals} out of range for {values.shape[0]} rows")
    if Q <= max_q:
        return _rowstream_call(
            keys, values, col_lo, col_hi, slabs, value_sel,
            col_parts=col_parts, n_vals=n_vals, block_n=block_n,
            interpret=interpret,
        )
    chunks = [
        _rowstream_call(
            keys, values, col_lo[s : s + max_q], col_hi[s : s + max_q],
            slabs[s : s + max_q], value_sel[s : s + max_q],
            col_parts=col_parts, n_vals=n_vals, block_n=block_n,
            interpret=interpret,
        )
        for s in range(0, Q, max_q)
    ]
    return jnp.concatenate(chunks, axis=0)


# -- legacy queries-outer grid (kept for the perf trajectory bench) ----------


def scan_agg_qgrid_kernel(slabs_ref, keys_ref, vals_ref, lo_ref, hi_ref, out_ref):
    """One (query, row block) grid step. A query's (1, 128) output block
    stays resident across its row blocks (row axis iterates fastest).
    Bounds arrive pre-transposed as (K_pad, Q) so the per-query column is
    a (K_pad, 1) slice that broadcasts against the keys tile."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]  # (K_pad, block_n) int32
    vals = vals_ref[...]  # (1, block_n) float32
    lo = lo_ref[...]  # (K_pad, 1) int32, inclusive — this query's column
    hi = hi_ref[...]  # (K_pad, 1) int32, exclusive

    block_n = keys.shape[1]
    row0 = i * block_n
    ridx = row0 + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
    slab_lo = slabs_ref[0, 0]
    slab_hi = slabs_ref[0, 1]
    in_slab = (ridx >= slab_lo) & (ridx < slab_hi)  # (1, block_n)

    col_ok = (keys >= lo) & (keys < hi)  # (K_pad, block_n)
    pred = jnp.all(col_ok, axis=0, keepdims=True) & in_slab  # (1, block_n)

    fmask = pred.astype(vals.dtype)
    part_sum = jnp.sum(vals * fmask)
    part_cnt = jnp.sum(fmask)

    acc = out_ref[...]
    upd = jnp.zeros_like(acc)
    upd = upd.at[0, 0].set(part_sum)
    upd = upd.at[0, 1].set(part_cnt)
    out_ref[...] = acc + upd


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def scan_agg_batched_qgrid_pallas(
    keys: jax.Array,  # int32[K, N] — columnar clustering keys, replica order
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[Q, K] inclusive per-query/column lower bounds
    col_hi: jax.Array,  # int32[Q, K] exclusive per-query/column upper bounds
    slabs: jax.Array,  # int32[Q, 2] — per-query [lo, hi) row slabs
    *,
    block_n: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """The PR 1 grid: (queries, row blocks), row axis fastest. Each
    query's output block stays resident while it scans, but key tiles
    are re-fetched per query — HBM key traffic scales with Q. Superseded
    by the row-streaming grid; kept as the benchmark baseline."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    K, N = keys.shape
    Q = col_lo.shape[0]
    K_pad = max(8, -(-K // 8) * 8)
    N_pad = -(-max(N, 1) // block_n) * block_n

    keys_p = _pad_to(_pad_to(keys.astype(jnp.int32), N_pad, 1, 0), K_pad, 0, 0)
    vals_p = _pad_to(values.astype(jnp.float32)[None, :], N_pad, 1, 0.0)
    # transpose bounds to (K_pad, Q): per-query column slices broadcast
    # against the keys tile. Padded K rows get always-true bounds; padded
    # N rows are killed by the slab mask (row index ≥ N ≥ slab hi).
    lo_p = _pad_to(col_lo.astype(jnp.int32).T, K_pad, 0, jnp.iinfo(jnp.int32).min)
    hi_p = _pad_to(col_hi.astype(jnp.int32).T, K_pad, 0, jnp.iinfo(jnp.int32).max)
    slabs_p = slabs.astype(jnp.int32)

    grid = (Q, N_pad // block_n)
    out = pl.pallas_call(
        scan_agg_qgrid_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 2), lambda q, i: (q, 0)),
            pl.BlockSpec((K_pad, block_n), lambda q, i: (0, i)),
            pl.BlockSpec((1, block_n), lambda q, i: (0, i)),
            pl.BlockSpec((K_pad, 1), lambda q, i: (0, q)),
            pl.BlockSpec((K_pad, 1), lambda q, i: (0, q)),
        ],
        out_specs=pl.BlockSpec((1, 128), lambda q, i: (q, 0)),
        out_shape=jax.ShapeDtypeStruct((Q, 128), jnp.float32),
        interpret=interpret,
    )(slabs_p, keys_p, vals_p, lo_p, hi_p)
    return out[:, :2]


def scan_agg_pallas(
    keys: jax.Array,  # int32[K, N]
    values: jax.Array,  # float32[N]
    col_lo: jax.Array,  # int32[K] inclusive per-column lower bounds
    col_hi: jax.Array,  # int32[K] exclusive per-column upper bounds
    slab: jax.Array,  # int32[2] = [lo, hi) row slab
    *,
    block_n: int = 2048,
    interpret: bool | None = None,
) -> jax.Array:
    """Returns float32[2] = (masked sum of values, matched row count).

    The Q = 1 case of :func:`scan_agg_batched_pallas`.
    """
    col_lo = jnp.asarray(col_lo)
    col_hi = jnp.asarray(col_hi)
    slab = jnp.asarray(slab)
    out = scan_agg_batched_pallas(
        keys, values, col_lo[None, :], col_hi[None, :], slab[None, :],
        block_n=block_n, interpret=interpret,
    )
    return out[0]
