"""Post-SPMD HLO analysis: collective bytes + roofline terms.

``collective_bytes`` parses the compiled HLO text and sums the *operand*
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (cost_analysis does not report these). Shapes are
parsed from the HLO type strings; sizes are per-participant (the compiled
module is the per-device program, so operand bytes ≈ bytes crossing this
chip's links, the right unit for the ICI roofline term).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["CollectiveStats", "collective_bytes", "roofline_terms", "HW"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %ag = bf16[2,1024,512]{2,1,0} all-gather(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|(?P<otype>[a-z0-9]+)\[(?P<oshape>[0-9,]*)\][^ ]*)\s+"
    r"(?P<op>" + "|".join(_COLLECTIVES) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _nbytes(dtype: str, dims: str) -> int:
    n = _DTYPE_BYTES.get(dtype)
    if n is None:
        return 0
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: dict  # op kind -> output bytes total
    count: dict

    @property
    def total(self) -> int:
        return sum(self.per_op_bytes.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    per = {k: 0 for k in _COLLECTIVES}
    cnt = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        if "-done(" in line:  # async pair: count only the start
            continue
        if m.group("otype") is not None:
            b = _nbytes(m.group("otype"), m.group("oshape"))
        else:
            # tuple result (e.g. variadic all-gather / -start): sum members
            head = line.split(f" {op}", 1)[0]
            b = sum(_nbytes(t, s) for t, s in _SHAPE_RE.findall(head))
        per[op] += b
        cnt[op] += 1
    return CollectiveStats(per, cnt)


#: TPU v5e hardware constants (per chip)
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bw": 819e9,  # B/s
    "ici_bw": 50e9,  # B/s per link
}


def roofline_terms(
    flops: float, hbm_bytes: float, coll_bytes: float, n_chips: int
) -> dict:
    """Three per-chip roofline terms in seconds. ``flops``/``hbm_bytes``
    come from compiled.cost_analysis() of the per-device module."""
    return {
        "t_compute": flops / HW["peak_flops_bf16"],
        "t_memory": hbm_bytes / HW["hbm_bw"],
        "t_collective": coll_bytes / HW["ici_bw"],
        "n_chips": n_chips,
    }
