import os
if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Roofline analysis with loop-corrected HLO accounting.

XLA's ``cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified in EXPERIMENTS.md §Dry-run), and this framework
deliberately uses `lax.scan` at three levels (layers, flash-attention
chunks, grad-accum microbatches). Raw numbers would undercount a 61-layer
model by ~61×. Correction strategy, per cell:

  1. *Analysis variants*: compile the SAME step with per-stack depths
     (1,1,…), then (2,1,…), (1,2,…) … — all with LOOP-FREE attention
     (q_chunk = kv_chunk = S, one block) and microbatches=1, so the only
     while loop left is the layer scan, which the depth extrapolation
     linearizes exactly:  total = base + Σ_s (n_s − 1) · per_layer_s.
     (Chunking changes memory layout, never FLOPs or collective bytes.)
  2. *SSD correction*: the Mamba-2 chunk scan remains a loop (chunk size
     changes real FLOPs, so it cannot be unrolled away); its body cost
     appears once per layer and is scaled by the analytic chunk count
     with an exact per-chunk FLOP formula.
  3. *Memory term*: HBM bytes are computed analytically (weights read
     once per step + activation/KV/logit traffic) — the CPU backend's
     'bytes accessed' reflects CPU buffer assignment, not TPU fusion.
  4. MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (+attention) and
     the MODEL_FLOPS / HLO_FLOPs ratio are reported per cell.

Outputs one JSON record per cell; EXPERIMENTS.md tables are generated
from these artifacts (benchmarks/roofline_report.py).
"""

import argparse
import dataclasses
import json

import numpy as np

from repro.configs.registry import ARCHS, SHAPES, ShapeSpec, get_arch, shape_applicable
from repro.launch import hlo_stats
from repro.models import lm as lm_mod
from repro.models.config import ArchConfig
from repro.parallel.padding import padded_dims

__all__ = ["analyze_cell", "analytic_model_flops", "analytic_hbm_bytes"]


# ------------------------------------------------------------ analytic --------


def _active_params(cfg: ArchConfig) -> float:
    """Params touched per token (MoE: shared + top_k experts only)."""
    total = cfg.param_count()
    if not cfg.is_moe:
        return total
    moe_layers = cfg.n_layers - cfg.first_k_dense
    g = 3 if cfg.gated_mlp else 2
    routed_all = moe_layers * cfg.n_experts * g * cfg.d_model * cfg.moe_d_ff
    routed_active = moe_layers * cfg.moe_top_k * g * cfg.d_model * cfg.moe_d_ff
    return total - routed_all + routed_active


def _attn_flops_per_layer(cfg: ArchConfig, B: int, S: int, mode: str) -> float:
    """Score+value matmul FLOPs (projections are inside param counts)."""
    if not cfg.uses_attention:
        return 0.0
    if cfg.attention == "mla":
        dh_qk = cfg.qk_nope_dim + cfg.qk_rope_dim
        dh_v = cfg.v_head_dim
    else:
        dh_qk = dh_v = cfg.d_head
    H = cfg.n_heads
    if mode == "decode":
        # one query over the full cache
        return 2.0 * B * H * S * (dh_qk + dh_v)
    # causal full-seq: ~half the S×S block
    eff = S * S / 2 if not cfg.sliding_window else S * min(S, cfg.sliding_window)
    return 2.0 * B * H * eff * (dh_qk + dh_v)


def _ssd_flops_per_layer(cfg: ArchConfig, B: int, S: int, mode: str) -> float:
    if not cfg.uses_ssm:
        return 0.0
    dims_H = cfg.d_inner // cfg.ssm_head_dim
    P, N = cfg.ssm_head_dim, cfg.ssm_state
    if mode == "decode":
        # state update + readout: B·H·P·N each
        return 2.0 * 2 * B * dims_H * P * N
    Q = min(cfg.ssm_chunk, S)
    nc = max(1, S // Q)
    # per chunk: CB (Q²N) + Y_diag (Q²·H(+HP)) + Y_off/state (Q·H·P·N ×2)
    per_chunk = 2.0 * B * (Q * Q * N + Q * Q * dims_H * (1 + P) + 2 * Q * dims_H * P * N)
    return per_chunk * nc


def analytic_model_flops(cfg: ArchConfig, shape: ShapeSpec) -> float:
    """Whole-step model FLOPs (all chips), fwd(+bwd ×3 for train)."""
    B, S = shape.global_batch, shape.seq_len
    n_active = _active_params(cfg)
    L = cfg.n_layers
    if shape.mode == "train":
        tokens = B * S
        base = 2.0 * n_active * tokens
        attn = _attn_flops_per_layer(cfg, B, S, "train") * L
        ssd = _ssd_flops_per_layer(cfg, B, S, "train") * L
        return 3.0 * (base + attn + ssd)  # fwd + 2× bwd
    if shape.mode == "prefill":
        tokens = B * S
        return 2.0 * n_active * tokens + (
            _attn_flops_per_layer(cfg, B, S, "prefill")
            + _ssd_flops_per_layer(cfg, B, S, "prefill")
        ) * L
    # decode: one token per sequence
    return 2.0 * n_active * B + (
        _attn_flops_per_layer(cfg, B, S, "decode")
        + _ssd_flops_per_layer(cfg, B, S, "decode")
    ) * L


def _cache_bytes(cfg: ArchConfig, B: int, S: int, tp: int) -> float:
    pd = padded_dims(cfg, tp)
    total = 0.0
    for spec in lm_mod.stacks_for(cfg):
        for _, shape_, dt, _ in lm_mod._cache_entry_shapes(cfg, pd, spec, B, S, tp):
            total += float(np.prod(shape_)) * np.dtype(dt).itemsize
    return total


def analytic_hbm_bytes(cfg: ArchConfig, shape: ShapeSpec, tp: int, n_chips: int) -> float:
    """Per-chip HBM bytes per step (floor: weights once + act/KV traffic).

    Train: weights read fwd + bwd + grads written + optimizer state rw
    (≈ 6× param bytes ÷ chips with full sharding) + activations 2 passes.
    Decode: full cache read + params/TP read. Prefill: params + act.
    """
    B, S = shape.global_batch, shape.seq_len
    p_bytes = cfg.param_count() * 2.0  # bf16
    d = cfg.d_model
    if shape.mode == "train":
        act = B * S * d * 2.0 * cfg.n_layers * 4  # x in/out per layer, fwd+bwd
        opt = cfg.param_count() * (4 + 4 + 4)  # m rw + fp32 master write
        return (3 * p_bytes + opt + act) / n_chips
    if shape.mode == "prefill":
        act = B * S * d * 2.0 * cfg.n_layers * 2
        return (p_bytes + act + _cache_bytes(cfg, B, S, tp)) / n_chips
    cache = _cache_bytes(cfg, B, S, tp)
    act = B * d * 2.0 * cfg.n_layers * 4
    return (p_bytes + cache + act) / n_chips


# ------------------------------------------------------------ HLO-corrected ---


def _reduced_cfg(cfg: ArchConfig, stack_sizes: dict) -> ArchConfig:
    """Same dims, reduced depth per stack."""
    if cfg.is_moe and cfg.first_k_dense:
        dense = stack_sizes.get("dense", 1)
        moe = stack_sizes.get("moe", 1)
        return dataclasses.replace(cfg, n_layers=dense + moe, first_k_dense=dense)
    name = lm_mod.stacks_for(cfg)[0].name
    n = stack_sizes.get(name, 1)
    # keep SWA global-layer structure meaningful at tiny depth
    glob = tuple(g for g in cfg.global_layers if g < n)
    return dataclasses.replace(cfg, n_layers=n, global_layers=glob)


def _compile_variant(cfg, shape, multi_pod, *, loop_free_attn, opt_kind, remat,
                     serve_sharding="fsdp", param_mode="fsdp", pipeline_micro=0):
    """One lower+compile with UNROLLED layers (see lm.ANALYSIS_UNROLL_LAYERS)."""
    from repro.launch.dryrun import run_cell_for_cfg

    lm_mod.ANALYSIS_UNROLL_LAYERS = True
    try:
        return run_cell_for_cfg(
            cfg, shape, multi_pod=multi_pod, opt_kind=opt_kind, remat=remat,
            microbatches=1,
            q_chunk=shape.seq_len if loop_free_attn else 512,
            kv_chunk=shape.seq_len if loop_free_attn else 1024,
            serve_sharding=serve_sharding,
            param_mode=param_mode,
            pipeline_micro=pipeline_micro,
            verbose=False,
        )
    finally:
        lm_mod.ANALYSIS_UNROLL_LAYERS = False


def analyze_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    opt_kind: str = "adafactor",
    remat: str = "full",
    serve_sharding: str = "fsdp",
    param_mode: str = "fsdp",
    pipeline_micro: int = 0,
    bf16_reduce: bool = False,
    depths: tuple = (1, 2),
    production_rec: dict | None = None,
    verbose: bool = True,
) -> dict:
    from repro.models import layers as _layers

    _layers.TP_REDUCE_BF16 = bf16_reduce
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped", "reason": reason}

    stacks = lm_mod.stacks_for(cfg)
    names = [s.name for s in stacks]
    depth = {s.name: s.n_layers for s in stacks}

    # base: every stack at depth d0 (d0=2 stabilizes cells where XLA picks
    # different collective strategies at depth 1 — pass depths=(2,3))
    d0 = depths[0]
    base_cfg = _reduced_cfg(cfg, {n: d0 for n in names})
    base = _compile_variant(base_cfg, shape, multi_pod,
                            loop_free_attn=shape.mode != "decode",
                            opt_kind=opt_kind, remat=remat,
                            serve_sharding=serve_sharding, param_mode=param_mode,
                            pipeline_micro=pipeline_micro)
    if base["status"] != "ok":
        return {"arch": arch, "shape": shape_name, "status": "failed",
                "stage": "base", "error": base.get("error")}

    per_layer = {}
    for n in names:
        sizes = {m: (depths[1] if m == n else d0) for m in names}
        var = _compile_variant(_reduced_cfg(cfg, sizes), shape, multi_pod,
                               loop_free_attn=shape.mode != "decode",
                               opt_kind=opt_kind, remat=remat,
                               serve_sharding=serve_sharding, param_mode=param_mode,
                               pipeline_micro=pipeline_micro)
        if var["status"] != "ok":
            return {"arch": arch, "shape": shape_name, "status": "failed",
                    "stage": f"depth2:{n}", "error": var.get("error")}
        per_layer[n] = {
            "flops": var["flops"] - base["flops"],
            "coll": var["collective_bytes_total"] - base["collective_bytes_total"],
            "hbm": var["hbm_bytes"] - base["hbm_bytes"],
        }

    flops = base["flops"]
    coll = base["collective_bytes_total"]
    hbm_raw = base["hbm_bytes"]
    for n in names:
        flops += per_layer[n]["flops"] * (depth[n] - d0)
        coll += per_layer[n]["coll"] * (depth[n] - d0)
        hbm_raw += per_layer[n]["hbm"] * (depth[n] - d0)

    # SSD chunk-loop correction (train/prefill only; decode has no loop)
    ssd_note = None
    if cfg.uses_ssm and shape.mode != "decode":
        B, S = shape.global_batch, shape.seq_len
        Q = min(cfg.ssm_chunk, S)
        nc = max(1, S // Q)
        per_chip = _ssd_flops_per_layer(cfg, B, S, shape.mode) / nc / (512 if multi_pod else 256)
        mult = 3.0 if shape.mode == "train" else 1.0
        add = per_chip * (nc - 1) * cfg.n_layers * mult
        flops += add
        ssd_note = f"+{add:.3e} flops for {nc - 1} uncounted SSD chunks/layer"

    n_chips = 512 if multi_pod else 256
    model_flops = analytic_model_flops(cfg, shape)
    hbm_analytic = analytic_hbm_bytes(cfg, shape, 16, n_chips)
    terms = hlo_stats.roofline_terms(flops, hbm_analytic, coll, n_chips)
    dominant = max(
        ("t_compute", "t_memory", "t_collective"), key=lambda k: terms[k]
    )
    rec = {
        "arch": arch,
        "shape": shape_name,
        "serve_sharding": serve_sharding if shape.mode != "train" else None,
        "param_mode": param_mode if shape.mode == "train" else None,
        "pipeline_micro": pipeline_micro,
        "bf16_reduce": bf16_reduce,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok",
        "n_chips": n_chips,
        "flops_per_chip_corrected": flops,
        "collective_bytes_per_chip": coll,
        "hbm_bytes_analytic_per_chip": hbm_analytic,
        "hbm_bytes_hlo_raw_per_chip": hbm_raw,
        "model_flops_total": model_flops,
        "model_flops_per_chip": model_flops / n_chips,
        "useful_flops_ratio": (model_flops / n_chips) / max(flops, 1e-9),
        "roofline": terms,
        "dominant": dominant,
        "per_layer": per_layer,
        "base": {k: base[k] for k in ("flops", "collective_bytes_total", "hbm_bytes")},
        "collectives_base_breakdown": base.get("collectives"),
        "ssd_correction": ssd_note,
        "production": (
            {k: production_rec.get(k) for k in ("memory", "compile_s", "status")}
            if production_rec
            else None
        ),
    }
    if verbose:
        t = terms
        print(
            f"[roofline] {arch:>18s} × {shape_name:<11s} "
            f"Tc={t['t_compute']*1e3:9.3f}ms Tm={t['t_memory']*1e3:9.3f}ms "
            f"Tx={t['t_collective']*1e3:9.3f}ms dom={dominant[2:]:<10s} "
            f"useful={rec['useful_flops_ratio']*100:5.1f}%"
        )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/roofline.json")
    ap.add_argument("--serve-sharding", default="fsdp", choices=("fsdp", "tp"))
    ap.add_argument("--param-mode", default="fsdp", choices=("fsdp", "fsdp_all"))
    ap.add_argument("--bf16-reduce", action="store_true")
    ap.add_argument("--pipeline-micro", type=int, default=0)
    ap.add_argument("--depths", default="1,2", help="extrapolation depths, e.g. 2,3")
    args = ap.parse_args()

    cells = (
        [(a, s) for a in ARCHS for s in SHAPES]
        if args.all
        else [(args.arch, args.shape)]
    )
    records = []
    for arch, shape in cells:
        records.append(analyze_cell(arch, shape, multi_pod=args.multi_pod,
                                    serve_sharding=args.serve_sharding,
                                    param_mode=args.param_mode,
                                    pipeline_micro=args.pipeline_micro,
                                    bf16_reduce=args.bf16_reduce,
                                    depths=tuple(int(d) for d in args.depths.split(","))))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    existing = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            existing = json.load(f)
    keyed = {(r["arch"], r["shape"], r.get("mesh", ""), r.get("serve_sharding"),
              r.get("param_mode"), r.get("bf16_reduce"), r.get("pipeline_micro")): r
             for r in existing}
    for r in records:
        keyed[(r["arch"], r["shape"], r.get("mesh", ""), r.get("serve_sharding"),
               r.get("param_mode"), r.get("bf16_reduce"), r.get("pipeline_micro"))] = r
    with open(args.out, "w") as f:
        json.dump(list(keyed.values()), f, indent=1)
    print(f"wrote {args.out} ({len(keyed)} cells)")


if __name__ == "__main__":
    main()
