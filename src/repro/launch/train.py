"""End-to-end training driver.

Wires every substrate together: HR-routed data pipeline → jit'd
train_step (FSDP/TP when a mesh is given) → checkpoint manager (async,
HR-layout replicas) → failure injection/recovery → resume.

CPU-runnable: ``examples/train_tiny.py`` drives this with a ~100M config
for a few hundred steps. On a real cluster the same entry point runs
under the production mesh (launch/mesh.py).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.registry import ARCHS, get_arch, get_smoke
from repro.data.corpus import CorpusSpec, SyntheticCorpus
from repro.data.pipeline import HRDataPipeline
from repro.ft.failures import FailureInjector, FailurePlan
from repro.models import lm
from repro.models.config import ArchConfig
from repro.parallel.sharding import MeshCtx
from repro.training.optimizer import OptConfig, init_opt
from repro.training.steps import TrainSettings, make_train_step

__all__ = ["TrainLoopConfig", "run_training", "main"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    batch_size: int = 8
    seq_len: int = 256
    ckpt_dir: str = "artifacts/ckpt"
    ckpt_every: int = 50
    replication_factor: int = 3
    data_mechanism: str = "HR"
    log_every: int = 10
    seed: int = 0
    microbatches: int = 1
    remat: str = "dots"
    opt: OptConfig = dataclasses.field(default_factory=lambda: OptConfig(warmup_steps=20))
    failure_plan: FailurePlan = dataclasses.field(default_factory=FailurePlan)


def run_training(
    cfg: ArchConfig,
    loop: TrainLoopConfig,
    ctx: MeshCtx | None = None,
    *,
    resume: bool = True,
) -> dict:
    """Returns a summary dict (losses, recovery log, data-routing stats)."""
    tp = ctx.tp_size if ctx else 1
    corpus = SyntheticCorpus(CorpusSpec(n_docs=20_000, vocab_size=cfg.vocab_size, seed=loop.seed))
    pipeline = HRDataPipeline(
        corpus,
        replication_factor=loop.replication_factor,
        mechanism=loop.data_mechanism,
        seed=loop.seed,
    )
    injector = FailureInjector(loop.failure_plan, pipeline.engine)

    settings = TrainSettings(
        microbatches=loop.microbatches,
        remat=loop.remat,
        q_chunk=min(512, loop.seq_len),
        kv_chunk=min(1024, loop.seq_len),
        opt=loop.opt,
    )
    step_fn, _, _ = make_train_step(cfg, ctx, settings)
    if ctx is None:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    params = lm.init_lm(jax.random.PRNGKey(loop.seed), cfg, tp)
    opt_state = init_opt(params, loop.opt)
    start_step = 0

    ckpt = CheckpointManager(loop.ckpt_dir, every=loop.ckpt_every, replicas=loop.replication_factor)
    if resume:
        restored = ckpt.restore_latest({"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree = restored
            params = jax.tree.map(jnp.asarray, tree["params"])
            opt_state = jax.tree.map(jnp.asarray, tree["opt"])

    losses = []
    t0 = time.perf_counter()
    step = start_step
    while step < loop.steps:
        step += 1
        if injector.maybe_fail(step):
            # node lost: data replicas already rebuilt by the injector via
            # HR Recovery; restart model state from the last checkpoint.
            ckpt.wait()
            restored = ckpt.restore_latest({"params": params, "opt": opt_state})
            if restored is not None:
                rstep, tree = restored
                params = jax.tree.map(jnp.asarray, tree["params"])
                opt_state = jax.tree.map(jnp.asarray, tree["opt"])
                step = rstep + 1
        batch_np, _ = pipeline.sample_batch(loop.batch_size, loop.seq_len)
        batch = jax.tree.map(jnp.asarray, batch_np)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        ckpt.maybe_save(step, {"params": params, "opt": opt_state})
        if step % loop.log_every == 0:
            dt = time.perf_counter() - t0
            tok_s = loop.batch_size * loop.seq_len * step / max(dt, 1e-9)
            print(f"step {step:5d} loss {loss:7.4f} lr {float(metrics['lr']):.2e} {tok_s:9.0f} tok/s")
    ckpt.wait()

    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "losses": losses,
        "recoveries": injector.log,
        "data_layouts": [list(a) for a in pipeline.layouts()],
        "avg_rows_scanned": pipeline.total_rows_scanned / max(1, pipeline.n_reads),
        "steps_run": step,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    ap.add_argument("--data-mechanism", default="HR", choices=("HR", "TR"))
    ap.add_argument("--fail-at", type=int, default=0, help="inject a node failure at this step")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    plan = FailurePlan(fail_at_steps=(args.fail_at,) if args.fail_at else (), nodes=(0,))
    loop = TrainLoopConfig(
        steps=args.steps,
        batch_size=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        data_mechanism=args.data_mechanism,
        failure_plan=plan,
    )
    summary = run_training(cfg, loop)
    print(
        f"done: {summary['steps_run']} steps, final loss {summary['final_loss']:.4f}, "
        f"avg rows scanned/read {summary['avg_rows_scanned']:.0f}, "
        f"recoveries {len(summary['recoveries'])}"
    )


if __name__ == "__main__":
    main()
