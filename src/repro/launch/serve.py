"""Batched serving driver: prefill a batch of prompts, decode N tokens.

CPU-runnable with smoke configs (examples/serve_batch.py). Greedy
sampling; reports prefill latency and decode tokens/s. Under a mesh the
same entry point runs the SP decode path (seq-sharded KV).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_arch, get_smoke
from repro.models import lm
from repro.models.config import ArchConfig
from repro.parallel.sharding import MeshCtx
from repro.serving.steps import make_decode_step, make_prefill_step

__all__ = ["serve_batch", "main"]


def serve_batch(
    cfg: ArchConfig,
    *,
    batch_size: int = 4,
    prompt_len: int = 64,
    gen_tokens: int = 32,
    ctx: MeshCtx | None = None,
    seed: int = 0,
) -> dict:
    tp = ctx.tp_size if ctx else 1
    params = lm.init_lm(jax.random.PRNGKey(seed), cfg, tp)
    rng = np.random.default_rng(seed)
    s_alloc = prompt_len + gen_tokens
    if ctx is not None:
        s_alloc = -(-s_alloc // tp) * tp

    if cfg.input_mode == "tokens":
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (batch_size, prompt_len)), jnp.int32)}
    else:
        batch = {"embeds": jnp.asarray(rng.normal(0, 1, (batch_size, prompt_len, cfg.d_model)), jnp.bfloat16)}

    prefill_step = make_prefill_step(cfg, ctx, s_alloc=s_alloc,
                                     q_chunk=min(512, prompt_len), kv_chunk=min(1024, prompt_len))
    decode_step = make_decode_step(cfg, ctx)

    t0 = time.perf_counter()
    logits, cache = prefill_step(params, batch)
    logits = jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    generated = []
    t0 = time.perf_counter()
    tok = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
    for i in range(gen_tokens):
        if cfg.n_codebooks > 1:
            tok_step = tok.reshape(batch_size, cfg.n_codebooks)[:, :1]  # greedy cb0
        else:
            tok_step = tok.reshape(batch_size, 1)
        generated.append(np.asarray(tok_step))
        if cfg.input_mode == "tokens":
            step_in = {"tokens": tok_step}
        else:
            # embedding-frontend archs decode from (stub) frame embeddings
            step_in = {"embeds": jnp.asarray(
                rng.normal(0, 1, (batch_size, 1, cfg.d_model)), jnp.bfloat16)}
        logits, cache = decode_step(params, cache, step_in, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[..., : cfg.vocab_size], axis=-1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0

    return {
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_s": batch_size * gen_tokens / max(t_decode, 1e-9),
        "tokens": np.concatenate(generated, axis=1),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-3b", choices=ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    out = serve_batch(cfg, batch_size=args.batch, prompt_len=args.prompt_len, gen_tokens=args.gen)
    print(
        f"prefill {out['prefill_s']*1e3:.1f} ms | decode {out['decode_tok_s']:.1f} tok/s "
        f"| sample tokens {out['tokens'][0, :8].tolist()}"
    )


if __name__ == "__main__":
    main()
