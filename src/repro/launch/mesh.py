"""Production meshes.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. Single-pod: 16×16 =
256 chips ("data", "model"); multi-pod: 2×16×16 = 512 chips
("pod", "data", "model") — the pod axis is an extra data-parallel /
pipeline dimension that crosses the inter-pod DCI links.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_shape"]


def make_mesh_shape(*, multi_pod: bool = False):
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    shape, axes = make_mesh_shape(multi_pod=multi_pod)
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, found {len(devices)} — the "
            "dry-run must set XLA_FLAGS=--xla_force_host_platform_device_count"
            " BEFORE importing jax (see launch/dryrun.py)"
        )
    kwargs = {}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:  # older jax: no axis_types kwarg either
        kwargs["axis_types"] = (axis_type.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, devices=devices[:n], **kwargs)
