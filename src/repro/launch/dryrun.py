import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements — jax locks the device
count on first init, and the production meshes need 512 host devices.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun

Per cell it records: compile ok, memory_analysis (bytes/device),
cost_analysis (FLOPs, bytes), and the collective-bytes breakdown parsed
from the post-SPMD HLO — the inputs for EXPERIMENTS.md §Roofline.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.configs.registry import ARCHS, SHAPES, get_arch, shape_applicable
from repro.launch import hlo_stats
from repro.launch.input_specs import (
    decode_input_specs,
    prefill_batch_specs,
    train_batch_specs,
)
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import make_ctx
from repro.serving.steps import make_decode_step, make_prefill_step
from repro.training.optimizer import OptConfig
from repro.training.steps import TrainSettings, make_train_step, train_state_shapes


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, opt_kind: str = "adafactor",
             remat: str = "full", microbatches: int = 1,
             serve_sharding: str = "fsdp", verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape_name)
    if not ok:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "mode": shape.mode, "status": "skipped", "reason": reason,
        }
    rec = run_cell_for_cfg(cfg, shape, multi_pod=multi_pod, opt_kind=opt_kind,
                           remat=remat, microbatches=microbatches,
                           serve_sharding=serve_sharding, verbose=verbose)
    rec["arch"] = arch
    rec["shape"] = shape_name
    return rec


def run_cell_for_cfg(cfg, shape, *, multi_pod: bool, opt_kind: str = "adafactor",
                     remat: str = "full", microbatches: int = 1,
                     q_chunk: int = 512, kv_chunk: int = 1024,
                     serve_sharding: str = "fsdp", param_mode: str = "fsdp",
                     pipeline_micro: int = 0,
                     verbose: bool = True) -> dict:
    arch = cfg.name
    shape_name = shape.name
    rec: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": shape.mode,
    }
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    shard_batch = shape.global_batch % _dp_size(mesh) == 0 and shape.global_batch >= _dp_size(mesh)
    ctx = make_ctx(mesh, shard_batch=shard_batch)
    tp = ctx.tp_size

    t0 = time.perf_counter()
    try:
        if shape.mode == "train":
            settings = TrainSettings(remat=remat, opt=OptConfig(kind=opt_kind),
                                     microbatches=microbatches, param_mode=param_mode,
                                     pipeline_micro=pipeline_micro,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk)
            step, in_sh, _ = make_train_step(cfg, ctx, settings)
            p_shapes, o_shapes = train_state_shapes(cfg, settings, tp)
            batch = train_batch_specs(cfg, shape)
            lowered = step.lower(p_shapes, o_shapes, batch)
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, ctx, s_alloc=shape.seq_len,
                                     q_chunk=q_chunk, kv_chunk=kv_chunk,
                                     serve_sharding=serve_sharding)
            p_shapes = jax.eval_shape(
                lambda: __import__("repro.models.lm", fromlist=["init_lm"]).init_lm(
                    jax.random.PRNGKey(0), cfg, tp
                )
            )
            batch = prefill_batch_specs(cfg, shape)
            lowered = step.lower(p_shapes, batch)
        else:  # decode
            step = make_decode_step(cfg, ctx, serve_sharding=serve_sharding)
            p_shapes = jax.eval_shape(
                lambda: __import__("repro.models.lm", fromlist=["init_lm"]).init_lm(
                    jax.random.PRNGKey(0), cfg, tp
                )
            )
            cache, batch_t, pos = decode_input_specs(cfg, shape, tp)
            lowered = step.lower(p_shapes, cache, batch_t, pos)

        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        coll = hlo_stats.collective_bytes(compiled.as_text())
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            n_chips=n_chips,
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            flops=ca.get("flops", 0.0),
            hbm_bytes=ca.get("bytes accessed", 0.0),
            collectives={"bytes": coll.per_op_bytes, "count": coll.count},
            collective_bytes_total=coll.total,
            roofline=hlo_stats.roofline_terms(
                ca.get("flops", 0.0), ca.get("bytes accessed", 0.0), coll.total, n_chips
            ),
        )
        if verbose:
            print(
                f"[ok] {arch:>18s} × {shape_name:<11s} mesh={rec['mesh']:<7s} "
                f"compile={t_compile:6.1f}s arg={ma.argument_size_in_bytes/1e9:6.2f}GB "
                f"temp={ma.temp_size_in_bytes/1e9:6.2f}GB "
                f"flops={rec['flops']:.3e} coll={coll.total/1e6:9.1f}MB"
            )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug we record
        rec["status"] = "failed"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} mesh={rec['mesh']}: {rec['error']}")
    return rec


def _dp_size(mesh) -> int:
    n = 1
    for name in mesh.axis_names:
        if name != "model":
            n *= mesh.shape[name]
    return n


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true", help="2×16×16 mesh (default 16×16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--opt", default="adafactor", choices=("adamw", "adamw_bf16", "adafactor"))
    ap.add_argument("--remat", default="full", choices=("none", "dots", "full"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--serve-sharding", default="fsdp", choices=("fsdp", "tp"))
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells.append((args.arch, args.shape))

    meshes = [True, False] if args.both_meshes else [args.multi_pod]
    records = []
    for arch, shape in cells:
        for mp in meshes:
            records.append(
                run_cell(arch, shape, multi_pod=mp, opt_kind=args.opt, remat=args.remat,
                         microbatches=args.microbatches,
                         serve_sharding=args.serve_sharding)
            )

    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skipped" for r in records)
    n_fail = sum(r["status"] == "failed" for r in records)
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_fail} FAILED")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
