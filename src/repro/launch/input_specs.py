"""ShapeDtypeStruct stand-ins for every model input — shardable,
weak-type-correct, zero allocation (the dry-run pattern).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.registry import ShapeSpec
from repro.models import lm
from repro.models.config import ArchConfig
from repro.parallel.padding import padded_dims

__all__ = ["train_batch_specs", "decode_input_specs", "prefill_batch_specs"]


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {}
    if cfg.input_mode == "tokens":
        batch["tokens"] = _sds((B, S), jnp.int32)
    else:
        batch["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    if cfg.n_codebooks > 1:
        batch["labels"] = _sds((B, S, cfg.n_codebooks), jnp.int32)
    else:
        batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.input_mode == "tokens":
        return {"tokens": _sds((B, S), jnp.int32)}
    return {"embeds": _sds((B, S, cfg.d_model), jnp.bfloat16)}


def decode_input_specs(cfg: ArchConfig, shape: ShapeSpec, tp: int):
    """(cache, batch_t, pos) stand-ins: one new token against a KV cache
    of shape.seq_len."""
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: lm.init_cache(cfg, B, S, tp))
    if cfg.input_mode == "tokens":
        batch_t = {"tokens": _sds((B, 1), jnp.int32)}
    else:
        batch_t = {"embeds": _sds((B, 1, cfg.d_model), jnp.bfloat16)}
    pos = _sds((), jnp.int32)
    return cache_shapes, batch_t, pos
