"""Yi-34B [arXiv:2403.04652; hf 01-ai/Yi-34B] — llama-arch GQA.

60L, d_model 7168, 56 q-heads, GQA kv=8, d_ff 20480, vocab 64000.
SwiGLU, RoPE theta 5e6.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    attention="gqa",
    rope_theta=5_000_000.0,
    act="silu",
    gated_mlp=True,
)

SMOKE = ArchConfig(
    name="yi-34b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=128,
    attention="gqa",
    act="silu",
    gated_mlp=True,
)
