"""PaliGemma-3B [arXiv:2407.07726; hf google/paligemma-3b-pt-224].

Gemma-2B text backbone: 18L, d_model 2048, 8 q-heads (MQA kv=1,
d_head 256), d_ff 16384 (GeGLU), vocab 257216, sqrt(d) embedding scale.
The SigLIP vision tower is a STUB — input_specs() provides precomputed
patch+text embeddings [B, S, d_model]. q-heads pad 8→16 for the 16-way
model axis.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_ff=16384,
    vocab_size=257_216,
    attention="gqa",
    d_head=256,
    act="gelu",
    gated_mlp=True,
    input_mode="embeddings",
    embed_scale=True,
)

SMOKE = ArchConfig(
    name="paligemma-3b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=192,
    vocab_size=256,
    attention="gqa",
    d_head=16,
    act="gelu",
    gated_mlp=True,
    input_mode="embeddings",
    embed_scale=True,
)
