"""Registry: --arch <id> → ArchConfig, shapes, and cell applicability."""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ArchConfig

__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "get_arch", "get_smoke", "shape_applicable"]

_MODULES = {
    "starcoder2-3b": "starcoder2_3b",
    "yi-34b": "yi_34b",
    "chatglm3-6b": "chatglm3_6b",
    "minitron-8b": "minitron_8b",
    "mamba2-780m": "mamba2_780m",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "hymba-1.5b": "hymba_1_5b",
    "musicgen-medium": "musicgen_medium",
    "paligemma-3b": "paligemma_3b",
}

ARCHS = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def _load(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get_arch(name: str) -> ArchConfig:
    return _load(name).CONFIG


def get_smoke(name: str) -> ArchConfig:
    return _load(name).SMOKE


def shape_applicable(cfg: ArchConfig, shape: str) -> tuple[bool, str]:
    """(applicable?, reason-if-not). long_500k needs sub-quadratic decode
    state; pure full-attention archs skip it (DESIGN §4)."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return False, (
            "pure full-attention arch: a 524k dense KV cache is quadratic-"
            "regime; no sub-quadratic attention in the published config"
        )
    return True, ""
