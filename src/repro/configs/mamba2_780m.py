"""Mamba2-780m [arXiv:2405.21060; hf state-spaces/mamba2-780m] — SSD.

48L, d_model 1536 (attention-free), vocab 50280, ssm_state 128,
expand 2 (d_inner 3072), head dim 64 (48 SSD heads), conv 4.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    attention="none",
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=128,
    attention="none",
    ssm_state=16,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=16,
    tie_embeddings=True,
)
