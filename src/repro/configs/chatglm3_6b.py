"""ChatGLM3-6B [arXiv:2406.12793; hf THUDM/chatglm3-6b].

28L, d_model 4096, 32 q-heads, GQA kv=2, d_ff 13696, vocab 65024.
2-d RoPE (rotary on half the head dims → rotary_pct 0.5), SwiGLU.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="chatglm3-6b",
    family="dense",
    n_layers=28,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=65024,
    attention="gqa",
    rotary_pct=0.5,
    act="silu",
    gated_mlp=True,
)

SMOKE = ArchConfig(
    name="chatglm3-6b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=128,
    attention="gqa",
    rotary_pct=0.5,
    act="silu",
    gated_mlp=True,
)
