"""Qwen1.5-MoE-A2.7B [hf Qwen/Qwen1.5-MoE-A2.7B].

24L, d_model 2048, 16 q-heads (MHA, kv=16), vocab 151936.
MoE: 60 routed experts top-4 (d_ff 1408 each) + 4 shared experts
(fused shared MLP width 5632). Routed experts are padded 60→64 for
EP over the 16-way model axis (pad experts masked in the router).
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab_size=151_936,
    attention="gqa",
    rope_theta=1_000_000.0,
    act="silu",
    gated_mlp=True,
    n_experts=60,
    n_shared_experts=4,
    moe_top_k=4,
    moe_d_ff=1408,
    moe_aux_alpha=0.001,
)

SMOKE = ArchConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=128,
    attention="gqa",
    act="silu",
    gated_mlp=True,
    n_experts=8,
    n_shared_experts=2,
    moe_top_k=2,
    moe_d_ff=32,
    moe_aux_alpha=0.001,
)
