"""DeepSeek-V3 671B [arXiv:2412.19437; hf deepseek-ai/DeepSeek-V3].

61L, d_model 7168, 128 heads, MLA (q-LoRA 1536, kv-LoRA 512,
qk nope 128 + rope 64, v 128). MoE: 1 shared + 256 routed top-8
(expert d_ff 2048), first 3 layers dense (d_ff 18432). Sigmoid router,
aux-loss-free (alpha 0). MTP head on. vocab 129280.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=0,
    vocab_size=129_280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
    d_head=192,  # qk_nope + qk_rope (scores dim)
    act="silu",
    gated_mlp=True,
    n_experts=256,
    n_shared_experts=1,
    moe_top_k=8,
    moe_d_ff=2048,
    first_k_dense=3,
    dense_d_ff=18432,
    router_score="sigmoid",
    moe_aux_alpha=0.0,
    mtp=True,
)

SMOKE = ArchConfig(
    name="deepseek-v3-671b-smoke",
    family="moe",
    n_layers=3,  # 1 dense + 2 moe
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=128,
    attention="mla",
    q_lora_rank=32,
    kv_lora_rank=16,
    qk_nope_dim=16,
    qk_rope_dim=8,
    v_head_dim=16,
    d_head=24,
    act="silu",
    gated_mlp=True,
    n_experts=8,
    n_shared_experts=1,
    moe_top_k=2,
    moe_d_ff=32,
    first_k_dense=1,
    dense_d_ff=96,
    router_score="sigmoid",
    moe_aux_alpha=0.0,
    mtp=True,
)
