"""StarCoder2-3B [arXiv:2402.19173; hf bigcode/starcoder2-3b].

30L, d_model 3072, 24 q-heads, GQA kv=2, d_ff 12288, vocab 49152.
GELU (non-gated) MLP, RoPE theta 999999, tied embeddings.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    attention="gqa",
    rope_theta=999_999.0,
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="starcoder2-3b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    attention="gqa",
    act="gelu",
    gated_mlp=False,
    tie_embeddings=True,
)
