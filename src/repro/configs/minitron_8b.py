"""Minitron-8B [arXiv:2407.14679; hf nvidia/Minitron-8B-Base] — pruned Nemotron-4.

32L, d_model 4096, 32 q-heads, GQA kv=8, d_ff 16384, vocab 256000.
Non-gated MLP (Nemotron squared-ReLU ≈ we use gelu — noted in DESIGN),
partial rotary 0.5.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=256_000,
    attention="gqa",
    rotary_pct=0.5,
    act="gelu",
    gated_mlp=False,
)

SMOKE = ArchConfig(
    name="minitron-8b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    attention="gqa",
    rotary_pct=0.5,
    act="gelu",
    gated_mlp=False,
)
