"""Hymba-1.5B [arXiv:2411.13676; hf nvidia/Hymba-1.5B-Base].

32L, d_model 1600, 25 q-heads (GQA kv=5, d_head 64), d_ff 5504,
vocab 32001, parallel attention ∥ Mamba heads in every layer
(per-branch RMSNorm, averaged), SWA 1024 everywhere except 3 global
layers {first, middle, last}, ssm_state 16.

Adaptation notes (DESIGN §Arch-applicability): q-heads pad 25→32 for
the 16-way model axis; 128 meta tokens omitted (config ships 0);
cross-layer KV sharing omitted.
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    attention="gqa",
    d_head=64,
    sliding_window=1024,
    global_layers=(0, 15, 31),
    act="silu",
    gated_mlp=True,
    hybrid=True,
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=128,
)

SMOKE = ArchConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=128,
    attention="gqa",
    d_head=16,
    sliding_window=8,
    global_layers=(0, 2),
    act="silu",
    gated_mlp=True,
    hybrid=True,
    ssm_state=8,
    ssm_head_dim=16,
    ssm_expand=2,
    ssm_conv=4,
    ssm_chunk=8,
)
