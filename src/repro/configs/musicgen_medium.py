"""MusicGen-medium [arXiv:2306.05284; hf facebook/musicgen-medium].

48L decoder over EnCodec tokens: d_model 1536, 24 heads (MHA),
d_ff 6144, vocab 2048 per codebook × 4 codebooks. The EnCodec frontend
is a STUB — input_specs() provides precomputed frame embeddings
[B, S, d_model]; the 4 per-codebook output heads are real. (MusicGen's
sinusoidal positions are replaced by RoPE — backbone-equivalent compute,
noted in DESIGN.)
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    attention="gqa",
    act="gelu",
    gated_mlp=False,
    input_mode="embeddings",
    n_codebooks=4,
)

SMOKE = ArchConfig(
    name="musicgen-medium-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=64,
    attention="gqa",
    act="gelu",
    gated_mlp=False,
    input_mode="embeddings",
    n_codebooks=4,
)
