"""Assigned-architecture configs (exact published numbers) + smoke configs."""

from .registry import ARCHS, SHAPES, get_arch, get_smoke, shape_applicable

__all__ = ["ARCHS", "SHAPES", "get_arch", "get_smoke", "shape_applicable"]
