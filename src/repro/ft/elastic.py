"""Elastic scaling: restore a checkpoint onto a different mesh.

Checkpoints are mesh-independent (full arrays reassembled from chunks),
so scaling in/out is: build the new mesh/ctx → compute the new sharding
specs from the SAME logical axes → device_put the restored tree. The
only constraint is divisibility of sharded dims by the new axis sizes —
``check_mesh_fits`` validates before committing.
"""

from __future__ import annotations

import jax

from repro.models import lm
from repro.models.config import ArchConfig
from repro.parallel.sharding import MeshCtx, param_specs_for_tree

__all__ = ["check_mesh_fits", "reshard_tree"]


def check_mesh_fits(cfg: ArchConfig, ctx: MeshCtx) -> list[str]:
    """Returns a list of divisibility violations (empty = fits)."""
    problems = []
    tp = ctx.tp_size
    shapes = jax.eval_shape(lambda: lm.init_lm(jax.random.PRNGKey(0), cfg, tp))
    specs = param_specs_for_tree(ctx, lm.lm_axes(cfg, tp))
    flat_s, _ = jax.tree.flatten(shapes)
    flat_p = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
    )
    for sds, spec in zip(flat_s, flat_p):
        for dim, entry in zip(sds.shape, tuple(spec) + (None,) * (len(sds.shape) - len(spec))):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in axes:
                size *= ctx.mesh.shape[a]
            if dim % size:
                problems.append(f"dim {dim} not divisible by {size} ({axes})")
    return problems


def reshard_tree(tree, ctx: MeshCtx, axes_tree):
    """device_put a host tree onto ctx's mesh with the given logical axes."""
    specs = param_specs_for_tree(ctx, axes_tree)
    shard = jax.tree.map(
        lambda s: ctx.sharding(*s),
        specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    return jax.device_put(tree, shard)
