"""Deterministic chaos harness for the availability layer.

Jepsen-style fault scheduling scaled to the in-process cluster: a
seeded :class:`ChaosSchedule` draws a sequence of fault events —
transient node crashes, torn commit-log tails, bit-flip run corruption,
straggler slowdowns, aborted flushes — and :class:`ChaosHarness` applies
them to a *victim* engine while feeding the identical write/query
stream to a fault-free *oracle* engine. Between faults the harness
keeps reading at ``QUORUM`` so digest comparison, read repair,
failover retry and the accrual failure detector all run under fire.

The acceptance property (the whole point): **for any seeded fault
schedule, after the heal phase — hinted-handoff ``node_up`` for every
crashed node, a drain of aborted flushes, one ``scrub_column_family``
sweep — the victim's replicas are mutually row-identical and every
partition's dataset fingerprint equals the oracle's, and a full QUORUM
probe battery returns the oracle's answers.** Everything is
deterministic: same seed → same schedule → same repairs → same report.

``python -m repro.ft.chaos --seeds 3 --steps 25`` runs the property
over several seeds (the CI smoke); a nonzero exit code means a seed
violated it.

:class:`OverloadHarness` (``--overload``) is the serving-layer sibling:
instead of storage faults it drives a seeded Poisson arrival stream —
with burst windows (arrival rate × ~10) and slow-drain windows (node
slowdowns injected mid-run through the front door's virtual timeline) —
through a :class:`~repro.serving.frontdoor.FrontDoor` over the victim.
Its acceptance property: **every request either answers identically to
the no-fault oracle or is *explicitly* refused** (rejected / shed /
deadline, each typed and counted in ``frontdoor.stats``), the
accounting balances, the queue never exceeds its bound, and the
overload is non-vacuous (at least one refusal actually happened) — no
silent slow requests, no unbounded queue growth. Unlike the storage
harness, its *counters* are not byte-stable across runs: the front
door's virtual clock consumes measured engine walls, so the split
between refusal kinds shifts with machine speed. The arrival stream
and the acceptance property are what a seed pins down.

Both harnesses double as the observability acceptance gate: each
``run()`` asserts that every typed refusal, degradation-rung
transition, and repair path is visible in the owning
:class:`~repro.obs.metrics.MetricsRegistry` (the response-status
accounting must equal the counters, and the audit inventories —
``REFUSAL_COUNTERS`` / ``RUNG_COUNTERS`` on the front door,
``FAULT_COUNTERS`` / ``REPAIR_COUNTERS`` on the engine — must all
resolve in the registry catalog). Pass ``tracer=`` to record span
trees: the storage harness roots one ``chaos.probe`` tree per victim
QUORUM probe (with a :class:`~repro.obs.trace.TickClock` tracer the
JSON-lines dump is byte-identical across runs of the same seed), and
the overload harness hands the tracer to its front door, whose
slow-query log keeps the K slowest request trees.
``--trace OUT.jsonl`` on the CLI dumps and re-validates the trace.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import (
    CommitLog,
    HREngine,
    KeySchema,
    QUORUM,
    TransientFault,
    random_workload,
)
from repro.core.engine import FAULT_COUNTERS, REPAIR_COUNTERS, VIEW_COUNTERS
from repro.ft.detector import FailureDetector
from repro.ft.straggler import clear_slowdowns, inject_slowdown
from repro.obs import TickClock, Tracer, dump_jsonl, load_jsonl

__all__ = [
    "ChaosEvent",
    "ChaosSchedule",
    "ChaosHarness",
    "ChaosReport",
    "OverloadHarness",
    "OverloadReport",
    "KINDS",
]

KINDS = ("crash", "torn_tail", "corrupt_run", "slow_node", "flush_abort")


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scheduled fault. Fields are kind-specific: ``node_id`` for
    crash/slow_node/flush_abort, ``partition_id`` for torn_tail /
    corrupt_run, ``magnitude`` the slowdown factor or the corruption
    placement salt, ``duration`` the outage/slowdown length in steps."""

    step: int
    kind: str
    node_id: int = -1
    partition_id: int = -1
    magnitude: float = 0.0
    duration: int = 0


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    """Seed-deterministic event sequence over a fixed step horizon."""

    seed: int
    n_steps: int
    n_nodes: int
    n_partitions: int
    events: tuple[ChaosEvent, ...]

    @classmethod
    def generate(
        cls,
        seed: int,
        *,
        n_steps: int = 30,
        n_nodes: int = 6,
        n_partitions: int = 4,
        rate: float = 0.35,
    ) -> "ChaosSchedule":
        """Draw one fault at each step with probability ``rate``. Crash
        outages are kept non-overlapping (at most one node down at a
        time) so an RF=3 partition always retains a read quorum — the
        regime hinted handoff is designed for; overlapping outages are
        ``recover_node``'s territory, tested separately."""
        rng = np.random.default_rng(seed)
        events: list[ChaosEvent] = []
        down: list[tuple[int, int]] = []  # inclusive crash intervals
        for step in range(n_steps):
            if rng.random() >= rate:
                continue
            kind = KINDS[int(rng.integers(len(KINDS)))]
            if kind == "crash":
                dur = int(rng.integers(1, 4))
                if any(not (step + dur < s or e < step) for s, e in down):
                    continue  # would overlap an open outage
                down.append((step, step + dur))
                events.append(
                    ChaosEvent(
                        step,
                        "crash",
                        node_id=int(rng.integers(n_nodes)),
                        duration=dur,
                    )
                )
            elif kind == "torn_tail":
                events.append(
                    ChaosEvent(
                        step,
                        "torn_tail",
                        partition_id=int(rng.integers(n_partitions)),
                    )
                )
            elif kind == "corrupt_run":
                events.append(
                    ChaosEvent(
                        step,
                        "corrupt_run",
                        partition_id=int(rng.integers(n_partitions)),
                        magnitude=float(rng.random()),
                    )
                )
            elif kind == "slow_node":
                events.append(
                    ChaosEvent(
                        step,
                        "slow_node",
                        node_id=int(rng.integers(n_nodes)),
                        magnitude=float(rng.uniform(20.0, 200.0)),
                        duration=int(rng.integers(2, 6)),
                    )
                )
            else:
                events.append(
                    ChaosEvent(
                        step,
                        "flush_abort",
                        node_id=int(rng.integers(n_nodes)),
                    )
                )
        return cls(
            seed=int(seed),
            n_steps=int(n_steps),
            n_nodes=int(n_nodes),
            n_partitions=int(n_partitions),
            events=tuple(events),
        )


@dataclasses.dataclass
class ChaosReport:
    seed: int
    ok: bool
    failures: list[str]
    n_events: int
    stats: dict


_CF = "chaos"
_REL_TOL = 1e-6  # replica layouts sum in different orders


class ChaosHarness:
    """Victim-vs-oracle chaos run (see module docstring)."""

    def __init__(
        self,
        seed: int,
        *,
        n_steps: int = 30,
        n_nodes: int = 6,
        n_partitions: int = 4,
        rate: float = 0.35,
        n_rows: int = 3000,
        write_rows: int = 120,
        n_probes: int = 8,
        probe_every: int = 5,
        memtable_rows: int = 200,
        views: bool = False,
        tracer: Tracer | None = None,
    ) -> None:
        self.tracer = tracer
        self.views = bool(views)
        self.schedule = ChaosSchedule.generate(
            seed,
            n_steps=n_steps,
            n_nodes=n_nodes,
            n_partitions=n_partitions,
            rate=rate,
        )
        self.write_rows = write_rows
        self.probe_every = probe_every
        rng = np.random.default_rng(seed + 1_000_003)  # data stream seed

        bits = {"k0": 12, "k1": 10, "k2": 8}
        self._dom = {c: 2**b for c, b in bits.items()}
        kc = {
            c: rng.integers(0, d, n_rows).astype(np.int64)
            for c, d in self._dom.items()
        }
        vc = {"metric": rng.uniform(0.0, 1.0, n_rows)}
        schema = KeySchema(bits=bits)
        self.probes = random_workload(
            rng, schema, list(kc), n_probes, value_col="metric"
        ).queries
        self._rng = rng

        cf_kwargs = dict(
            replication_factor=3,
            mechanism="HR",
            workload=random_workload(
                np.random.default_rng(0), schema, list(kc), 16, value_col="metric"
            ),
            schema=schema,
            hrca_kwargs={"k_max": 200, "seed": 0},
            partitions=n_partitions,
            memtable_rows=memtable_rows,
        )
        if self.views:
            # materialized-view chaos: BOTH engines go device-resident
            # with views so the oracle property stays exact — the view
            # serve path is bit-identical to the fused full scan, and
            # the heal phase additionally audits the derived partials
            cf_kwargs.update(device_resident=True, views=True)
        # deterministic scan walls: the detector's routing penalties —
        # and therefore which replica answers each probe — must be a
        # pure function of the schedule, or the same-seed traced runs
        # could not export byte-identical span trees
        self.victim = HREngine(
            n_nodes=n_nodes, failure_detector=FailureDetector(),
            scan_timer=TickClock(),
        )
        self.oracle = HREngine(n_nodes=n_nodes)
        self.victim.create_column_family(_CF, kc, vc, **cf_kwargs)
        self.oracle.create_column_family(_CF, kc, vc, **cf_kwargs)

        self._pending_up: dict[int, int] = {}  # node -> step to bring up
        self._slow_until: dict[int, int] = {}

    # -- event application --------------------------------------------------

    def _apply(self, ev: ChaosEvent) -> None:
        eng = self.victim
        cf = eng.column_families[_CF]
        if ev.kind == "crash":
            eng.fail_node(ev.node_id, transient=True)
            self._pending_up[ev.node_id] = ev.step + ev.duration
        elif ev.kind == "torn_tail":
            # reserialize the partition log with a torn frame appended:
            # the byte codec must drop exactly the torn tail
            part = cf.partitions[ev.partition_id]
            tear = CommitLog()
            tear.append({"k": np.array([0], np.int64)}, {})
            blob = part.commitlog.to_bytes() + tear.to_bytes()[:-3]
            restored = CommitLog.from_bytes(blob)
            if len(restored) != len(part.commitlog):
                raise AssertionError("torn tail ate a committed record")
            part.commitlog = restored
        elif ev.kind == "corrupt_run":
            part = cf.partitions[ev.partition_id]
            salt = int(ev.magnitude * 1e9)
            cands = [
                r
                for r in part.replicas
                if eng.nodes[r.node_id].alive
                and (cf.name, r.replica_id) in eng.nodes[r.node_id].tables
            ]
            if not cands:
                return
            r = cands[salt % len(cands)]
            arr = eng._table(cf, r).value_cols["metric"]
            if arr.size == 0 or arr.dtype != np.float64:
                return
            # one exponent-bit flip: silent on-disk corruption the
            # checksum (scrub) and value digests (QUORUM) must catch
            arr.view(np.int64)[salt % arr.size] ^= np.int64(1) << np.int64(62)
        elif ev.kind == "slow_node":
            inject_slowdown(eng, ev.node_id, ev.magnitude)
            self._slow_until[ev.node_id] = ev.step + ev.duration
        elif ev.kind == "flush_abort":
            eng.nodes[ev.node_id].flush_fault_budget += 1
        else:  # pragma: no cover - schedule only emits known kinds
            raise ValueError(f"unknown chaos kind {ev.kind!r}")

    def _write_batch(self) -> None:
        n = self.write_rows
        kc = {
            c: self._rng.integers(0, d, n).astype(np.int64)
            for c, d in self._dom.items()
        }
        vc = {"metric": self._rng.uniform(0.0, 1.0, n)}
        self.oracle.write(_CF, kc, vc)
        try:
            self.victim.write(_CF, kc, vc)
        except TransientFault:
            # an aborted flush: the rows are already committed to the
            # log and staged — a later flush (or the heal drain) lands
            # them
            pass

    def _probe(self, failures: list[str], tag: str) -> None:
        for qi, q in enumerate(self.probes):
            want, _ = self.oracle.read(_CF, q)
            root = None
            if self.tracer is not None:
                # one span tree per victim probe; with a TickClock
                # tracer these are byte-identical across runs of the
                # same seed (the dump is the determinism fixture)
                root = self.tracer.root("chaos.probe", tag=tag, probe=qi)
            try:
                got, _ = self.victim.read(
                    _CF, q, consistency=QUORUM, trace=root
                )
            except (TransientFault, RuntimeError) as exc:
                if root is not None:
                    root.end(error=type(exc).__name__)
                failures.append(f"{tag} probe {qi}: raised {exc!r}")
                continue
            finally:
                if root is not None and root.t_end is None:
                    root.end()
            if got.rows_matched != want.rows_matched:
                failures.append(
                    f"{tag} probe {qi}: rows {got.rows_matched} != "
                    f"{want.rows_matched}"
                )
            tol = _REL_TOL * max(1.0, abs(want.value))
            if abs(got.value - want.value) > tol:
                failures.append(
                    f"{tag} probe {qi}: value {got.value!r} != {want.value!r}"
                )

    # -- the run -------------------------------------------------------------

    def run(self) -> ChaosReport:
        sched = self.schedule
        by_step: dict[int, list[ChaosEvent]] = {}
        for ev in sched.events:
            by_step.setdefault(ev.step, []).append(ev)
        failures: list[str] = []

        for step in range(sched.n_steps):
            # due recoveries first: a node can return the same step
            # another event lands
            for nid, up_at in list(self._pending_up.items()):
                if step >= up_at:
                    self.victim.node_up(nid)
                    del self._pending_up[nid]
            for nid, until in list(self._slow_until.items()):
                if step >= until:
                    self.victim.nodes[nid].slowdown = 1.0
                    del self._slow_until[nid]
            for ev in by_step.get(step, ()):
                self._apply(ev)
            self._write_batch()
            if step and step % self.probe_every == 0:
                self._probe(failures, f"step {step}")

        # heal phase: hinted handoff for every open outage, straggler
        # flags cleared, aborted flushes drained, one scrub sweep
        for nid in range(sched.n_nodes):
            self.victim.node_up(nid)
        clear_slowdowns(self.victim)
        for node in self.victim.nodes:  # chaos window closed
            node.flush_fault_budget = 0
            node.read_fault_budget = 0
        self.victim.flush_memtables(_CF)
        self.oracle.flush_memtables(_CF)
        self.victim.scrub_column_family(_CF)

        # the oracle property
        cf_v = self.victim.column_families[_CF]
        cf_o = self.oracle.column_families[_CF]
        for part_v, part_o in zip(cf_v.partitions, cf_o.partitions):
            if (part_v.token_lo, part_v.token_hi) != (
                part_o.token_lo,
                part_o.token_hi,
            ):
                failures.append(
                    f"partition {part_v.partition_id}: ring diverged"
                )
                continue
            fps = {
                self.victim._table(cf_v, r).dataset_fingerprint()
                for r in part_v.replicas
            }
            if len(fps) != 1:
                failures.append(
                    f"partition {part_v.partition_id}: replicas disagree "
                    f"({len(fps)} distinct fingerprints)"
                )
                continue
            want_fp = self.oracle._table(
                cf_o, part_o.replicas[0]
            ).dataset_fingerprint()
            if fps != {want_fp}:
                failures.append(
                    f"partition {part_v.partition_id}: fingerprint != oracle"
                )
        self._probe(failures, "final")

        if self.views:
            # derived-state audit: after heal every live replica's
            # per-block partials must re-derive exactly from its
            # resident arrays, an always-eligible probe must route
            # through the view path (counted), and its answer must
            # still match the oracle bit-for-bit
            from repro.core.storage.views import verify_views
            from repro.core.workload import Query

            for part in cf_v.partitions:
                for r in part.replicas:
                    node = self.victim.nodes[r.node_id]
                    t = node.tables.get((_CF, r.replica_id))
                    if t is None or not node.alive:
                        continue
                    if not t.has_views or not verify_views(t):
                        failures.append(
                            f"replica {r.replica_id}: views diverged from "
                            "resident arrays after heal"
                        )
            hits0 = int(self.victim.stats["view_hits"])
            probe = Query(agg="count", filters={})
            want, _ = self.oracle.read(_CF, probe)
            got, _ = self.victim.read(_CF, probe)
            if got.value != want.value:
                failures.append(
                    f"view probe: count {got.value!r} != {want.value!r}"
                )
            if int(self.victim.stats["view_hits"]) <= hits0:
                failures.append(
                    "view probe: eligible count did not route through views"
                )
            # counter-balance: the heal phase rebuilt at least every
            # scrub-healed or log-rebuilt replica's views, and the
            # boundary-row counter only moves with hits
            st = self.victim.stats
            if st["view_boundary_rows"] and not st["view_hits"]:
                failures.append("view_boundary_rows moved without view_hits")

        # observability audit: every repair path and typed engine fault
        # the harness can provoke must resolve to a registry counter
        cat = set(self.victim.metrics.catalog())
        missing = [
            n
            for n in (*REPAIR_COUNTERS, *FAULT_COUNTERS.values(), *VIEW_COUNTERS)
            if n not in cat
        ]
        if missing:
            failures.append(
                f"registry catalog missing repair/fault counters: {missing}"
            )

        return ChaosReport(
            seed=sched.seed,
            ok=not failures,
            failures=failures,
            n_events=len(sched.events),
            stats=self.victim.stats,
        )


@dataclasses.dataclass
class OverloadReport:
    seed: int
    ok: bool
    failures: list[str]
    n_requests: int
    stats: dict


class OverloadHarness:
    """Front-door overload chaos: Poisson arrivals with burst and
    slow-drain windows, checked shed-or-exact against a no-fault oracle
    (see module docstring).

    Only *slowdown* faults are injected — never corruption: under queue
    pressure the front door degrades QUORUM to ONE, and a degraded read
    of a corrupted replica could legitimately diverge from the oracle.
    Overload correctness (every answer exact or explicitly refused) and
    corruption repair (:class:`ChaosHarness`) are separate properties.
    """

    def __init__(
        self,
        seed: int,
        *,
        n_requests: int = 400,
        n_rows: int = 3000,
        n_nodes: int = 6,
        n_partitions: int = 4,
        base_interarrival_s: float = 200e-6,
        burst_factor: float = 10.0,
        slowdown: float = 50.0,
        deadline_s: float = 50e-3,
        quorum_frac: float = 0.3,
        tracer: Tracer | None = None,
    ) -> None:
        from repro.serving.frontdoor import FrontDoor, Request

        self.seed = int(seed)
        rng = np.random.default_rng(seed + 7_000_037)
        bits = {"k0": 12, "k1": 10, "k2": 8}
        dom = {c: 2**b for c, b in bits.items()}
        kc = {
            c: rng.integers(0, d, n_rows).astype(np.int64)
            for c, d in dom.items()
        }
        vc = {"metric": rng.uniform(0.0, 1.0, n_rows)}
        schema = KeySchema(bits=bits)
        cf_kwargs = dict(
            replication_factor=3,
            mechanism="HR",
            workload=random_workload(
                np.random.default_rng(0), schema, list(kc), 16, value_col="metric"
            ),
            schema=schema,
            hrca_kwargs={"k_max": 200, "seed": 0},
            partitions=n_partitions,
        )
        self.victim = HREngine(
            n_nodes=n_nodes,
            failure_detector=FailureDetector(),
            result_cache=False,
        )
        self.oracle = HREngine(n_nodes=n_nodes, result_cache=False)
        self.victim.create_column_family(_CF, kc, vc, **cf_kwargs)
        self.oracle.create_column_family(_CF, kc, vc, **cf_kwargs)

        queries = random_workload(
            rng, schema, list(kc), n_requests, value_col="metric"
        ).queries

        # Poisson arrivals; the middle third of the run is a burst
        # window (rate × burst_factor). Gaps are seeded draws, so the
        # whole stream replays bit-identically per seed.
        t = 0.0
        arrivals: list[float] = []
        burst_lo, burst_hi = n_requests // 3, 2 * n_requests // 3
        for i in range(n_requests):
            mean = base_interarrival_s / (
                burst_factor if burst_lo <= i < burst_hi else 1.0
            )
            t += float(rng.exponential(mean))
            arrivals.append(t)

        self.requests = [
            Request(
                _CF,
                q,
                arrival_s=arrivals[i],
                deadline_s=deadline_s,
                priority=int(rng.integers(0, 3)),
                consistency=QUORUM if rng.random() < quorum_frac else "ONE",
            )
            for i, q in enumerate(queries)
        ]
        # slow-drain window: while the burst is still queued, straggle
        # half the nodes; cleared later so the tail of the run recovers
        slow_at = arrivals[burst_lo]
        clear_at = arrivals[min(burst_hi + n_requests // 6, n_requests - 1)]
        slow_nodes = list(range(0, n_nodes, 2))
        self.timeline = [
            (
                slow_at,
                lambda: [
                    inject_slowdown(self.victim, n, slowdown) for n in slow_nodes
                ],
            ),
            (clear_at, lambda: clear_slowdowns(self.victim)),
        ]
        self.frontdoor = FrontDoor(
            self.victim,
            max_batch=16,
            max_wait=base_interarrival_s * 4,
            max_queue=96,
            bulkhead_inflight=64,
            tracer=tracer,
        )

    def run(self) -> OverloadReport:
        failures: list[str] = []
        responses = self.frontdoor.serve(self.requests, timeline=self.timeline)
        stats = self.frontdoor.stats
        refused = 0
        for i, (req, resp) in enumerate(zip(self.requests, responses)):
            if resp is None:
                failures.append(f"request {i}: no response at all")
                continue
            if resp.ok:
                want, _ = self.oracle.read(_CF, req.query)
                tol = _REL_TOL * max(1.0, abs(want.value))
                if (
                    resp.result.rows_matched != want.rows_matched
                    or abs(resp.result.value - want.value) > tol
                ):
                    failures.append(
                        f"request {i}: served {resp.result.value!r} != "
                        f"oracle {want.value!r}"
                    )
                if (
                    req.deadline_s is not None
                    and resp.latency_s > req.deadline_s
                ):
                    failures.append(
                        f"request {i}: silently slow ok answer "
                        f"({resp.latency_s * 1e3:.1f} ms > budget)"
                    )
            else:
                refused += 1
                if resp.status not in ("rejected", "shed", "deadline"):
                    failures.append(
                        f"request {i}: unknown terminal status {resp.status!r}"
                    )
                if not resp.error:
                    failures.append(f"request {i}: untyped refusal")
        answered = sum(1 for r in responses if r is not None and r.ok)
        if answered + refused != len(self.requests):
            failures.append(
                f"accounting leak: {answered} ok + {refused} refused != "
                f"{len(self.requests)} submitted"
            )
        # observability audit: the response-status accounting must be
        # mirrored exactly in the registry counters — a refusal or rung
        # transition the counters cannot see is a silent path
        from repro.serving.frontdoor import REFUSAL_COUNTERS, RUNG_COUNTERS

        by = {
            s: sum(1 for r in responses if r is not None and r.status == s)
            for s in ("ok", "rejected", "shed", "deadline")
        }
        mirror = (
            ("ok responses", by["ok"], stats["served_ok"]),
            (
                "rejected responses",
                by["rejected"],
                stats["rejected_throttle"]
                + stats["rejected_bulkhead"]
                + stats["rejected_queue_full"],
            ),
            ("shed responses", by["shed"], stats["shed_overload"]),
            ("deadline responses", by["deadline"], stats["shed_deadline"]),
            (
                "degraded responses",
                sum(1 for r in responses if r is not None and r.degraded),
                stats["consistency_degraded"],
            ),
        )
        for what, seen, counted in mirror:
            if seen != counted:
                failures.append(
                    f"counter mirror broken: {seen} {what} but the "
                    f"registry counted {counted}"
                )
        cat = set(self.frontdoor.metrics.catalog())
        missing = sorted(
            (set(REFUSAL_COUNTERS.values()) | set(RUNG_COUNTERS.values())) - cat
        )
        if missing:
            failures.append(
                f"registry catalog missing refusal/rung counters: {missing}"
            )
        if stats["max_queue_depth"] > self.frontdoor.max_queue:
            failures.append(
                f"queue grew past its bound "
                f"({stats['max_queue_depth']} > {self.frontdoor.max_queue})"
            )
        if refused == 0:
            failures.append(
                "vacuous run: the overload never forced a single refusal"
            )
        return OverloadReport(
            seed=self.seed,
            ok=not failures,
            failures=failures,
            n_requests=len(self.requests),
            stats=stats,
        )


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seeds", type=int, default=3, help="run seeds 0..N-1")
    ap.add_argument("--seed", type=int, default=None, help="run one seed")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--rate", type=float, default=0.35)
    ap.add_argument(
        "--overload",
        action="store_true",
        help="front-door overload scenario (shed-or-exact property) "
        "instead of the storage-fault schedule",
    )
    ap.add_argument(
        "--views",
        action="store_true",
        help="run the storage-fault schedule on device-resident column "
        "families with materialized aggregate views: every view-routed "
        "answer must stay bit-identical to the no-fault oracle and the "
        "view partials must verify after heal",
    )
    ap.add_argument(
        "--trace",
        metavar="OUT.jsonl",
        default=None,
        help="record span trees and dump them as JSON-lines (storage "
        "mode: one TickClock tree per QUORUM probe, byte-identical per "
        "seed; overload mode: the front door's slowest request trees); "
        "the dump is re-validated and an empty or malformed trace "
        "fails the run",
    )
    args = ap.parse_args(argv)

    seeds = [args.seed] if args.seed is not None else list(range(args.seeds))
    bad = 0
    traced: list = []  # (latency, Span) pairs or bare Spans, all seeds

    def _dump_trace() -> int:
        """Write + re-validate the trace dump; nonzero on a bad dump."""
        if args.trace is None:
            return 0
        n = dump_jsonl(traced, args.trace)
        try:
            docs = load_jsonl(args.trace)
        except ValueError as e:
            print(f"trace: INVALID dump: {e}")
            return 1
        if not docs:
            print(f"trace: EMPTY dump at {args.trace} — no span trees recorded")
            return 1
        print(f"trace: wrote {n} span trees to {args.trace}")
        return 0

    if args.overload:
        for seed in seeds:
            tracer = Tracer() if args.trace is not None else None
            harness = OverloadHarness(seed, tracer=tracer)
            report = harness.run()
            if tracer is not None:
                traced.extend(harness.frontdoor.slow_log.entries())
            s = report.stats
            counters = ", ".join(
                f"{k}={int(s[k])}"
                for k in (
                    "served_ok",
                    "rejected_queue_full",
                    "rejected_bulkhead",
                    "shed_overload",
                    "shed_deadline",
                    "consistency_degraded",
                    "hedged_batches",
                    "batches",
                )
            )
            print(
                f"overload seed {seed}: {'OK' if report.ok else 'FAIL'} "
                f"({report.n_requests} requests; {counters})"
            )
            for f in report.failures:
                print(f"  - {f}")
            bad += not report.ok
        bad += _dump_trace()
        return 1 if bad else 0
    for seed in seeds:
        # a fresh TickClock tracer per seed: span ids and timestamps
        # restart, so the per-seed dump is byte-stable across runs
        tracer = Tracer(clock=TickClock()) if args.trace is not None else None
        harness = ChaosHarness(
            seed, n_steps=args.steps, rate=args.rate, tracer=tracer,
            views=args.views,
        )
        report = harness.run()
        if tracer is not None:
            traced.extend(tracer.roots)
        keys = (
            "hints_queued",
            "hint_replays",
            "hint_fallbacks",
            "digest_mismatches",
            "read_repairs",
            "read_retries",
            "scrub_repairs",
        )
        if args.views:
            keys += ("view_hits", "view_rebuilds")
        counters = ", ".join(f"{k}={report.stats[k]}" for k in keys)
        print(
            f"seed {seed}: {'OK' if report.ok else 'FAIL'} "
            f"({report.n_events} events; {counters})"
        )
        for f in report.failures:
            print(f"  - {f}")
        bad += not report.ok
    bad += _dump_trace()
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
