"""Accrual failure detection (phi-style) over per-node scan latencies.

Cassandra never answers "is this node down?" with a boolean — its
phi-accrual detector (Hayashibara et al.) outputs a *suspicion level*
that grows continuously as a node's responses fall outside the latency
distribution its peers establish, and each consumer picks its own
threshold. This module is that idea fitted to the simulated cluster:

* ``record(node_id, latency)`` feeds one measured scan wall time per
  executed replica group (the engine calls it from the read path;
  result-cache hits don't execute and are not samples).
* ``record_failure(node_id)`` feeds a raised scan (injected fault /
  chaos event); consecutive failures add a fixed phi step each, and
  one successful sample clears the streak — the classic accrual shape
  where silence is evidence that accumulates.
* ``phi(node_id)`` is the suspicion level: the failure-streak term plus
  ``-log10 P(latency >= observed mean | peer distribution)`` under a
  normal fit of the *other* nodes' recent samples. Comparing against
  peers, not the node's own history, is what makes a straggler visible:
  its own window would just normalize the slowness away.
* ``cost_factor(node_id)`` maps phi onto the engine's cost matrices:
  1.0 while alive, ``suspect_penalty`` at ``phi >= phi_suspect``,
  ``dead_penalty`` at ``phi >= phi_dead``. The engine *multiplies*
  ranking costs by this factor — soft avoidance (Cassandra's dynamic
  snitch badness threshold), never hard exclusion: a suspected node
  still serves when it is the only replica, and keeps producing the
  samples that can clear its suspicion.

Everything is deterministic — phi is a pure function of the recorded
samples, so seeded chaos schedules replay to identical routing.
"""

from __future__ import annotations

import math
from collections import deque

__all__ = ["FailureDetector", "LatencyEWMA"]

#: sigma floor as a fraction of the peer mean: scan walls are heavy-
#: tailed at microsecond scale, and a near-zero fitted sigma would let
#: scheduler jitter alone push phi past any threshold.
_SIGMA_FLOOR_FRAC = 0.25


class LatencyEWMA:
    """Exponentially-weighted latency tracker: mean plus mean absolute
    deviation of a stream of wall-time samples.

    The serving front door feeds it per-request queue waits and reads
    it to decide when to *hedge* — the same observed-latency idea as
    the phi detector above, but over the front door's own queue rather
    than per-node scan walls, and with a threshold the caller owns
    (``mean() > k × max_wait`` style) instead of a suspicion level.
    Deterministic: the state is a pure fold over the recorded samples.
    """

    def __init__(self, *, alpha: float = 0.2) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.alpha = float(alpha)
        self._mean: float | None = None
        self._dev: float = 0.0
        self._count: int = 0

    def record(self, latency_s: float) -> None:
        """Fold one wall-seconds sample into the running estimates."""
        x = float(latency_s)
        if self._mean is None:
            self._mean = x
        else:
            err = abs(x - self._mean)
            self._dev += self.alpha * (err - self._dev)
            self._mean += self.alpha * (x - self._mean)
        self._count += 1

    def mean(self) -> float:
        """Smoothed mean latency (0.0 before any sample)."""
        return 0.0 if self._mean is None else self._mean

    def deviation(self) -> float:
        """Smoothed mean absolute deviation — a cheap spread estimate
        for "how far past the mean is surprising"."""
        return self._dev

    @property
    def count(self) -> int:
        """Samples recorded so far (thresholds often want a warm-up)."""
        return self._count


class FailureDetector:
    """Phi-accrual-style detector over per-node operation latencies."""

    def __init__(
        self,
        *,
        window: int = 32,
        phi_suspect: float = 4.0,
        phi_dead: float = 12.0,
        suspect_penalty: float = 4.0,
        dead_penalty: float = 64.0,
        failure_phi: float = 4.0,
        min_samples: int = 4,
    ) -> None:
        if window < 2:
            raise ValueError("window must be >= 2")
        if not 0 < phi_suspect <= phi_dead:
            raise ValueError("need 0 < phi_suspect <= phi_dead")
        if suspect_penalty < 1.0 or dead_penalty < suspect_penalty:
            raise ValueError("need 1.0 <= suspect_penalty <= dead_penalty")
        self.window = int(window)
        self.phi_suspect = float(phi_suspect)
        self.phi_dead = float(phi_dead)
        self.suspect_penalty = float(suspect_penalty)
        self.dead_penalty = float(dead_penalty)
        self.failure_phi = float(failure_phi)
        self.min_samples = int(min_samples)
        self._samples: dict[int, deque[float]] = {}
        self._failures: dict[int, int] = {}

    # -- feeding -----------------------------------------------------------

    def record(self, node_id: int, latency_s: float) -> None:
        """One successful operation's wall seconds; clears any failure
        streak (the node answered)."""
        self._samples.setdefault(int(node_id), deque(maxlen=self.window)).append(
            float(latency_s)
        )
        self._failures.pop(int(node_id), None)

    def record_failure(self, node_id: int) -> None:
        """One raised/timed-out operation; consecutive failures stack."""
        self._failures[int(node_id)] = self._failures.get(int(node_id), 0) + 1

    # -- reading -----------------------------------------------------------

    def _latency_phi(self, node_id: int) -> float:
        mine = self._samples.get(int(node_id))
        if mine is None or len(mine) < self.min_samples:
            return 0.0
        peers: list[float] = []
        for nid, dq in self._samples.items():
            if nid != int(node_id):
                peers.extend(dq)
        if len(peers) < self.min_samples:
            return 0.0
        mu = sum(peers) / len(peers)
        var = sum((x - mu) ** 2 for x in peers) / len(peers)
        sigma = max(math.sqrt(var), _SIGMA_FLOOR_FRAC * abs(mu), 1e-9)
        recent = sum(mine) / len(mine)
        z = (recent - mu) / sigma
        # one-sided survival under the peer normal; phi = -log10 of it
        sf = 0.5 * math.erfc(z / math.sqrt(2.0))
        return -math.log10(max(sf, 1e-300))

    def phi(self, node_id: int) -> float:
        """Current suspicion level: failure-streak term plus the
        latency-outlier term (0.0 for an unknown / healthy node —
        ``-log10(0.5) ≈ 0.3`` is the at-the-mean baseline)."""
        return self.failure_phi * self._failures.get(
            int(node_id), 0
        ) + self._latency_phi(node_id)

    def state(self, node_id: int) -> str:
        """``"alive"`` | ``"suspected"`` | ``"dead"`` at the configured
        thresholds (a label over :meth:`phi`, for observability)."""
        p = self.phi(node_id)
        if p >= self.phi_dead:
            return "dead"
        if p >= self.phi_suspect:
            return "suspected"
        return "alive"

    def cost_factor(self, node_id: int) -> float:
        """Multiplier the engine applies to this node's ranking costs:
        soft down-ranking, never exclusion."""
        p = self.phi(node_id)
        if p >= self.phi_dead:
            return self.dead_penalty
        if p >= self.phi_suspect:
            return self.suspect_penalty
        return 1.0

    def suspected_nodes(self) -> list[int]:
        """Node ids currently at or past ``phi_suspect``, ascending."""
        return sorted(
            nid
            for nid in set(self._samples) | set(self._failures)
            if self.phi(nid) >= self.phi_suspect
        )
