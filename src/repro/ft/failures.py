"""Failure simulation + recovery orchestration.

``FailurePlan`` injects node failures at chosen steps; the training
driver (launch/train.py) responds by: (1) rebuilding lost data-index
replicas through the HR engine's Recovery module (log replay or
survivor re-sort), (2) restarting the step loop from the last
checkpoint. This is the single-host simulation of the pod-level
contract: checkpoint/restart + replica rebuild, with straggler hedging
handled in ``ft.straggler``, suspicion-based routing in
``ft.detector`` and randomized multi-fault scheduling in ``ft.chaos``.

Two outage shapes, per plan entry:

* ``durations`` absent or 0 — the legacy instant fail-and-recover: the
  node goes down and is rebuilt within the same ``maybe_fail`` call
  (what a driver that only checkpoints/restarts expects).
* ``durations[i] > 0`` — an *open outage*: the node stays down for
  that many steps while the cluster serves degraded, then
  ``maybe_recover`` (or ``tick``) heals it — via hinted handoff
  (``HREngine.node_up``) when the plan is ``transient``, else the full
  ``recover_node`` rebuild.

Entries sharing a step all fire at that step (each against its own
node) — indexing the plan by entry, not by step value, is what makes
repeated steps well defined.
"""

from __future__ import annotations

import dataclasses

from repro.core import HREngine

__all__ = ["FailurePlan", "FailureInjector"]


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    fail_at_steps: tuple[int, ...] = ()
    nodes: tuple[int, ...] = ()  # node failing at each entry (cycled)
    durations: tuple[int, ...] = ()  # outage length in steps (cycled; 0 = instant)
    transient: bool = False  # transient outage (heal = node_up) vs node loss

    def entry(self, idx: int) -> tuple[int, int, int]:
        """(step, node, duration) of plan entry ``idx``."""
        step = self.fail_at_steps[idx]
        node = self.nodes[idx % len(self.nodes)] if self.nodes else 0
        dur = self.durations[idx % len(self.durations)] if self.durations else 0
        return step, node, dur


class FailureInjector:
    def __init__(self, plan: FailurePlan, engine: HREngine | None) -> None:
        self.plan = plan
        self.engine = engine
        self.log: list[dict] = []
        # plan entry indices already fired — NOT step values: two
        # entries at the same step are distinct failures, and after a
        # checkpoint-restart rewind a fired entry must not re-fire
        self._fired: set[int] = set()
        self._open: list[dict] = []  # outages awaiting recovery

    @property
    def open_outages(self) -> list[dict]:
        """Outages currently down, each ``{"node", "recover_step"}``."""
        return [dict(o) for o in self._open]

    def maybe_fail(self, step: int) -> bool:
        """Fire every not-yet-fired plan entry scheduled at ``step``.
        Zero-duration entries fail and recover atomically (legacy
        shape); positive durations leave the node down until
        ``maybe_recover`` reaches ``step + duration``."""
        fired = False
        for idx in range(len(self.plan.fail_at_steps)):
            entry_step, node, dur = self.plan.entry(idx)
            if entry_step != step or idx in self._fired:
                continue
            self._fired.add(idx)
            fired = True
            secs = 0.0
            if self.engine is not None:
                self.engine.fail_node(node, transient=self.plan.transient)
                if dur <= 0:
                    secs = self._heal(node)
            if dur > 0:
                self._open.append({"node": node, "recover_step": step + dur})
            self.log.append(
                {
                    "step": step,
                    "node": node,
                    "duration": dur,
                    "recovery_s": secs,
                }
            )
        return fired

    def maybe_recover(self, step: int) -> bool:
        """Heal every open outage whose recovery step has arrived."""
        due = [o for o in self._open if o["recover_step"] <= step]
        if not due:
            return False
        self._open = [o for o in self._open if o["recover_step"] > step]
        for o in due:
            secs = self._heal(o["node"]) if self.engine is not None else 0.0
            self.log.append(
                {"step": step, "node": o["node"], "recovered": True,
                 "recovery_s": secs}
            )
        return True

    def tick(self, step: int) -> bool:
        """One driver step: recoveries due first (a node can come back
        the same step another goes down), then new failures."""
        recovered = self.maybe_recover(step)
        failed = self.maybe_fail(step)
        return recovered or failed

    def _heal(self, node: int) -> float:
        if self.plan.transient:
            return self.engine.node_up(node)
        return self.engine.recover_node(node)
