"""Failure simulation + recovery orchestration.

``FailurePlan`` injects node failures at chosen steps; the training
driver (launch/train.py) responds by: (1) rebuilding lost data-index
replicas through the HR engine's Recovery module (re-sort a survivor),
(2) restarting the step loop from the last checkpoint. This is the
single-host simulation of the pod-level contract: checkpoint/restart +
replica rebuild, with straggler hedging handled in ft.straggler.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import HREngine

__all__ = ["FailurePlan", "FailureInjector"]


@dataclasses.dataclass(frozen=True)
class FailurePlan:
    fail_at_steps: tuple[int, ...] = ()
    nodes: tuple[int, ...] = ()  # node failing at each step (cycled)


class FailureInjector:
    def __init__(self, plan: FailurePlan, engine: HREngine | None) -> None:
        self.plan = plan
        self.engine = engine
        self.log: list[dict] = []
        self._fired: set[int] = set()

    def maybe_fail(self, step: int) -> bool:
        # each planned failure fires once — after recovery the step loop
        # rewinds past it (restart-from-checkpoint) and must not re-fail
        if step not in self.plan.fail_at_steps or step in self._fired:
            return False
        self._fired.add(step)
        idx = self.plan.fail_at_steps.index(step)
        node = self.plan.nodes[idx % len(self.plan.nodes)] if self.plan.nodes else 0
        if self.engine is not None:
            self.engine.fail_node(node)
            secs = self.engine.recover_node(node)
        else:
            secs = 0.0
        self.log.append({"step": step, "node": node, "recovery_s": secs})
        return True
