"""Straggler mitigation: cost-ranked hedged reads.

The HR engine already ranks replicas by estimated cost (Eq 3); hedging
duplicates a read that landed on a slow node onto the next-cheapest
replica on a different node — the paper's load-balance property made
into a tail-latency tool. ``inject_slowdown`` marks nodes as stragglers;
``measure_tail`` quantifies p50/p95/p99 with and without hedging.

Hedging is the *fast* half of the availability story: it races a
duplicate without declaring anyone unhealthy. Its slow half lives in
``ft.detector`` (phi-accrual suspicion that down-ranks a persistently
slow node in the cost matrices before the pick is even made) and
``ft.failures``/``ft.chaos`` (outage injection and the seeded
multi-fault harness that checks the whole stack against a no-fault
oracle).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import HREngine, Workload

__all__ = ["inject_slowdown", "clear_slowdowns", "measure_tail", "TailStats"]


def inject_slowdown(engine: HREngine, node_id: int, factor: float) -> None:
    engine.nodes[node_id].slowdown = factor


def clear_slowdowns(engine: HREngine) -> None:
    for n in engine.nodes:
        n.slowdown = 1.0


@dataclasses.dataclass
class TailStats:
    p50: float
    p95: float
    p99: float
    mean: float
    hedged_fraction: float


def measure_tail(
    engine: HREngine, cf: str, workload: Workload, *, hedge: bool, repeats: int = 1
) -> TailStats:
    lat = []
    hedged = 0
    for _ in range(repeats):
        for q in workload.queries:
            _, rep = engine.read(cf, q, hedge=hedge)
            lat.append(rep.wall_seconds)
            hedged += int(rep.hedged)
    lat = np.asarray(lat)
    return TailStats(
        p50=float(np.percentile(lat, 50)),
        p95=float(np.percentile(lat, 95)),
        p99=float(np.percentile(lat, 99)),
        mean=float(lat.mean()),
        hedged_fraction=hedged / max(1, len(lat)),
    )
