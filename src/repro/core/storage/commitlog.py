"""Append-only commit log — the layout-agnostic durability record.

One log per column family, shared by every replica: records hold the
written rows in *canonical column order* (the schema's key/value names),
never in any replica's layout, so a single record stream can rebuild any
heterogeneous serialization (replay → sort by that replica's layout).
Records carry monotonically increasing sequence numbers (LSNs); the
CREATE-time base dataset is record 0, so replaying from the beginning
reconstructs the full table, including writes a dead node missed.

Durability is modeled by the byte codec: ``to_bytes`` frames every
record as ``magic · lsn · payload length · crc32(payload) · payload``
and ``from_bytes`` replays frames until the first torn or corrupt one —
a crash mid-append loses at most the tail record, never a prefix
(classic commit-log semantics, property-tested in
``tests/test_properties.py``).

Memory: the log holds exactly one extra copy of the column family's
dataset. This system is append-only (no updates or deletes), so log
rows == current table rows — retention is O(current rows), the same
asymptote as any single replica, not O(operations). What *does* grow
with write count is the per-record framing overhead and replay's
concatenation fan-in; ``checkpoint`` collapses the history into one
snapshot record to bound both (``HREngine.checkpoint_commitlog`` is
the flush-then-checkpoint form), and the count-based trigger
(:meth:`CommitLog.should_checkpoint`, mirroring ``CompactionPolicy``'s
threshold rule) lets the engine fire it automatically after a flush
once more than ``k`` records accumulated since the last snapshot
(``HREngine(commitlog_checkpoint_records=k)``; 0 disables — the
manual method remains). Unlike Cassandra, flushed
records cannot simply be dropped: a node failure here wipes the node's
sstables too, so the log (or a surviving peer) is the only rebuild
source.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib
from typing import Iterator, Mapping, Sequence

import numpy as np

__all__ = ["CommitLog", "LogRecord"]

_MAGIC = 0x48524C47  # "HRLG"
_HEADER = struct.Struct("<IQQI")  # magic, lsn, payload_len, crc32(payload)


@dataclasses.dataclass(frozen=True)
class LogRecord:
    """One committed write batch: columns in canonical (schema) order."""

    lsn: int
    key_cols: dict[str, np.ndarray]
    value_cols: dict[str, np.ndarray]

    @property
    def n_rows(self) -> int:
        for v in self.key_cols.values():
            return int(v.shape[0])
        return 0


def _pack_cols(cols: Mapping[str, np.ndarray]) -> bytes:
    out = [struct.pack("<I", len(cols))]
    for name, arr in cols.items():
        a = np.ascontiguousarray(arr)
        nb = name.encode("utf-8")
        db = a.dtype.str.encode("ascii")
        out.append(struct.pack("<III", len(nb), len(db), a.shape[0]))
        out.append(nb)
        out.append(db)
        out.append(a.tobytes())
    return b"".join(out)


def _unpack_cols(buf: memoryview, off: int) -> tuple[dict[str, np.ndarray], int]:
    (n_cols,) = struct.unpack_from("<I", buf, off)
    off += 4
    cols: dict[str, np.ndarray] = {}
    for _ in range(n_cols):
        nlen, dlen, n = struct.unpack_from("<III", buf, off)
        off += 12
        name = bytes(buf[off : off + nlen]).decode("utf-8")
        off += nlen
        dtype = np.dtype(bytes(buf[off : off + dlen]).decode("ascii"))
        off += dlen
        nbytes = dtype.itemsize * n
        cols[name] = np.frombuffer(buf[off : off + nbytes], dtype=dtype).copy()
        off += nbytes
    return cols, off


class CommitLog:
    """In-order record log with LSNs, replay, truncation and a byte codec."""

    def __init__(
        self,
        key_names: Sequence[str] | None = None,
        value_names: Sequence[str] | None = None,
    ) -> None:
        self._records: list[LogRecord] = []
        self._next_lsn = 0
        self._key_names = tuple(key_names) if key_names is not None else None
        self._value_names = tuple(value_names) if value_names is not None else None
        # appends since the last checkpoint() — the auto-checkpoint
        # trigger's counter (reset by checkpoint, approximated by the
        # record count after truncate/from_bytes, where the true append
        # history is unknown)
        self._since_checkpoint = 0
        # LSN of the last snapshot record (None = never checkpointed).
        # A checkpoint collapses history into one fresh-LSN record, so
        # per-record replay from any cursor at or below it is impossible
        # — hinted-handoff watermarks must fall back to a full rebuild
        # (see can_replay_from).
        self._snapshot_lsn: int | None = None

    # -- append ------------------------------------------------------------

    def append(
        self, key_cols: Mapping[str, np.ndarray], value_cols: Mapping[str, np.ndarray]
    ) -> int:
        """Commit one write batch; returns its LSN. Columns are copied
        (the log must be immune to caller-side mutation) and stored in
        canonical order — the declared column names when the log was
        created, else the first record's order."""
        if self._key_names is None:
            self._key_names = tuple(key_cols)
            self._value_names = tuple(value_cols)
        missing = set(self._key_names) - set(key_cols)
        missing |= set(self._value_names or ()) - set(value_cols)
        if missing:
            raise KeyError(f"write batch missing columns {sorted(missing)}")
        kc = {c: np.array(key_cols[c], dtype=np.int64, copy=True) for c in self._key_names}
        vc = {c: np.array(value_cols[c], copy=True) for c in self._value_names or ()}
        n = {v.shape[0] for v in kc.values()} | {v.shape[0] for v in vc.values()}
        if len(n) > 1:
            raise ValueError(f"ragged write batch: column lengths {sorted(n)}")
        lsn = self._next_lsn
        self._next_lsn += 1
        self._records.append(LogRecord(lsn=lsn, key_cols=kc, value_cols=vc))
        self._since_checkpoint += 1
        return lsn

    # -- replay ------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._records)

    @property
    def tail(self) -> LogRecord | None:
        """The most recent record (e.g. the one ``append`` just wrote).
        Its arrays are the log's own normalized copies — safe to stage
        by reference as long as the borrower never mutates them."""
        return self._records[-1] if self._records else None

    @property
    def n_rows(self) -> int:
        return sum(r.n_rows for r in self._records)

    @property
    def next_lsn(self) -> int:
        """The LSN the next ``append`` will take — the exclusive upper
        bound of the committed history. A replica flushed through every
        current record is complete up to (excluding) this LSN, which is
        exactly the hinted-handoff watermark the engine stores."""
        return self._next_lsn

    def can_replay_from(self, start_lsn: int) -> bool:
        """Can the per-record suffix ``lsn >= start_lsn`` alone bring a
        replica that is complete below ``start_lsn`` up to date? False
        once a checkpoint collapsed records at-or-after the watermark
        into a snapshot (the snapshot holds the *whole* dataset — the
        tail is no longer separable), in which case the caller must
        rebuild from a full replay instead."""
        return self._snapshot_lsn is None or start_lsn > self._snapshot_lsn

    @property
    def records_since_checkpoint(self) -> int:
        """Appends since the last :meth:`checkpoint` (what the
        count-based auto-checkpoint trigger measures — per-record
        framing and replay fan-in grow with this, not with rows)."""
        return self._since_checkpoint

    def should_checkpoint(self, max_records: int) -> bool:
        """Count-based trigger mirroring ``CompactionPolicy``: True when
        more than ``max_records`` records accumulated since the last
        snapshot. ``max_records <= 0`` disables. The caller remains
        responsible for the safety condition (every replica flushed
        through the tail — ``HREngine`` checks its partition's
        memtables are drained before firing)."""
        return max_records > 0 and self._since_checkpoint > max_records

    def replay(self, start_lsn: int = 0) -> Iterator[LogRecord]:
        """Records with ``lsn >= start_lsn`` in commit order."""
        for rec in self._records:
            if rec.lsn >= start_lsn:
                yield rec

    def replay_columns(
        self, end_lsn: int | None = None, *, start_lsn: int = 0
    ) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
        """All rows of records with ``start_lsn <= lsn < end_lsn``
        (default: all), concatenated in commit order — the input any
        replica rebuild sorts into its own layout. A nonzero
        ``start_lsn`` is the hinted-handoff tail replay: only valid when
        :meth:`can_replay_from` holds for it."""
        recs = [
            r
            for r in self._records
            if r.lsn >= start_lsn and (end_lsn is None or r.lsn < end_lsn)
        ]
        if not recs:
            kn, vn = self._key_names or (), self._value_names or ()
            return (
                {c: np.empty(0, np.int64) for c in kn},
                {c: np.empty(0, np.float64) for c in vn},
            )
        kc = {
            c: np.concatenate([r.key_cols[c] for r in recs])
            for c in recs[0].key_cols
        }
        vc = {
            c: np.concatenate([r.value_cols[c] for r in recs])
            for c in recs[0].value_cols
        }
        return kc, vc

    def truncate(self, n_records: int) -> None:
        """Keep only the first ``n_records`` records (crash simulation:
        everything after the truncation point is lost)."""
        if n_records < 0:
            raise ValueError("n_records must be >= 0")
        self._records = self._records[:n_records]
        self._next_lsn = self._records[-1].lsn + 1 if self._records else 0
        self._since_checkpoint = min(self._since_checkpoint, len(self._records))

    def checkpoint(self) -> int:
        """Collapse the whole record history into one snapshot record
        holding the concatenated rows (Cassandra's "the sstables ARE
        the checkpoint", applied to this in-memory log): replaying the
        checkpointed log rebuilds exactly the same dataset, but memory
        and future replay cost become O(current rows) instead of
        O(total rows ever written). LSNs keep counting — the snapshot
        takes a fresh LSN, so ``replay(start_lsn)`` with an old cursor
        never silently skips rows. Returns the snapshot's LSN.

        Call it only when every replica has flushed through the log's
        tail (``HREngine.checkpoint_commitlog`` enforces that): the
        per-record structure is what lets a *partially applied* suffix
        be replayed record-by-record."""
        kc, vc = self.replay_columns()
        lsn = self._next_lsn
        self._next_lsn += 1
        self._records = [LogRecord(lsn=lsn, key_cols=kc, value_cols=vc)]
        self._since_checkpoint = 0
        self._snapshot_lsn = lsn  # tail-only replay below here is gone
        return lsn

    # -- migration surgery (vnode split/merge lineage) ---------------------

    def sliced(self, row_mask) -> "CommitLog":
        """Token-slice the record stream: a new log holding, per record
        and in the same commit order, only the rows where
        ``row_mask(record.key_cols)`` is True.

        This is the migration half of partition split: the child
        partition's log is a row-filtered *view of the same history*,
        re-LSN'd contiguously from 0. Record 0 is always kept (possibly
        empty) so the CREATE-base invariant holds; later records that
        filter to zero rows are dropped — they carry no lineage.
        """
        out = CommitLog(self._key_names, self._value_names)
        for i, rec in enumerate(self._records):
            if rec.n_rows:
                m = np.asarray(row_mask(rec.key_cols), dtype=bool)
                kc = {c: v[m] for c, v in rec.key_cols.items()}
                vc = {c: v[m] for c, v in rec.value_cols.items()}
            else:
                kc = {c: v.copy() for c, v in rec.key_cols.items()}
                vc = {c: v.copy() for c, v in rec.value_cols.items()}
            if i > 0 and next(iter(kc.values()), np.empty(0)).shape[0] == 0:
                continue
            out._records.append(
                LogRecord(lsn=out._next_lsn, key_cols=kc, value_cols=vc)
            )
            out._next_lsn += 1
        out._since_checkpoint = len(out._records)
        return out

    @classmethod
    def concatenated(cls, logs: Sequence["CommitLog"]) -> "CommitLog":
        """Concatenate record streams (the merge half of partition
        merge): records of ``logs[0]`` in order, then ``logs[1]``, …,
        with fresh contiguous LSNs. Pass the logs in ring order so the
        merged partition's record 0 is the leftmost CREATE base. Empty
        non-base records are dropped; the first log's record 0 is kept
        even when empty.

        Replaying the result concatenates exactly the per-log replays
        in ring order — and because equal packed keys cannot straddle a
        partition boundary, every replica's stable re-sort of that
        replay is bit-identical to re-sorting the union (tie runs stay
        whole, in their original commit order).
        """
        if not logs:
            raise ValueError("need at least one log to concatenate")
        out = cls(logs[0]._key_names, logs[0]._value_names)
        for j, log in enumerate(logs):
            for i, rec in enumerate(log._records):
                if rec.n_rows == 0 and not (j == 0 and i == 0):
                    continue
                out._records.append(
                    LogRecord(
                        lsn=out._next_lsn,
                        key_cols=rec.key_cols,
                        value_cols=rec.value_cols,
                    )
                )
                out._next_lsn += 1
        out._since_checkpoint = len(out._records)
        return out

    # -- byte codec --------------------------------------------------------

    def to_bytes(self) -> bytes:
        """Framed serialization: per record ``magic · lsn · len ·
        crc32 · payload``."""
        frames = []
        for rec in self._records:
            payload = _pack_cols(rec.key_cols) + _pack_cols(rec.value_cols)
            frames.append(
                _HEADER.pack(_MAGIC, rec.lsn, len(payload), zlib.crc32(payload))
            )
            frames.append(payload)
        return b"".join(frames)

    @classmethod
    def from_bytes(cls, data: bytes) -> "CommitLog":
        """Replay frames until the first torn (incomplete) or corrupt
        (bad magic / crc mismatch) one: a crash mid-append drops the
        tail record and every complete earlier record survives."""
        log = cls()
        buf = memoryview(data)
        off = 0
        while off + _HEADER.size <= len(buf):
            magic, lsn, plen, crc = _HEADER.unpack_from(buf, off)
            if magic != _MAGIC or off + _HEADER.size + plen > len(buf):
                break  # corrupt header or torn payload: stop at the prefix
            payload = buf[off + _HEADER.size : off + _HEADER.size + plen]
            if zlib.crc32(payload) != crc:
                break
            kc, p_off = _unpack_cols(payload, 0)
            vc, _ = _unpack_cols(payload, p_off)
            if log._key_names is None:
                log._key_names = tuple(kc)
                log._value_names = tuple(vc)
            log._records.append(LogRecord(lsn=lsn, key_cols=kc, value_cols=vc))
            log._next_lsn = lsn + 1
            off += _HEADER.size + plen
        log._since_checkpoint = len(log._records)
        # conservative snapshot marker: a first record with lsn > 0 can
        # only come from a checkpoint collapse (appends start at 0), so
        # hint watermarks at or below it must fall back to full rebuild
        if log._records and log._records[0].lsn > 0:
            log._snapshot_lsn = log._records[0].lsn
        return log
