"""Durable write path — commit log → memtable → sorted runs (paper §4).

Cassandra's write path staged onto this repro's tables: every write is
first appended to a layout-agnostic :class:`CommitLog` shared by all
replicas of a *partition* (on a token-ring-partitioned column family —
``repro.core.ring`` — each partition owns its own log holding exactly
the rows its token range covers; an unpartitioned CF is the P = 1
case with one log). Sequence numbers, a replay iterator and torn-tail-
safe byte framing make the log the durability record; a count-based
trigger (``CommitLog.should_checkpoint``) lets the engine collapse a
partition's record history into one snapshot automatically once it
outgrows ``commitlog_checkpoint_records``. Writes are then staged in
each replica's :class:`Memtable` and flushed as an immutable sorted
run in the replica's *own* heterogeneous key layout
(``SortedTable.merge_run``). :class:`CompactionPolicy` bounds how many
flushed runs a device-resident replica accumulates before they are
collapsed by the Pallas k-way merge kernel
(``repro.kernels.merge_device_runs``) — no host re-upload, no manual
``place_on_device(rebuild=True)``.

Recovery replays the owning partition's log: any replica's
serialization can be rebuilt from the record stream alone,
bit-identical to re-sorting a surviving peer (the paper's
heterogeneous-recovery claim), and a lost node rebuilds only the
partition replicas it hosted.
"""

from .commitlog import CommitLog, LogRecord
from .compaction import CompactionPolicy, compact_table
from .memtable import (
    Memtable,
    SortedRun,
    combine_digests,
    content_digest,
    run_crc32,
)

__all__ = [
    "CommitLog",
    "LogRecord",
    "CompactionPolicy",
    "compact_table",
    "Memtable",
    "SortedRun",
    "combine_digests",
    "content_digest",
    "run_crc32",
]
