"""Durable write path — commit log → memtable → sorted runs (paper §4).

Cassandra's write path staged onto this repro's tables: every write is
first appended to a layout-agnostic :class:`CommitLog` shared by all
replicas of a column family (sequence numbers, replay iterator,
torn-tail-safe byte framing), then staged in each replica's
:class:`Memtable`, and flushed as an immutable sorted run in the
replica's *own* heterogeneous key layout (``SortedTable.merge_run``).
:class:`CompactionPolicy` bounds how many flushed runs a
device-resident replica accumulates before they are collapsed by the
Pallas k-way merge kernel (``repro.kernels.merge_device_runs``) — no
host re-upload, no manual ``place_on_device(rebuild=True)``.

Recovery replays the shared log: any replica's serialization can be
rebuilt from the record stream alone, bit-identical to re-sorting a
surviving peer (the paper's heterogeneous-recovery claim).
"""

from .commitlog import CommitLog, LogRecord
from .compaction import CompactionPolicy, compact_table
from .memtable import Memtable, SortedRun

__all__ = [
    "CommitLog",
    "LogRecord",
    "CompactionPolicy",
    "compact_table",
    "Memtable",
    "SortedRun",
]
