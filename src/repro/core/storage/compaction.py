"""Automatic compaction policy for device-resident run stacks.

Flushed memtable runs are *appended* to a resident table's device arrays
(``device_state_append``): reads stay exact at any run count, but each
run adds an O(N) ``row_map`` maintenance cost on the host and kicks the
table off the single-run fast paths (device ``slab_many``, the no-gather
select). This policy bounds the stack: a replica is compacted when its
appended rows exceed ``appended_frac`` of the base run, or when the run
count alone exceeds ``max_runs`` (many small flushes). Compaction runs
the Pallas k-way merge (``SortedTable.compact_runs`` →
``repro.kernels.merge_device_runs``), collapsing the runs *on device* —
no host re-upload, no manual ``place_on_device(rebuild=True)``. This
closes the ROADMAP "compaction policy" open item.
"""

from __future__ import annotations

import dataclasses

__all__ = ["CompactionPolicy", "compact_table"]


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """Threshold rule: compact when appended rows outgrow the base run
    (``appended_frac``) or the stack outgrows ``max_runs``.

    Multi-cycle behavior (audited in PR 5,
    ``tests/test_storage.py::TestAutoCompaction``): every compaction
    folds the appended rows into the base, so the *next*
    ``appended_frac`` trigger needs ``appended_frac ×`` the new, larger
    base — a geometric full-merge cadence, the standard size-tiered
    trade (amortized O(log) rewrites per row). Under a steady drip of
    small writes that trigger therefore goes quiet and ``max_runs``
    becomes the binding rule, bounding both the run stack (no
    starvation of the single-run fast paths) and the cadence at one
    full merge per ``max_runs`` flushes (no compact-every-flush
    thrash). The accounting is drift-free across cycles: ``base_rows``
    is always ``run_starts[1]`` of the live device state, and an
    append onto an *empty* base becomes the base run itself rather
    than a phantom appended run (``device_state_append``)."""

    appended_frac: float = 0.5
    max_runs: int = 8

    def __post_init__(self) -> None:
        if self.appended_frac < 0:
            raise ValueError("appended_frac must be >= 0")
        if self.max_runs < 1:
            raise ValueError("max_runs must be >= 1")

    def should_compact(
        self, *, base_rows: int, appended_rows: int, n_runs: int
    ) -> bool:
        if n_runs <= 1:
            return False
        if n_runs > self.max_runs:
            return True
        return appended_rows > self.appended_frac * max(base_rows, 1)


def compact_table(table, policy: CompactionPolicy, *, use_pallas: bool = True) -> bool:
    """Apply ``policy`` to one table; returns True when a compaction ran.

    Host tables never compact (the host merge path is always fully
    merged — runs are a device-residency structure only).
    """
    state = getattr(table, "_device", None)
    if state is None or state.get("n_runs", 1) <= 1:
        return False
    run_starts = state["run_starts"]
    base_rows = int(run_starts[1]) if len(run_starts) > 1 else int(state["n_rows"])
    appended = int(state["n_rows"]) - base_rows
    if not policy.should_compact(
        base_rows=base_rows, appended_rows=appended, n_runs=int(state["n_runs"])
    ):
        return False
    table.compact_runs(use_pallas=use_pallas)
    return True
