"""Materialized per-slab aggregate views — O(slabs touched) reads.

Each device-resident replica can carry a *view*: per-block partial
sums of its resident value tile (one float32 partial per
``DEVICE_BLOCK_N`` row block per value row, in the replica's own sort
order — ``repro.kernels.block_agg``). A view-eligible range aggregate
is then answered as

    interior blocks   → stored partials (one lookup each)
    boundary blocks   → one masked rescan per window edge
    accumulation      → sequential float32 fold in ascending block
                        order (``np.cumsum``)

instead of the fused O(N) device stream — the materialized-view / CQRS
pattern applied to heterogeneous layouts: every replica's view is
sorted its own way, so the Cost Evaluator ranks view hits exactly as
it ranks layouts (a capped row estimate, ``VIEW_ROWS_CAP``).

**Eligibility** (:func:`query_view_eligible`): sum/count aggregates
whose filters are fully consumed by the slab walk on this replica's
layout — an equality prefix plus at most one range, nothing filtered
after the prefix opens. For those queries residual matching equals
slab membership equals a row-index window per sorted run, so the
answer is a pure function of the windows and the stored partials.
"select" and residual-filtered queries keep the fused path.

**Maintenance** mirrors the table's storage moves, and rides the same
engine events that invalidate the per-replica result cache (flush,
compaction, node failure/recovery, migration, read repair — views are
maintained where cache entries are dropped, one invalidation path):

* flush — ``SortedTable.merge_run`` extends the partials O(run)
  (:func:`extend_views_state`: only blocks at/after the append point
  are refolded) and appends the run's packed keys to the per-run
  window index;
* compaction / rebuild — ``compact_runs`` and recovery re-place the
  arrays, so the view is rebuilt whole (:func:`build_views_state`);
  views are *derived* state: any corruption heals by rebuilding from
  the resident arrays (``scrub_column_family`` verifies this —
  :func:`verify_views`);
* migration — vnode tables are rebuilt by log replay, so a fresh
  view rides along; untouched vnodes keep their tables and therefore
  their views byte-for-byte.

The view state lives inside the table's ``_device`` dict under
``"views"``::

    {"block_sums": np.float32[V_pad, n_blocks],   # stored partials
     "block_n": int,                              # DEVICE_BLOCK_N
     "n_rows": int,                               # rows covered
     "run_packed": [np.int64[...], ...]}          # per-run sorted keys

``run_packed`` mirrors the device run stack (``run_starts``): slab
windows per run come from two host ``searchsorted`` calls on each
run's sorted packed keys — O(R log n) per query, R = resident runs.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "VIEW_AGGS",
    "VIEW_ROWS_CAP",
    "query_view_eligible",
    "view_eligible_matrix",
    "build_views_state",
    "extend_views_state",
    "verify_views",
    "serve_view_many",
]

VIEW_AGGS = ("sum", "count")

# Planning-time row estimate for a view hit: at most two boundary
# blocks are rescanned per (query, run) — interior work is O(1) per
# block. The Cost Evaluator feeds min(estimated_rows, cap) through the
# same Eq 1-2 cost polynomial, so a view-serving replica outranks a
# full scan exactly when the scan would stream more than the cap.
# Shared verbatim by the scalar and batched cost paths (parity).
VIEW_ROWS_CAP = 2 * 8192  # 2 * kernels.DEVICE_BLOCK_N (import-cycle-free)


def query_view_eligible(query, layout) -> bool:
    """True when ``query`` is answerable from ``layout``'s view alone:
    a sum/count whose filters form an equality prefix plus at most one
    range in this layout — the slab walk consumes every filter, no
    residual predicate remains, and matched rows == slab rows == the
    row-index window. Filters *after* the prefix opens keep the query
    on the fused path (value-column residency is the caller's check)."""
    if query.agg not in VIEW_AGGS:
        return False
    open_range = False
    for col in layout:
        f = query.filters.get(col)
        if open_range:
            if f is not None:
                return False
        elif f is None or not f.is_equality:
            open_range = True
    return True


def view_eligible_matrix(layouts, queries) -> np.ndarray:
    """bool[R, Q] view-eligibility per (replica layout, query) — the
    batched planning twin of :func:`query_view_eligible` (same walk per
    cell, so scalar and batched routing agree bit-for-bit). Callers
    short-circuit select-only batches *before* calling this (the
    aggregate planning arrays must not be walked for them)."""
    out = np.zeros((len(layouts), len(queries)), dtype=bool)
    for k, layout in enumerate(layouts):
        for j, q in enumerate(queries):
            out[k, j] = query_view_eligible(q, layout)
    return out


def _table_block_sums(state, *, use_pallas: bool = True) -> np.ndarray:
    from repro.kernels import DEVICE_BLOCK_N, block_sums, block_sums_ref

    fn = block_sums if use_pallas else block_sums_ref
    return np.asarray(fn(state["values_tile"], block_n=DEVICE_BLOCK_N))


def build_views_state(state, packed, *, use_pallas: bool = True) -> dict:
    """Fresh view over a device state holding one sorted run (CREATE,
    recovery, post-compaction): fold the whole value tile into
    per-block partials and index the single run's packed keys."""
    from repro.kernels import DEVICE_BLOCK_N

    n = int(state["n_rows"])
    return {
        "block_sums": _table_block_sums(state, use_pallas=use_pallas),
        "block_n": DEVICE_BLOCK_N,
        "n_rows": n,
        "run_packed": [np.asarray(packed, np.int64)[:n].copy()],
    }


def extend_views_state(
    views, state, run_packed, n_old: int, *, use_pallas: bool = True
) -> dict:
    """O(run) view extension for a flush append: the run's rows landed
    at ``[n_old, n_rows)`` in the resident arrays, so only blocks from
    ``n_old // block_n`` on changed — refold those, keep the earlier
    partials, and append the run's sorted packed keys as a new window
    index. Returns a fresh dict (the pre-merge table keeps its view)."""
    from repro.kernels import DEVICE_BLOCK_N, block_sums, block_sums_ref

    bn = int(views["block_n"])
    n_new = int(state["n_rows"])
    if n_old <= 0:
        # appending to an empty base collapses to a fresh single-run
        # build (device_state_append keeps it single-run too)
        return build_views_state(state, run_packed, use_pallas=use_pallas)
    b0 = n_old // bn
    fn = block_sums if use_pallas else block_sums_ref
    tail = np.asarray(fn(state["values_tile"][:, b0 * bn :], block_n=bn))
    return {
        "block_sums": np.concatenate(
            [views["block_sums"][:, :b0], tail], axis=1
        ),
        "block_n": DEVICE_BLOCK_N,
        "n_rows": n_new,
        "run_packed": list(views["run_packed"])
        + [np.asarray(run_packed, np.int64).copy()],
    }


def verify_views(table, *, use_pallas: bool = True) -> bool:
    """True when the table's stored view partials still match a fresh
    fold of the resident arrays (views are derived state — the arrays
    are ground truth, so a corrupted partial is healed by rebuild, not
    repair). Missing or shape-drifted view state also fails."""
    state = getattr(table, "_device", None)
    if state is None or "views" not in state:
        return False
    vs = state["views"]
    if int(vs["n_rows"]) != int(state["n_rows"]):
        return False
    if sum(p.shape[0] for p in vs["run_packed"]) != int(state["n_rows"]):
        return False
    fresh = _table_block_sums(state, use_pallas=use_pallas)
    stored = np.asarray(vs["block_sums"])
    return stored.shape == fresh.shape and bool(
        np.array_equal(stored, fresh)
    )


def _run_windows(vs, bounds) -> tuple[np.ndarray, np.ndarray]:
    """Global row-index windows int64[Q, R] (lo inclusive, hi
    exclusive) of each query's slab in each resident run: two
    vectorized searchsorteds per run over its sorted packed keys
    (``bounds`` comes from ``slab_bounds_many`` — hi inclusive, so
    ``side="right"`` matches the fused kernel's ``<=`` rank)."""
    n_q = bounds.shape[0]
    runs = vs["run_packed"]
    wlo = np.empty((n_q, len(runs)), np.int64)
    whi = np.empty((n_q, len(runs)), np.int64)
    start = 0
    for r, p in enumerate(runs):
        wlo[:, r] = start + np.searchsorted(p, bounds[:, 0], side="left")
        whi[:, r] = start + np.searchsorted(p, bounds[:, 1], side="right")
        start += int(p.shape[0])
    return wlo, whi


def serve_view_many(table, queries, *, trace=None, view_stats=None) -> list:
    """Answer a batch of view-eligible queries from the table's view:
    per query, locate its per-run row windows (host searchsorted),
    classify touched blocks as interior (all real rows covered → use
    the stored partial) or boundary (one masked rescan), and fold the
    partials sequentially in float32, ascending block order — bits
    equal to the fused full-scan launch (see ``kernels.block_agg``).

    ``trace`` records one ``view.serve`` span; ``view_stats`` (a dict,
    or None) accumulates ``hits`` (queries answered) and
    ``boundary_rows`` (rows streamed through boundary rescans — the
    honest residual scan cost a view hit still pays)."""
    from repro.core.table import ScanResult, slab_bounds_many
    from repro.kernels import boundary_block_sums

    state = table._device
    vs = state["views"]
    bn = int(vs["block_n"])
    n_rows = int(state["n_rows"])
    queries = list(queries)
    sp = (
        trace.child("view.serve", queries=len(queries))
        if trace is not None
        else None
    )
    bounds = slab_bounds_many(queries, table.layout, table.schema)
    wlo, whi = _run_windows(vs, bounds)
    lens = np.maximum(whi - wlo, 0)
    matched = lens.sum(axis=1)

    value_rows = state["value_rows"]
    block_sums = vs["block_sums"]
    # plans[i]: ordered per-block partial sources for sum query i —
    # ("s", block) stored partial, ("b", pair_idx) boundary rescan
    plans: dict[int, list] = {}
    pair_sel: list[int] = []
    pair_block: list[int] = []
    pair_q: list[int] = []
    boundary_rows = 0
    for i, q in enumerate(queries):
        if q.agg != "sum":
            continue
        windows = [
            (int(wlo[i, r]), int(whi[i, r]))
            for r in range(lens.shape[1])
            if lens[i, r] > 0
        ]
        cov: dict[int, int] = {}
        for a, b in windows:  # disjoint (runs partition the row space)
            for blk in range(a // bn, (b - 1) // bn + 1):
                lo = max(a, blk * bn)
                hi = min(b, (blk + 1) * bn)
                cov[blk] = cov.get(blk, 0) + (hi - lo)
        plan: list = []
        vrow = value_rows[q.value_col]
        for blk in sorted(cov):
            real = min((blk + 1) * bn, n_rows) - blk * bn
            if cov[blk] == real:
                plan.append(("s", blk))
            else:
                plan.append(("b", len(pair_sel)))
                pair_sel.append(vrow)
                pair_block.append(blk)
                pair_q.append(i)
                boundary_rows += real
        plans[i] = plan

    bvals = np.empty(0, np.float32)
    if pair_sel:
        n_w = wlo.shape[1]
        p_lo = np.zeros((len(pair_sel), n_w), np.int64)
        p_hi = np.zeros((len(pair_sel), n_w), np.int64)
        for p, i in enumerate(pair_q):
            p_lo[p] = wlo[i]
            p_hi[p] = np.maximum(whi[i], wlo[i])  # empty slots: lo == hi
        bvals = np.asarray(
            boundary_block_sums(
                state["values_tile"], pair_sel, pair_block, p_lo, p_hi,
                block_n=bn,
            )
        )

    out: list[ScanResult] = []
    for i, q in enumerate(queries):
        m = int(matched[i])
        if q.agg == "count":
            out.append(ScanResult(float(m), m, m))
            continue
        plan = plans[i]
        if not plan:
            out.append(ScanResult(0.0, m, m))
            continue
        parts = np.array(
            [
                block_sums[value_rows[q.value_col], ref] if kind == "s"
                else bvals[ref]
                for kind, ref in plan
            ],
            np.float32,
        )
        # np.cumsum is a strictly sequential fold (unlike np.sum's
        # pairwise tree) — the fused kernel's block-order accumulation
        acc = np.cumsum(parts, dtype=np.float32)[-1]
        out.append(ScanResult(float(acc), m, m))

    if view_stats is not None:
        view_stats["hits"] = view_stats.get("hits", 0) + len(queries)
        view_stats["boundary_rows"] = (
            view_stats.get("boundary_rows", 0) + boundary_rows
        )
    if sp is not None:
        sp.end(boundary_rows=boundary_rows, boundary_blocks=len(pair_sel))
    return out
