"""Per-replica memtable: the staging half of the LSM write path.

Each replica owns one memtable. Writes land as column batches in arrival
order (cheap appends — no sort on the write path); ``flush`` concatenates
the staged batches, sorts them **once** by the replica's own layout and
emits an immutable :class:`SortedRun` ready for
``SortedTable.merge_run``. Group commit therefore falls out of the
staging itself: ``g`` writes of ``b`` rows flush as one sort + one merge
of ``g × b`` rows instead of ``g`` separate merges — the amortization
``benchmarks/write_queue.py`` measures, superseding the thread-pool
overlap of ``HREngine.write(parallel=True)`` that the GIL held at
break-even.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Mapping, Sequence

import numpy as np

from ..keys import KeySchema, pack_columns

__all__ = [
    "Memtable",
    "SortedRun",
    "combine_digests",
    "content_digest",
    "run_crc32",
    "sort_run",
]


def run_crc32(
    packed: np.ndarray,
    key_cols: Mapping[str, np.ndarray],
    value_cols: Mapping[str, np.ndarray],
) -> int:
    """crc32 over a run's column arrays: the packed keys, then each
    key/value column in name-sorted order. Buffer-integrity seal for
    ``SortedRun.crc`` — the flush path verifies it before merging a
    run, catching a run corrupted between sort and merge. (Tables use
    :func:`content_digest` instead: it is order-independent, so flushes
    can maintain it incrementally.)"""
    crc = zlib.crc32(np.ascontiguousarray(packed))
    for name in sorted(key_cols):
        crc = zlib.crc32(np.ascontiguousarray(key_cols[name]), crc)
    for name in sorted(value_cols):
        crc = zlib.crc32(np.ascontiguousarray(value_cols[name]), crc)
    return crc


_U64 = np.uint64
_DIGEST_MASK = 0xFFFFFFFFFFFFFFFF


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer, vectorized (uint64 wraparound is the mod)."""
    x = x.copy()
    x ^= x >> _U64(30)
    x *= _U64(0xBF58476D1CE4E5B9)
    x ^= x >> _U64(27)
    x *= _U64(0x94D049BB133111EB)
    x ^= x >> _U64(31)
    return x


def _bits64(arr: np.ndarray) -> np.ndarray:
    a = np.ascontiguousarray(arr)
    if a.dtype.kind == "f":
        a = np.ascontiguousarray(a.astype(np.float64, copy=False))
    elif a.dtype.kind in "iu":
        a = np.ascontiguousarray(a.astype(np.int64, copy=False))
    else:
        raise TypeError(f"content_digest: unhashable column dtype {a.dtype}")
    return a.view(_U64)


def content_digest(
    key_cols: Mapping[str, np.ndarray],
    value_cols: Mapping[str, np.ndarray],
) -> int:
    """Order- and layout-independent digest of a row multiset: each row
    hashes to a 64-bit value — ``mix(Σ_c mix(bits_c ^ salt_c))``, the
    inner mix per column salted by column name so equal values in
    different columns differ, the outer mix binding the columns of a
    row together — and the digest is the sum of row hashes mod 2⁶⁴.
    Columns are stacked into one (rows × cols) uint64 matrix first, so
    the numpy op count is constant in the column count (this runs on
    every flush).

    The sum form is the point: ``digest(A ∪ B) = combine_digests(
    digest(A), digest(B))``, so a flush extends a table's sealed digest
    with just the run's digest (O(run), not O(table)), compaction
    carries it unchanged, and every replica of a partition — each
    sorted its own way — agrees on the value. Crucially the sealed
    digest is therefore derived from the *durable history* (CREATE seal
    + run digests), never recomputed from table memory: an in-place bit
    flip can't be laundered into a fresh seal by a later flush, and
    scrub catches it whenever it looks."""
    named = [
        (f"{group}:{name}", cols[name])
        for group, cols in (("k", key_cols), ("v", value_cols))
        for name in sorted(cols)
    ]
    if not named:
        return 0
    mat = np.stack([_bits64(arr) for _, arr in named], axis=1)
    salts = np.array(
        [zlib.crc32(tag.encode()) + 0x9E3779B9 for tag, _ in named], dtype=_U64
    )
    rows = _mix64(_mix64(mat ^ salts).sum(axis=1, dtype=_U64))
    return int(rows.sum(dtype=_U64))


def combine_digests(a: int, b: int) -> int:
    """Digest of the union of two row multisets (Σ row-hash mod 2⁶⁴)."""
    return (a + b) & _DIGEST_MASK


@dataclasses.dataclass(frozen=True)
class SortedRun:
    """An immutable flushed run: columns sorted by ``layout``, with the
    packed composite key alongside (ascending) and a crc32 over all of
    it (``crc``) sealed at sort time — the flush path verifies it
    before merging, so a run corrupted between sort and merge is caught
    instead of poisoning the table. ``digest`` is the run's multiset
    :func:`content_digest`, what the flush adds to the merged table's
    sealed digest."""

    layout: tuple[str, ...]
    key_cols: dict[str, np.ndarray]
    value_cols: dict[str, np.ndarray]
    packed: np.ndarray
    crc: int = 0
    digest: int = 0

    def __len__(self) -> int:
        return int(self.packed.shape[0])

    def verify(self) -> bool:
        """Recompute the content crc32 and compare to the sealed one."""
        return run_crc32(self.packed, self.key_cols, self.value_cols) == self.crc


def sort_run(
    key_cols: Mapping[str, np.ndarray],
    value_cols: Mapping[str, np.ndarray],
    layout: Sequence[str],
    schema: KeySchema,
) -> SortedRun:
    """Sort one batch into a run in ``layout`` order (stable, so rows
    with equal keys keep arrival order — the tie rule every merge layer
    preserves)."""
    layout = tuple(layout)
    packed = pack_columns(key_cols, layout, schema)
    order = np.argsort(packed, kind="stable")
    kc = {c: np.asarray(v)[order].astype(np.int64) for c, v in key_cols.items()}
    vc = {c: np.asarray(v)[order] for c, v in value_cols.items()}
    sorted_packed = packed[order]
    return SortedRun(
        layout=layout,
        key_cols=kc,
        value_cols=vc,
        packed=sorted_packed,
        crc=run_crc32(sorted_packed, kc, vc),
        digest=content_digest(kc, vc),
    )


class Memtable:
    """Sorted staging buffer for one replica (sorted at flush time)."""

    def __init__(
        self,
        layout: Sequence[str],
        schema: KeySchema,
        key_names: Sequence[str],
        value_names: Sequence[str],
    ) -> None:
        self.layout = tuple(layout)
        self.schema = schema
        self.key_names = tuple(key_names)
        self.value_names = tuple(value_names)
        self._key_bufs: list[dict[str, np.ndarray]] = []
        self._value_bufs: list[dict[str, np.ndarray]] = []
        self._n_staged = 0

    def __len__(self) -> int:
        return self._n_staged

    @property
    def n_staged(self) -> int:
        return self._n_staged

    def stage(
        self,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        *,
        copy: bool = True,
    ) -> None:
        """Absorb one write batch (arrival order, no sort). ``copy=False``
        borrows the caller's arrays instead of copying — the engine
        stages each commit-log record's already-copied columns into all
        RF memtables this way, avoiding RF redundant memcpys per write
        (the memtable never mutates staged arrays, so sharing is safe)."""
        if copy:
            kc = {
                c: np.array(key_cols[c], dtype=np.int64, copy=True)
                for c in self.key_names
            }
            vc = {c: np.array(value_cols[c], copy=True) for c in self.value_names}
        else:
            kc = {c: key_cols[c] for c in self.key_names}
            vc = {c: value_cols[c] for c in self.value_names}
        n = next(iter(kc.values())).shape[0] if kc else 0
        if n == 0:
            return
        self._key_bufs.append(kc)
        self._value_bufs.append(vc)
        self._n_staged += n

    def peek_run(self) -> SortedRun | None:
        """Sort the staged batches into one :class:`SortedRun` in this
        replica's layout (one concatenate + one stable sort for the
        whole group) WITHOUT draining them — the engine merges the run
        and calls :meth:`clear` only once the merged table is installed,
        so a failed merge never loses committed rows. ``None`` when
        nothing is staged."""
        if self._n_staged == 0:
            return None
        if len(self._key_bufs) == 1:
            kc, vc = self._key_bufs[0], self._value_bufs[0]
        else:
            kc = {
                c: np.concatenate([b[c] for b in self._key_bufs])
                for c in self.key_names
            }
            vc = {
                c: np.concatenate([b[c] for b in self._value_bufs])
                for c in self.value_names
            }
        return sort_run(kc, vc, self.layout, self.schema)

    def flush(self) -> SortedRun | None:
        """:meth:`peek_run` + :meth:`clear` in one step, for callers
        that consume the run unconditionally."""
        run = self.peek_run()
        self.clear()
        return run

    def clear(self) -> None:
        """Drop staged rows (node failure: the memtable dies with the
        node; the commit log is the durable copy)."""
        self._key_bufs = []
        self._value_bufs = []
        self._n_staged = 0
