"""Per-replica memtable: the staging half of the LSM write path.

Each replica owns one memtable. Writes land as column batches in arrival
order (cheap appends — no sort on the write path); ``flush`` concatenates
the staged batches, sorts them **once** by the replica's own layout and
emits an immutable :class:`SortedRun` ready for
``SortedTable.merge_run``. Group commit therefore falls out of the
staging itself: ``g`` writes of ``b`` rows flush as one sort + one merge
of ``g × b`` rows instead of ``g`` separate merges — the amortization
``benchmarks/write_queue.py`` measures, superseding the thread-pool
overlap of ``HREngine.write(parallel=True)`` that the GIL held at
break-even.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from ..keys import KeySchema, pack_columns

__all__ = ["Memtable", "SortedRun", "sort_run"]


@dataclasses.dataclass(frozen=True)
class SortedRun:
    """An immutable flushed run: columns sorted by ``layout``, with the
    packed composite key alongside (ascending)."""

    layout: tuple[str, ...]
    key_cols: dict[str, np.ndarray]
    value_cols: dict[str, np.ndarray]
    packed: np.ndarray

    def __len__(self) -> int:
        return int(self.packed.shape[0])


def sort_run(
    key_cols: Mapping[str, np.ndarray],
    value_cols: Mapping[str, np.ndarray],
    layout: Sequence[str],
    schema: KeySchema,
) -> SortedRun:
    """Sort one batch into a run in ``layout`` order (stable, so rows
    with equal keys keep arrival order — the tie rule every merge layer
    preserves)."""
    layout = tuple(layout)
    packed = pack_columns(key_cols, layout, schema)
    order = np.argsort(packed, kind="stable")
    return SortedRun(
        layout=layout,
        key_cols={
            c: np.asarray(v)[order].astype(np.int64) for c, v in key_cols.items()
        },
        value_cols={c: np.asarray(v)[order] for c, v in value_cols.items()},
        packed=packed[order],
    )


class Memtable:
    """Sorted staging buffer for one replica (sorted at flush time)."""

    def __init__(
        self,
        layout: Sequence[str],
        schema: KeySchema,
        key_names: Sequence[str],
        value_names: Sequence[str],
    ) -> None:
        self.layout = tuple(layout)
        self.schema = schema
        self.key_names = tuple(key_names)
        self.value_names = tuple(value_names)
        self._key_bufs: list[dict[str, np.ndarray]] = []
        self._value_bufs: list[dict[str, np.ndarray]] = []
        self._n_staged = 0

    def __len__(self) -> int:
        return self._n_staged

    @property
    def n_staged(self) -> int:
        return self._n_staged

    def stage(
        self,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        *,
        copy: bool = True,
    ) -> None:
        """Absorb one write batch (arrival order, no sort). ``copy=False``
        borrows the caller's arrays instead of copying — the engine
        stages each commit-log record's already-copied columns into all
        RF memtables this way, avoiding RF redundant memcpys per write
        (the memtable never mutates staged arrays, so sharing is safe)."""
        if copy:
            kc = {
                c: np.array(key_cols[c], dtype=np.int64, copy=True)
                for c in self.key_names
            }
            vc = {c: np.array(value_cols[c], copy=True) for c in self.value_names}
        else:
            kc = {c: key_cols[c] for c in self.key_names}
            vc = {c: value_cols[c] for c in self.value_names}
        n = next(iter(kc.values())).shape[0] if kc else 0
        if n == 0:
            return
        self._key_bufs.append(kc)
        self._value_bufs.append(vc)
        self._n_staged += n

    def peek_run(self) -> SortedRun | None:
        """Sort the staged batches into one :class:`SortedRun` in this
        replica's layout (one concatenate + one stable sort for the
        whole group) WITHOUT draining them — the engine merges the run
        and calls :meth:`clear` only once the merged table is installed,
        so a failed merge never loses committed rows. ``None`` when
        nothing is staged."""
        if self._n_staged == 0:
            return None
        if len(self._key_bufs) == 1:
            kc, vc = self._key_bufs[0], self._value_bufs[0]
        else:
            kc = {
                c: np.concatenate([b[c] for b in self._key_bufs])
                for c in self.key_names
            }
            vc = {
                c: np.concatenate([b[c] for b in self._value_bufs])
                for c in self.value_names
            }
        return sort_run(kc, vc, self.layout, self.schema)

    def flush(self) -> SortedRun | None:
        """:meth:`peek_run` + :meth:`clear` in one step, for callers
        that consume the run unconditionally."""
        run = self.peek_run()
        self.clear()
        return run

    def clear(self) -> None:
        """Drop staged rows (node failure: the memtable dies with the
        node; the commit log is the durable copy)."""
        self._key_bufs = []
        self._value_bufs = []
        self._n_staged = 0
