"""SortedTable — the SSTable analogue (paper §3.1, Fig 2).

A table holds columnar data sorted lexicographically by a *layout*
(permutation of the clustering key columns). A query with an equality
prefix and one range filter touches a *contiguous slab* of rows: Cassandra
"traverses from the lower bound and terminates at the first key exceeding
the end boundary" — here the slab is located with two binary searches on
the packed composite key, then scanned with residual predicates.

The slab size IS the paper's ``Row(r, q)`` ground truth; ``execute``
returns it alongside the query result so the cost model can be validated
against reality (tests + Fig 4 benches).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .keys import KeySchema, _field_shifts, pack_columns, pack_tuple
from .workload import Query

__all__ = [
    "SortedTable",
    "ScanResult",
    "slab_bounds_for",
    "slab_bounds_many",
    "merge_partial_scans",
]


@dataclasses.dataclass(frozen=True)
class ScanResult:
    """Result of executing a query on one replica's table.

    Frozen: the engine's result cache hands the same object to every
    hit, so field mutation would corrupt later reads (the ``selected``
    array's *buffer* is additionally write-protected when cached)."""

    value: float  # aggregate value ("select" reports match count here too)
    rows_scanned: int  # slab size — rows streamed from storage (paper Row())
    rows_matched: int  # rows passing all residual predicates
    selected: np.ndarray | None = None  # row indices for agg == "select"


def merge_partial_scans(
    partials: Sequence[tuple[ScanResult, int]], agg: str
) -> ScanResult:
    """Merge per-partition partial scan results into one ``ScanResult``
    (the gather half of a partitioned ``read_many``).

    ``partials`` is ``[(result, row_offset)]`` in ring order; partitions
    hold disjoint row sets, so sums, match counts and slab row counts
    simply add (partial sums accumulate in ring order, so the float
    result is deterministic). For ``agg == "select"`` each partition's
    local row indices shift by that partition's global row offset and
    concatenate — the merged index space is "partitions in ring order,
    each in its serving replica's serialization order", the P-partition
    analogue of a single replica's row order. Offsets are applied into
    fresh arrays: a partial may be a shared (frozen) result-cache entry.
    """
    if len(partials) == 1 and agg != "select":
        return partials[0][0]
    value = sum(float(r.value) for r, _ in partials)
    scanned = sum(int(r.rows_scanned) for r, _ in partials)
    matched = sum(int(r.rows_matched) for r, _ in partials)
    if agg != "select":
        return ScanResult(value, scanned, matched)
    chunks = [
        r.selected.astype(np.int64, copy=True) + off
        for r, off in partials
        if r.selected is not None and r.selected.size
    ]
    selected = (
        np.concatenate(chunks) if chunks else np.empty(0, np.int64)
    )
    return ScanResult(float(matched), scanned, matched, selected=selected)


def slab_bounds_for(
    query: Query, layout: Sequence[str], schema: KeySchema
) -> tuple[int, int]:
    """Packed-key [lo, hi) bounds of the contiguous slab a query touches.

    Walk the layout: keys with equality filters extend the fixed prefix;
    the first non-equality key contributes its range and terminates the
    prefix (everything after it is residual-filtered during the scan, so
    its slab bounds are the full per-column domain).
    """
    los: list[int] = []
    his: list[int] = []
    open_range = False
    for col in layout:
        if open_range:
            lo_c, hi_c = 0, schema.max_value(col) + 1
        else:
            lo_c, hi_c = query.filter_bounds(schema, col)
            if hi_c <= lo_c:
                # degenerate (empty) filter range: the query matches no
                # row — return an empty slab instead of packing hi_c - 1
                # (< lo_c), which would raise.
                return 0, 0
            if not query.is_equality_on(col):
                open_range = True
        los.append(lo_c)
        his.append(hi_c - 1)  # inclusive upper value per field
    lo = pack_tuple(los, layout, schema)
    hi = pack_tuple(his, layout, schema) + 1  # exclusive
    return lo, hi


def _slab_col_bounds(
    queries: Sequence[Query], layout: Sequence[str], schema: KeySchema
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Per-column slab bounds for a query batch: ``(los, his, nonempty)``
    with ``los``/``his`` int64[Q, K] (his *inclusive*) and ``nonempty``
    a bool[Q] mask of queries whose filter ranges are all non-degenerate.

    This is the layout walk shared by :func:`slab_bounds_many` (which
    packs the columns into composite keys for the host searchsorted) and
    the device locate path (which ships them as int32 key lanes to the
    Pallas kernels — ``repro.kernels``). Validation is deferred and
    masked exactly like the scalar walk: only nonempty queries may raise
    on out-of-domain bounds.
    """
    schema.check_layout(layout)
    n_q, n_k = len(queries), len(layout)
    los = np.zeros((n_q, n_k), dtype=np.int64)
    his = np.zeros((n_q, n_k), dtype=np.int64)
    nonempty = np.ones(n_q, dtype=bool)
    open_range = np.zeros(n_q, dtype=bool)
    for j, col in enumerate(layout):
        full_lo, full_hi = 0, schema.max_value(col) + 1
        for i, q in enumerate(queries):
            if open_range[i] or not nonempty[i]:
                # open prefix — or a query already known empty, whose
                # remaining filters must not be evaluated (the scalar
                # walk returns before reaching them)
                lo_c, hi_c = full_lo, full_hi
            else:
                f = q.filters.get(col)
                if f is None:  # global range filter opens the prefix
                    lo_c, hi_c = full_lo, full_hi
                    open_range[i] = True
                elif f.is_equality:
                    lo_c = f.value
                    hi_c = lo_c + 1
                else:
                    lo_c, hi_c = f.start, f.end
                    if hi_c <= lo_c:
                        nonempty[i] = False
                        lo_c, hi_c = full_lo, full_hi  # placeholder; masked below
                    else:
                        open_range[i] = True
            los[i, j] = lo_c
            his[i, j] = hi_c - 1  # inclusive upper value per field
    # validation is deferred and masked: the scalar walk returns (empty
    # slab) on a degenerate range before pack_tuple ever checks the
    # other columns, so only nonempty queries may raise here
    for j, col in enumerate(layout):
        bad = nonempty & ((los[:, j] < 0) | (his[:, j] > schema.max_value(col)))
        if bad.any():
            raise ValueError(
                f"query {int(np.argmax(bad))} bounds out of range for column {col!r}"
            )
    return los, his, nonempty


def slab_bounds_many(
    queries: Sequence[Query], layout: Sequence[str], schema: KeySchema
) -> np.ndarray:
    """Packed-key [lo, hi] slab bounds for a query batch: int64[Q, 2].

    Same walk as :func:`slab_bounds_for` but with the per-column bounds
    gathered into ``int64[Q, K]`` arrays (:func:`_slab_col_bounds`) and
    packed with one vectorized shift-or per column. Unlike the scalar
    function the upper bound is returned *inclusive* — a 63-bit schema
    packs its maximum key to ``2**63 − 1``, and the scalar ``+ 1`` would
    wrap int64 (``slab_many`` compensates with ``side="right"``, an
    exact equivalent). Queries with a degenerate (empty) filter range
    get ``lo = 0, hi = −1``.
    """
    los, his, nonempty = _slab_col_bounds(queries, layout, schema)
    n_q = len(queries)
    # MSB-first packing, same field shifts as keys.pack_tuple
    sh = np.asarray(_field_shifts(schema, layout), dtype=np.int64)
    out = np.empty((n_q, 2), dtype=np.int64)
    out[:, 0] = ((los << sh).sum(axis=1)) * nonempty
    out[:, 1] = np.where(nonempty, (his << sh).sum(axis=1), -1)
    return out


@dataclasses.dataclass
class SortedTable:
    layout: tuple[str, ...]
    schema: KeySchema
    key_cols: dict[str, np.ndarray]  # sorted, int64
    value_cols: dict[str, np.ndarray]  # sorted alongside
    packed: np.ndarray  # int64, ascending
    # device-resident column cache (repro.kernels.build_device_state) —
    # populated by place_on_device(); never part of table identity
    _device: dict | None = dataclasses.field(default=None, repr=False, compare=False)
    # multiset content digest sealed at CREATE/recovery and *extended*
    # (never recomputed from memory) by each flush — see
    # ``storage.content_digest``; scrub recomputes from the arrays and
    # compares to detect at-rest bit flips. Not table identity — two
    # equal tables may differ only in whether a digest was sealed
    stored_digest: int | None = dataclasses.field(default=None, repr=False, compare=False)

    # -- construction ------------------------------------------------------

    @classmethod
    def from_columns(
        cls,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        layout: Sequence[str],
        schema: KeySchema | None = None,
    ) -> "SortedTable":
        if schema is None:
            schema = KeySchema.for_columns(key_cols)
        layout = tuple(layout)
        packed = pack_columns(key_cols, layout, schema)
        order = np.argsort(packed, kind="stable")
        return cls(
            layout=layout,
            schema=schema,
            key_cols={c: np.asarray(v)[order].astype(np.int64) for c, v in key_cols.items()},
            value_cols={c: np.asarray(v)[order] for c, v in value_cols.items()},
            packed=packed[order],
        )

    def __len__(self) -> int:
        return int(self.packed.shape[0])

    @property
    def n_rows(self) -> int:
        return len(self)

    def resorted(self, layout: Sequence[str]) -> "SortedTable":
        """Same dataset, different serialization — the HR recovery path
        (rebuild a lost replica by re-sorting a survivor, paper §4)."""
        return SortedTable.from_columns(self.key_cols, self.value_cols, layout, self.schema)

    # -- content checksums (scrub) ------------------------------------------

    def content_digest(self) -> int:
        """Order/layout-independent multiset digest of the key + value
        columns (see ``storage.content_digest``): every replica of the
        same row set agrees on it regardless of serialization."""
        from .storage.memtable import content_digest

        return content_digest(self.key_cols, self.value_cols)

    def seal_checksum(self) -> "SortedTable":
        """Record the current content digest in ``stored_digest``. The
        engine seals at CREATE and recovery; a flush *extends* the seal
        with the run's digest instead (``combine_digests``), so the
        sealed value always derives from the durable history — merging
        on top of a corrupted array can't launder the corruption into a
        fresh seal. Returns ``self`` for chaining."""
        self.stored_digest = self.content_digest()
        return self

    def verify_checksum(self) -> bool:
        """True when the sealed digest still matches the content (or no
        digest was ever sealed — nothing to verify against)."""
        return self.stored_digest is None or self.content_digest() == self.stored_digest

    # -- device residency ----------------------------------------------------

    def place_on_device(self, *, rebuild: bool = False) -> "SortedTable":
        """Materialize the columns as device-resident jax arrays (int32
        key lanes — wide columns split into two — plus float32 value
        rows). Afterwards ``execute``/``execute_many`` answer sum, count
        AND select queries entirely on device (fused locate+scan, plus
        index compaction for selects) and ``slab_many`` locates slabs
        with the Pallas binary-search kernel instead of host
        searchsorted. Raises ``ValueError`` naming the offending column
        if a key column exceeds the device path's two-lane 60-bit budget.

        Placement is *incremental*: ``merge_insert`` appends each merged
        write run to the already-resident arrays, so a resident table is
        NOT re-uploaded after writes. Calling ``place_on_device()`` on a
        table that is already resident is a no-op; pass ``rebuild=True``
        to force a fresh, fully-sorted re-upload (collapses appended
        runs and restores device row order == host row order). Returns
        ``self`` for chaining."""
        from repro.kernels import build_device_state

        if self._device is None or rebuild:
            self._device = build_device_state(self)
        return self

    def evict_from_device(self) -> None:
        """Drop the device-resident cache; reads fall back to numpy."""
        self._device = None

    @property
    def device_resident(self) -> bool:
        return self._device is not None

    # -- materialized per-slab views ----------------------------------------

    def build_views(self, *, use_pallas: bool = True, trace=None) -> "SortedTable":
        """(Re)build the materialized per-slab aggregate view over the
        resident arrays (``repro.core.storage.views``): per-block
        float32 partial sums of the value tile plus the per-run packed
        key index. Views are *derived* state — this is also the heal
        path when scrub finds a corrupted partial. Requires device
        residency. Returns ``self`` for chaining."""
        from .storage.views import build_views_state

        if self._device is None:
            raise ValueError("build_views requires a device-resident table")
        vb = (
            trace.child("view.build", rows=len(self))
            if trace is not None
            else None
        )
        self._device["views"] = build_views_state(
            self._device, self.packed, use_pallas=use_pallas
        )
        if vb is not None:
            vb.end()
        return self

    @property
    def has_views(self) -> bool:
        return self._device is not None and "views" in self._device

    def _view_eligible(self, query: Query) -> bool:
        """Queries the view answers bit-identically to the fused scan:
        sum/count whose filters the slab walk fully consumes on this
        layout (no residual predicate), with the view built and sum
        value columns resident."""
        from .storage.views import query_view_eligible

        return (
            self.has_views
            and query_view_eligible(query, self.layout)
            and (
                query.agg != "sum"
                or query.value_col in self._device["value_rows"]
            )
        )

    def _device_eligible(self, query: Query) -> bool:
        """Queries the device path answers end-to-end: sum/count
        aggregations and "select" row emission (fused locate+scan plus
        prefix-sum index compaction). Sums need their value column
        resident; unknown aggregations keep the numpy path (which
        raises, same as a host table)."""
        return (
            self._device is not None
            and query.agg in ("sum", "count", "select")
            and (query.agg != "sum" or query.value_col in self.value_cols)
        )

    # -- writes (LSM-style bulk merge) --------------------------------------

    def merge_insert(
        self, key_cols: Mapping[str, np.ndarray], value_cols: Mapping[str, np.ndarray]
    ) -> "SortedTable":
        """Merge an unsorted write batch: sort it into a run in this
        table's own layout, then :meth:`merge_run` it.

        The per-replica sort order is this table's own layout, mirroring
        Cassandra's per-replica LSM write path: HR costs the same writes
        as TR because every replica sorts exactly one copy (Table 1).
        The engine's memtable path produces the run itself (one sort for
        a whole commit group) and calls :meth:`merge_run` directly.
        """
        from .storage.memtable import sort_run

        return self.merge_run(sort_run(key_cols, value_cols, self.layout, self.schema))

    def merge_run(self, run, *, trace=None) -> "SortedTable":
        """Merge one presorted run (memtable flush → SSTable merge).

        ``run`` carries ``key_cols``/``value_cols``/``packed`` already
        sorted by this table's layout (``repro.core.storage.SortedRun``).
        Ties merge new-rows-first: a freshly written row lands *before*
        equal existing rows, and rows within the run keep arrival order
        — the order every layer above (``row_map`` bookkeeping, the
        device k-way merge kernel) reproduces.

        The hot path is GIL-friendly by construction: the dominant
        O(N log N) step is an in-place ``np.sort`` on a concatenated
        packed-key buffer (numpy's sort releases the GIL; its stable
        sort is adaptive, so two sorted runs merge in ~O(N)), and the
        columns are placed by precomputed destination scatters — no
        ``np.argsort`` and no ``np.insert`` on the base-sized arrays,
        which held the GIL and kept ``write(parallel=True)`` at
        break-even (``benchmarks/write_queue.py`` records the overlap).

        If this table is device-resident, the run is *appended* to the
        resident arrays (``repro.kernels.device_state_append``) instead
        of re-uploading the whole table: the returned table is
        immediately resident, with a ``row_map`` translating device row
        order (base rows then appended runs) back to the merged host
        order for "select". Automatic compaction (or
        ``place_on_device(rebuild=True)``) collapses the run stack.
        """
        new_packed = np.asarray(run.packed)
        m = int(new_packed.shape[0])
        n_old = len(self)
        # merge positions of the new run into the existing rows
        pos = np.searchsorted(self.packed, new_packed, side="left")
        if m == 0:
            kc = {c: v.copy() for c, v in self.key_cols.items()}
            vc = {c: np.asarray(v).copy() for c, v in self.value_cols.items()}
            merged = SortedTable(self.layout, self.schema, kc, vc, self.packed.copy())
        else:
            # destination rows reproduce np.insert semantics exactly:
            # run row j lands at pos[j] + j, old row i shifts past the
            # new rows at-or-before it (ties: new rows first)
            dest_new = pos + np.arange(m, dtype=np.int64)
            shift = np.searchsorted(new_packed, self.packed, side="right")
            dest_old = np.arange(n_old, dtype=np.int64) + shift
            merged_packed = np.concatenate([self.packed, new_packed])
            merged_packed.sort(kind="stable")

            def _scatter(old: np.ndarray, new: np.ndarray) -> np.ndarray:
                out = np.empty(n_old + m, dtype=old.dtype)
                out[dest_old] = old
                out[dest_new] = new
                return out

            kc = {c: _scatter(self.key_cols[c], run.key_cols[c]) for c in self.key_cols}
            vc = {
                c: _scatter(np.asarray(self.value_cols[c]), np.asarray(run.value_cols[c]))
                for c in self.value_cols
            }
            merged = SortedTable(self.layout, self.schema, kc, vc, merged_packed)
        if self._device is not None:
            from repro.kernels import device_state_append

            merged._device = device_state_append(
                self._device, merged, run.key_cols, run.value_cols, pos
            )
            if "views" in self._device and m > 0:
                # extend the materialized view O(run): only blocks at or
                # after the append point refold (storage.views)
                from .storage.views import extend_views_state

                vb = (
                    trace.child("view.build", rows=m, incremental=True)
                    if trace is not None
                    else None
                )
                merged._device["views"] = extend_views_state(
                    self._device["views"], merged._device, new_packed, n_old
                )
                if vb is not None:
                    vb.end()
        return merged

    def compact_runs(self, *, use_pallas: bool = True) -> "SortedTable":
        """Collapse appended device runs into one sorted run *on device*
        via the Pallas k-way merge kernel
        (``repro.kernels.merge_device_runs``) — unlike
        ``place_on_device(rebuild=True)`` nothing is re-uploaded. After
        compaction device row order equals host row order again
        (``row_map`` is identity), so the single-run fast paths apply.
        No-op on host tables and single-run states. Returns ``self``."""
        if self._device is not None and self._device.get("n_runs", 1) > 1:
            from repro.kernels import merge_device_runs

            had_views = "views" in self._device
            self._device = merge_device_runs(self._device, use_pallas=use_pallas)
            if had_views:
                # compaction permuted the resident arrays into one
                # sorted run: rebuild the view whole (per-run partials
                # cannot be permuted cheaper than refolding)
                self.build_views(use_pallas=use_pallas)
        return self

    # -- reads ---------------------------------------------------------------

    def slab(self, query: Query) -> tuple[int, int]:
        """Row index range [lo_idx, hi_idx) the query must stream."""
        lo_key, hi_key = slab_bounds_for(query, self.layout, self.schema)
        lo = int(np.searchsorted(self.packed, lo_key, side="left"))
        # search for the inclusive upper key with side="right": a 63-bit
        # schema's exclusive bound is 2**63, which does not fit int64 and
        # would be float-cast (losing low bits) by searchsorted
        hi = int(np.searchsorted(self.packed, hi_key - 1, side="right"))
        return lo, hi

    def slab_many(self, queries: Sequence[Query]) -> np.ndarray:
        """Row index slabs ``int64[Q, 2]`` for a query batch.

        On a device-resident table holding a single sorted run, the
        ranks come from the Pallas vectorized binary-search kernel
        (``repro.kernels.table_slab_locate_many``) — no host
        searchsorted. Otherwise (host tables, or resident arrays with
        appended write runs, whose device row order is no longer
        sorted) one vectorized ``np.searchsorted`` over the packed
        bound array replaces 2·Q per-query binary searches; that numpy
        path stays the oracle the kernel is property-tested against.
        """
        queries = list(queries)
        if queries and self._device is not None and self._device.get("n_runs", 1) == 1:
            from repro.kernels import table_slab_locate_many

            return table_slab_locate_many(self, queries)
        bounds = slab_bounds_many(queries, self.layout, self.schema)
        lo = np.searchsorted(self.packed, bounds[:, 0], side="left")
        # inclusive upper key + side="right" ≡ scalar (hi + 1, side="left")
        # without the int64 wrap at 63-bit schemas
        hi = np.searchsorted(self.packed, bounds[:, 1], side="right")
        return np.stack([lo, hi], axis=1).astype(np.int64)

    def execute(self, query: Query) -> ScanResult:
        """Stream the slab, apply residual predicates, aggregate.

        Device-resident tables answer eligible queries (sum, count,
        select) with the fused locate+scan launch at Q = 1 — no host
        searchsorted, no numpy scan — so a scalar loop and
        ``execute_many`` compute per-query results identically; numpy is
        the reference engine and the path for host tables.
        """
        if self._view_eligible(query):
            from .storage.views import serve_view_many

            return serve_view_many(self, [query])[0]
        if self._device_eligible(query):
            from repro.kernels import table_execute_device_many

            return table_execute_device_many(self, [query])[0]
        lo, hi = self.slab(query)
        return self._scan_slab(query, lo, hi)

    def execute_many(
        self, queries: Sequence[Query], *, trace=None, view_stats=None
    ) -> list[ScanResult]:
        """Batched ``execute``.

        On a device-resident table every eligible query (sum, count AND
        select) is served by ``repro.kernels.table_execute_device_many``:
        one fused locate+scan launch answers the whole group — slab
        membership is decided against the packed slab key bounds inside
        the scan predicate, so no host searchsorted runs and no host
        sync separates locate from scan — plus one compaction launch
        when the group contains selects with matches. Host tables (and
        ineligible aggregations) locate slabs with one vectorized
        searchsorted and run the numpy residual scan. Either way result
        ``i`` equals ``execute(queries[i])``, which routes per query the
        same way.

        ``trace`` (an open :class:`repro.obs.Span`, or None) records
        the device launches as ``kernel.scan_launch`` /
        ``kernel.select_compact`` children, view hits as ``view.serve``
        and the numpy fallback as ``engine.host_scan`` — the deepest
        tier of the read-path span tree.

        When the table carries a materialized view
        (:meth:`build_views`), view-eligible queries (sum/count fully
        consumed by the slab walk) are answered from the stored
        per-block partials — O(blocks touched) instead of the O(N)
        fused stream, bit-identical by construction — and
        ``view_stats`` (a dict, or None) receives their ``hits`` /
        ``boundary_rows`` tallies for the engine's counters.
        """
        queries = list(queries)
        if not queries:
            return []
        results: list[ScanResult | None] = [None] * len(queries)
        view_idx = [i for i, q in enumerate(queries) if self._view_eligible(q)]
        if view_idx:
            from .storage.views import serve_view_many

            out = serve_view_many(
                self, [queries[i] for i in view_idx], trace=trace,
                view_stats=view_stats,
            )
            for i, r in zip(view_idx, out):
                results[i] = r
        dev_idx = [
            i
            for i, q in enumerate(queries)
            if results[i] is None and self._device_eligible(q)
        ]
        if dev_idx:
            from repro.kernels import table_execute_device_many

            out = table_execute_device_many(
                self, [queries[i] for i in dev_idx], trace=trace
            )
            for i, r in zip(dev_idx, out):
                results[i] = r
        host_idx = [i for i in range(len(queries)) if results[i] is None]
        if host_idx:
            hs = (
                trace.child("engine.host_scan", queries=len(host_idx))
                if trace is not None
                else None
            )
            sub = [queries[i] for i in host_idx]
            slabs = self.slab_many(sub)
            for j, i in enumerate(host_idx):
                results[i] = self._scan_slab(sub[j], int(slabs[j, 0]), int(slabs[j, 1]))
            if hs is not None:
                hs.end()
        return results  # type: ignore[return-value]

    def _scan_slab(self, query: Query, lo: int, hi: int) -> ScanResult:
        n = hi - lo
        if n <= 0:
            return ScanResult(0.0, 0, 0, np.empty(0, np.int64) if query.agg == "select" else None)
        mask = np.ones(n, dtype=bool)
        for col in self.layout:
            lo_c, hi_c = query.filter_bounds(self.schema, col)
            v = self.key_cols[col][lo:hi]
            mask &= (v >= lo_c) & (v < hi_c)
        matched = int(mask.sum())
        if query.agg == "count":
            return ScanResult(float(matched), n, matched)
        if query.agg == "sum":
            if query.value_col is None:
                raise ValueError("sum aggregation requires value_col")
            vals = self.value_cols[query.value_col][lo:hi]
            return ScanResult(float(np.sum(vals * mask)), n, matched)
        if query.agg == "select":
            idx = np.nonzero(mask)[0] + lo
            return ScanResult(float(matched), n, matched, selected=idx)
        raise ValueError(f"unknown agg {query.agg!r}")

    # -- identity ------------------------------------------------------------

    def dataset_fingerprint(self) -> str:
        """Order-independent content hash: replicas of the same dataset have
        equal fingerprints regardless of serialization (HR invariant).

        Rows are brought to a canonical order (lexicographic over sorted
        column names, value columns as tiebreakers) and hashed exactly.
        """
        import hashlib

        canon = tuple(sorted(self.key_cols))
        packed = pack_columns(self.key_cols, canon, self.schema)
        vnames = tuple(sorted(self.value_cols))
        tiebreak = [
            np.asarray(self.value_cols[c], dtype=np.float64) for c in reversed(vnames)
        ]
        order = np.lexsort(tuple(tiebreak) + (packed,))
        md = hashlib.md5()
        md.update(packed[order].tobytes())
        for c in vnames:
            md.update(c.encode())
            md.update(np.asarray(self.value_cols[c], dtype=np.float64)[order].tobytes())
        return md.hexdigest()
