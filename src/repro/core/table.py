"""SortedTable — the SSTable analogue (paper §3.1, Fig 2).

A table holds columnar data sorted lexicographically by a *layout*
(permutation of the clustering key columns). A query with an equality
prefix and one range filter touches a *contiguous slab* of rows: Cassandra
"traverses from the lower bound and terminates at the first key exceeding
the end boundary" — here the slab is located with two binary searches on
the packed composite key, then scanned with residual predicates.

The slab size IS the paper's ``Row(r, q)`` ground truth; ``execute``
returns it alongside the query result so the cost model can be validated
against reality (tests + Fig 4 benches).
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

from .keys import KeySchema, pack_columns, pack_tuple
from .workload import Query

__all__ = ["SortedTable", "ScanResult", "slab_bounds_for"]


@dataclasses.dataclass
class ScanResult:
    """Result of executing a query on one replica's table."""

    value: float  # aggregate value ("select" reports match count here too)
    rows_scanned: int  # slab size — rows streamed from storage (paper Row())
    rows_matched: int  # rows passing all residual predicates
    selected: np.ndarray | None = None  # row indices for agg == "select"


def slab_bounds_for(
    query: Query, layout: Sequence[str], schema: KeySchema
) -> tuple[int, int]:
    """Packed-key [lo, hi) bounds of the contiguous slab a query touches.

    Walk the layout: keys with equality filters extend the fixed prefix;
    the first non-equality key contributes its range and terminates the
    prefix (everything after it is residual-filtered during the scan, so
    its slab bounds are the full per-column domain).
    """
    los: list[int] = []
    his: list[int] = []
    open_range = False
    for col in layout:
        if open_range:
            lo_c, hi_c = 0, schema.max_value(col) + 1
        else:
            lo_c, hi_c = query.filter_bounds(schema, col)
            if not query.is_equality_on(col):
                open_range = True
        los.append(lo_c)
        his.append(hi_c - 1)  # inclusive upper value per field
    lo = pack_tuple(los, layout, schema)
    hi = pack_tuple(his, layout, schema) + 1  # exclusive
    return lo, hi


@dataclasses.dataclass
class SortedTable:
    layout: tuple[str, ...]
    schema: KeySchema
    key_cols: dict[str, np.ndarray]  # sorted, int64
    value_cols: dict[str, np.ndarray]  # sorted alongside
    packed: np.ndarray  # int64, ascending

    # -- construction ------------------------------------------------------

    @classmethod
    def from_columns(
        cls,
        key_cols: Mapping[str, np.ndarray],
        value_cols: Mapping[str, np.ndarray],
        layout: Sequence[str],
        schema: KeySchema | None = None,
    ) -> "SortedTable":
        if schema is None:
            schema = KeySchema.for_columns(key_cols)
        layout = tuple(layout)
        packed = pack_columns(key_cols, layout, schema)
        order = np.argsort(packed, kind="stable")
        return cls(
            layout=layout,
            schema=schema,
            key_cols={c: np.asarray(v)[order].astype(np.int64) for c, v in key_cols.items()},
            value_cols={c: np.asarray(v)[order] for c, v in value_cols.items()},
            packed=packed[order],
        )

    def __len__(self) -> int:
        return int(self.packed.shape[0])

    @property
    def n_rows(self) -> int:
        return len(self)

    def resorted(self, layout: Sequence[str]) -> "SortedTable":
        """Same dataset, different serialization — the HR recovery path
        (rebuild a lost replica by re-sorting a survivor, paper §4)."""
        return SortedTable.from_columns(self.key_cols, self.value_cols, layout, self.schema)

    # -- writes (LSM-style bulk merge) --------------------------------------

    def merge_insert(
        self, key_cols: Mapping[str, np.ndarray], value_cols: Mapping[str, np.ndarray]
    ) -> "SortedTable":
        """Merge a sorted-on-arrival batch (memtable flush → SSTable merge).

        The per-replica sort order is this table's own layout, mirroring
        Cassandra's per-replica LSM write path: HR costs the same writes
        as TR because every replica sorts exactly one copy (Table 1).
        """
        new_packed = pack_columns(key_cols, self.layout, self.schema)
        order = np.argsort(new_packed, kind="stable")
        new_packed = new_packed[order]
        # merge positions of the new run into the existing run
        pos = np.searchsorted(self.packed, new_packed, side="left")
        merged_packed = np.insert(self.packed, pos, new_packed)
        kc = {
            c: np.insert(self.key_cols[c], pos, np.asarray(key_cols[c])[order].astype(np.int64))
            for c in self.key_cols
        }
        vc = {
            c: np.insert(self.value_cols[c], pos, np.asarray(value_cols[c])[order])
            for c in self.value_cols
        }
        return SortedTable(self.layout, self.schema, kc, vc, merged_packed)

    # -- reads ---------------------------------------------------------------

    def slab(self, query: Query) -> tuple[int, int]:
        """Row index range [lo_idx, hi_idx) the query must stream."""
        lo_key, hi_key = slab_bounds_for(query, self.layout, self.schema)
        lo = int(np.searchsorted(self.packed, lo_key, side="left"))
        hi = int(np.searchsorted(self.packed, hi_key, side="left"))
        return lo, hi

    def execute(self, query: Query) -> ScanResult:
        """Stream the slab, apply residual predicates, aggregate.

        This is the numpy reference engine; the Pallas `scan_agg` kernel
        (repro.kernels) implements the same slab scan for the TPU path and
        is tested against this method.
        """
        lo, hi = self.slab(query)
        n = hi - lo
        if n <= 0:
            return ScanResult(0.0, 0, 0, np.empty(0, np.int64) if query.agg == "select" else None)
        mask = np.ones(n, dtype=bool)
        for col in self.layout:
            lo_c, hi_c = query.filter_bounds(self.schema, col)
            v = self.key_cols[col][lo:hi]
            mask &= (v >= lo_c) & (v < hi_c)
        matched = int(mask.sum())
        if query.agg == "count":
            return ScanResult(float(matched), n, matched)
        if query.agg == "sum":
            if query.value_col is None:
                raise ValueError("sum aggregation requires value_col")
            vals = self.value_cols[query.value_col][lo:hi]
            return ScanResult(float(np.sum(vals * mask)), n, matched)
        if query.agg == "select":
            idx = np.nonzero(mask)[0] + lo
            return ScanResult(float(matched), n, matched, selected=idx)
        raise ValueError(f"unknown agg {query.agg!r}")

    # -- identity ------------------------------------------------------------

    def dataset_fingerprint(self) -> str:
        """Order-independent content hash: replicas of the same dataset have
        equal fingerprints regardless of serialization (HR invariant).

        Rows are brought to a canonical order (lexicographic over sorted
        column names, value columns as tiebreakers) and hashed exactly.
        """
        import hashlib

        canon = tuple(sorted(self.key_cols))
        packed = pack_columns(self.key_cols, canon, self.schema)
        vnames = tuple(sorted(self.value_cols))
        tiebreak = [
            np.asarray(self.value_cols[c], dtype=np.float64) for c in reversed(vnames)
        ]
        order = np.lexsort(tuple(tiebreak) + (packed,))
        md = hashlib.md5()
        md.update(packed[order].tobytes())
        for c in vnames:
            md.update(c.encode())
            md.update(np.asarray(self.value_cols[c], dtype=np.float64)[order].tobytes())
        return md.hexdigest()
