"""Synthetic TPC-H ``orders`` (paper §5) and the simulation dataset.

The paper uses the TPC-H ``orders`` table with clustering keys
(custkey, orderdate, clerk) at scale factors 1–5 (1.5 M – 7.5 M rows),
plus a "simulation dataset" whose |D| clustering keys are integers
uniform over a domain sized so that every key has ~|P|^(1/|D|) distinct
values ("value scope 0 ~ log_{|D|} |P|" — the paper's notation for a
domain that keeps the expected rows-per-full-key-prefix ≈ 1).

Query templates Q1/Q2 match the paper's SQL:
  Q1: orderdate = ?, clerk = ?, custkey ≥ 0      (range over custkey)
  Q2: custkey = ?, clerk = ?, orderdate ∈ [?, ?)  (range over orderdate)
"""

from __future__ import annotations

import numpy as np

from .keys import KeySchema
from .workload import Eq, Query, Range, Workload

__all__ = [
    "ROWS_PER_SF",
    "generate_orders",
    "orders_schema",
    "q1_q2_workload",
    "generate_simulation",
]

ROWS_PER_SF = 1_500_000

# TPC-H ratios: ~10 orders per customer, ~1500 orders per clerk, 2406
# distinct order dates. Domains scale with the dataset so the per-key
# selectivities match the paper's at any rows_per_sf (the figures are
# reproduced at reduced scale on CPU; ratios are what transfer).
N_DATES = 2406
ORDERS_PER_CUSTOMER = 10
ORDERS_PER_CLERK = 1500


def n_custkey(n_rows: int) -> int:
    return max(1024, n_rows // ORDERS_PER_CUSTOMER)


def n_clerks(n_rows: int) -> int:
    return max(32, n_rows // ORDERS_PER_CLERK)


def orders_schema() -> KeySchema:
    return KeySchema(
        {
            "custkey": 20,
            "orderdate": 12,
            "clerk": 13,
        }
    )


def generate_orders(
    scale_factor: float, seed: int = 0, rows_per_sf: int = ROWS_PER_SF
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray]]:
    """Return (key_cols, value_cols) for ``orders`` at a scale factor."""
    n = int(scale_factor * rows_per_sf)
    rng = np.random.default_rng(seed)
    key_cols = {
        "custkey": rng.integers(0, n_custkey(n), n, dtype=np.int64),
        "orderdate": rng.integers(0, N_DATES, n, dtype=np.int64),
        "clerk": rng.integers(0, n_clerks(n), n, dtype=np.int64),
    }
    value_cols = {
        "totalprice": np.round(rng.uniform(857.71, 555285.16, n), 2),
        "shippriority": rng.integers(0, 5, n).astype(np.float64),
    }
    return key_cols, value_cols


def q1_q2_workload(
    n_instances: int = 500, seed: int = 1, date_range_days: int = 30,
    n_rows: int = ROWS_PER_SF,
) -> Workload:
    """500 instances of Q1/Q2 with randomized parameters (paper §5).
    Parameter domains follow the dataset size (see generate_orders)."""
    rng = np.random.default_rng(seed)
    nck, ncl = n_custkey(n_rows), n_clerks(n_rows)
    queries = []
    for i in range(n_instances):
        if i % 2 == 0:
            # Q1: orderdate = ?, clerk = ?, custkey >= 0
            queries.append(
                Query(
                    filters={
                        "orderdate": Eq(int(rng.integers(0, N_DATES))),
                        "clerk": Eq(int(rng.integers(0, ncl))),
                        "custkey": Range(0, nck),
                    },
                    agg="sum",
                    value_col="totalprice",
                )
            )
        else:
            # Q2: custkey = ?, clerk = ?, orderdate in [?, ?)
            span = int(rng.integers(1, date_range_days + 1))
            start = int(rng.integers(0, max(1, N_DATES - span)))
            queries.append(
                Query(
                    filters={
                        "custkey": Eq(int(rng.integers(0, nck))),
                        "clerk": Eq(int(rng.integers(0, ncl))),
                        "orderdate": Range(start, start + span),
                    },
                    agg="sum",
                    value_col="totalprice",
                )
            )
    return Workload(queries)


def generate_simulation(
    n_rows: int, n_keys: int, seed: int = 0
) -> tuple[dict[str, np.ndarray], dict[str, np.ndarray], KeySchema]:
    """Paper §5 simulation dataset: ``n_keys`` integer clustering keys,
    each uniform over a domain of ~n_rows^(1/n_keys) values (so a full
    equality prefix selects ~1 row), values random over the data space."""
    rng = np.random.default_rng(seed)
    domain = max(2, int(round(n_rows ** (1.0 / n_keys))))
    bits = max(1, (domain - 1).bit_length())
    names = [f"k{i}" for i in range(n_keys)]
    key_cols = {c: rng.integers(0, domain, n_rows, dtype=np.int64) for c in names}
    value_cols = {"metric": rng.uniform(0.0, 1.0, n_rows)}
    schema = KeySchema({c: bits for c in names})
    return key_cols, value_cols, schema
