"""Token-ring partitioning — Cassandra's ring, order-preserving form.

A production keyspace does not fit one replica set: Cassandra assigns
every row a *token* and splits the token space into contiguous ranges,
each owned by its own replica group, so reads and writes fan out and a
node holds only a slice of the dataset. This module reproduces that for
the heterogeneous-replica engine:

* The **token** of a row is its composite key packed in *canonical*
  order (``key_names`` as declared at CREATE — never a replica layout,
  which differs per replica). Packing is order-preserving
  (``keys.pack_columns``), so this is Cassandra's
  ByteOrderedPartitioner rather than the hash partitioner: token ranges
  are key ranges, which is what lets a query's slab bounds be
  intersected with the ring by pure host arithmetic (no hashing a
  range — see :meth:`TokenRing.span_partitions`).
* The ring splits ``[0, 2**total_bits)`` into ``P`` near-equal
  contiguous ranges (:meth:`TokenRing.build`). A row belongs to exactly
  one partition regardless of which replica serialization it lands in.
* Each :class:`Partition` owns a full heterogeneous replica set (one
  table per layout), its own commit log, its own memtables and its own
  compaction policy — the engine's write/flush/recovery machinery runs
  per partition, and a node failure costs only the partition replicas
  that node hosted.
* Placement onto nodes uses the same deterministic crc32 scheme as
  ``HREngine._place`` (:func:`place_replica` — the engine delegates to
  it): replica ids are global across partitions
  (``partition_id * RF + slot``), so partition 0 of a ``P = 1`` column
  family places exactly where the unpartitioned engine always did.

Query planning (the scatter half of scatter-gather ``read_many``):
``slab_bounds_many(queries, key_names, schema)`` gives each query's
canonical packed slab ``[lo, hi]`` (componentwise filter bounds imply
packed bounds, since the fields occupy disjoint bit ranges), and the
partitions a query can touch are exactly the contiguous ring ranges
intersecting it — two vectorized ``searchsorted`` calls over the ring's
start tokens, the same pure-arithmetic style as the slab walk itself.
An equality filter on the leading canonical key pins the query to a
single partition (Cassandra's partition-key point read); an open query
fans out to all ``P``.

Virtual nodes, skew, and migration (PR 6)
-----------------------------------------

Equal token splits balance *key space*, not *rows*: a Zipf-skewed
keyspace piles most rows into the low-token partitions and starves the
rest, which both unbalances storage and blunts the Cost Evaluator (a
CF-global histogram misdescribes every individual partition). Three
mechanisms fix that, Cassandra-vnode style:

* **Identity.** Each :class:`Partition` carries a stable ``vnode_id``
  assigned once at birth and never reused; global replica ids are
  ``vnode_id * RF + slot`` so node-table keys, result-cache keys and
  crc32 placement survive ring surgery unchanged for partitions that
  did not move. ``partition_id`` remains the *ring position* (index
  into ``TokenRing.starts``) and is renumbered after a migration — it
  is a routing coordinate, not an identity.
* **Skew-aware boundaries.** :meth:`TokenRing.from_tokens` places the
  ``P-1`` interior boundaries at exact quantiles of an observed token
  stream (duplicate-token runs are rounded to whichever side lands the
  cut closer to the ideal quantile — a boundary token need not be an
  observed token). :class:`TokenHistogram` is the cheap device-side
  form: a fixed-width histogram over ``token >> shift`` (≤ 4096 bins,
  accumulated by the ``ecdf_hist`` Pallas kernel when the rows are
  device-resident), good for drift *detection*
  (:meth:`TokenHistogram.imbalance`) and coarse boundary *proposals*
  (:meth:`TokenRing.from_histogram`, linear interpolation within a
  bin); the engine's ``rebalance(exact=True)`` default uses exact
  committed-token quantiles because the ≤ 1.25× imbalance target is
  tighter than one histogram bin's resolution.
* **Migration = log surgery, recovery = log replay.** An online split
  or merge never copies table state. The new partition's commit log is
  built by token-slicing each overlapping old partition's record
  stream (per record, preserving intra-log commit order) and
  concatenating the slices in ring order with fresh contiguous LSNs
  (``CommitLog.sliced`` / ``CommitLog.concatenated``); record 0 of the
  leftmost slice survives as the new record 0 so the CREATE-base
  invariant holds. Every new replica table is then built by *replaying
  that log* — exactly the ``recover_node(source="log")`` code path —
  so post-migration log-replay recovery is bit-identical to the
  surviving-peer re-sort *by construction*, not by audit. (Equal
  packed keys in any layout imply equal full key tuples, hence equal
  canonical tokens, hence the same partition: ties can never straddle
  a boundary, so slicing commutes with the stable sorts everywhere.)
  Partitions whose ``[lo, hi]`` range is untouched by the new
  boundaries keep their log, tables, memtables, stats, caches and
  round-robin state byte-for-byte; only migrated replica ids have
  their node tables and result-cache entries dropped.

Per-partition statistics ride along: ``Partition.stats`` is the
:class:`~repro.core.ecdf.TableStats` of exactly the rows the partition
owns, seeded at CREATE/migration and merged incrementally on every
routed write, so ``read_many`` ranks each partition's replica set with
that partition's selectivities rather than CF-global ones.
"""

from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import TYPE_CHECKING, Mapping, Sequence

import numpy as np

from .keys import KeySchema, pack_columns

from .ecdf import TableStats

if TYPE_CHECKING:  # imported for annotations only; storage never imports us
    from .storage import CommitLog, CompactionPolicy, Memtable

__all__ = [
    "TokenHistogram",
    "TokenRing",
    "Partition",
    "ReplicaHandle",
    "place_replica",
]

#: Histogram width cap — matches the ``ecdf_hist`` kernel's bin limit.
_HIST_MAX_BINS_LOG2 = 12


@dataclasses.dataclass
class TokenHistogram:
    """Fixed-width histogram over the canonical token space.

    Bin of a token is ``token >> shift`` — a pure shift so the mapping
    is monotone and the device path stays integer-exact: shifted tokens
    fit int32 (≤ ``2**_HIST_MAX_BINS_LOG2`` bins) and counts accumulate
    in float32, exact below 2**24 per kernel call. Used by the engine
    for cheap skew-drift detection (:meth:`imbalance`) and for coarse
    quantile boundary proposals (:meth:`quantile_starts`, consumed by
    :meth:`TokenRing.from_histogram`).
    """

    total_bits: int
    shift: int
    counts: np.ndarray  # float64[n_bins]

    @classmethod
    def build(cls, total_bits: int) -> "TokenHistogram":
        bins_log2 = min(int(total_bits), _HIST_MAX_BINS_LOG2)
        return cls(
            total_bits=int(total_bits),
            shift=int(total_bits) - bins_log2,
            counts=np.zeros(1 << bins_log2, dtype=np.float64),
        )

    @property
    def n_bins(self) -> int:
        return self.counts.size

    @property
    def total(self) -> float:
        return float(self.counts.sum())

    def add_tokens(self, tokens: np.ndarray, *, device: bool = False) -> None:
        """Accumulate a token batch. ``device=True`` routes the bin
        count through the ``ecdf_hist`` Pallas kernel (float32 one-hot
        accumulate — exact below 2**24 rows per call); otherwise a host
        ``bincount``."""
        tokens = np.asarray(tokens, dtype=np.int64)
        if tokens.size == 0:
            return
        bins = tokens >> self.shift
        if device and tokens.size < (1 << 24):
            from repro.kernels import ecdf_hist  # lazy: keeps core import-light

            add = np.asarray(
                ecdf_hist(bins.astype(np.int32), n_bins=self.n_bins, bin_width=1),
                dtype=np.float64,
            )
        else:
            add = np.bincount(bins, minlength=self.n_bins).astype(np.float64)
        self.counts += add

    def partition_masses(self, starts: Sequence[int]) -> np.ndarray:
        """Approximate row mass per partition under ``starts`` — bin
        counts split by linear interpolation where a boundary lands
        inside a bin."""
        cum = np.concatenate([[0.0], np.cumsum(self.counts)])
        edges = np.asarray(starts, dtype=np.int64)  # all < 2**total_bits, fit int64
        bin_of = edges >> self.shift
        rem = (edges - (bin_of << self.shift)).astype(np.float64)
        frac = rem / float(1 << self.shift)
        mass_at = cum[bin_of] + frac * self.counts[bin_of]
        return np.diff(np.append(mass_at, cum[-1]))

    def imbalance(self, starts: Sequence[int]) -> float:
        """Max/mean partition row mass under ``starts`` (1.0 = perfectly
        balanced; the engine's rebalance drift trigger)."""
        masses = self.partition_masses(starts)
        total = masses.sum()
        if total <= 0 or masses.size == 0:
            return 1.0
        return float(masses.max() / (total / masses.size))

    def quantile_starts(self, n_partitions: int) -> tuple[int, ...]:
        """Boundary proposal: ``n_partitions`` start tokens placing the
        interior boundaries at histogram quantiles (linear interpolation
        within a bin). Falls back to equal splits on an empty histogram."""
        space = 1 << self.total_bits
        if not 1 <= n_partitions <= space:
            raise ValueError(f"partitions must be in [1, {space}], got {n_partitions}")
        total = self.counts.sum()
        if total <= 0:
            return tuple((space * p) // n_partitions for p in range(n_partitions))
        cum = np.concatenate([[0.0], np.cumsum(self.counts)])
        starts = [0]
        for p in range(1, n_partitions):
            target = total * p / n_partitions
            b = int(np.searchsorted(cum, target, side="right")) - 1
            b = min(max(b, 0), self.n_bins - 1)
            in_bin = self.counts[b]
            frac = (target - cum[b]) / in_bin if in_bin > 0 else 0.0
            starts.append((b << self.shift) + int(frac * (1 << self.shift)))
        return _monotone_starts(starts, space)


def _monotone_starts(starts: Sequence[int], space: int) -> tuple[int, ...]:
    """Force a boundary proposal strictly increasing inside the token
    space (duplicate quantiles — e.g. one token value holding more than
    1/P of the mass — are bumped right; the resulting empty partitions
    are valid ring members)."""
    out: list[int] = []
    prev = -1
    for s in starts:
        s = max(int(s), prev + 1)
        if s >= space:
            raise ValueError(f"cannot fit {len(starts)} distinct boundaries in [0, {space})")
        out.append(s)
        prev = s
    return tuple(out)


def place_replica(cf_name: str, replica_id: int, n_nodes: int) -> int:
    """Deterministic replica placement ``hash(cf, replica) → node``.

    crc32, not the builtin ``hash`` (salted per process), so placement
    is a pure function of the name and cluster size. Successive replica
    ids land on distinct nodes when possible; with global replica ids
    (``partition_id * RF + slot``) successive *partitions* stagger
    around the ring too. ``HREngine._place`` delegates here, so ring
    placement and engine placement can never drift apart.
    """
    h = zlib.crc32(cf_name.encode("utf-8")) % n_nodes
    return (h + replica_id) % n_nodes


@dataclasses.dataclass
class ReplicaHandle:
    """One replica of one partition: a heterogeneous serialization of
    that partition's row slice, hosted on ``node_id``. ``replica_id``
    is global across the column family (``partition_id * RF + slot``)
    — node table keys and result-cache keys stay flat."""

    replica_id: int
    layout: tuple[str, ...]
    node_id: int
    partition_id: int = 0


@dataclasses.dataclass(frozen=True)
class TokenRing:
    """Order-preserving token ring over the canonical packed key space.

    ``starts[p]`` is the first token partition ``p`` owns; partition
    ``p`` owns ``[starts[p], starts[p+1])`` (the last runs to
    ``2**total_bits``). Start tokens are built once at CREATE and are
    immutable — routing must be a pure function or replicas disagree
    about row ownership.
    """

    key_names: tuple[str, ...]
    total_bits: int
    starts: tuple[int, ...]

    @classmethod
    def build(
        cls, schema: KeySchema, key_names: Sequence[str], n_partitions: int = 1
    ) -> "TokenRing":
        """Split the canonical packed key space into ``n_partitions``
        near-equal contiguous token ranges."""
        key_names = tuple(key_names)
        schema.check_layout(key_names)
        total_bits = schema.total_bits(key_names)
        space = 1 << total_bits
        if not 1 <= n_partitions <= space:
            raise ValueError(
                f"partitions must be in [1, {space}] for a {total_bits}-bit "
                f"key space, got {n_partitions}"
            )
        starts = tuple((space * p) // n_partitions for p in range(n_partitions))
        return cls(key_names=key_names, total_bits=total_bits, starts=starts)

    @classmethod
    def from_tokens(
        cls,
        schema: KeySchema,
        key_names: Sequence[str],
        tokens: np.ndarray,
        n_partitions: int,
    ) -> "TokenRing":
        """Skew-aware ring: interior boundaries at exact quantiles of an
        observed token stream, so each partition owns ~``1/P`` of the
        *rows* rather than of the key space.

        A boundary cannot cut inside a run of equal tokens (equal token
        ⇒ same partition), so at each ideal cut the run containing the
        quantile token is rounded to whichever side leaves the realized
        cut closer to the ideal one — the residual imbalance is bounded
        by half the largest duplicate-token run. Falls back to equal
        splits when no tokens were observed.
        """
        key_names = tuple(key_names)
        schema.check_layout(key_names)
        toks = np.sort(np.asarray(tokens, dtype=np.int64))
        if toks.size == 0:
            return cls.build(schema, key_names, n_partitions)
        total_bits = schema.total_bits(key_names)
        space = 1 << total_bits
        if not 1 <= n_partitions <= space:
            raise ValueError(
                f"partitions must be in [1, {space}] for a {total_bits}-bit "
                f"key space, got {n_partitions}"
            )
        n = toks.size
        starts = [0]
        for p in range(1, n_partitions):
            cut = (n * p) // n_partitions
            t = int(toks[min(cut, n - 1)])
            left = int(np.searchsorted(toks, t, side="left"))
            right = int(np.searchsorted(toks, t, side="right"))
            # boundary = t puts the duplicate run of t on the right
            # (realized cut at ``left``); boundary = t + 1 puts it on
            # the left (realized cut at ``right``).
            if abs(left - cut) <= abs(right - cut) or t + 1 >= space:
                starts.append(t)
            else:
                starts.append(t + 1)
        return cls(
            key_names=key_names,
            total_bits=total_bits,
            starts=_monotone_starts(starts, space),
        )

    @classmethod
    def from_histogram(
        cls,
        schema: KeySchema,
        key_names: Sequence[str],
        hist: TokenHistogram,
        n_partitions: int,
    ) -> "TokenRing":
        """Skew-aware ring from a :class:`TokenHistogram` boundary
        proposal — the cheap device-friendly variant of
        :meth:`from_tokens` (resolution = one histogram bin)."""
        key_names = tuple(key_names)
        schema.check_layout(key_names)
        total_bits = schema.total_bits(key_names)
        if hist.total_bits != total_bits:
            raise ValueError(
                f"histogram covers a {hist.total_bits}-bit space, ring needs {total_bits}"
            )
        return cls(
            key_names=key_names,
            total_bits=total_bits,
            starts=hist.quantile_starts(n_partitions),
        )

    def with_starts(self, starts: Sequence[int]) -> "TokenRing":
        """Same key space, new boundaries (a migration's new ring).
        Validates ``starts`` is a well-formed ring."""
        space = 1 << self.total_bits
        starts = tuple(int(s) for s in starts)
        if not starts or starts[0] != 0:
            raise ValueError("ring starts must begin at token 0")
        if any(b <= a for a, b in zip(starts, starts[1:])) or starts[-1] >= space:
            raise ValueError("ring starts must be strictly increasing inside the token space")
        return TokenRing(key_names=self.key_names, total_bits=self.total_bits, starts=starts)

    @property
    def n_partitions(self) -> int:
        return len(self.starts)

    def token_range(self, partition_id: int) -> tuple[int, int]:
        """Inclusive ``[lo, hi]`` token range owned by a partition."""
        lo = self.starts[partition_id]
        if partition_id + 1 < len(self.starts):
            return lo, self.starts[partition_id + 1] - 1
        return lo, (1 << self.total_bits) - 1

    def tokens(
        self, key_cols: Mapping[str, np.ndarray], schema: KeySchema
    ) -> np.ndarray:
        """Row tokens: the composite keys packed in canonical order."""
        return pack_columns(key_cols, self.key_names, schema)

    def partition_of_tokens(self, tokens: np.ndarray) -> np.ndarray:
        """Owning partition id per token (vectorized)."""
        starts = np.asarray(self.starts, dtype=np.int64)
        return np.searchsorted(starts, tokens, side="right") - 1

    def span_partitions(self, bounds: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Partition id span ``[p_lo, p_hi]`` (inclusive) per query from
        canonical packed slab bounds ``int64[Q, 2]`` (inclusive ``hi``,
        the ``slab_bounds_many(queries, key_names, schema)`` output).

        Every row matching a query satisfies the query's componentwise
        filter bounds, so its canonical token lies inside the slab — a
        partition outside the span cannot hold a matching row, and the
        partitions inside it apply the full residual filters themselves
        (visiting an over-approximated partition is harmless). A query
        with a degenerate (empty) slab (``hi < lo``) is clamped to its
        home partition so it still executes (and returns zero rows)
        somewhere — mirroring the scalar empty-slab behavior.
        """
        starts = np.asarray(self.starts, dtype=np.int64)
        p_lo = np.searchsorted(starts, bounds[:, 0], side="right") - 1
        p_hi = np.searchsorted(starts, bounds[:, 1], side="right") - 1
        return p_lo, np.maximum(p_hi, p_lo)


@dataclasses.dataclass
class Partition:
    """One token range's full storage state: the heterogeneous replica
    set over its row slice, the slice's own commit log (record 0 = the
    CREATE-time rows this partition owns), per-replica memtables, the
    compaction policy bounding its device run stacks, and the
    round-robin tie-break counter for its replica set (each partition
    load-balances independently)."""

    partition_id: int
    token_lo: int
    token_hi: int
    replicas: list[ReplicaHandle]
    commitlog: "CommitLog | None" = None
    memtables: "dict[int, Memtable]" = dataclasses.field(default_factory=dict)
    compaction: "CompactionPolicy | None" = None
    rr_counter: "itertools.count" = dataclasses.field(default_factory=itertools.count)
    #: Stable virtual-node identity — assigned at birth, never reused,
    #: survives ring renumbering. Global replica ids are
    #: ``vnode_id * RF + slot``.
    vnode_id: int = 0
    #: Selectivity stats over exactly this partition's rows (None for
    #: single-partition CFs, which plan with the CF-global stats).
    stats: "TableStats | None" = None
    #: Observed committed-token extrema (None until the first row) —
    #: the scatter path's empty-range skip test. Monotone under the
    #: append-only write path, so never stale: a query slab disjoint
    #: from ``[token_min, token_max]`` cannot match any committed *or*
    #: staged row (staged rows are in the log too).
    token_min: "int | None" = None
    token_max: "int | None" = None
    #: Hinted handoff (per replica id): ``flushed_lsn[rid]`` is the
    #: exclusive log LSN through which the replica's *table* is
    #: complete (maintained by the engine at CREATE/flush/recovery),
    #: and ``hints[rid]`` — present only while the replica's node is
    #: transiently down — freezes that watermark at failure time. A
    #: hint is an LSN range against the partition's own commit log, not
    #: a data copy: node-up replays just ``[hints[rid], next_lsn)`` and
    #: merges it into the surviving table instead of rebuilding from
    #: record 0.
    flushed_lsn: "dict[int, int]" = dataclasses.field(default_factory=dict)
    hints: "dict[int, int]" = dataclasses.field(default_factory=dict)

    @property
    def n_rows_committed(self) -> int:
        """Rows this partition owns per its durable log (base + every
        committed write) — equal to any fully-flushed live replica's
        table length, and independent of staging state, which is what
        the cross-partition select offsets are built from."""
        return self.commitlog.n_rows if self.commitlog is not None else 0

    def observe_tokens(self, tokens: np.ndarray) -> None:
        """Fold a committed token batch into the token extrema."""
        if tokens.size == 0:
            return
        lo = int(tokens.min())
        hi = int(tokens.max())
        self.token_min = lo if self.token_min is None else min(self.token_min, lo)
        self.token_max = hi if self.token_max is None else max(self.token_max, hi)

    def may_contain(self, slab_lo: int, slab_hi: int) -> bool:
        """Can any committed/staged row's canonical token fall in the
        inclusive slab ``[slab_lo, slab_hi]``? False ⇒ the partition is
        guaranteed to contribute zero matching rows (the scatter path
        skips the launch and the cache probe entirely)."""
        if self.token_min is None:
            return False
        return not (slab_hi < self.token_min or slab_lo > self.token_max)
