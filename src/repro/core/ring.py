"""Token-ring partitioning — Cassandra's ring, order-preserving form.

A production keyspace does not fit one replica set: Cassandra assigns
every row a *token* and splits the token space into contiguous ranges,
each owned by its own replica group, so reads and writes fan out and a
node holds only a slice of the dataset. This module reproduces that for
the heterogeneous-replica engine:

* The **token** of a row is its composite key packed in *canonical*
  order (``key_names`` as declared at CREATE — never a replica layout,
  which differs per replica). Packing is order-preserving
  (``keys.pack_columns``), so this is Cassandra's
  ByteOrderedPartitioner rather than the hash partitioner: token ranges
  are key ranges, which is what lets a query's slab bounds be
  intersected with the ring by pure host arithmetic (no hashing a
  range — see :meth:`TokenRing.span_partitions`).
* The ring splits ``[0, 2**total_bits)`` into ``P`` near-equal
  contiguous ranges (:meth:`TokenRing.build`). A row belongs to exactly
  one partition regardless of which replica serialization it lands in.
* Each :class:`Partition` owns a full heterogeneous replica set (one
  table per layout), its own commit log, its own memtables and its own
  compaction policy — the engine's write/flush/recovery machinery runs
  per partition, and a node failure costs only the partition replicas
  that node hosted.
* Placement onto nodes uses the same deterministic crc32 scheme as
  ``HREngine._place`` (:func:`place_replica` — the engine delegates to
  it): replica ids are global across partitions
  (``partition_id * RF + slot``), so partition 0 of a ``P = 1`` column
  family places exactly where the unpartitioned engine always did.

Query planning (the scatter half of scatter-gather ``read_many``):
``slab_bounds_many(queries, key_names, schema)`` gives each query's
canonical packed slab ``[lo, hi]`` (componentwise filter bounds imply
packed bounds, since the fields occupy disjoint bit ranges), and the
partitions a query can touch are exactly the contiguous ring ranges
intersecting it — two vectorized ``searchsorted`` calls over the ring's
start tokens, the same pure-arithmetic style as the slab walk itself.
An equality filter on the leading canonical key pins the query to a
single partition (Cassandra's partition-key point read); an open query
fans out to all ``P``.
"""

from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import TYPE_CHECKING, Mapping, Sequence

import numpy as np

from .keys import KeySchema, pack_columns

if TYPE_CHECKING:  # imported for annotations only; storage never imports us
    from .storage import CommitLog, CompactionPolicy, Memtable

__all__ = ["TokenRing", "Partition", "ReplicaHandle", "place_replica"]


def place_replica(cf_name: str, replica_id: int, n_nodes: int) -> int:
    """Deterministic replica placement ``hash(cf, replica) → node``.

    crc32, not the builtin ``hash`` (salted per process), so placement
    is a pure function of the name and cluster size. Successive replica
    ids land on distinct nodes when possible; with global replica ids
    (``partition_id * RF + slot``) successive *partitions* stagger
    around the ring too. ``HREngine._place`` delegates here, so ring
    placement and engine placement can never drift apart.
    """
    h = zlib.crc32(cf_name.encode("utf-8")) % n_nodes
    return (h + replica_id) % n_nodes


@dataclasses.dataclass
class ReplicaHandle:
    """One replica of one partition: a heterogeneous serialization of
    that partition's row slice, hosted on ``node_id``. ``replica_id``
    is global across the column family (``partition_id * RF + slot``)
    — node table keys and result-cache keys stay flat."""

    replica_id: int
    layout: tuple[str, ...]
    node_id: int
    partition_id: int = 0


@dataclasses.dataclass(frozen=True)
class TokenRing:
    """Order-preserving token ring over the canonical packed key space.

    ``starts[p]`` is the first token partition ``p`` owns; partition
    ``p`` owns ``[starts[p], starts[p+1])`` (the last runs to
    ``2**total_bits``). Start tokens are built once at CREATE and are
    immutable — routing must be a pure function or replicas disagree
    about row ownership.
    """

    key_names: tuple[str, ...]
    total_bits: int
    starts: tuple[int, ...]

    @classmethod
    def build(
        cls, schema: KeySchema, key_names: Sequence[str], n_partitions: int = 1
    ) -> "TokenRing":
        """Split the canonical packed key space into ``n_partitions``
        near-equal contiguous token ranges."""
        key_names = tuple(key_names)
        schema.check_layout(key_names)
        total_bits = schema.total_bits(key_names)
        space = 1 << total_bits
        if not 1 <= n_partitions <= space:
            raise ValueError(
                f"partitions must be in [1, {space}] for a {total_bits}-bit "
                f"key space, got {n_partitions}"
            )
        starts = tuple((space * p) // n_partitions for p in range(n_partitions))
        return cls(key_names=key_names, total_bits=total_bits, starts=starts)

    @property
    def n_partitions(self) -> int:
        return len(self.starts)

    def token_range(self, partition_id: int) -> tuple[int, int]:
        """Inclusive ``[lo, hi]`` token range owned by a partition."""
        lo = self.starts[partition_id]
        if partition_id + 1 < len(self.starts):
            return lo, self.starts[partition_id + 1] - 1
        return lo, (1 << self.total_bits) - 1

    def tokens(
        self, key_cols: Mapping[str, np.ndarray], schema: KeySchema
    ) -> np.ndarray:
        """Row tokens: the composite keys packed in canonical order."""
        return pack_columns(key_cols, self.key_names, schema)

    def partition_of_tokens(self, tokens: np.ndarray) -> np.ndarray:
        """Owning partition id per token (vectorized)."""
        starts = np.asarray(self.starts, dtype=np.int64)
        return np.searchsorted(starts, tokens, side="right") - 1

    def span_partitions(self, bounds: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        """Partition id span ``[p_lo, p_hi]`` (inclusive) per query from
        canonical packed slab bounds ``int64[Q, 2]`` (inclusive ``hi``,
        the ``slab_bounds_many(queries, key_names, schema)`` output).

        Every row matching a query satisfies the query's componentwise
        filter bounds, so its canonical token lies inside the slab — a
        partition outside the span cannot hold a matching row, and the
        partitions inside it apply the full residual filters themselves
        (visiting an over-approximated partition is harmless). A query
        with a degenerate (empty) slab (``hi < lo``) is clamped to its
        home partition so it still executes (and returns zero rows)
        somewhere — mirroring the scalar empty-slab behavior.
        """
        starts = np.asarray(self.starts, dtype=np.int64)
        p_lo = np.searchsorted(starts, bounds[:, 0], side="right") - 1
        p_hi = np.searchsorted(starts, bounds[:, 1], side="right") - 1
        return p_lo, np.maximum(p_hi, p_lo)


@dataclasses.dataclass
class Partition:
    """One token range's full storage state: the heterogeneous replica
    set over its row slice, the slice's own commit log (record 0 = the
    CREATE-time rows this partition owns), per-replica memtables, the
    compaction policy bounding its device run stacks, and the
    round-robin tie-break counter for its replica set (each partition
    load-balances independently)."""

    partition_id: int
    token_lo: int
    token_hi: int
    replicas: list[ReplicaHandle]
    commitlog: "CommitLog | None" = None
    memtables: "dict[int, Memtable]" = dataclasses.field(default_factory=dict)
    compaction: "CompactionPolicy | None" = None
    rr_counter: "itertools.count" = dataclasses.field(default_factory=itertools.count)

    @property
    def n_rows_committed(self) -> int:
        """Rows this partition owns per its durable log (base + every
        committed write) — equal to any fully-flushed live replica's
        table length, and independent of staging state, which is what
        the cross-partition select offsets are built from."""
        return self.commitlog.n_rows if self.commitlog is not None else 0
