"""HRCA — Heterogeneous Replica Constructing Algorithm (paper Alg. 1).

Search over replica *states* R = (layout_1 … layout_N), each layout a
permutation of the clustering keys. Enumerating all C(m!+N−1, N)
multisets is infeasible, so Algorithm 1 runs simulated annealing:

    NewState(R): swap two clustering keys inside one replica
    accept if C' < C, else with probability exp((C − C') / t)

Faithful options: geometric cooling from ``t0``, ``k_max`` steps.
Beyond-paper extras (all off by default, used by benchmarks/§Perf):
  * ``restarts`` — independent SA chains, keep the best (SA is cheap:
    "the algorithm is only called once … converges in ten seconds").
  * ``greedy_descent`` — steepest-descent polish over all single-swap
    neighbors after annealing.
Costs are memoized per (layout, query) — the annealer revisits states.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
import time
from typing import Sequence

import numpy as np

from .cost_model import CostModel
from .workload import Workload

__all__ = ["HRCAResult", "hrca", "exhaustive_search", "initial_state"]

State = tuple[tuple[str, ...], ...]


@dataclasses.dataclass
class HRCAResult:
    layouts: State
    cost: float
    initial_cost: float
    n_steps: int
    n_accepted: int
    wall_seconds: float
    trace: list[float]  # accepted-cost trajectory (for convergence bench)


#: What the search optimizes against: one CF-global model, or a
#: row-fraction-weighted set of per-partition models (the vnode ring's
#: view — every partition serves every query with its own selectivities,
#: so the state cost is the weighted sum of per-partition Eq (4)).
ModelSpec = "CostModel | Sequence[tuple[float, CostModel]]"


def _normalize_models(model) -> list[tuple[float, CostModel]]:
    if isinstance(model, CostModel):
        return [(1.0, model)]
    models = [(float(w), m) for w, m in model]
    if not models:
        raise ValueError("need at least one cost model")
    total = sum(w for w, _ in models)
    if total <= 0:  # empty partitions everywhere — weight uniformly
        return [(1.0 / len(models), m) for _, m in models]
    return [(w / total, m) for w, m in models]


class _MemoCost:
    """Eq (4) with per-(layout, query-index) memoization.

    Accepts a single :class:`CostModel` or a weighted sequence
    ``[(weight, model), ...]`` (per-partition stats); a query's cost is
    then the weight-blended cost across models — each partition picks
    its own cheapest replica at serve time, but the *layout set* is
    shared ring-wide, so construction optimizes the blend."""

    def __init__(self, model, workload: Workload) -> None:
        self.models = _normalize_models(model)
        self.workload = workload
        self.weights = workload.normalized_weights()
        self._cache: dict[tuple[tuple[str, ...], int], tuple[float, ...]] = {}

    def _model_costs(self, layout: tuple[str, ...], qi: int) -> tuple[float, ...]:
        """Per-model ``weight · Cost(layout, q)`` for one query."""
        key = (layout, qi)
        c = self._cache.get(key)
        if c is None:
            q = self.workload.queries[qi]
            c = tuple(w * m.query_cost(layout, q) for w, m in self.models)
            self._cache[key] = c
        return c

    def query_cost(self, layout: tuple[str, ...], qi: int) -> float:
        return float(sum(self._model_costs(layout, qi)))

    def state_cost(self, state: State) -> float:
        """Eq (4), generalized: each partition serves each query from
        *its own* cheapest replica, so the min over the layout set is
        taken per model, then blended — ``Σ_q w_q Σ_p w_p min_r
        Cost_p(r, q)``. With a single model this reduces exactly to the
        paper's ``Σ_q w_q min_r Cost(r, q)``."""
        n_m = len(self.models)
        total = 0.0
        for qi, w in enumerate(self.weights):
            per_layout = [self._model_costs(a, qi) for a in state]
            total += w * sum(
                min(pc[p] for pc in per_layout) for p in range(n_m)
            )
        return float(total)


def initial_state(key_cols: Sequence[str], n_replicas: int) -> State:
    """Arbitrary initial state R0 (paper: "arbitrary"): every replica gets
    the same natural order — also exactly the TR baseline layout set."""
    return tuple(tuple(key_cols) for _ in range(n_replicas))


def _new_state(state: State, rng: np.random.Generator) -> State:
    """NewState(R): swap two clustering keys of one replica (paper §3.2)."""
    j = int(rng.integers(len(state)))
    layout = list(state[j])
    if len(layout) < 2:
        return state
    a, b = rng.choice(len(layout), size=2, replace=False)
    layout[a], layout[b] = layout[b], layout[a]
    return state[:j] + (tuple(layout),) + state[j + 1 :]


def _greedy_polish(state: State, memo: _MemoCost) -> tuple[State, float]:
    """Steepest descent over all single-swap neighbors until no gain."""
    cur, cur_c = state, memo.state_cost(state)
    improved = True
    while improved:
        improved = False
        for j in range(len(cur)):
            lay = cur[j]
            for a in range(len(lay)):
                for b in range(a + 1, len(lay)):
                    nl = list(lay)
                    nl[a], nl[b] = nl[b], nl[a]
                    cand = cur[:j] + (tuple(nl),) + cur[j + 1 :]
                    c = memo.state_cost(cand)
                    if c < cur_c - 1e-12:
                        cur, cur_c, improved = cand, c, True
    return cur, cur_c


def hrca(
    model: "CostModel | Sequence[tuple[float, CostModel]]",
    workload: Workload,
    initial: State,
    *,
    t0: float | None = None,
    cooling: float = 0.995,
    k_max: int = 4000,
    seed: int = 0,
    restarts: int = 1,
    greedy_descent: bool = False,
) -> HRCAResult:
    """Algorithm 1. ``t0`` defaults to the initial cost (so early uphill
    moves of relative size ~1 are accepted with prob ~1/e).

    ``model`` may be a single :class:`CostModel` or a weighted sequence
    ``[(weight, model), ...]`` — the vnode ring passes one model per
    partition, weighted by the partition's row fraction, so the shared
    layout set is optimized against per-partition selectivities rather
    than the CF-global blend (see ``_MemoCost.state_cost``)."""
    memo = _MemoCost(model, workload)
    rng = np.random.default_rng(seed)
    start = time.perf_counter()
    c0 = memo.state_cost(initial)

    best_state, best_cost = initial, c0
    total_steps = total_accepted = 0
    trace: list[float] = [c0]

    for r in range(max(1, restarts)):
        state, cost = initial, c0
        t = float(t0) if t0 is not None else max(c0, 1e-9)
        for _ in range(k_max):
            total_steps += 1
            cand = _new_state(state, rng)
            c = memo.state_cost(cand)
            if c < cost or math.exp(min(0.0, (cost - c) / max(t, 1e-300))) > rng.random():
                state, cost = cand, c
                total_accepted += 1
                trace.append(cost)
                if cost < best_cost:
                    best_state, best_cost = state, cost
            t *= cooling

    if greedy_descent:
        best_state, best_cost = _greedy_polish(best_state, memo)

    return HRCAResult(
        layouts=best_state,
        cost=best_cost,
        initial_cost=c0,
        n_steps=total_steps,
        n_accepted=total_accepted,
        wall_seconds=time.perf_counter() - start,
        trace=trace,
    )


def exhaustive_search(
    model: "CostModel | Sequence[tuple[float, CostModel]]",
    workload: Workload,
    key_cols: Sequence[str],
    n_replicas: int,
) -> tuple[State, float]:
    """Enumerate all multisets of permutations — the tiny-instance oracle
    used to test HRCA optimality (feasible for m ≤ 4, N ≤ 3)."""
    memo = _MemoCost(model, workload)
    perms = [tuple(p) for p in itertools.permutations(key_cols)]
    best: tuple[State, float] | None = None
    for combo in itertools.combinations_with_replacement(perms, n_replicas):
        c = memo.state_cost(combo)
        if best is None or c < best[1]:
            best = (combo, c)
    assert best is not None
    return best
