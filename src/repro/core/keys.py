"""Composite clustering keys.

A replica's on-disk order is lexicographic over a *permutation* of the
clustering key columns (the paper's "structure of the replica", §3.1).
To make range location O(log N) we pack the permuted integer key columns
into a single uint64 whose natural order equals the lexicographic order.

Columns are non-negative integers with a declared bit width. The packed
key allocates each column its width, most-significant field first, so
``packed(a) < packed(b)  <=>  tuple(a) < tuple(b)`` lexicographically.
Total width must fit 63 bits (we stay in int64 land to keep jnp-friendly
dtypes); all paper workloads (≤6 keys, ≤2^20 domains) fit easily.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import numpy as np

__all__ = ["KeySchema", "pack_columns", "pack_tuple", "unpack_key"]


@dataclasses.dataclass(frozen=True)
class KeySchema:
    """Bit layout for a set of clustering key columns.

    ``bits[name]`` is the field width for column ``name``. The packing
    order is given per-call (it is the replica layout, not a schema
    property).
    """

    bits: Mapping[str, int]

    def __post_init__(self) -> None:
        for name, b in self.bits.items():
            if not 0 < b <= 62:
                raise ValueError(f"column {name!r}: bits must be in (0, 62], got {b}")

    def total_bits(self, layout: Sequence[str]) -> int:
        return sum(self.bits[c] for c in layout)

    def check_layout(self, layout: Sequence[str]) -> None:
        missing = [c for c in layout if c not in self.bits]
        if missing:
            raise KeyError(f"layout references unknown columns {missing}")
        if len(set(layout)) != len(layout):
            raise ValueError(f"layout has duplicate columns: {layout}")
        if self.total_bits(layout) > 63:
            raise ValueError(
                f"packed key needs {self.total_bits(layout)} bits > 63; "
                "reduce column domains or split the table"
            )

    def max_value(self, col: str) -> int:
        return (1 << self.bits[col]) - 1

    @staticmethod
    def for_columns(columns: Mapping[str, np.ndarray]) -> "KeySchema":
        """Infer minimal widths from observed data (with one spare value
        of headroom so exclusive upper bounds stay representable)."""
        bits = {}
        for name, col in columns.items():
            if col.size and int(col.min()) < 0:
                raise ValueError(f"column {name!r} has negative values")
            hi = int(col.max()) + 1 if col.size else 1
            bits[name] = max(1, int(hi).bit_length())
        return KeySchema(bits)


def _field_shifts(schema: KeySchema, layout: Sequence[str]) -> list[int]:
    """Left-shift for each layout position (MSB-first packing)."""
    shifts = []
    acc = schema.total_bits(layout)
    for col in layout:
        acc -= schema.bits[col]
        shifts.append(acc)
    return shifts


def pack_columns(
    columns: Mapping[str, np.ndarray], layout: Sequence[str], schema: KeySchema
) -> np.ndarray:
    """Pack per-column arrays into a single int64 composite key array."""
    schema.check_layout(layout)
    shifts = _field_shifts(schema, layout)
    out = None
    for col, sh in zip(layout, shifts):
        v = columns[col].astype(np.int64, copy=False)
        if v.size and int(v.max()) > schema.max_value(col):
            raise ValueError(
                f"column {col!r} exceeds its {schema.bits[col]}-bit field"
            )
        term = v << np.int64(sh)
        out = term if out is None else out | term
    if out is None:
        raise ValueError("empty layout")
    return out


def pack_tuple(
    values: Sequence[int], layout: Sequence[str], schema: KeySchema
) -> int:
    """Pack one composite key value (python ints; used for bounds)."""
    schema.check_layout(layout)
    shifts = _field_shifts(schema, layout)
    out = 0
    for col, sh, v in zip(layout, shifts, values):
        if not 0 <= int(v) <= schema.max_value(col):
            raise ValueError(f"value {v} out of range for column {col!r}")
        out |= int(v) << sh
    return out


def unpack_key(key: int, layout: Sequence[str], schema: KeySchema) -> tuple[int, ...]:
    shifts = _field_shifts(schema, layout)
    return tuple(
        (int(key) >> sh) & schema.max_value(col) for col, sh in zip(layout, shifts)
    )
