"""Per-column distribution statistics: F (ECDF) and f (pmf), paper §3.1.

Eq (1) needs, per clustering key column, the distribution function
``F_k(x)`` and the density ``f_k(v)`` ("probability a row has value v").
Small integer domains get exact value counts; large domains fall back to
equi-width histograms (B bins). Stats are maintained by the engine's Cost
Evaluator and refreshed on writes.

The histogram build is a measurable hot loop at corpus scale, so it has a
Pallas kernel (`repro.kernels.ecdf_hist`), wired in behind
``merge_rows(..., device=True)`` — the engine passes ``device=True`` for
device-resident column families so the Cost Evaluator's ECDF refresh
after every memtable flush runs on the accelerator next to the data it
describes. This module is the numpy reference (bit-equal: the kernel's
float32 bin counts are exact integers below 2**24) and the serving API.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping

import numpy as np

from .keys import KeySchema

__all__ = ["ColumnStats", "TableStats"]

_EXACT_DOMAIN_LIMIT = 1 << 16


@dataclasses.dataclass
class ColumnStats:
    """Counts per bin over [0, domain); exact when bin_width == 1."""

    domain: int  # values are in [0, domain)
    bin_width: int
    counts: np.ndarray  # float64[n_bins]
    total: float

    @classmethod
    def from_values(cls, values: np.ndarray, domain: int, max_bins: int = 4096) -> "ColumnStats":
        if domain <= 0:
            raise ValueError("domain must be positive")
        if domain <= min(_EXACT_DOMAIN_LIMIT, max_bins):
            bw = 1
            nb = domain
        else:
            nb = max_bins
            bw = -(-domain // nb)  # ceil
            nb = -(-domain // bw)
        idx = np.asarray(values, dtype=np.int64) // bw
        counts = np.bincount(idx, minlength=nb).astype(np.float64)
        return cls(domain=domain, bin_width=bw, counts=counts, total=float(counts.sum()))

    @property
    def n_bins(self) -> int:
        return int(self.counts.shape[0])

    def _cum(self) -> np.ndarray:
        # cached cumulative counts (prefix-exclusive)
        cum = getattr(self, "_cum_cache", None)
        if cum is None or cum.shape[0] != self.n_bins + 1:
            cum = np.concatenate([[0.0], np.cumsum(self.counts)])
            object.__setattr__(self, "_cum_cache", cum)
        return cum

    def cdf(self, x: float) -> float:
        """F(x) = P[value < x] (left-continuous: mass strictly below x)."""
        if self.total == 0:
            return 0.0
        x = float(np.clip(x, 0, self.domain))
        b = int(x // self.bin_width)
        cum = self._cum()
        below = cum[min(b, self.n_bins)]
        frac = (x - b * self.bin_width) / self.bin_width if b < self.n_bins else 0.0
        inbin = self.counts[b] * frac if b < self.n_bins else 0.0
        return float((below + inbin) / self.total)

    def range_selectivity(self, lo: float, hi: float) -> float:
        """P[value ∈ [lo, hi)] = F(hi) − F(lo), Eq (1) range term."""
        return max(0.0, self.cdf(hi) - self.cdf(lo))

    def pmf(self, v: int) -> float:
        """f(v) — equality selectivity. Exact bins: count/total; coarse
        bins: bin mass spread uniformly across the bin's values."""
        if self.total == 0:
            return 0.0
        b = int(v) // self.bin_width
        if not 0 <= b < self.n_bins:
            return 0.0
        mass = self.counts[b] / self.total
        return float(mass if self.bin_width == 1 else mass / self.bin_width)

    # -- vectorized forms (batched read path) ------------------------------
    #
    # These evaluate the exact same float64 expressions as the scalar
    # methods, elementwise, so per-query costs from the batched estimator
    # are bit-identical to the sequential ones (routing decisions match).

    def cdf_many(self, x: np.ndarray) -> np.ndarray:
        """Vectorized ``cdf``: float64[...] → float64[...]."""
        x = np.asarray(x, dtype=np.float64)
        if self.total == 0:
            return np.zeros_like(x)
        x = np.clip(x, 0, self.domain)
        b = (x // self.bin_width).astype(np.int64)
        cum = self._cum()
        below = cum[np.minimum(b, self.n_bins)]
        interior = b < self.n_bins
        frac = np.where(interior, (x - b * self.bin_width) / self.bin_width, 0.0)
        inbin = np.where(interior, self.counts[np.minimum(b, self.n_bins - 1)], 0.0) * frac
        return (below + inbin) / self.total

    def range_selectivity_many(self, lo: np.ndarray, hi: np.ndarray) -> np.ndarray:
        """Vectorized ``range_selectivity`` over [lo, hi) pairs."""
        return np.maximum(0.0, self.cdf_many(hi) - self.cdf_many(lo))

    def pmf_many(self, v: np.ndarray) -> np.ndarray:
        """Vectorized ``pmf``: int[...] → float64[...]."""
        v = np.asarray(v, dtype=np.int64)
        if self.total == 0:
            return np.zeros(v.shape, dtype=np.float64)
        b = v // self.bin_width
        valid = (b >= 0) & (b < self.n_bins)
        mass = self.counts[np.where(valid, b, 0)] / self.total
        if self.bin_width != 1:
            mass = mass / self.bin_width
        return np.where(valid, mass, 0.0)

    # the ecdf_hist kernel holds values and bin ids in int32 lanes, its
    # one-hot compare is sized for n_bins <= 4096, and its float32 bin
    # counts are exact integers only below 2**24 rows per launch; wider
    # domains, bin tables or batches keep the numpy path (same counts)
    _DEVICE_MAX_BINS = 4096
    _DEVICE_MAX_DOMAIN = 1 << 31
    _DEVICE_MAX_ROWS = 1 << 24

    def merge_values(self, values: np.ndarray, *, device: bool = False) -> None:
        """Streaming update on writes (engine Write Scheduler).

        With ``device=True`` the bin counts come from the Pallas
        ``ecdf_hist`` kernel instead of host ``np.bincount`` — exact for
        any batch below 2**24 rows per launch (float32 integer counts) —
        so stats refresh stays on the accelerator for device-resident
        column families. Falls back to numpy when the column's domain
        exceeds the kernel's int32 lanes or bin budget, or the batch
        exceeds the float32 count exactness bound."""
        values = np.asarray(values, dtype=np.int64)
        if (
            device
            and 0 < values.size < self._DEVICE_MAX_ROWS
            and self.n_bins <= self._DEVICE_MAX_BINS
            and self.domain <= self._DEVICE_MAX_DOMAIN
        ):
            from repro.kernels import ecdf_hist

            add = np.asarray(
                ecdf_hist(values, n_bins=self.n_bins, bin_width=self.bin_width)
            ).astype(np.float64)
        else:
            idx = values // self.bin_width
            add = np.bincount(idx, minlength=self.n_bins).astype(np.float64)
        self.counts = self.counts + add
        self.total = float(self.total + add.sum())
        if hasattr(self, "_cum_cache"):
            delattr(self, "_cum_cache")

    def merged_with(self, other: "ColumnStats") -> "ColumnStats":
        """Pure union of two compatible histograms (same domain and
        binning): bin counts add. The partition-merge stats fast path —
        two partitions' row sets are disjoint, so their histograms sum
        to exactly the merged partition's histogram, no re-scan."""
        if (self.domain, self.bin_width, self.n_bins) != (
            other.domain,
            other.bin_width,
            other.n_bins,
        ):
            raise ValueError("cannot merge ColumnStats with different binning")
        return ColumnStats(
            domain=self.domain,
            bin_width=self.bin_width,
            counts=self.counts + other.counts,
            total=self.total + other.total,
        )


@dataclasses.dataclass
class TableStats:
    """Cost-Evaluator statistics for one column family."""

    n_rows: int
    columns: dict[str, ColumnStats]

    @classmethod
    def from_columns(
        cls, key_cols: Mapping[str, np.ndarray], schema: KeySchema, max_bins: int = 4096
    ) -> "TableStats":
        n = len(next(iter(key_cols.values()))) if key_cols else 0
        cols = {
            name: ColumnStats.from_values(v, schema.max_value(name) + 1, max_bins)
            for name, v in key_cols.items()
        }
        return cls(n_rows=n, columns=cols)

    def merge_rows(
        self, key_cols: Mapping[str, np.ndarray], *, device: bool = False
    ) -> None:
        """Fold a write batch into the stats; ``device=True`` routes the
        per-column histogram updates through the ``ecdf_hist`` kernel
        (the engine's choice for device-resident column families)."""
        n = len(next(iter(key_cols.values()))) if key_cols else 0
        self.n_rows += n
        for name, v in key_cols.items():
            self.columns[name].merge_values(v, device=device)

    def merged_with(self, other: "TableStats") -> "TableStats":
        """Union of two disjoint row sets' stats (partition merge):
        per-column histograms add bin-wise — exactly the stats a full
        re-scan of the union would produce, without the re-scan."""
        if set(self.columns) != set(other.columns):
            raise ValueError("cannot merge TableStats with different columns")
        return TableStats(
            n_rows=self.n_rows + other.n_rows,
            columns={
                name: cs.merged_with(other.columns[name])
                for name, cs in self.columns.items()
            },
        )
