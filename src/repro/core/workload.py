"""Queries and workloads (paper §3.1).

A query is a conjunction of per-column filters over the clustering keys:
equality (``d.ck = v``) or half-open range (``d.ck ∈ [s, e)``). Columns
with no filter are treated as carrying the *global* range filter (the
paper assigns these explicitly so every clustering key has a filter);
Cassandra would evaluate the residual predicates with ALLOW FILTERING.

A workload is a list of queries, optionally weighted.
"""

from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence

import numpy as np

from .keys import KeySchema

__all__ = ["Eq", "Range", "Query", "Workload", "random_workload"]


@dataclasses.dataclass(frozen=True)
class Eq:
    value: int

    def bounds(self, schema: KeySchema, col: str) -> tuple[int, int]:
        return int(self.value), int(self.value) + 1

    @property
    def is_equality(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Range:
    start: int  # inclusive
    end: int  # exclusive

    def __post_init__(self) -> None:
        if self.end < self.start:
            raise ValueError(f"empty-inverted range [{self.start}, {self.end})")

    def bounds(self, schema: KeySchema, col: str) -> tuple[int, int]:
        return int(self.start), int(self.end)

    @property
    def is_equality(self) -> bool:
        return False


Filter = Eq | Range


@dataclasses.dataclass(frozen=True)
class Query:
    """Conjunctive filters + an aggregation over a value column.

    ``agg`` ∈ {"sum", "count", "select"}: TPC-H Q1/Q2 are sums over
    ``totalprice``; "select" returns matching rows (used in tests).
    """

    filters: Mapping[str, Filter]
    agg: str = "count"
    value_col: Optional[str] = None

    def filter_bounds(self, schema: KeySchema, col: str) -> tuple[int, int]:
        """[lo, hi) bounds for a column; global range if unfiltered."""
        f = self.filters.get(col)
        if f is None:
            return 0, schema.max_value(col) + 1
        return f.bounds(schema, col)

    def is_equality_on(self, col: str) -> bool:
        f = self.filters.get(col)
        return f is not None and f.is_equality


@dataclasses.dataclass(frozen=True)
class Workload:
    queries: Sequence[Query]
    weights: Optional[Sequence[float]] = None

    def __post_init__(self) -> None:
        if self.weights is not None and len(self.weights) != len(self.queries):
            raise ValueError("weights length mismatch")

    def __len__(self) -> int:
        return len(self.queries)

    def normalized_weights(self) -> np.ndarray:
        if self.weights is None:
            return np.full(len(self.queries), 1.0 / max(1, len(self.queries)))
        w = np.asarray(self.weights, dtype=np.float64)
        return w / w.sum()


def random_workload(
    rng: np.random.Generator,
    schema: KeySchema,
    key_cols: Sequence[str],
    n_queries: int,
    *,
    p_eq: float = 0.5,
    p_absent: float = 0.2,
    range_frac: float = 0.1,
    agg: str = "count",
    value_col: Optional[str] = None,
) -> Workload:
    """Random conjunctive workload over ``key_cols`` (paper §5, simulation
    dataset: "the queries we used is randomly generated").

    Each key independently gets: no filter (p_absent), an equality filter
    (p_eq), else a range filter covering ~``range_frac`` of the domain.
    Queries with no filter at all are re-drawn.
    """
    queries: list[Query] = []
    while len(queries) < n_queries:
        filters: dict[str, Filter] = {}
        for col in key_cols:
            u = rng.random()
            dom = schema.max_value(col) + 1
            if u < p_absent:
                continue
            if u < p_absent + p_eq:
                filters[col] = Eq(int(rng.integers(0, dom)))
            else:
                width = max(1, int(dom * range_frac))
                start = int(rng.integers(0, max(1, dom - width)))
                filters[col] = Range(start, start + width)
        if not filters:
            continue
        queries.append(Query(filters=filters, agg=agg, value_col=value_col))
    return Workload(queries)
