"""Query cost model (paper §3.1, Eq 1–4).

Eq (1): with layout ``A = (ck_1 … ck_n)`` and ``i`` the first position
whose filter is a *range* (keys before it all equality-filtered):

    Row(r, q) = N · ∏_{p<i} f_{ck_p}(v_p) · (F_{ck_i}(e_i) − F_{ck_i}(s_i))

(The paper prints ``|P|`` for the leading factor; §5's "data size |P|"
confirms it is the dataset row count N.) Keys *after* position i do not
shrink the slab — they are residual predicates evaluated during the scan.

Eq (2): Cost = f(Row) with f fitted linear per environment; the slope
depends on the number of clustering keys (Fig 4b) so fits are keyed by
|A|. Eq (3)/(4): per-query cost is the min over replicas; workload cost
is the (weighted) mean of per-query minima.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .ecdf import TableStats
from .workload import Query, Workload

__all__ = ["estimate_rows", "LinearCostFunction", "CostModel"]


def estimate_rows(stats: TableStats, layout: Sequence[str], query: Query) -> float:
    """Eq (1) — expected slab rows for ``query`` on a replica with ``layout``."""
    sel = 1.0
    for col in layout:
        cs = stats.columns[col]
        if query.is_equality_on(col):
            sel *= cs.pmf(query.filters[col].value)  # type: ignore[union-attr]
            if sel == 0.0:
                break
        else:
            f = query.filters.get(col)
            if f is None:
                # global range filter: selectivity 1, and the prefix ends here.
                break
            lo, hi = f.bounds(None, col)  # Range.bounds ignores schema args
            sel *= cs.range_selectivity(lo, hi)
            break
    return float(stats.n_rows) * sel


@dataclasses.dataclass(frozen=True)
class LinearCostFunction:
    """f(Row) = slope · Row + intercept (Fig 4: linear in Row; slope grows
    with the clustering-key count, insensitive to value byte width)."""

    slope: float
    intercept: float

    def __call__(self, rows: float) -> float:
        return self.slope * float(rows) + self.intercept

    @classmethod
    def fit(cls, rows: np.ndarray, times: np.ndarray) -> "LinearCostFunction":
        rows = np.asarray(rows, dtype=np.float64)
        times = np.asarray(times, dtype=np.float64)
        if rows.size < 2:
            raise ValueError("need ≥2 samples to fit")
        slope, intercept = np.polyfit(rows, times, 1)
        return cls(slope=float(slope), intercept=float(intercept))

    def r2(self, rows: np.ndarray, times: np.ndarray) -> float:
        pred = self.slope * np.asarray(rows, np.float64) + self.intercept
        t = np.asarray(times, np.float64)
        ss_res = float(((t - pred) ** 2).sum())
        ss_tot = float(((t - t.mean()) ** 2).sum())
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0


#: Default per-|A| cost functions (re-fitted by benchmarks/fig4; unit
#: slope makes Cost ≡ Row, which preserves all argmin decisions).
_IDENTITY = LinearCostFunction(slope=1.0, intercept=0.0)


@dataclasses.dataclass
class CostModel:
    """Eq (2)–(4) over a set of replica layouts."""

    stats: TableStats
    cost_fns: dict[int, LinearCostFunction] = dataclasses.field(default_factory=dict)

    def cost_fn(self, n_keys: int) -> LinearCostFunction:
        return self.cost_fns.get(n_keys, _IDENTITY)

    def query_cost(self, layout: Sequence[str], query: Query) -> float:
        """Eq (2): Cost(r, q) = f(Row(r, q))."""
        rows = estimate_rows(self.stats, layout, query)
        return self.cost_fn(len(layout))(rows)

    def min_cost(self, layouts: Sequence[Sequence[str]], query: Query) -> tuple[float, int]:
        """Eq (3): (min cost, argmin replica index)."""
        costs = [self.query_cost(a, query) for a in layouts]
        j = int(np.argmin(costs))
        return costs[j], j

    def workload_cost(self, layouts: Sequence[Sequence[str]], workload: Workload) -> float:
        """Eq (4): weighted mean of per-query minima."""
        w = workload.normalized_weights()
        return float(
            sum(
                wi * self.min_cost(layouts, q)[0]
                for wi, q in zip(w, workload.queries)
            )
        )
