"""Query cost model (paper §3.1, Eq 1–4).

Eq (1): with layout ``A = (ck_1 … ck_n)`` and ``i`` the first position
whose filter is a *range* (keys before it all equality-filtered):

    Row(r, q) = N · ∏_{p<i} f_{ck_p}(v_p) · (F_{ck_i}(e_i) − F_{ck_i}(s_i))

(The paper prints ``|P|`` for the leading factor; §5's "data size |P|"
confirms it is the dataset row count N.) Keys *after* position i do not
shrink the slab — they are residual predicates evaluated during the scan.

Eq (2): Cost = f(Row) with f fitted linear per environment; the slope
depends on the number of clustering keys (Fig 4b) so fits are keyed by
|A|. Eq (3)/(4): per-query cost is the min over replicas; workload cost
is the (weighted) mean of per-query minima.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .ecdf import TableStats
from .workload import Query, Workload

__all__ = [
    "estimate_rows",
    "estimate_rows_many",
    "precompute_query_stats",
    "LinearCostFunction",
    "CostModel",
]


def estimate_rows(stats: TableStats, layout: Sequence[str], query: Query) -> float:
    """Eq (1) — expected slab rows for ``query`` on a replica with ``layout``."""
    sel = 1.0
    for col in layout:
        cs = stats.columns[col]
        if query.is_equality_on(col):
            sel *= cs.pmf(query.filters[col].value)  # type: ignore[union-attr]
            if sel == 0.0:
                break
        else:
            f = query.filters.get(col)
            if f is None:
                # global range filter: selectivity 1, and the prefix ends here.
                break
            lo, hi = f.bounds(None, col)  # Range.bounds ignores schema args
            sel *= cs.range_selectivity(lo, hi)
            break
    return float(stats.n_rows) * sel


def precompute_query_stats(
    stats: TableStats, queries: Sequence[Query], columns: Sequence[str]
) -> dict[str, tuple[np.ndarray, np.ndarray, np.ndarray]]:
    """Per-column (has_eq, has_rng, selectivity) arrays for a batch.

    A query's per-column selectivity (pmf for equality, F(hi)−F(lo) for
    a range) does not depend on the replica layout — only *which*
    columns contribute does. Precomputing it once lets ``read_many``
    amortize the filter extraction and the vectorized ECDF lookups
    across all replicas instead of redoing them per layout.
    """
    n_q = len(queries)
    pre = {}
    for col in columns:
        cs = stats.columns[col]
        has_eq = np.zeros(n_q, dtype=bool)
        has_rng = np.zeros(n_q, dtype=bool)
        vals = np.zeros(n_q, dtype=np.int64)
        los = np.zeros(n_q, dtype=np.float64)
        his = np.zeros(n_q, dtype=np.float64)
        for i, q in enumerate(queries):
            f = q.filters.get(col)
            if f is None:
                continue
            if f.is_equality:
                has_eq[i] = True
                vals[i] = f.value  # type: ignore[union-attr]
            else:
                has_rng[i] = True
                los[i], his[i] = f.start, f.end  # type: ignore[union-attr]
        sel = np.ones(n_q, dtype=np.float64)
        if has_eq.any():
            sel[has_eq] = cs.pmf_many(vals[has_eq])
        if has_rng.any():
            sel[has_rng] = cs.range_selectivity_many(los[has_rng], his[has_rng])
        pre[col] = (has_eq, has_rng, sel)
    return pre


def estimate_rows_many(
    stats: TableStats,
    layout: Sequence[str],
    queries: Sequence[Query],
    pre: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray]] | None = None,
) -> np.ndarray:
    """Vectorized Eq (1) over a query batch: float64[len(queries)].

    Evaluates the same float64 expressions as :func:`estimate_rows`, in
    the same per-column order, so each entry is bit-identical to the
    scalar estimate — the batched scheduler makes exactly the routing
    decisions the sequential one would. Pass ``pre`` from
    :func:`precompute_query_stats` to share the per-column selectivity
    extraction across several layouts.
    """
    n_q = len(queries)
    if pre is None:
        pre = precompute_query_stats(stats, queries, layout)
    sel = np.ones(n_q, dtype=np.float64)
    active = np.ones(n_q, dtype=bool)  # equality prefix still extending
    for col in layout:
        if not active.any():
            break
        has_eq, has_rng, col_sel = pre[col]
        apply = active & (has_eq | has_rng)
        if apply.any():
            sel[apply] = sel[apply] * col_sel[apply]
        # only an equality filter extends the prefix; absent (global
        # range, selectivity 1) and range filters both terminate it
        active &= has_eq
    return float(stats.n_rows) * sel


@dataclasses.dataclass(frozen=True)
class LinearCostFunction:
    """f(Row) = slope · Row + intercept (Fig 4: linear in Row; slope grows
    with the clustering-key count, insensitive to value byte width)."""

    slope: float
    intercept: float

    def __call__(self, rows: float) -> float:
        return self.slope * float(rows) + self.intercept

    def many(self, rows: np.ndarray) -> np.ndarray:
        """Vectorized evaluation (same float64 ops as ``__call__``)."""
        return self.slope * np.asarray(rows, dtype=np.float64) + self.intercept

    @classmethod
    def fit(cls, rows: np.ndarray, times: np.ndarray) -> "LinearCostFunction":
        rows = np.asarray(rows, dtype=np.float64)
        times = np.asarray(times, dtype=np.float64)
        if rows.size < 2:
            raise ValueError("need ≥2 samples to fit")
        slope, intercept = np.polyfit(rows, times, 1)
        return cls(slope=float(slope), intercept=float(intercept))

    def r2(self, rows: np.ndarray, times: np.ndarray) -> float:
        pred = self.slope * np.asarray(rows, np.float64) + self.intercept
        t = np.asarray(times, np.float64)
        ss_res = float(((t - pred) ** 2).sum())
        ss_tot = float(((t - t.mean()) ** 2).sum())
        return 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0


#: Default per-|A| cost functions (re-fitted by benchmarks/fig4; unit
#: slope makes Cost ≡ Row, which preserves all argmin decisions).
_IDENTITY = LinearCostFunction(slope=1.0, intercept=0.0)


@dataclasses.dataclass
class CostModel:
    """Eq (2)–(4) over a set of replica layouts."""

    stats: TableStats
    cost_fns: dict[int, LinearCostFunction] = dataclasses.field(default_factory=dict)

    def cost_fn(self, n_keys: int) -> LinearCostFunction:
        return self.cost_fns.get(n_keys, _IDENTITY)

    def query_cost(self, layout: Sequence[str], query: Query) -> float:
        """Eq (2): Cost(r, q) = f(Row(r, q))."""
        rows = estimate_rows(self.stats, layout, query)
        return self.cost_fn(len(layout))(rows)

    def cost_many(self, layout: Sequence[str], queries: Sequence[Query]) -> np.ndarray:
        """Vectorized Eq (2) over a query batch: float64[len(queries)]."""
        rows = estimate_rows_many(self.stats, layout, queries)
        return self.cost_fn(len(layout)).many(rows)

    def rank_matrices(
        self,
        layouts: Sequence[Sequence[str]],
        queries: Sequence[Query],
        *,
        stats: TableStats | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """Vectorized Eq (1)–(2) over a layout set × query batch:
        ``(rows, cost)`` float64 matrices of shape
        ``[len(layouts), len(queries)]``.

        The scatter-gather planner calls this once per partition with
        ``stats=partition.stats`` so every partition's replica ranking
        uses *that partition's* selectivities; with ``stats=None`` the
        model's own (CF-global) stats apply — bit-identical to stacking
        :func:`estimate_rows_many` per layout, which is what the
        single-partition path always did.
        """
        st = self.stats if stats is None else stats
        pre = precompute_query_stats(st, queries, list(st.columns))
        rows = np.stack(
            [estimate_rows_many(st, layout, queries, pre) for layout in layouts]
        )
        cost = np.stack(
            [
                self.cost_fn(len(layout)).many(rows[s])
                for s, layout in enumerate(layouts)
            ]
        )
        return rows, cost

    def min_cost(self, layouts: Sequence[Sequence[str]], query: Query) -> tuple[float, int]:
        """Eq (3): (min cost, argmin replica index)."""
        costs = [self.query_cost(a, query) for a in layouts]
        j = int(np.argmin(costs))
        return costs[j], j

    def workload_cost(self, layouts: Sequence[Sequence[str]], workload: Workload) -> float:
        """Eq (4): weighted mean of per-query minima."""
        w = workload.normalized_weights()
        return float(
            sum(
                wi * self.min_cost(layouts, q)[0]
                for wi, q in zip(w, workload.queries)
            )
        )
